"""Condensation benchmark: compression ratio + condensed-vs-raw speedup.

Measures, per Stream-HLS design, the event-graph condensation cascade
(``repro.core.condense``):

* condensation ratio per rung (raw events / condensed events),
* batched-evaluation throughput with the cascade vs with it disabled
  (``condense=None``), asserting bit-identical results,
* certificate economics: rows resolved on a rung vs fallbacks.

The scan (jax) backend is the headline: its per-iteration cost is
proportional to E_pad, so compression converts ~directly into speedup
(folding back-pressure anchors away also slashes Jacobi iterations).
The per-row numpy worklist is wave-bound, reported for reference.

``check_regression.py``'s ``check_condense`` gates on the scan-backend
geomean speedup and on result identity.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import Timer, geomean, quick_mode, save_json
from repro.core import EvalConfig, build_simgraph
from repro.core.condense import condense_auto
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design

DESIGNS = ["gemm", "FeedForward", "k15mmseq"]


def _bench(ev, cfgs, reps: int):
    ev.evaluate(cfgs[:2])                 # warm / compile
    ev.evaluate(cfgs)                     # warm the batch bucket
    best, result = float("inf"), None
    for _ in range(reps):
        with Timer() as t:
            result = ev.evaluate(cfgs)
        best = min(best, t.s)
    return best, result


def run(seed: int = 0) -> Dict:
    C = 32 if quick_mode() else 64
    reps = 2 if quick_mode() else 3
    out: Dict = {"designs": {}, "batch": C}
    scan_speedups = []
    identical_all = True
    for name in DESIGNS:
        g = build_simgraph(make_design(name))
        rng = np.random.default_rng(seed)
        u = g.upper_bounds
        # feasible-leaning batch (the DSE steady state)
        cfgs = np.stack([np.maximum(
            2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
            for _ in range(C)])
        cgs = condense_auto(g)
        row: Dict = {
            "events_raw": g.n_events,
            "rungs": [{"tag": cg.tag, "events": cg.n_events,
                       "compression": round(cg.compression, 2)}
                      for cg in cgs],
            "condensation_ratio": round(
                max((cg.compression for cg in cgs), default=1.0), 2),
            "backends": {},
        }
        for backend in ["numpy", "jax"]:
            t_raw, r_raw = _bench(
                BatchedEvaluator(
                    g, EvalConfig(backend=backend, max_iters=64,
                                  condense=None)),
                cfgs, reps)
            ev_c = BatchedEvaluator(
                g, EvalConfig(backend=backend, max_iters=64))
            t_cond, r_cond = _bench(ev_c, cfgs, reps)
            identical = all((a == b).all() for a, b in zip(r_raw, r_cond))
            identical_all &= identical
            speedup = t_raw / max(t_cond, 1e-12)
            row["backends"][backend] = dict(
                raw_us_per_config=round(1e6 * t_raw / C, 1),
                cond_us_per_config=round(1e6 * t_cond / C, 1),
                speedup=round(speedup, 2),
                identical=identical,
                condensed_rows=ev_c.stats.n_condensed,
                cert_failures=ev_c.stats.n_cond_fail)
            if backend == "jax":
                scan_speedups.append(speedup)
        out["designs"][name] = row
    out["geomean_speedup_scan"] = round(geomean(scan_speedups), 2)
    out["geomean_condensation_ratio"] = round(geomean(
        [d["condensation_ratio"] for d in out["designs"].values()]), 2)
    out["identical_all"] = bool(identical_all)
    save_json("condense.json", out)
    return out


def main():
    out = run()
    for name, d in out["designs"].items():
        rungs = " ".join(f"{r['tag']}:{r['compression']}x"
                         for r in d["rungs"])
        cols = "  ".join(
            f"{k}={v['speedup']:.2f}x" for k, v in d["backends"].items())
        print(f"{name:14s} E={d['events_raw']:6d} [{rungs}] {cols} "
              f"identical={all(v['identical'] for v in d['backends'].values())}")
    print(f"geomean scan speedup {out['geomean_speedup_scan']}x, "
          f"condensation ratio {out['geomean_condensation_ratio']}x, "
          f"identical={out['identical_all']}")


if __name__ == "__main__":
    main()
