"""Condensation benchmark: compression ratio + condensed-vs-raw speedup.

Measures, per Stream-HLS design, the event-graph condensation cascade
(``repro.core.condense``):

* condensation ratio per rung (raw events / condensed events),
* batched-evaluation throughput with the cascade vs with it disabled
  (``condense=None``), asserting bit-identical results,
* certificate economics: rows resolved on a rung vs fallbacks.

The scan (jax) backend is the headline: its per-iteration cost is
proportional to E_pad, so compression converts ~directly into speedup
(folding back-pressure anchors away also slashes Jacobi iterations).
The per-row numpy worklist is wave-bound, reported for reference.

The **aggressive-rung shootout** additionally races the fused Pallas
mega-kernel (:mod:`repro.kernels.fifo_eval.condensed` — fixpoint +
on-device certificate in one launch) against the scan backend's rung
protocol (evaluate, ship event times to the host, ``verify_rows``) at
the top rung, asserting identical statuses / latencies / certificate
masks, and records which backend ``backend="auto"`` calibration picks
per design.

``check_regression.py``'s ``check_condense`` gates on the scan-backend
geomean speedup and on result identity; ``check_condensed_kernel``
gates on the shootout (identity + the kernel still winning).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import Timer, geomean, quick_mode, save_json
from repro.core import EvalConfig, build_simgraph
from repro.core.condense import condense_auto
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design

DESIGNS = ["gemm", "FeedForward", "k15mmseq"]


def _bench(ev, cfgs, reps: int):
    ev.evaluate(cfgs[:2])                 # warm / compile
    ev.evaluate(cfgs)                     # warm the batch bucket
    best, result = float("inf"), None
    for _ in range(reps):
        with Timer() as t:
            result = ev.evaluate(cfgs)
        best = min(best, t.s)
    return best, result


def _bench_fn(fn, reps: int):
    fn()                                  # warm / compile
    best, result = float("inf"), None
    for _ in range(reps):
        with Timer() as t:
            result = fn()
        best = min(best, t.s)
    return best, result


def _rung_shootout(g, cg, cfgs, reps: int) -> Dict:
    """Race the fused kernel against the scan rung protocol at one rung.

    The scan side pays the rung's REAL cost: evaluate with per-anchor
    times, ship them to the host, run ``verify_rows`` on converged rows.
    The kernel side is one ``evaluate_certified`` launch.  Identity of
    statuses, certificate masks, and converged-row latencies is asserted
    (integer-exact, so ``==``).
    """
    from repro.core.backends.base import CONVERGED
    from repro.core.backends.fixpoint import FixpointBackend
    from repro.core.backends.pallas import PallasBackend
    from repro.core.condense import verify_rows

    C = cfgs.shape[0]
    scan = FixpointBackend(max_iters=64)
    scan.prepare(cg)
    kern = PallasBackend(max_iters=64)
    kern.prepare(cg)
    if not kern.fused_certificate:
        return {"skipped": "no certificate tables for the fused kernel"}

    def scan_rung():
        lat, bram, status, times = scan.evaluate_with_times(cfgs)
        ok = np.zeros(C, dtype=bool)
        conv = status == CONVERGED
        if conv.any():
            ok[conv] = verify_rows(cg, cfgs[conv], times[conv])
        return lat, bram, status, ok

    def kernel_rung():
        return kern.evaluate_certified(cfgs)

    t_scan, r_scan = _bench_fn(scan_rung, reps)
    t_kern, r_kern = _bench_fn(kernel_rung, reps)
    conv = r_scan[2] == CONVERGED
    identical = (
        bool((r_scan[2] == r_kern[2]).all())           # statuses
        and bool((r_scan[3] == r_kern[3]).all())       # cert masks
        and bool((r_scan[1] == r_kern[1]).all())       # bram
        and bool((r_scan[0][conv] == r_kern[0][conv]).all()))
    return {
        "rung": cg.tag,
        "scan_cfgs_per_s": round(C / max(t_scan, 1e-12), 1),
        "kernel_cfgs_per_s": round(C / max(t_kern, 1e-12), 1),
        "kernel_speedup": round(t_scan / max(t_kern, 1e-12), 2),
        "certified_rows": int(np.asarray(r_kern[3]).sum()),
        "identical": identical,
    }


def run(seed: int = 0) -> Dict:
    C = 32 if quick_mode() else 64
    reps = 2 if quick_mode() else 3
    out: Dict = {"designs": {}, "batch": C}
    scan_speedups = []
    identical_all = True
    kernel_speedups, calib_picks = [], {}
    kernel_wins, kernel_identical = 0, True
    for name in DESIGNS:
        g = build_simgraph(make_design(name))
        rng = np.random.default_rng(seed)
        u = g.upper_bounds
        # feasible-leaning batch (the DSE steady state)
        cfgs = np.stack([np.maximum(
            2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
            for _ in range(C)])
        cgs = condense_auto(g)
        row: Dict = {
            "events_raw": g.n_events,
            "rungs": [{"tag": cg.tag, "events": cg.n_events,
                       "compression": round(cg.compression, 2)}
                      for cg in cgs],
            "condensation_ratio": round(
                max((cg.compression for cg in cgs), default=1.0), 2),
            "backends": {},
        }
        for backend in ["numpy", "jax"]:
            t_raw, r_raw = _bench(
                BatchedEvaluator(
                    g, EvalConfig(backend=backend, max_iters=64,
                                  condense=None)),
                cfgs, reps)
            ev_c = BatchedEvaluator(
                g, EvalConfig(backend=backend, max_iters=64))
            t_cond, r_cond = _bench(ev_c, cfgs, reps)
            identical = all((a == b).all() for a, b in zip(r_raw, r_cond))
            identical_all &= identical
            speedup = t_raw / max(t_cond, 1e-12)
            row["backends"][backend] = dict(
                raw_us_per_config=round(1e6 * t_raw / C, 1),
                cond_us_per_config=round(1e6 * t_cond / C, 1),
                speedup=round(speedup, 2),
                identical=identical,
                condensed_rows=ev_c.stats.n_condensed,
                cert_failures=ev_c.stats.n_cond_fail)
            if backend == "jax":
                scan_speedups.append(speedup)
        # aggressive-rung shootout: fused kernel vs scan-rung protocol
        if cgs:
            shoot = _rung_shootout(g, cgs[0], cfgs.astype(np.int32), reps)
            ev_auto = BatchedEvaluator(
                g, EvalConfig(backend="auto", max_iters=64))
            shoot["calibration_pick"] = ev_auto.backend
            row["kernel_shootout"] = shoot
            if "kernel_speedup" in shoot:
                kernel_speedups.append(shoot["kernel_speedup"])
                kernel_wins += shoot["kernel_speedup"] > 1.0
                kernel_identical &= shoot["identical"]
                calib_picks[name] = shoot["calibration_pick"]
        out["designs"][name] = row
    out["geomean_speedup_scan"] = round(geomean(scan_speedups), 2)
    out["kernel_geomean_speedup"] = round(geomean(kernel_speedups), 2)
    out["kernel_wins"] = int(kernel_wins)
    out["kernel_designs"] = len(kernel_speedups)
    out["kernel_identical_all"] = bool(kernel_identical)
    out["calibration_picks"] = calib_picks
    out["geomean_condensation_ratio"] = round(geomean(
        [d["condensation_ratio"] for d in out["designs"].values()]), 2)
    out["identical_all"] = bool(identical_all)
    save_json("condense.json", out)
    return out


def main():
    out = run()
    for name, d in out["designs"].items():
        rungs = " ".join(f"{r['tag']}:{r['compression']}x"
                         for r in d["rungs"])
        cols = "  ".join(
            f"{k}={v['speedup']:.2f}x" for k, v in d["backends"].items())
        shoot = d.get("kernel_shootout", {})
        extra = ""
        if "kernel_speedup" in shoot:
            extra = (f" kernel@{shoot['rung']}={shoot['kernel_speedup']}x"
                     f" ({shoot['kernel_cfgs_per_s']:.0f} vs "
                     f"{shoot['scan_cfgs_per_s']:.0f} cfg/s,"
                     f" auto->{shoot['calibration_pick']})")
        print(f"{name:14s} E={d['events_raw']:6d} [{rungs}] {cols} "
              f"identical="
              f"{all(v['identical'] for v in d['backends'].values())}"
              f"{extra}")
    print(f"geomean scan speedup {out['geomean_speedup_scan']}x, "
          f"condensation ratio {out['geomean_condensation_ratio']}x, "
          f"identical={out['identical_all']}")
    print(f"fused kernel: geomean {out['kernel_geomean_speedup']}x over "
          f"the scan rung, wins {out['kernel_wins']}/"
          f"{out['kernel_designs']}, "
          f"identical={out['kernel_identical_all']}, "
          f"calibration picks {out['calibration_picks']}")


if __name__ == "__main__":
    main()
