"""Analytical channel-bounds benchmark: seeded vs unseeded certification.

``core/bounds.py`` derives per-FIFO ``(lower, upper)`` depth bounds from
one trace, classifies every channel (in-order rate-matched / mismatched,
reorder, data-dependent), and hands ``certify_min_depths`` a feasible
floor to descend from.  Three numbers the regression gate watches:

* **identity** — bounds-seeded certification must return the exact
  depth vector unseeded certification returns, on every design;
* **bracket** — ``lower <= certified <= upper`` per FIFO;
* **probe reduction** — evaluator probes (cache misses) unseeded vs
  seeded.  On the affine Stream-HLS suite the analytical floor is the
  answer, so the seeded run needs only the start check plus one
  shortcut probe; the gate holds a >=3x geomean.

  QUICK=1 PYTHONPATH=src:. python benchmarks/bounds.py   # CI smoke
  PYTHONPATH=src:. python benchmarks/bounds.py           # default set
  FULL=1 PYTHONPATH=src:. python benchmarks/bounds.py    # all 24
"""

from __future__ import annotations

import time
from collections import Counter

import numpy as np

from benchmarks.common import (full_mode, geomean, quick_mode, save_json)

#: affine designs in the gated probe-reduction geomean
_GATED_QUICK = ("gemm", "FeedForward", "mvt", "k2mm")
_GATED = _GATED_QUICK + ("atax", "bicg", "Autoencoder", "ResidualBlock")
#: reported (not gated): DDCF reference point — bounds still bracket and
#: seed there, but the floor is not always the certified answer
_EXTRA = ("flowgnn_small", "mult_by_2_64")


def _design(name):
    from repro.designs import make_design
    from repro.designs.ddcf import flowgnn_pna, mult_by_2
    if name == "flowgnn_small":
        return flowgnn_pna(n_nodes=24, n_edges=64)
    if name == "mult_by_2_64":
        return mult_by_2(64)
    return make_design(name)


def bench_bounds(names) -> dict:
    """Per design: taxonomy, then unseeded vs seeded certification with
    fresh caches each so ``n_probes`` (cache misses) are comparable."""
    from repro.core import EvalConfig
    from repro.core.backends import ConfigCache
    from repro.core.bounds import channel_bounds
    from repro.core.deadlock import certify_min_depths
    from repro.core.simgraph import build_simgraph
    from repro.core.simulate import BatchedEvaluator

    per_design = {}
    for name in names:
        g = build_simgraph(_design(name))
        ev = BatchedEvaluator(g, EvalConfig(backend="worklist"))
        t0 = time.perf_counter()
        b = channel_bounds(g)
        bounds_s = time.perf_counter() - t0
        plain = certify_min_depths(g, ev, cache=ConfigCache(g.n_fifos))
        seeded = certify_min_depths(g, ev, cache=ConfigCache(g.n_fifos),
                                    bounds=b)
        per_design[name] = {
            "n_fifos": int(g.n_fifos),
            "n_events": int(g.n_events),
            "kinds": dict(Counter(b.kinds)),
            "n_pinned": int(b.n_pinned),
            "bounds_s": round(bounds_s, 5),
            "unseeded_probes": int(plain.n_probes),
            "seeded_probes": int(seeded.n_probes),
            "probe_reduction": round(
                plain.n_probes / max(seeded.n_probes, 1), 2),
            "identical_depths": bool(
                (plain.depths == seeded.depths).all()),
            "bracket": bool((b.lower <= plain.depths).all()
                            and (plain.depths <= b.upper).all()),
            "floor_exact": bool((plain.depths == b.lower).all()),
            "certified_sum": int(plain.depths.sum()),
        }
    return per_design


def run() -> dict:
    if quick_mode():
        gated, extra = _GATED_QUICK, ()
    elif full_mode():
        from repro.designs import STREAMHLS_DESIGNS
        gated, extra = tuple(sorted(STREAMHLS_DESIGNS)), _EXTRA
    else:
        gated, extra = _GATED, _EXTRA

    table = bench_bounds(tuple(gated) + tuple(extra))
    gated_rows = {k: v for k, v in table.items() if k in gated}
    payload = {
        "per_design": table,
        "gated_designs": list(gated),
        "probe_reduction_geomean": round(
            geomean([v["probe_reduction"] for v in gated_rows.values()]), 2),
        "identical_depths_all": all(
            v["identical_depths"] for v in table.values()),
        "bracket_all": all(v["bracket"] for v in table.values()),
        "gated_floor_exact_all": all(
            v["floor_exact"] for v in gated_rows.values()),
        "total_pinned": int(np.sum(
            [v["n_pinned"] for v in table.values()])),
    }
    save_json("bounds.json", payload)
    return payload


def main():
    out = run()
    for name, row in out["per_design"].items():
        print(f"bounds {name:14s} probes {row['unseeded_probes']:4d} -> "
              f"{row['seeded_probes']:2d} ({row['probe_reduction']:6.1f}x) "
              f"pinned={row['n_pinned']:3d}/{row['n_fifos']:3d} "
              f"identical={row['identical_depths']} "
              f"bracket={row['bracket']}")
    print(f"gated probe-reduction geomean: "
          f"{out['probe_reduction_geomean']}x "
          f"(identical_all={out['identical_depths_all']}, "
          f"bracket_all={out['bracket_all']})")


if __name__ == "__main__":
    main()
