"""Fig. 4 analogue: quality of the alpha=0.7 selected point per design x
optimizer, vs Baseline-Max and Baseline-Min (latency ratio geomeans, BRAM
reduction, un-deadlocked count)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import budget, design_set, geomean, save_json
from repro.core import FifoAdvisor
from repro.core.optimizers import PAPER_OPTIMIZERS
from repro.designs import make_design


def run(optimizers=PAPER_OPTIMIZERS, seed: int = 0) -> Dict:
    per_design = []
    for name in design_set():
        adv = FifoAdvisor(make_design(name))
        row = {"design": name,
               "baseline_max": [adv.baseline_max.latency,
                                adv.baseline_max.bram],
               "baseline_min": [adv.baseline_min.latency,
                                adv.baseline_min.bram],
               "min_deadlocked": adv.baseline_min.deadlocked,
               "optimizers": {}}
        for opt in optimizers:
            r = adv.run(opt, budget=budget(), seed=seed)
            sel = r.selected(alpha=0.7)
            if sel is None:
                row["optimizers"][opt] = None
                continue
            (lat, bram), _ = sel
            entry = dict(
                lat=int(lat), bram=int(bram),
                lat_vs_max=lat / max(adv.baseline_max.latency, 1),
                bram_red_vs_max=1 - bram / max(adv.baseline_max.bram, 1),
                runtime_s=r.result.runtime_s,
                n_evals=r.result.n_evals)
            if not adv.baseline_min.deadlocked:
                entry["lat_vs_min"] = lat / max(adv.baseline_min.latency, 1)
                entry["bram_over_min"] = int(bram - adv.baseline_min.bram)
            else:
                entry["undeadlocked"] = True
            row["optimizers"][opt] = entry
        per_design.append(row)

    summary = {}
    for opt in optimizers:
        entries = [r["optimizers"][opt] for r in per_design
                   if r["optimizers"].get(opt)]
        summary[opt] = dict(
            geomean_lat_vs_max=geomean([e["lat_vs_max"] for e in entries]),
            mean_bram_red=float(np.mean([e["bram_red_vs_max"]
                                         for e in entries])),
            geomean_lat_vs_min=geomean([e["lat_vs_min"] for e in entries
                                        if "lat_vs_min" in e]),
            mean_bram_over_min=float(np.mean(
                [e["bram_over_min"] for e in entries
                 if "bram_over_min" in e])) if any(
                "bram_over_min" in e for e in entries) else None,
            undeadlocked=sum(1 for e in entries if e.get("undeadlocked")),
        )
    out = {"per_design": per_design, "summary": summary}
    save_json("improvement.json", out)
    return out


def main():
    out = run()
    print(f"{'optimizer':16s} {'lat/max':>8} {'bram red':>9} "
          f"{'lat/min':>8} {'undeadlocked':>12}")
    for opt, s in out["summary"].items():
        lat_min = (f"{s['geomean_lat_vs_min']:8.4f}"
                   if s["geomean_lat_vs_min"] == s["geomean_lat_vs_min"]
                   else "     n/a")
        print(f"{opt:16s} {s['geomean_lat_vs_max']:8.4f} "
              f"{s['mean_bram_red']:9.2%} {lat_min} "
              f"{s['undeadlocked']:12d}")


if __name__ == "__main__":
    main()
