"""Fig. 3 analogue: Pareto frontiers per optimizer for selected designs
(k15mmtree, k15mmtree_relu, Autoencoder), with both baselines."""

from __future__ import annotations

from typing import Dict

from benchmarks.common import budget, save_json
from repro.core import FifoAdvisor
from repro.core.optimizers import PAPER_OPTIMIZERS
from repro.designs import make_design

DESIGNS = ["k15mmtree", "k15mmtree_relu", "Autoencoder"]


def run(seed: int = 0) -> Dict:
    out = {}
    for name in DESIGNS:
        adv = FifoAdvisor(make_design(name))
        entry = {
            "baseline_max": [adv.baseline_max.latency, adv.baseline_max.bram],
            "baseline_min": ([adv.baseline_min.latency,
                              adv.baseline_min.bram]
                             if not adv.baseline_min.deadlocked else None),
            "min_deadlocked": adv.baseline_min.deadlocked,
            "fronts": {}, "selected": {}, "hypervolume": {},
        }
        for opt in PAPER_OPTIMIZERS:
            r = adv.run(opt, budget=budget(), seed=seed)
            entry["fronts"][opt] = r.frontier_points.tolist()
            sel = r.selected(alpha=0.7)
            entry["selected"][opt] = (list(map(float, sel[0]))
                                      if sel else None)
            entry["hypervolume"][opt] = r.hypervolume()
        out[name] = entry
    save_json("pareto_fronts.json", out)
    return out


def main():
    out = run()
    for name, e in out.items():
        print(f"=== {name}  (baseline-max {e['baseline_max']}, "
              f"min {'DEADLOCK' if e['min_deadlocked'] else e['baseline_min']})")
        for opt, front in e["fronts"].items():
            sel = e["selected"][opt]
            print(f"  {opt:16s} |front|={len(front):3d} "
                  f"hv={e['hypervolume'][opt]:12.1f} star={sel}")


if __name__ == "__main__":
    main()
