"""Roofline aggregation: reads the dry-run JSON records and emits the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline, plus the
three hillclimb-cell picks (worst roofline fraction, most collective-bound,
most paper-representative).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[Dict], mesh: str = "16x16") -> List[Dict]:
    out = []
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            out.append(dict(arch=r["arch"], shape=r["shape"], mesh=mesh,
                            status=r["status"],
                            reason=r.get("reason", r.get("error", ""))[:60]))
            continue
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        # roofline fraction: ideal (compute-only) time over the bound given
        # by the dominant term (serial upper bound: max of terms)
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / bound if bound > 0 else 0.0
        out.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=mesh, status="ok",
            compute_s=rf["compute_s"], memory_s=rf["memory_s"],
            collective_s=rf["collective_s"], dominant=rf["dominant"],
            roofline_fraction=frac,
            model_flops=r.get("model_flops"),
            hlo_flops=r.get("hlo_flops"),
            useful_ratio=r.get("useful_compute_ratio"),
            mem_gb=r["memory"]["total"] / 1e9,
            fits_hbm=r["memory"]["fits_hbm"],
            compile_s=r.get("compile_s"),
        ))
    return out


def pick_hillclimb_cells(rows: List[Dict]) -> Dict[str, Dict]:
    ok = [r for r in table(rows, "16x16") if r["status"] == "ok"]
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["collective_s"])
    # paper-representative: FIFOAdvisor is a DSE/serving-pipeline paper —
    # the decode cell of the largest arch exercises buffer/queue sizing
    # most directly (KV-cache = the sized buffer); pick the biggest
    # memory-bound decode cell.
    decode = [r for r in ok if r["shape"].startswith("decode")]
    rep = max(decode, key=lambda r: r["memory_s"]) if decode else worst
    return {"worst_roofline_fraction": worst,
            "most_collective_bound": coll,
            "paper_representative": rep}


def markdown(rows: List[Dict]) -> str:
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s |"
             " dominant | frac | useful | mem/dev GB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        for r in table(rows, mesh):
            if r["status"] != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                             f"SKIP ({r.get('reason','')[:40]}…) "
                             "| | | | | | | |")
                continue
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} "
                f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                f"| {r['collective_s']:.3e} | {r['dominant']} "
                f"| {r['roofline_fraction']:.2f} "
                f"| {r['useful_ratio']:.2f} "
                f"| {r['mem_gb']:.1f} | {'y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def main():
    rows = load()
    if not rows:
        print("no dry-run records found; run: "
              "python -m repro.launch.dryrun --all")
        return
    for mesh in ("16x16",):
        print(f"--- mesh {mesh}")
        for r in table(rows, mesh):
            if r["status"] != "ok":
                print(f"{r['arch']:22s} {r['shape']:12s} {r['status']}")
                continue
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                  f"x={r['collective_s']:.2e} dom={r['dominant']:10s} "
                  f"frac={r['roofline_fraction']:.2f} "
                  f"useful={r['useful_ratio']:.2f} "
                  f"mem={r['mem_gb']:6.1f}GB")
    picks = pick_hillclimb_cells(rows)
    print("--- hillclimb picks")
    for k, v in picks.items():
        print(f"{k}: {v['arch']} x {v['shape']} (dom={v['dominant']})")
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "roofline_table.md"), "w") as f:
        f.write(markdown(rows))


if __name__ == "__main__":
    main()
