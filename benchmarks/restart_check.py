"""Warm-restart check: cold boot vs snapshot restore, end to end.

Boots the real server (``python -m repro.launch.serve --stdio``) twice
with the same preloaded design set:

1. **cold** — traces every design from scratch, serves one session,
   writes a snapshot via the ``snapshot`` op, and shuts down;
2. **warm** — same command line; the server finds the snapshot, restores
   the registry from it, serves the same session, and shuts down.

Asserts that the warm registry-ready time (parsed from the server's
``registry ready in ...`` stderr line, which excludes interpreter/jax
startup) beats cold by at least ``--min-speedup`` (default 10x), and
that the warm session's frontier is bit-identical to the cold one —
restoring state must never change answers.

  PYTHONPATH=src python benchmarks/restart_check.py
Exit code 0 = both hold.  CI runs this as the warm-restart gate.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile

#: the served design set: every StreamHLS benchmark design, so the cold
#: boot pays the full tracing bill the snapshot is meant to erase
DESIGNS = ("gemm,FeedForward,atax,bicg,gesummv,k15mmseq,ResMLP,"
           "Autoencoder,DepthSepConvBlock,ResidualBlock,k15mmseq_relu,"
           "k15mmseq_imbalanced")
READY_RE = re.compile(
    r"registry ready in ([0-9.]+)s \((cold|warm, (\d+) restored)\)")


def boot(snapshot_dir: str, take_snapshot: bool, budget: int) -> dict:
    """One server lifetime over stdio; returns parsed timings + result."""
    script = [
        {"op": "hello", "proto": 2},
        {"op": "open", "design": "gemm", "optimizer": "grouped_sa",
         "budget": budget, "seed": 0, "id": "open"},
        {"op": "run"},
        {"op": "result", "session": "s0", "id": "result"},
    ]
    if take_snapshot:
        script.append({"op": "snapshot", "id": "snap"})
    script.append({"op": "shutdown"})
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--stdio",
         "--no-progress", "--snapshot-dir", snapshot_dir,
         "--designs", DESIGNS],
        input="".join(json.dumps(m) + "\n" for m in script),
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH="src"), timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"server exited {proc.returncode}:\n"
                           f"{proc.stderr[-2000:]}")
    m = READY_RE.search(proc.stderr)
    if not m:
        raise RuntimeError(f"no 'registry ready' line in stderr:\n"
                           f"{proc.stderr[-2000:]}")
    frames = [json.loads(line) for line in proc.stdout.splitlines()
              if line.strip()]
    by_id = {f["id"]: f for f in frames if "id" in f}
    if take_snapshot and not by_id.get("snap", {}).get("ok"):
        raise RuntimeError(f"snapshot op failed: {by_id.get('snap')}")
    return {
        "ready_s": float(m.group(1)),
        "warm": m.group(2) != "cold",
        "restored": int(m.group(3)) if m.group(3) else 0,
        "frontier": by_id["result"]["result"]["frontier"],
        "n_evals": by_id["result"]["result"]["n_evals"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--min-speedup", type=float, default=10.0,
                    help="required cold/warm registry-ready ratio")
    ap.add_argument("--budget", type=int, default=60)
    ap.add_argument("--snapshot-dir", default=None,
                    help="snapshot directory (default: a temp dir)")
    args = ap.parse_args(argv)

    with tempfile.TemporaryDirectory() as tmp:
        snap_dir = args.snapshot_dir or os.path.join(tmp, "snap")
        cold = boot(snap_dir, take_snapshot=True, budget=args.budget)
        warm = boot(snap_dir, take_snapshot=False, budget=args.budget)

    n_designs = len(DESIGNS.split(","))
    speedup = cold["ready_s"] / max(warm["ready_s"], 1e-9)
    print(f"cold ready: {cold['ready_s'] * 1e3:8.1f} ms "
          f"({n_designs} designs traced)")
    print(f"warm ready: {warm['ready_s'] * 1e3:8.1f} ms "
          f"({warm['restored']} restored from snapshot)")
    print(f"speedup:    {speedup:8.1f}x (required: "
          f">={args.min_speedup:.0f}x)")
    print(f"warm first answer: n_evals={warm['n_evals']} "
          f"(cold: {cold['n_evals']})")

    failures = []
    if cold["warm"]:
        failures.append("first boot unexpectedly found a snapshot")
    if not warm["warm"] or warm["restored"] != n_designs:
        failures.append(
            f"second boot did not restore all {n_designs} designs "
            f"(restored={warm['restored']})")
    if speedup < args.min_speedup:
        failures.append(
            f"warm restart speedup {speedup:.1f}x below required "
            f"{args.min_speedup:.0f}x")
    if warm["frontier"] != cold["frontier"]:
        failures.append(
            "warm frontier differs from cold — snapshot restore changed "
            "answers")
    if warm["n_evals"] != 0:
        failures.append(
            f"warm run simulated {warm['n_evals']} configs; the restored "
            "cache should serve every one")
    if failures:
        print("WARM-RESTART CHECK FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("warm-restart check passed (snapshot restore fast + "
          "bit-identical)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
