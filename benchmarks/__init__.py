"""Benchmark harness: one module per paper table/figure + roofline.

  accuracy.py      Table II   (trace-sim vs cycle-accurate oracle)
  pareto_fronts.py Fig. 3     (frontiers on selected designs)
  improvement.py   Fig. 4     (alpha=0.7 point vs both baselines)
  runtime.py       Table III  (search runtime vs estimated co-sim)
  convergence.py   Fig. 5     (iso-runtime convergence, k15mmtree)
  case_study.py    Fig. 6     (FlowGNN-PNA DDCF case study)
  batched_eval.py  beyond-paper evaluator throughput
  pruning.py       beyond-paper sound lower-bound pruning
  roofline.py      dry-run roofline aggregation (EXPERIMENTS.md §Roofline)

Run everything: PYTHONPATH=src python -m benchmarks.run   (FULL=1 for the
full-budget versions used in EXPERIMENTS.md).
"""
