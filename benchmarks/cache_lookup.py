"""Microbenchmark: vectorized vs per-row ConfigCache hit resolution.

The DSE hot loop screens every candidate batch through the advisor-wide
:class:`~repro.core.backends.ConfigCache` before touching an evaluator.
The cache's lookup used to resolve hash hits with a per-row python dict
loop (``for i in range(C)``); it now does one ``searchsorted`` over a
lazily sorted hash index.  This benchmark measures both resolutions on
identical cache contents across batch sizes — the win shows from C≈64,
exactly the batch sizes the optimizers and the campaign router emit.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import quick_mode, save_json
from repro.core.backends.cache import ConfigCache


def _dict_loop_resolution(cache: ConfigCache, m: np.ndarray):
    """The pre-vectorization resolution, kept here as the baseline."""
    hashes = cache._hash_rows(m)
    idx = np.full(m.shape[0], -1, dtype=np.int64)
    for i in range(m.shape[0]):
        idx[i] = cache._map.get(int(hashes[i]), -1)
    cand = np.flatnonzero(idx >= 0)
    if cand.size:
        ok = (cache._rows[idx[cand]] == m[cand]).all(axis=1)
        return cand[ok]
    return cand


def _vector_resolution(cache: ConfigCache, m: np.ndarray):
    """The vectorized resolution, mirrored from ConfigCache.lookup
    (hash + searchsorted + exact verify, no result gathers) so both
    variants measure exactly the hit-resolution step."""
    hashes = cache._hash_rows(m)
    sh, sidx = cache._index()
    pos = np.minimum(np.searchsorted(sh, hashes), sh.size - 1)
    idx = np.where(sh[pos] == hashes, sidx[pos], -1)
    cand = np.flatnonzero(idx >= 0)
    if cand.size:
        ok = (cache._rows[idx[cand]] == m[cand]).all(axis=1)
        return cand[ok]
    return cand


def _bench(fn, cache, batches, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for m in batches:
            fn(cache, m)
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> Dict:
    rng = np.random.default_rng(0)
    F = 48
    n_entries = 2000 if quick_mode() else 20000
    entries = rng.integers(1, 256, size=(n_entries, F), dtype=np.int64)
    cache = ConfigCache(F)
    cache.insert(entries, np.arange(n_entries, dtype=np.int64),
                 np.arange(n_entries, dtype=np.int64),
                 np.zeros(n_entries, dtype=bool))

    out = {"n_entries": n_entries, "n_fifos": F, "batch": []}
    reps = 3 if quick_mode() else 5
    for C in (16, 64, 256, 1024):
        # half hits, half misses — the DSE steady state
        hits = entries[rng.integers(0, n_entries, C // 2)]
        misses = rng.integers(256, 512, size=(C - C // 2, F), dtype=np.int64)
        batches = [np.concatenate([hits, misses])[rng.permutation(C)]
                   for _ in range(8)]
        cache._index()     # index built; both variants measure steady state
        t_loop = _bench(_dict_loop_resolution, cache, batches, reps)
        t_vec = _bench(_vector_resolution, cache, batches, reps)
        out["batch"].append({
            "C": C,
            "dict_loop_us": round(1e6 * t_loop / 8, 1),
            "vectorized_us": round(1e6 * t_vec / 8, 1),
            "speedup": round(t_loop / max(t_vec, 1e-12), 2),
        })
    save_json("cache_lookup.json", out)
    return out


def main():
    out = run()
    for row in out["batch"]:
        print(f"C={row['C']:5d}  dict-loop={row['dict_loop_us']:8.1f}us  "
              f"vectorized={row['vectorized_us']:8.1f}us  "
              f"speedup={row['speedup']:.2f}x")


if __name__ == "__main__":
    main()
