"""Shared benchmark utilities: design set, result IO, timing."""

from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

def _fast_designs() -> List[str]:
    """Fast default subset (FULL=1 runs everything) — the canonical list
    lives in repro.designs so the campaign CLI stays in sync."""
    from repro.designs import FAST_DESIGNS
    return list(FAST_DESIGNS)


def full_mode() -> bool:
    return os.environ.get("FULL", "0") == "1"


def quick_mode() -> bool:
    """CI smoke mode: a couple of small designs, tiny budgets."""
    return os.environ.get("QUICK", "0") == "1"


def design_set() -> List[str]:
    from repro.designs import QUICK_DESIGNS, STREAMHLS_DESIGNS
    if quick_mode():
        return list(QUICK_DESIGNS)
    return sorted(STREAMHLS_DESIGNS) if full_mode() else _fast_designs()


def budget() -> int:
    if quick_mode():
        return 60
    return 1000 if full_mode() else 300


def save_json(name: str, payload) -> str:
    """Write a result JSON; quick-mode runs get a ``.quick.json`` suffix
    so CI smoke results never clobber the committed full-run baselines
    (the regression gate diffs same-named files)."""
    if (quick_mode() and name.endswith(".json")
            and not name.endswith(".quick.json")):
        name = name[: -len(".json")] + ".quick.json"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=float)
    return float(np.exp(np.log(xs).mean())) if xs.size else float("nan")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
        return False
