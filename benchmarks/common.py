"""Shared benchmark utilities: design set, result IO, timing."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# fast default subset (FULL=1 runs everything)
FAST_DESIGNS = ["atax", "gemm", "gesummv", "FeedForward", "Autoencoder",
                "k7mmtree_balanced", "k15mmseq", "k15mmtree",
                "ResidualBlock", "mvt"]


def full_mode() -> bool:
    return os.environ.get("FULL", "0") == "1"


def quick_mode() -> bool:
    """CI smoke mode: a couple of small designs, tiny budgets."""
    return os.environ.get("QUICK", "0") == "1"


def design_set() -> List[str]:
    from repro.designs import STREAMHLS_DESIGNS
    if quick_mode():
        return ["gemm", "FeedForward"]
    return sorted(STREAMHLS_DESIGNS) if full_mode() else FAST_DESIGNS


def budget() -> int:
    if quick_mode():
        return 60
    return 1000 if full_mode() else 300


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=float)
    return float(np.exp(np.log(xs).mean())) if xs.size else float("nan")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
        return False
