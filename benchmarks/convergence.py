"""Fig. 5 analogue: iso-runtime convergence on k15mmtree — best alpha-score
observed vs wall-clock, per optimizer (including the beyond-paper batched
searchers)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import budget, save_json
from repro.core import FifoAdvisor
from repro.core.pareto import alpha_score
from repro.designs import make_design

OPTS = ["greedy", "random", "grouped_random", "sa", "grouped_sa",
        "nsga2", "vmap_search"]


def run(design: str = "k15mmtree", seed: int = 0, n_points: int = 20
        ) -> Dict:
    adv = FifoAdvisor(make_design(design))
    base = (adv.baseline_max.latency, adv.baseline_max.bram)
    out = {"design": design, "baseline_max": list(base), "curves": {}}
    for opt in OPTS:
        r = adv.run(opt, budget=budget(), seed=seed)
        res = r.result
        # reconstruct best-so-far alpha score over evaluation order,
        # normalized to the run's wall time (evaluations dominate it)
        ok = ~res.deadlock
        pts = np.stack([res.latency, res.bram], axis=1).astype(float)
        scores = np.where(ok, alpha_score(pts, base, 0.7), np.inf)
        best = np.minimum.accumulate(scores)
        n = len(best)
        ts = np.linspace(res.runtime_s / max(n, 1), res.runtime_s, n)
        idx = np.unique(np.linspace(0, n - 1, n_points).astype(int))
        out["curves"][opt] = {
            "t": ts[idx].round(3).tolist(),
            "best_score": [None if not np.isfinite(b) else round(b, 5)
                           for b in best[idx]],
            "runtime_s": round(res.runtime_s, 3),
            "final": None if not np.isfinite(best[-1])
            else round(float(best[-1]), 5),
        }
    save_json("convergence.json", out)
    return out


def main():
    out = run()
    print(f"design {out['design']}")
    for opt, c in out["curves"].items():
        print(f"  {opt:16s} final_score={c['final']} "
              f"runtime={c['runtime_s']:7.2f}s")


if __name__ == "__main__":
    main()
