"""Table III analogue: FIFOAdvisor search runtime vs estimated
co-simulation search runtime.

Vitis HLS/XSIM is not available in this container, so per-config RTL
co-simulation cost is MODELLED, with the model calibrated from the paper's
own published numbers (Table II cycle counts x Table III co-sim days per
1000 samples): effective RTL co-sim throughput in their data ranges from
~40 cycles/s (gemm/atax/k3mm-class designs) to ~2500 cycles/s
(ResidualBlock).  We report speedups under BOTH constants as a
conservative bracket, plus the directly-measured algorithmic gain of
incremental trace evaluation over re-running our own DES per config.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import Timer, budget, design_set, geomean, save_json
from repro.core import FifoAdvisor, simulate
from repro.core.optimizers import PAPER_OPTIMIZERS
from repro.designs import make_design

RTL_CPS_FAST = 2500.0     # cycles/s, paper's best case (ResidualBlock)
RTL_CPS_SLOW = 40.0       # cycles/s, paper's typical case (gemm/atax/k3mm)


def run(seed: int = 0) -> Dict:
    rows = []
    for name in design_set():
        d = make_design(name)
        adv = FifoAdvisor(d)
        # best-case co-sim config: Baseline-Max minimizes simulated cycles
        with Timer() as t:
            simulate(d, adv.baseline_max.depths)
        des_one = t.s
        cycles = adv.baseline_max.latency
        rtl_fast = cycles / RTL_CPS_FAST          # seconds per co-sim
        rtl_slow = cycles / RTL_CPS_SLOW
        row = {"design": name, "cycles": cycles,
               "des_one_s": round(des_one, 4),
               "rtl_one_est_s": [round(rtl_fast, 2), round(rtl_slow, 1)],
               "trace_s": round(adv.trace_time_s, 3), "optimizers": {}}
        for opt in PAPER_OPTIMIZERS:
            r = adv.run(opt, budget=budget(), seed=seed)
            n = r.result.n_evals
            wall = max(r.result.runtime_s, 1e-9)
            row["optimizers"][opt] = dict(
                runtime_s=round(r.result.runtime_s, 3),
                n_evals=n,
                us_per_eval=round(1e6 * wall / max(n, 1), 1),
                speedup_vs_des=des_one * n / wall,
                speedup_vs_rtl_fast=rtl_fast * n / wall,
                speedup_vs_rtl_slow=rtl_slow * n / wall,
                speedup_vs_rtl_slow_par32=rtl_slow * n / 32 / wall)
        rows.append(row)

    summary = {}
    for opt in PAPER_OPTIMIZERS:
        def g(key):
            return geomean([r["optimizers"][opt][key] for r in rows])
        summary[opt] = dict(
            geomean_speedup_vs_des=g("speedup_vs_des"),
            geomean_speedup_vs_rtl_fast=g("speedup_vs_rtl_fast"),
            geomean_speedup_vs_rtl_slow=g("speedup_vs_rtl_slow"),
            geomean_speedup_vs_rtl_slow_par32=g(
                "speedup_vs_rtl_slow_par32"),
            median_runtime_s=float(np.median(
                [r["optimizers"][opt]["runtime_s"] for r in rows])),
            median_us_per_eval=float(np.median(
                [r["optimizers"][opt]["us_per_eval"] for r in rows])))
    out = {"per_design": rows, "summary": summary,
           "rtl_model": {"fast_cycles_per_s": RTL_CPS_FAST,
                         "slow_cycles_per_s": RTL_CPS_SLOW,
                         "calibration": "paper Table II cycles x Table III "
                                        "co-sim days per 1000 samples"},
           "note": ("our benchmark designs are ~100-1000x smaller in cycle "
                    "count than the paper's (DESIGN.md §8); at their scale "
                    "the same model reproduces the 1e5-1e7x speedups")}
    save_json("runtime.json", out)
    return out


def main():
    out = run()
    print(f"{'optimizer':16s} {'median rt':>10} {'us/eval':>9} "
          f"{'vs DES':>8} {'vs RTL(fast)':>13} {'vs RTL(slow)':>13}")
    for opt, s in out["summary"].items():
        print(f"{opt:16s} {s['median_runtime_s']:9.2f}s "
              f"{s['median_us_per_eval']:9.0f} "
              f"{s['geomean_speedup_vs_des']:7.1f}x "
              f"{s['geomean_speedup_vs_rtl_fast']:12.1f}x "
              f"{s['geomean_speedup_vs_rtl_slow']:12.0f}x")


if __name__ == "__main__":
    main()
