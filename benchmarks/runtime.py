"""Table III analogue: FIFOAdvisor search runtime vs estimated
co-simulation search runtime, plus the evaluation-subsystem numbers that
make the search cheap: per-backend throughput, shared-cache hit rate, and
the incremental re-simulation speedup.

Vitis HLS/XSIM is not available in this container, so per-config RTL
co-simulation cost is MODELLED, with the model calibrated from the paper's
own published numbers (Table II cycle counts x Table III co-sim days per
1000 samples): effective RTL co-sim throughput in their data ranges from
~40 cycles/s (gemm/atax/k3mm-class designs) to ~2500 cycles/s
(ResidualBlock).  We report speedups under BOTH constants as a
conservative bracket, plus the directly-measured algorithmic gain of
incremental trace evaluation over re-running our own DES per config.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import (Timer, budget, design_set, full_mode,
                               geomean, quick_mode, save_json)
from repro.core import EvalConfig, FifoAdvisor, simulate
from repro.core.backends import worklist as wl
from repro.core.optimizers import PAPER_OPTIMIZERS
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design

RTL_CPS_FAST = 2500.0     # cycles/s, paper's best case (ResidualBlock)
RTL_CPS_SLOW = 40.0       # cycles/s, paper's typical case (gemm/atax/k3mm)


def backend_throughput(g, seed: int = 0) -> Dict:
    """us/config of every registered backend on a feasible-leaning batch.

    ``pallas`` runs in interpret mode on CPU (correctness-grade, orders of
    magnitude off its compiled TPU speed), so it is only measured — with a
    small batch — in FULL mode.
    """
    rng = np.random.default_rng(seed)
    u = g.upper_bounds
    C = 64 if not quick_mode() else 16
    cfgs = np.stack([np.maximum(
        2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
        for _ in range(C)])
    out = {}
    backends = ["numpy", "jax"] + (["pallas"] if full_mode() else [])
    for backend in backends:
        n = C if backend != "pallas" else 8
        ev = BatchedEvaluator(g, EvalConfig(backend=backend, max_iters=64))
        ev.evaluate(cfgs[:2])              # warm / compile
        ev.evaluate(cfgs[:n])              # warm the batch bucket
        with Timer() as t:
            ev.evaluate(cfgs[:n])
        out[backend] = dict(batch=n, total_s=round(t.s, 4),
                            us_per_config=round(1e6 * t.s / n, 1),
                            fallbacks=ev.stats.n_fallbacks,
                            condensed_rows=ev.stats.n_condensed,
                            condensation=ev.condensation_info())
    # one-shot per-design backend calibration (DispatchPolicy satellite):
    # which backend the auto probe would pick, and the probe timings
    ev_auto = BatchedEvaluator(g, EvalConfig(backend="auto", max_iters=64))
    out["auto"] = dict(chosen=ev_auto.calibration["chosen"],
                       probe_s={k: round(v, 5) for k, v in
                                ev_auto.calibration["probe_s"].items()})
    return out


def incremental_speedup(g, n_trials: int = None) -> Dict:
    """Single-FIFO re-evaluation: incremental delta solve vs full solve.

    This is the LightningSim primitive the greedy/annealing single-move
    optimizers lean on: starting from a solved Baseline-Max state, each
    trial drops one FIFO to depth 2 and re-solves only the task segments
    whose timing actually diverges.
    """
    F = g.n_fifos
    n = min(F, n_trials if n_trials is not None else F)
    base = np.maximum(g.upper_bounds, 2)
    state = wl.solve(g, base)
    trials = []
    for f in range(n):
        nxt = base.copy()
        nxt[f] = 2
        trials.append(nxt)
    with Timer() as t_full:
        full = [wl.evaluate_np(g, nxt) for nxt in trials]
    counters = [0]
    with Timer() as t_delta:
        delta = [wl.solve_delta(g, state, nxt, counters=counters)
                 for nxt in trials]
    assert all((d.latency, d.deadlocked) == f
               for d, f in zip(delta, full)), "delta/full disagreement"
    n_segs = int(state.seg_cursor.shape[0])
    return dict(
        n_trials=n,
        full_ms_per_eval=round(1e3 * t_full.s / n, 3),
        incremental_ms_per_eval=round(1e3 * t_delta.s / n, 3),
        speedup=round(t_full.s / max(t_delta.s, 1e-12), 2),
        segments_rerun_avg=round(counters[0] / n, 2),
        segments_total=n_segs)


def run(seed: int = 0) -> Dict:
    rows = []
    graphs = {}                # reuse each advisor's graph (trace once)
    for name in design_set():
        d = make_design(name)
        adv = FifoAdvisor(d)
        graphs[name] = adv.graph
        # best-case co-sim config: Baseline-Max minimizes simulated cycles
        with Timer() as t:
            simulate(d, adv.baseline_max.depths)
        des_one = t.s
        cycles = adv.baseline_max.latency
        rtl_fast = cycles / RTL_CPS_FAST          # seconds per co-sim
        rtl_slow = cycles / RTL_CPS_SLOW
        backends = backend_throughput(adv.graph, seed)
        cond = [r for b in backends.values()
                for r in b.get("condensation", []) or []]
        row = {"design": name, "cycles": cycles,
               "des_one_s": round(des_one, 4),
               "rtl_one_est_s": [round(rtl_fast, 2), round(rtl_slow, 1)],
               "trace_s": round(adv.trace_time_s, 3),
               # raw AND condensed event counts so the perf trajectory
               # stays comparable across PRs
               "events": adv.graph.n_events,
               "events_condensed": (min(r["events_condensed"]
                                        for r in cond) if cond else None),
               "backends": backends,
               "optimizers": {}}
        for opt in PAPER_OPTIMIZERS:
            r = adv.run(opt, budget=budget(), seed=seed)
            n = r.result.n_evals
            wall = max(r.result.runtime_s, 1e-9)
            row["optimizers"][opt] = dict(
                runtime_s=round(r.result.runtime_s, 3),
                n_evals=n,
                us_per_eval=round(1e6 * wall / max(n, 1), 1),
                speedup_vs_des=des_one * n / wall,
                speedup_vs_rtl_fast=rtl_fast * n / wall,
                speedup_vs_rtl_slow=rtl_slow * n / wall,
                speedup_vs_rtl_slow_par32=rtl_slow * n / 32 / wall)
        cs = adv.cache_stats()
        row["cache"] = dict(hits=cs.hits, misses=cs.misses,
                            hit_rate=round(cs.hit_rate, 4))
        ist = adv.evaluator.incr_stats
        row["incremental_evals"] = dict(
            n_delta=ist.n_delta,
            resolve_fraction=round(ist.resolve_fraction, 4))
        rows.append(row)

    # incremental-vs-full microbenchmark on the largest design in the set
    largest = max(graphs, key=lambda n: graphs[n].n_events)
    g_largest = graphs[largest]
    incr = dict(design=largest, events=g_largest.n_events,
                **incremental_speedup(g_largest))

    summary = {}
    for opt in PAPER_OPTIMIZERS:
        def g(key):
            return geomean([r["optimizers"][opt][key] for r in rows])
        summary[opt] = dict(
            geomean_speedup_vs_des=g("speedup_vs_des"),
            geomean_speedup_vs_rtl_fast=g("speedup_vs_rtl_fast"),
            geomean_speedup_vs_rtl_slow=g("speedup_vs_rtl_slow"),
            geomean_speedup_vs_rtl_slow_par32=g(
                "speedup_vs_rtl_slow_par32"),
            median_runtime_s=float(np.median(
                [r["optimizers"][opt]["runtime_s"] for r in rows])),
            median_us_per_eval=float(np.median(
                [r["optimizers"][opt]["us_per_eval"] for r in rows])))
    out = {"per_design": rows, "summary": summary, "incremental": incr,
           "rtl_model": {"fast_cycles_per_s": RTL_CPS_FAST,
                         "slow_cycles_per_s": RTL_CPS_SLOW,
                         "calibration": "paper Table II cycles x Table III "
                                        "co-sim days per 1000 samples"},
           "note": ("our benchmark designs are ~100-1000x smaller in cycle "
                    "count than the paper's (DESIGN.md §8); at their scale "
                    "the same model reproduces the 1e5-1e7x speedups")}
    save_json("runtime.json", out)
    return out


def main():
    out = run()
    print(f"{'optimizer':16s} {'median rt':>10} {'us/eval':>9} "
          f"{'vs DES':>8} {'vs RTL(fast)':>13} {'vs RTL(slow)':>13}")
    for opt, s in out["summary"].items():
        print(f"{opt:16s} {s['median_runtime_s']:9.2f}s "
              f"{s['median_us_per_eval']:9.0f} "
              f"{s['geomean_speedup_vs_des']:7.1f}x "
              f"{s['geomean_speedup_vs_rtl_fast']:12.1f}x "
              f"{s['geomean_speedup_vs_rtl_slow']:12.0f}x")

    print("\nper-backend throughput (us/config) and cache hit rate:")
    for r in out["per_design"]:
        b = r["backends"]
        cols = "  ".join(
            f"{k}={v['us_per_config']:9.1f}" for k, v in b.items()
            if "us_per_config" in v)
        ec = r.get("events_condensed")
        ev_s = (f"E={r['events']}"
                + (f"->{ec}" if ec else ""))
        print(f"  {r['design']:18s} {cols}  auto={b['auto']['chosen']:6s} "
              f"{ev_s:14s} "
              f"cache_hit_rate={r['cache']['hit_rate']:.2%} "
              f"({r['cache']['hits']}/{r['cache']['hits'] + r['cache']['misses']})")

    i = out["incremental"]
    print(f"\nincremental re-simulation on {i['design']} "
          f"(E={i['events']}, largest in set):")
    print(f"  full solve        {i['full_ms_per_eval']:8.2f} ms/eval")
    print(f"  incremental delta {i['incremental_ms_per_eval']:8.2f} ms/eval "
          f"({i['segments_rerun_avg']:.1f}/{i['segments_total']} "
          f"segments re-run)")
    print(f"  speedup           {i['speedup']:8.2f}x")


if __name__ == "__main__":
    main()
