"""Fig. 6 analogue (§IV-D): the FlowGNN-PNA-like DDCF design.

Baseline-Max models the hand-sized accelerator (declared depths); the
frontier shows FIFOAdvisor improving on the expert sizing, and the minimal
feasible msg-queue depth is shown to depend on the runtime graph."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import full_mode, save_json
from repro.core import FifoAdvisor, build_simgraph
from repro.core.optimizers import PAPER_OPTIMIZERS
from repro.core.simulate import BatchedEvaluator
from repro.designs import flowgnn_pna


def run(seed: int = 0) -> Dict:
    b = 5000 if full_mode() else 800
    adv = FifoAdvisor(flowgnn_pna())
    out = {"baseline_max": [adv.baseline_max.latency, adv.baseline_max.bram],
           "baseline_min_deadlocked": adv.baseline_min.deadlocked,
           "budget": b, "fronts": {}, "selected": {}, "runtime_s": {}}
    for opt in PAPER_OPTIMIZERS:
        r = adv.run(opt, budget=b, seed=seed)
        out["fronts"][opt] = r.frontier_points.tolist()
        sel = r.selected(alpha=0.7)
        out["selected"][opt] = list(map(float, sel[0])) if sel else None
        out["runtime_s"][opt] = round(r.result.runtime_s, 2)

    # graph-dependence of minimal feasible uniform msg-queue depth
    dep = {}
    for seed_g in (7, 99, 1234):
        d = flowgnn_pna(seed=seed_g)
        g = build_simgraph(d)
        ev = BatchedEvaluator(g)
        found = None
        for depth in [2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64]:
            cfg = np.maximum(g.upper_bounds, 2).copy()
            for f in range(g.n_fifos):
                if d.fifos[f].name.startswith("deg_"):
                    cfg[f] = depth
            _, _, dead = ev.evaluate(cfg[None, :])
            if not dead[0]:
                found = depth
                break
        dep[f"graph_seed_{seed_g}"] = found
    out["min_feasible_msg_depth_by_graph"] = dep
    save_json("case_study.json", out)
    return out


def main():
    out = run()
    print(f"pna baseline-max {out['baseline_max']} "
          f"(min deadlocked: {out['baseline_min_deadlocked']})")
    for opt in out["fronts"]:
        print(f"  {opt:16s} |front|={len(out['fronts'][opt]):3d} "
              f"star={out['selected'][opt]} "
              f"t={out['runtime_s'][opt]:6.2f}s")
    print("min feasible msg depth by runtime graph:",
          out["min_feasible_msg_depth_by_graph"])


if __name__ == "__main__":
    main()
