"""Chaos differential harness: injected faults vs fault-free runs.

Four phases, each driving a real engine under a deterministic
:class:`~repro.core.faults.FaultPlan` (``docs/robustness.md``) and
holding the repo-wide bar — the final result under injected faults must
be **bit-identical** to the fault-free run, and every recovery must be
bounded (no hangs, no zombies, no lost work):

``pool_crash``            a pooled campaign whose worker lanes are
                          killed mid-round; per-task frontiers must
                          equal the inline fault-free campaign's, with
                          the pool reporting the respawns/requeues that
                          got it there.
``snapshot_corruption``   a save aborted mid-write must leave the prior
                          snapshot loadable; a torn member write must
                          quarantine ONLY the damaged design (the rest
                          restore warm and answer with zero evals) and
                          the quarantined design must re-trace to the
                          same answers.
``kill_resume``           a campaign killed after a few rounds and
                          resumed from its checkpoint must finish with
                          the uninterrupted campaign's exact frontiers.
``service_faults``        a wedged evaluation round must fail ONLY the
                          deadline-carrying victim session (stable
                          ``E_TIMEOUT``, partial result kept) while its
                          peers finish bit-identical to solo runs, and
                          a reconnecting client must replay its exact
                          event-stream suffix.

``check_chaos`` in ``benchmarks/check_regression.py`` gates the
booleans plus a recovery-time ceiling against the committed
``chaos.quick.json`` baseline.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import budget, design_set, save_json

OPTIMIZERS = ("grouped_sa", "grouped_random")


def _frontier_map(store) -> Dict[str, np.ndarray]:
    return {k: store[k].frontier_points for k in store.keys()}


def _identical(a: Dict[str, np.ndarray], b: Dict[str, np.ndarray]) -> bool:
    return (set(a) == set(b)
            and all(np.array_equal(a[k], b[k]) for k in a))


def pool_crash_phase(designs: List[str], bdg: int) -> Dict:
    """Pooled campaign under lane-kill faults vs inline fault-free."""
    from repro.core.campaign import Campaign, CampaignSpec
    from repro.core.config import EvalConfig
    from repro.core.faults import Fault, FaultPlan

    base_spec = CampaignSpec(designs=tuple(designs),
                             optimizers=OPTIMIZERS, budget=bdg,
                             seed=0, workers=0)
    baseline = _frontier_map(Campaign(base_spec).run())

    # two wildcard-lane crashes at job 0: every lane dies on its first
    # job after (re)spawn until both faults are consumed, exercising
    # detect -> respawn -> requeue on whichever lanes get work first
    plan = FaultPlan([Fault("crash_worker", at=0),
                      Fault("crash_worker", at=0)])
    chaos_spec = CampaignSpec(designs=tuple(designs),
                              optimizers=OPTIMIZERS, budget=bdg,
                              seed=0, workers=2,
                              eval=EvalConfig(faults=plan.to_json()))
    t0 = time.perf_counter()
    camp = Campaign(chaos_spec)
    chaos = _frontier_map(camp.run())
    wall = time.perf_counter() - t0
    stats = camp.pool_stats or {}
    strays = mp.active_children()
    for p in strays:  # pragma: no cover - the defect this phase catches
        p.kill()
    return {
        "n_tasks": len(designs) * len(OPTIMIZERS),
        "identical_frontiers": _identical(baseline, chaos),
        "respawns": int(stats.get("respawns", 0)),
        "requeued": int(stats.get("requeued", 0)),
        "escalated": int(stats.get("escalated", 0)),
        "recovery_s": round(float(stats.get("recovery_s", 0.0)), 4),
        "all_faults_fired": camp.faults.all_fired if camp.faults else False,
        "no_zombies": not strays,
        "wall_s": round(wall, 3),
    }


def snapshot_corruption_phase(designs: List[str], bdg: int) -> Dict:
    """Crash-consistent saves + per-design quarantine on torn writes."""
    from repro.core.service import (AdvisoryService, DesignRegistry,
                                    InjectedFault, load_snapshot,
                                    save_snapshot)
    from repro.core.faults import Fault, FaultPlan

    d_hurt, d_ok = designs[0], designs[1]
    reg = DesignRegistry()
    with AdvisoryService(registry=reg) as svc:
        ref = {}
        for d in (d_hurt, d_ok):
            sid = svc.open_session(d, optimizer="grouped_sa",
                                   budget=bdg, seed=0).id
            svc.run_until_idle()
            ref[d] = svc.result(sid)

    out: Dict = {}
    with tempfile.TemporaryDirectory() as tmp:
        snap = os.path.join(tmp, "snap")
        save_snapshot(reg, snap)

        # 1. kill-mid-save: an aborted re-save must leave the previous
        #    snapshot fully (strict-)loadable
        crash = FaultPlan([Fault("crash_save", at=0)])
        try:
            save_snapshot(reg, snap, faults=crash)
            out["survived_crash_save"] = False      # fault never fired
        except InjectedFault:
            probe = DesignRegistry()
            load_snapshot(snap, registry=probe, strict=True)
            out["survived_crash_save"] = sorted(probe.names()) == sorted(
                [d_hurt, d_ok])

        # 2. torn member write: load quarantines ONLY the damaged design
        torn = FaultPlan([Fault("corrupt_snapshot", at=0, value=40,
                                target=d_hurt)])
        save_snapshot(reg, snap, faults=torn)
        reg2 = DesignRegistry()
        load_snapshot(snap, registry=reg2)
        report = reg2.restore_report or {}
        out["quarantined_only_damaged"] = (
            sorted(report.get("quarantined", {})) == [d_hurt]
            and report.get("restored") == [d_ok])

        # 3. the healthy design restores warm: same session answers
        #    bit-identically with every row served from the restored cache
        with AdvisoryService(registry=reg2) as svc2:
            sid = svc2.open_session(d_ok, optimizer="grouped_sa",
                                    budget=bdg, seed=0).id
            svc2.run_until_idle()
            warm = svc2.result(sid)
            out["healthy_warm_identical"] = np.array_equal(
                warm.frontier_points, ref[d_ok].frontier_points)
            out["healthy_warm_n_evals"] = int(warm.result.n_evals)

            # 4. the quarantined design re-traces on first use and still
            #    produces the exact pre-corruption answers
            sid = svc2.open_session(d_hurt, optimizer="grouped_sa",
                                    budget=bdg, seed=0).id
            svc2.run_until_idle()
            out["retraced_identical"] = np.array_equal(
                svc2.result(sid).frontier_points,
                ref[d_hurt].frontier_points)
    return out


def kill_resume_phase(designs: List[str], bdg: int) -> Dict:
    """Interrupted campaign + checkpoint resume vs uninterrupted."""
    from repro.core.campaign import Campaign, CampaignSpec

    spec = CampaignSpec(designs=tuple(designs), optimizers=OPTIMIZERS,
                        budget=bdg, seed=0, workers=0)
    full = _frontier_map(Campaign(spec).run())
    rounds_before_kill = 3
    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "campaign.npz")
        Campaign(spec, checkpoint_path=ckpt).run(
            max_rounds=rounds_before_kill)
        resumed = _frontier_map(Campaign.resume(ckpt).run())
    return {
        "rounds_before_kill": rounds_before_kill,
        "identical_frontiers": _identical(full, resumed),
    }


def service_faults_phase(designs: List[str], bdg: int) -> Dict:
    """Deadline fail-fast isolation + exact event-stream replay."""
    from repro.core import FifoAdvisor
    from repro.core.faults import Fault, FaultPlan
    from repro.core.service import AdvisoryService
    from repro.designs import make_design

    d_victim, d_peer = designs[0], designs[1]
    plan = FaultPlan([Fault("hang_eval", at=1, target=d_victim,
                            value=0.2)])
    t0 = time.perf_counter()
    with AdvisoryService(faults=plan) as svc:
        victim = svc.open_session(d_victim, optimizer="grouped_sa",
                                  budget=bdg, seed=0, deadline_s=0.05)
        peer = svc.open_session(d_peer, optimizer="grouped_sa",
                                budget=bdg, seed=1)
        # a client that drains a prefix then loses its connection...
        svc.run_until_idle(max_rounds=2)
        seen = victim.drain_events()
        last_seq = seen[-1]["seq"] if seen else -1
        svc.run_until_idle()
        peer_result = svc.result(peer.id)
    wall = time.perf_counter() - t0

    # ...re-attaches and must receive exactly the missed suffix, no
    # duplicates, terminal event included
    replayed = victim.events_after(last_seq)
    stream = seen + replayed
    seqs = [e["seq"] for e in stream]
    replay_exact = (seqs == sorted(set(seqs))
                    and seqs[0] == 0 and len(seqs) == seqs[-1] + 1
                    and stream[-1]["event"] == "failed")

    solo = FifoAdvisor(make_design(d_peer)).run("grouped_sa",
                                                budget=bdg, seed=1)
    return {
        "victim_failed_fast": victim.state == "failed",
        "victim_code": victim.error_code,
        "victim_kept_partial": victim.rounds >= 2,
        "peer_identical": np.array_equal(peer_result.frontier_points,
                                         solo.frontier_points),
        "replay_exact": replay_exact,
        "all_faults_fired": plan.all_fired,
        "wall_s": round(wall, 3),
    }


def run() -> Dict:
    designs = design_set()[:2]
    bdg = budget()
    out = {
        "designs": list(designs),
        "budget": bdg,
        "pool_crash": pool_crash_phase(designs, bdg),
        "snapshot_corruption": snapshot_corruption_phase(designs, bdg),
        "kill_resume": kill_resume_phase(designs, bdg),
        "service_faults": service_faults_phase(designs, bdg),
    }
    save_json("chaos.json", out)
    return out


def main():
    out = run()
    pc, sc = out["pool_crash"], out["snapshot_corruption"]
    kr, sf = out["kill_resume"], out["service_faults"]
    print(f"chaos harness: designs={out['designs']} budget={out['budget']}")
    print(f"  pool_crash: identical={pc['identical_frontiers']} "
          f"respawns={pc['respawns']} requeued={pc['requeued']} "
          f"escalated={pc['escalated']} "
          f"recovery={pc['recovery_s'] * 1e3:.1f}ms "
          f"no_zombies={pc['no_zombies']}")
    print(f"  snapshot_corruption: survived_crash_save="
          f"{sc['survived_crash_save']} quarantine_exact="
          f"{sc['quarantined_only_damaged']} warm_identical="
          f"{sc['healthy_warm_identical']} "
          f"(n_evals={sc['healthy_warm_n_evals']}) retraced_identical="
          f"{sc['retraced_identical']}")
    print(f"  kill_resume: identical={kr['identical_frontiers']} "
          f"(killed after {kr['rounds_before_kill']} rounds)")
    print(f"  service_faults: victim={sf['victim_code']} "
          f"(failed_fast={sf['victim_failed_fast']}) peer_identical="
          f"{sf['peer_identical']} replay_exact={sf['replay_exact']}")


if __name__ == "__main__":
    main()
