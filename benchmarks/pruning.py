"""Beyond-paper: sound local lower-bound pruning (core/prune.py).

Compares DSE quality with and without the task-pair feasibility bounds on
the reorder-hazard designs where Baseline-Min deadlocks: pruning removes
candidates that deadlock in EVERY configuration, so random/SA budgets stop
being spent on infeasible points.
"""

from __future__ import annotations

from typing import Dict

from benchmarks.common import budget, save_json
from repro.core import EvalConfig, FifoAdvisor
from repro.designs import flowgnn_pna, make_design

DESIGNS = {
    "k15mmtree": lambda: make_design("k15mmtree"),
    "k15mmtree_relu": lambda: make_design("k15mmtree_relu"),
    "flowgnn_pna": flowgnn_pna,
}


def run(seed: int = 0) -> Dict:
    out = {}
    for name, factory in DESIGNS.items():
        row = {}
        for lb in (False, True):
            adv = FifoAdvisor(factory(), EvalConfig(local_bounds=lb))
            for opt in ("random", "grouped_sa"):
                r = adv.run(opt, budget=budget(), seed=seed)
                sel = r.selected(alpha=0.7)
                row[f"{opt}_{'pruned' if lb else 'raw'}"] = dict(
                    dead=int(r.result.deadlock.sum()),
                    n=int(r.result.n_evals),
                    hypervolume=r.hypervolume(),
                    selected=(list(map(float, sel[0])) if sel else None),
                    runtime_s=round(r.result.runtime_s, 2))
        out[name] = row
    save_json("pruning.json", out)
    return out


def main():
    out = run()
    for name, row in out.items():
        print(f"=== {name}")
        for k, v in row.items():
            print(f"  {k:22s} dead={v['dead']:4d}/{v['n']:4d} "
                  f"hv={v['hypervolume']:12.0f} star={v['selected']} "
                  f"t={v['runtime_s']:6.2f}s")


if __name__ == "__main__":
    main()
