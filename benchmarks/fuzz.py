"""Fuzzing + certification benchmark.

Two numbers the regression gate watches:

* **differential throughput** — generated-design differential
  evaluations per second (oracle + worklist over the fuzz depth matrix),
  with the hard requirement that the campaign reports ZERO
  disagreements;
* **certification speedup** — minimal-safe-depth certification through
  the incremental ``solve_delta`` / shared-cache fast path vs the naive
  discrete-event-oracle bisection (identical probe sequences, identical
  certified vectors — the speedup is pure evaluator economics).  The
  affine benchmark designs clear 3x comfortably; heavily back-pressured
  DDCF shapes (flowgnn) gain less because delta cascades re-run most
  segments, and are reported but kept out of the gated geomean.

  QUICK=1 PYTHONPATH=src:. python benchmarks/fuzz.py   # CI smoke
  PYTHONPATH=src:. python benchmarks/fuzz.py           # default set
  FULL=1 PYTHONPATH=src:. python benchmarks/fuzz.py    # everything
"""

from __future__ import annotations

import time

from benchmarks.common import Timer, full_mode, geomean, quick_mode, save_json

#: designs in the gated certification geomean (affine, delta-friendly)
_CERT_GATED_QUICK = ("mvt", "Autoencoder", "gemm")
_CERT_GATED = _CERT_GATED_QUICK + ("FeedForward", "ResidualBlock", "k2mm")
#: reported (not gated): back-pressure-heavy DDCF reference point
_CERT_EXTRA = ("flowgnn_small",)


def _design(name):
    from repro.designs import make_design
    from repro.designs.ddcf import flowgnn_pna, mult_by_2
    if name == "flowgnn_small":
        return flowgnn_pna(n_nodes=24, n_edges=64)
    if name == "mult_by_2_64":
        return mult_by_2(64)
    return make_design(name)


def bench_differential(seeds: range, quick: bool) -> dict:
    """Throughput of the differential campaign loop (oracle + worklist)."""
    from repro.designs.generate import generate_design
    from repro.launch.fuzz import differential_check

    n_rows = n_mism = 0
    with Timer() as t:
        for seed in seeds:
            gen = generate_design(seed, quick=quick)
            mism, rows = differential_check(gen, backends=("worklist",),
                                            n_random=3)
            n_rows += rows
            n_mism += len(mism)
    # each config row is evaluated by the oracle AND the worklist
    evals = n_rows * 2
    return {
        "n_designs": len(seeds), "n_rows": n_rows,
        "n_mismatches": n_mism, "zero_mismatches": n_mism == 0,
        "wall_s": round(t.s, 3),
        "evals_per_s": round(evals / max(t.s, 1e-9), 1),
    }


def bench_certification(names) -> dict:
    """Fast-path vs naive-oracle certification, per design."""
    from repro.core import FifoAdvisor
    from repro.core.deadlock import (certify_min_depths,
                                     certify_min_depths_oracle)

    per_design = {}
    for name in names:
        design = _design(name)
        adv = FifoAdvisor(design)
        t0 = time.perf_counter()
        res = certify_min_depths(adv.graph, adv.evaluator, cache=adv.cache)
        fast_s = time.perf_counter() - t0
        naive = certify_min_depths_oracle(design)
        per_design[name] = {
            "n_fifos": int(adv.graph.n_fifos),
            "n_events": int(adv.graph.n_events),
            "n_probes": int(res.n_probes),
            "fast_s": round(fast_s, 4),
            "naive_s": round(naive.wall_s, 4),
            "speedup": round(naive.wall_s / max(fast_s, 1e-9), 2),
            "identical_depths": bool((res.depths == naive.depths).all()),
            "certified_sum": int(res.depths.sum()),
        }
    return per_design


def run() -> dict:
    if quick_mode():
        seeds, quick, gated = range(0, 40), True, _CERT_GATED_QUICK
        extra = ()
    elif full_mode():
        seeds, quick, gated = range(0, 150), False, _CERT_GATED
        extra = _CERT_EXTRA + ("mult_by_2_64",)
    else:
        seeds, quick, gated = range(0, 80), True, _CERT_GATED
        extra = _CERT_EXTRA

    diff = bench_differential(seeds, quick)
    cert = bench_certification(tuple(gated) + tuple(extra))
    gated_rows = {k: v for k, v in cert.items() if k in gated}
    payload = {
        "differential": diff,
        "certification": cert,
        "cert_gated_designs": list(gated),
        "cert_geomean_speedup": round(
            geomean([v["speedup"] for v in gated_rows.values()]), 2),
        "cert_identical_depths": all(
            v["identical_depths"] for v in cert.values()),
    }
    save_json("fuzz.json", payload)
    return payload


def main():
    out = run()
    d = out["differential"]
    print(f"differential: {d['n_designs']} designs, {d['n_rows']} rows, "
          f"{d['evals_per_s']}/s, mismatches={d['n_mismatches']}")
    for name, row in out["certification"].items():
        print(f"certify {name:14s} fast={row['fast_s']:8.3f}s "
              f"naive={row['naive_s']:8.3f}s {row['speedup']:5.1f}x "
              f"identical={row['identical_depths']}")
    print(f"gated geomean speedup: {out['cert_geomean_speedup']}x "
          f"(designs: {', '.join(out['cert_gated_designs'])})")


if __name__ == "__main__":
    main()
