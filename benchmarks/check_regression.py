"""Benchmark regression gate: fresh quick-mode results vs committed ones.

Compares same-named ``*.quick.json`` files between a baseline directory
(the committed ``benchmarks/results``) and a current directory (what the
CI run just produced) and FAILS (exit 1) when a key metric regresses
beyond tolerance:

* ``accuracy.quick.json``  — ``all_exact`` must stay true (the batched
  backends must agree with the DES oracle bit for bit);
* ``runtime.quick.json``   — per-design shared-cache hit rate must not
  drop more than ``--hit-rate-tol`` (joined on design name);
* ``campaign.quick.json``  — the campaign speedup over the sequential
  per-pair loop must stay above ``--campaign-floor`` AND above
  ``--campaign-frac`` of the committed baseline value (wall-clock ratios
  on shared CI runners are noisy, so the tolerance is generous — this
  gate catches "the campaign engine stopped helping", not percent-level
  drift), and per-task frontiers must still be identical across modes;
* ``fuzz.quick.json``      — the differential fuzz campaign must report
  ZERO oracle/backend disagreements, certified depth vectors must stay
  identical between the incremental fast path and the naive oracle
  bisection, and the gated certification speedup must hold its floor;
* ``bounds.quick.json``    — bounds-seeded certification must return
  depth vectors identical to the unseeded descent on every design, the
  analytical bounds must bracket every certified depth, and the gated
  probe-reduction geomean must hold its >=3x floor;
* ``chaos.quick.json``     — every fault-injected run must stay
  bit-identical to its fault-free twin (pooled campaign under lane
  kills, checkpoint resume, peer sessions next to a deadline-failed
  victim), recovery must be bounded (respawn time under the ceiling, no
  zombie workers), snapshot corruption must quarantine only the damaged
  design, and event-stream replay must be exact.

Exit code 0 = gate passed.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load(directory: str, name: str):
    path = os.path.join(directory, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def check_accuracy(base, cur, failures):
    if cur is None:
        failures.append("accuracy.quick.json missing from current run")
        return
    diverged = [r["design"] for r in cur.get("table", [])
                if r.get("max_abs_diff", 0) != 0]
    if base is not None and base.get("all_exact") and not cur.get(
            "all_exact"):
        failures.append(
            "accuracy regression: all_exact was true in baseline, now "
            f"false (diverging designs: {diverged})")
    elif not cur.get("all_exact"):
        failures.append(f"accuracy: all_exact is false ({diverged})")


def check_cache_hit_rate(base, cur, tol, failures):
    if cur is None:
        failures.append("runtime.quick.json missing from current run")
        return
    if base is None:
        return   # first run establishes the baseline
    base_rows = {r["design"]: r for r in base.get("per_design", [])}
    for row in cur.get("per_design", []):
        ref = base_rows.get(row["design"])
        if ref is None:
            continue
        b = ref.get("cache", {}).get("hit_rate")
        c = row.get("cache", {}).get("hit_rate")
        if b is not None and c is not None and c < b - tol:
            failures.append(
                f"cache hit-rate regression on {row['design']}: "
                f"{c:.3f} < baseline {b:.3f} - {tol}")


def check_campaign(base, cur, floor, frac, failures):
    if cur is None:
        failures.append("campaign.quick.json missing from current run")
        return
    if not cur.get("identical_frontiers"):
        failures.append(
            "campaign regression: per-task frontiers differ between the "
            "campaign and the sequential loop")
    speedup = cur.get("campaign_speedup", 0.0)
    if speedup < floor:
        failures.append(
            f"campaign speedup {speedup:.2f}x below hard floor "
            f"{floor:.2f}x")
    if base is not None:
        ref = base.get("campaign_speedup")
        if ref and speedup < frac * ref:
            failures.append(
                f"campaign speedup regression: {speedup:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_service(base, cur, floor, frac, failures):
    if cur is None:
        failures.append("service.quick.json missing from current run")
        return
    if not cur.get("identical_frontiers"):
        failures.append(
            "service regression: per-session results differ from solo "
            "FifoAdvisor.run() — batching changed results")
    speedup = cur.get("service_speedup", 0.0)
    if speedup < floor:
        failures.append(
            f"service speedup {speedup:.2f}x below hard floor "
            f"{floor:.2f}x")
    if base is not None:
        ref = base.get("service_speedup")
        if ref and speedup < frac * ref:
            failures.append(
                f"service speedup regression: {speedup:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_condense(base, cur, floor, frac, failures):
    if cur is None:
        failures.append("condense.quick.json missing from current run")
        return
    if not cur.get("identical_all"):
        failures.append(
            "condensation regression: condensed evaluation no longer "
            "bit-identical to the raw path")
    ratio = cur.get("geomean_condensation_ratio", 0.0)
    if ratio < 1.5:
        failures.append(
            f"condensation ratio {ratio:.2f}x below 1.5x — the pass "
            "stopped compressing the event graph")
    speedup = cur.get("geomean_speedup_scan", 0.0)
    if speedup < floor:
        failures.append(
            f"condensed scan speedup {speedup:.2f}x below hard floor "
            f"{floor:.2f}x")
    if base is not None:
        ref = base.get("geomean_speedup_scan")
        if ref and speedup < frac * ref:
            failures.append(
                f"condensed scan speedup regression: {speedup:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_condensed_kernel(base, cur, min_wins, frac, failures):
    """Gate the fused-kernel rung shootout (``benchmarks/condense.py``).

    Identity of the kernel's on-device certificate path with the scan
    rung protocol is unconditional.  The perf criterion is ordinal — the
    kernel must still *win* (speedup > 1) on at least ``min_wins``
    benchmark designs, with auto-calibration agreeing on those designs —
    plus a generous baseline-relative band on the geomean (shared-runner
    interpret-mode wall clocks are noisy).
    """
    if cur is None:
        failures.append("condense.quick.json missing from current run")
        return
    if not cur.get("kernel_identical_all", False):
        failures.append(
            "fused-kernel regression: kernel rung results (status / "
            "latency / certificate mask) no longer identical to the "
            "scan + verify_rows protocol")
    wins = cur.get("kernel_wins", 0)
    n = cur.get("kernel_designs", 0)
    if wins < min_wins:
        failures.append(
            f"fused-kernel regression: kernel beats the scan rung on "
            f"only {wins}/{n} designs (need >= {min_wins})")
    picks = cur.get("calibration_picks", {})
    n_pallas = sum(1 for v in picks.values() if v == "pallas")
    if n_pallas < min_wins:
        failures.append(
            f"calibration regression: auto picks the kernel backend on "
            f"only {n_pallas}/{len(picks)} designs ({picks}); the fused "
            f"path stopped paying end to end")
    speedup = cur.get("kernel_geomean_speedup", 0.0)
    if base is not None:
        ref = base.get("kernel_geomean_speedup")
        if ref and speedup < frac * ref:
            failures.append(
                f"fused-kernel speedup regression: {speedup:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_mesh(base, cur, floor, eff, frac, failures):
    """Gate the sharded-evaluation benchmark (``benchmarks/mesh.py``).

    Bit-identity of the sharded path is unconditional.  The scaling
    expectation adapts to the runner: host-platform CPU devices are
    threads, so the 8-vs-1-shard speedup is bounded by real cores.  The
    required speedup is ``max(floor, eff * min(max_shards, cores))``
    with the run's recorded ``usable_cores`` — at ``eff=0.375`` that is
    the ISSUE criterion (>=3x at 8 devices) wherever 8 cores exist, and
    the early-exit floor on single-core runners.
    """
    if cur is None:
        failures.append("mesh.quick.json missing from current run")
        return
    if not cur.get("identical_all"):
        failures.append(
            "mesh regression: sharded evaluation no longer bit-identical "
            "to the solo jit path")
    cores = max(1, int(cur.get("usable_cores", 1)))
    max_shards = max(1, int(cur.get("max_shards", 8)))
    need = max(floor, eff * min(max_shards, cores))
    speedup = cur.get("geomean_speedup_8v1", 0.0)
    if speedup < need:
        failures.append(
            f"mesh speedup {speedup:.2f}x below required {need:.2f}x "
            f"(= max({floor}, {eff} x min({max_shards} shards, "
            f"{cores} cores)))")
    if base is not None:
        ref = base.get("geomean_speedup_8v1")
        # only hold the baseline fraction on comparable hardware — a
        # baseline recorded on a wider host would gate 1-core runners
        # on a speedup they cannot reach
        if (ref and base.get("usable_cores") == cur.get("usable_cores")
                and speedup < frac * ref):
            failures.append(
                f"mesh speedup regression: {speedup:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_fuzz(base, cur, floor, frac, failures):
    if cur is None:
        failures.append("fuzz.quick.json missing from current run")
        return
    diff = cur.get("differential", {})
    if not diff.get("zero_mismatches"):
        failures.append(
            f"fuzz regression: {diff.get('n_mismatches')} oracle/backend "
            "disagreements on generated designs")
    if not cur.get("cert_identical_depths"):
        failures.append(
            "certification regression: fast-path depths differ from the "
            "naive oracle bisection")
    speedup = cur.get("cert_geomean_speedup", 0.0)
    if speedup < floor:
        failures.append(
            f"certification speedup {speedup:.2f}x below hard floor "
            f"{floor:.2f}x")
    if base is not None:
        ref = base.get("cert_geomean_speedup")
        if ref and speedup < frac * ref:
            failures.append(
                f"certification speedup regression: {speedup:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_bounds(base, cur, floor, frac, failures):
    """Gate the channel-bounds benchmark (``benchmarks/bounds.py``).

    Identity (seeded == unseeded depth vectors) and bracketing
    (``lower <= certified <= upper``) are unconditional — they are the
    soundness contract of ``core/bounds.py``.  The probe-reduction
    geomean is a hard >=3x floor on the gated affine suite (the ISSUE-9
    criterion: the analytical floor replaces per-FIFO binary searches
    with a start check plus one shortcut probe), with a generous
    baseline-relative band on top.
    """
    if cur is None:
        failures.append("bounds.quick.json missing from current run")
        return
    if not cur.get("identical_depths_all"):
        bad = [k for k, v in cur.get("per_design", {}).items()
               if not v.get("identical_depths")]
        failures.append(
            "bounds regression: seeded certification no longer returns "
            f"the unseeded depth vector (designs: {bad})")
    if not cur.get("bracket_all"):
        bad = [k for k, v in cur.get("per_design", {}).items()
               if not v.get("bracket")]
        failures.append(
            "bounds regression: analytical bounds stopped bracketing "
            f"certified depths (designs: {bad})")
    reduction = cur.get("probe_reduction_geomean", 0.0)
    if reduction < floor:
        failures.append(
            f"bounds probe reduction {reduction:.2f}x below hard floor "
            f"{floor:.2f}x")
    if base is not None:
        ref = base.get("probe_reduction_geomean")
        if ref and reduction < frac * ref:
            failures.append(
                f"bounds probe-reduction regression: {reduction:.2f}x < "
                f"{frac:.0%} of baseline {ref:.2f}x")


def check_load(base, cur, p99_ceiling, p99_frac, failures):
    """Gate the service load harness (``benchmarks/load.py``).

    Overload behavior is exact — shedding with a retry hint while
    respecting the session cap is correctness, not performance.  The
    latency SLO is a hard p99 ceiling plus a generous baseline-relative
    band (shared-runner wall clocks are noisy; this catches "the service
    got an order of magnitude slower", not millisecond drift).
    """
    if cur is None:
        failures.append("load.quick.json missing from current run")
        return
    steady, over = cur.get("steady", {}), cur.get("overload", {})
    if not steady.get("all_completed"):
        failures.append("load regression: steady-phase sessions never "
                        "completed")
    p99 = steady.get("p99_s")
    if p99 is None or p99 > p99_ceiling:
        failures.append(
            f"load SLO violated: steady p99 {p99}s > hard ceiling "
            f"{p99_ceiling}s")
    if not over.get("cap_respected"):
        failures.append(
            f"load regression: running sessions exceeded max_sessions "
            f"(observed {over.get('max_running_observed')})")
    if not over.get("shed_and_recovered"):
        failures.append(
            "load regression: overload burst was not shed with "
            "E_OVERLOADED, or shed clients never recovered")
    hint = over.get("min_retry_after_s")
    if hint is None or hint <= 0:
        failures.append(
            f"load regression: overload replies carry no positive "
            f"retry_after_s hint (got {hint})")
    if base is not None:
        ref = base.get("steady", {}).get("p99_s")
        if ref and p99 is not None and p99 > max(
                p99_frac * ref, p99_ceiling / 2):
            failures.append(
                f"load p99 regression: {p99:.3f}s > {p99_frac:.0f}x "
                f"baseline {ref:.3f}s")


def check_chaos(base, cur, recovery_ceiling, failures):
    """Gate the chaos harness (``benchmarks/chaos.py``).

    Everything here is exact — identity under injected faults, bounded
    recovery, quarantine precision — so the gate is boolean except for
    the respawn-recovery wall-clock ceiling (generous: it catches "lane
    respawn became a multi-second stall", not millisecond drift).
    """
    if cur is None:
        failures.append("chaos.quick.json missing from current run")
        return
    pc = cur.get("pool_crash", {})
    if not pc.get("identical_frontiers"):
        failures.append(
            "chaos regression: pooled campaign under injected lane kills "
            "no longer bit-identical to the fault-free inline campaign")
    if pc.get("respawns", 0) < 1:
        failures.append(
            "chaos regression: no lane was respawned — the injected "
            "crashes never exercised the recovery path")
    if not pc.get("no_zombies"):
        failures.append(
            "chaos regression: worker processes outlived pool.close()")
    rec = pc.get("recovery_s")
    if rec is None or rec > recovery_ceiling:
        failures.append(
            f"chaos regression: lane recovery took {rec}s > ceiling "
            f"{recovery_ceiling}s")
    sc = cur.get("snapshot_corruption", {})
    if not sc.get("survived_crash_save"):
        failures.append(
            "chaos regression: a save aborted mid-write destroyed the "
            "previous snapshot")
    if not sc.get("quarantined_only_damaged"):
        failures.append(
            "chaos regression: snapshot corruption did not quarantine "
            "exactly the damaged design")
    if not sc.get("healthy_warm_identical") or sc.get(
            "healthy_warm_n_evals", 1) != 0:
        failures.append(
            "chaos regression: healthy designs no longer restore warm "
            f"and bit-identical (n_evals="
            f"{sc.get('healthy_warm_n_evals')})")
    if not sc.get("retraced_identical"):
        failures.append(
            "chaos regression: the quarantined design's re-trace "
            "changed answers")
    if not cur.get("kill_resume", {}).get("identical_frontiers"):
        failures.append(
            "chaos regression: checkpoint resume after a mid-campaign "
            "kill no longer reproduces the uninterrupted frontiers")
    sf = cur.get("service_faults", {})
    if not sf.get("victim_failed_fast") or sf.get(
            "victim_code") != "E_TIMEOUT":
        failures.append(
            f"chaos regression: deadline-exceeded session did not fail "
            f"fast with E_TIMEOUT (state code: {sf.get('victim_code')})")
    if not sf.get("peer_identical"):
        failures.append(
            "chaos regression: a peer session was perturbed by its "
            "neighbour's injected hang/deadline failure")
    if not sf.get("replay_exact"):
        failures.append(
            "chaos regression: reconnect replay no longer returns the "
            "exact missed event-stream suffix")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="directory with the committed result JSONs")
    ap.add_argument("--current", required=True,
                    help="directory with the freshly produced JSONs")
    ap.add_argument("--hit-rate-tol", type=float, default=0.05)
    # wall-clock ratios on shared runners are noisy even with the
    # benchmark's median-of-ratios protocol; the floor catches "the
    # campaign engine actively slows things down", not percent drift
    ap.add_argument("--campaign-floor", type=float, default=0.8,
                    help="hard minimum campaign speedup")
    ap.add_argument("--campaign-frac", type=float, default=0.5,
                    help="required fraction of the baseline speedup")
    # the quick service mix (4 sessions, tiny budgets) amortizes much
    # less than the real workload (1.7x at default budgets), so the
    # quick floor only catches "the service actively slows clients down"
    ap.add_argument("--service-floor", type=float, default=0.8,
                    help="hard minimum service speedup")
    ap.add_argument("--service-frac", type=float, default=0.5,
                    help="required fraction of the baseline speedup")
    # the ISSUE-4 expectation is >=3x from the solve_delta path on the
    # affine designs; the hard floor below that absorbs runner noise
    ap.add_argument("--cert-floor", type=float, default=2.0,
                    help="hard minimum certification geomean speedup")
    ap.add_argument("--cert-frac", type=float, default=0.4,
                    help="required fraction of the baseline cert speedup")
    # the ISSUE-9 criterion: bounds-seeded certification needs >=3x
    # fewer evaluator probes on the affine suite (probe counts are
    # deterministic, so no noise band is needed below the floor)
    ap.add_argument("--bounds-floor", type=float, default=3.0,
                    help="hard minimum bounds probe-reduction geomean")
    ap.add_argument("--bounds-frac", type=float, default=0.5,
                    help="required fraction of the baseline bounds "
                         "probe reduction")
    # the quick mix runs smaller batches than the committed full-mode
    # result (~6x scan speedup), so the hard floor only catches "the
    # condensation engine stopped paying", not runner-noise drift
    ap.add_argument("--condense-floor", type=float, default=1.3,
                    help="hard minimum condensed scan geomean speedup")
    ap.add_argument("--condense-frac", type=float, default=0.4,
                    help="required fraction of the baseline condensed "
                         "speedup")
    # the ISSUE-8 criterion: the fused kernel beats the scan rung on
    # >= 2 of the 3 benchmark designs with calibration agreeing
    ap.add_argument("--kernel-min-wins", type=int, default=2,
                    help="designs the fused kernel must beat the scan "
                         "rung on (and auto-calibration must pick it)")
    ap.add_argument("--kernel-frac", type=float, default=0.4,
                    help="required fraction of the baseline fused-kernel "
                         "geomean speedup")
    # host-platform devices are threads: the achievable 8-vs-1-shard
    # speedup scales with real cores, so the requirement is
    # max(floor, eff * min(8, cores)) — 3x at 8 cores (the ISSUE
    # criterion), the early-exit floor on 1-core runners
    ap.add_argument("--mesh-floor", type=float, default=0.75,
                    help="hard minimum 8-vs-1-shard speedup on any host")
    ap.add_argument("--mesh-eff", type=float, default=0.375,
                    help="required speedup per usable core (x min(8, "
                         "cores))")
    ap.add_argument("--mesh-frac", type=float, default=0.5,
                    help="required fraction of the baseline mesh "
                         "speedup (same-core-count hosts only)")
    # the steady-phase p99 on the quick mix is ~0.25s on this container;
    # the ceiling is the SLO ("a session answers within 2s even behind a
    # queue"), the frac band catches order-of-magnitude slowdowns
    ap.add_argument("--load-p99", type=float, default=2.0,
                    help="hard p99 latency ceiling (seconds) for the "
                         "steady load phase")
    ap.add_argument("--load-p99-frac", type=float, default=5.0,
                    help="allowed p99 multiple of the committed baseline")
    # lane respawn is a terminate + fork, milliseconds in practice; the
    # ceiling catches "recovery became a multi-second stall"
    ap.add_argument("--chaos-recovery", type=float, default=5.0,
                    help="hard ceiling (seconds) on total lane-respawn "
                         "recovery time in the chaos pool phase")
    args = ap.parse_args(argv)

    failures = []
    check_accuracy(load(args.baseline, "accuracy.quick.json"),
                   load(args.current, "accuracy.quick.json"), failures)
    check_cache_hit_rate(load(args.baseline, "runtime.quick.json"),
                         load(args.current, "runtime.quick.json"),
                         args.hit_rate_tol, failures)
    check_campaign(load(args.baseline, "campaign.quick.json"),
                   load(args.current, "campaign.quick.json"),
                   args.campaign_floor, args.campaign_frac, failures)
    check_service(load(args.baseline, "service.quick.json"),
                  load(args.current, "service.quick.json"),
                  args.service_floor, args.service_frac, failures)
    check_fuzz(load(args.baseline, "fuzz.quick.json"),
               load(args.current, "fuzz.quick.json"),
               args.cert_floor, args.cert_frac, failures)
    check_bounds(load(args.baseline, "bounds.quick.json"),
                 load(args.current, "bounds.quick.json"),
                 args.bounds_floor, args.bounds_frac, failures)
    check_condense(load(args.baseline, "condense.quick.json"),
                   load(args.current, "condense.quick.json"),
                   args.condense_floor, args.condense_frac, failures)
    check_condensed_kernel(load(args.baseline, "condense.quick.json"),
                           load(args.current, "condense.quick.json"),
                           args.kernel_min_wins, args.kernel_frac,
                           failures)
    check_mesh(load(args.baseline, "mesh.quick.json"),
               load(args.current, "mesh.quick.json"),
               args.mesh_floor, args.mesh_eff, args.mesh_frac, failures)
    check_load(load(args.baseline, "load.quick.json"),
               load(args.current, "load.quick.json"),
               args.load_p99, args.load_p99_frac, failures)
    check_chaos(load(args.baseline, "chaos.quick.json"),
                load(args.current, "chaos.quick.json"),
                args.chaos_recovery, failures)

    if failures:
        print("REGRESSION GATE FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("regression gate passed (accuracy exact, cache hit rate held, "
          "campaign + service speedups held, fuzz differential clean, "
          "certification speedup held, bounds exact + still seeding, "
          "condensation exact + still paying, "
          "fused kernel exact + winning its rungs, "
          "mesh sharding exact + scaling, load SLOs + overload shed held, "
          "chaos identity + bounded recovery held)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
