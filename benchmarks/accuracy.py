"""Table II analogue: trace-based simulator accuracy vs the cycle-accurate
DES oracle (our RTL co-simulation stand-in), per design.

The paper reports LightningSim within one cycle of co-simulation on 20/21
designs; our trace evaluator implements the same timing contract as the
DES, so the expected diff is exactly 0 — any nonzero diff is a bug.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import Timer, design_set, save_json
from repro.core import build_simgraph, simulate
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design


def run() -> Dict:
    rows = []
    rng = np.random.default_rng(0)
    for name in design_set():
        d = make_design(name)
        g = build_simgraph(d)
        ev = BatchedEvaluator(g)
        u = g.upper_bounds
        cfgs = [u] + [rng.integers(2, np.maximum(3, u + 1))
                      for _ in range(2)]
        max_diff = 0
        cosim_cycles = trace_cycles = None
        t_cosim = t_trace = 0.0
        for i, cfg in enumerate(cfgs):
            with Timer() as tc:
                r = simulate(d, cfg)
            with Timer() as tt:
                lat, _, dead = ev.evaluate(np.asarray(cfg)[None, :])
            t_cosim += tc.s
            t_trace += tt.s
            if not r.deadlocked:
                max_diff = max(max_diff, abs(r.latency - int(lat[0])))
            if i == 0:
                cosim_cycles, trace_cycles = r.latency, int(lat[0])
        rows.append(dict(design=name, fifos=g.n_fifos, events=g.n_events,
                         cosim=cosim_cycles, lightningsim=trace_cycles,
                         max_abs_diff=max_diff,
                         cosim_s=round(t_cosim / len(cfgs), 4),
                         trace_ms=round(1000 * t_trace / len(cfgs), 3)))
    out = {"table": rows,
           "all_exact": all(r["max_abs_diff"] == 0 for r in rows)}
    save_json("accuracy.json", out)
    return out


def main():
    out = run()
    print(f"{'design':28s} {'FIFOs':>5} {'cosim':>9} {'trace':>9} diff")
    for r in out["table"]:
        mark = "ok" if r["max_abs_diff"] == 0 else f"+{r['max_abs_diff']}"
        print(f"{r['design']:28s} {r['fifos']:5d} {r['cosim']:9d} "
              f"{r['lightningsim']:9d} {mark}")
    print("all exact:", out["all_exact"])


if __name__ == "__main__":
    main()
