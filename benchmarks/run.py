"""Run every benchmark; print one ``name,seconds,derived`` CSV line each.

  PYTHONPATH=src python -m benchmarks.run            # fast budgets
  FULL=1 PYTHONPATH=src python -m benchmarks.run     # paper budgets
"""

from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (accuracy, batched_eval, cache_lookup, campaign,
                            case_study, condense, convergence, fuzz,
                            improvement, pareto_fronts, pruning, roofline,
                            runtime, service)

    print("name,seconds,derived")

    t0 = time.perf_counter()
    acc = accuracy.run()
    print(f"accuracy,{time.perf_counter() - t0:.2f},"
          f"all_exact={acc['all_exact']}")

    t0 = time.perf_counter()
    imp = improvement.run()
    gsa = imp["summary"].get("grouped_sa", {})
    print(f"improvement,{time.perf_counter() - t0:.2f},"
          f"grouped_sa_lat_vs_max={gsa.get('geomean_lat_vs_max'):.4f};"
          f"bram_red={gsa.get('mean_bram_red'):.3f};"
          f"undeadlocked={gsa.get('undeadlocked')}")

    t0 = time.perf_counter()
    rt = runtime.run()
    g = rt["summary"]["grouped_sa"]
    print(f"runtime,{time.perf_counter() - t0:.2f},"
          f"grouped_sa_vs_des={g['geomean_speedup_vs_des']:.1f}x;"
          f"vs_rtl_slow={g['geomean_speedup_vs_rtl_slow']:.0f}x")

    t0 = time.perf_counter()
    pf = pareto_fronts.run()
    print(f"pareto_fronts,{time.perf_counter() - t0:.2f},"
          f"designs={len(pf)}")

    t0 = time.perf_counter()
    cv = convergence.run()
    print(f"convergence,{time.perf_counter() - t0:.2f},"
          f"final_grouped_sa={cv['curves']['grouped_sa']['final']}")

    t0 = time.perf_counter()
    cs = case_study.run()
    print(f"case_study,{time.perf_counter() - t0:.2f},"
          f"msg_depths={cs['min_feasible_msg_depth_by_graph']}")

    t0 = time.perf_counter()
    be = batched_eval.run()
    n_us = be["gemm"]["numpy"]["us_per_config"]
    print(f"batched_eval,{time.perf_counter() - t0:.2f},"
          f"gemm_numpy_us_per_cfg={n_us}")

    t0 = time.perf_counter()
    cp = campaign.run()
    print(f"campaign,{time.perf_counter() - t0:.2f},"
          f"speedup_vs_seq={cp['campaign_speedup']:.2f}x;"
          f"identical_frontiers={cp['identical_frontiers']}")

    t0 = time.perf_counter()
    sv = service.run()
    print(f"service,{time.perf_counter() - t0:.2f},"
          f"speedup_vs_solo={sv['service_speedup']:.2f}x;"
          f"identical_frontiers={sv['identical_frontiers']}")

    t0 = time.perf_counter()
    cd = condense.run()
    print(f"condense,{time.perf_counter() - t0:.2f},"
          f"scan_speedup={cd['geomean_speedup_scan']:.2f}x;"
          f"ratio={cd['geomean_condensation_ratio']:.1f}x;"
          f"identical={cd['identical_all']}")

    t0 = time.perf_counter()
    cl = cache_lookup.run()
    print(f"cache_lookup,{time.perf_counter() - t0:.2f},"
          f"c1024_speedup={cl['batch'][-1]['speedup']:.2f}x")

    t0 = time.perf_counter()
    fz = fuzz.run()
    print(f"fuzz,{time.perf_counter() - t0:.2f},"
          f"zero_mismatches={fz['differential']['zero_mismatches']};"
          f"cert_speedup={fz['cert_geomean_speedup']:.2f}x")

    t0 = time.perf_counter()
    pr = pruning.run()
    k = pr["k15mmtree"]
    print(f"pruning,{time.perf_counter() - t0:.2f},"
          f"k15mmtree_random_dead:{k['random_raw']['dead']}->"
          f"{k['random_pruned']['dead']}")

    t0 = time.perf_counter()
    rows = roofline.load()
    if rows:
        picks = roofline.pick_hillclimb_cells(rows)
        rep = picks["paper_representative"]
        print(f"roofline,{time.perf_counter() - t0:.2f},"
              f"cells={len(rows)};rep={rep['arch']}x{rep['shape']}")
    else:
        print(f"roofline,{time.perf_counter() - t0:.2f},no_dryrun_records")


if __name__ == "__main__":
    main()
