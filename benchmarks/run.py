"""Run every benchmark; print one ``name,seconds,derived`` CSV line each.

  PYTHONPATH=src python -m benchmarks.run              # fast budgets
  FULL=1 PYTHONPATH=src python -m benchmarks.run       # paper budgets
  PYTHONPATH=src python -m benchmarks.run --only mesh  # just one
  PYTHONPATH=src python -m benchmarks.run --list       # show names

``--only`` may be repeated (or comma-separated) to run a subset in the
canonical order; unknown names fail fast with the available list.
"""

from __future__ import annotations

import argparse
import sys
import time


def _accuracy() -> str:
    from benchmarks import accuracy
    acc = accuracy.run()
    return f"all_exact={acc['all_exact']}"


def _improvement() -> str:
    from benchmarks import improvement
    gsa = improvement.run()["summary"].get("grouped_sa", {})
    return (f"grouped_sa_lat_vs_max={gsa.get('geomean_lat_vs_max'):.4f};"
            f"bram_red={gsa.get('mean_bram_red'):.3f};"
            f"undeadlocked={gsa.get('undeadlocked')}")


def _runtime() -> str:
    from benchmarks import runtime
    g = runtime.run()["summary"]["grouped_sa"]
    return (f"grouped_sa_vs_des={g['geomean_speedup_vs_des']:.1f}x;"
            f"vs_rtl_slow={g['geomean_speedup_vs_rtl_slow']:.0f}x")


def _pareto_fronts() -> str:
    from benchmarks import pareto_fronts
    return f"designs={len(pareto_fronts.run())}"


def _convergence() -> str:
    from benchmarks import convergence
    cv = convergence.run()
    return f"final_grouped_sa={cv['curves']['grouped_sa']['final']}"


def _case_study() -> str:
    from benchmarks import case_study
    cs = case_study.run()
    return f"msg_depths={cs['min_feasible_msg_depth_by_graph']}"


def _batched_eval() -> str:
    from benchmarks import batched_eval
    be = batched_eval.run()
    return f"gemm_numpy_us_per_cfg={be['gemm']['numpy']['us_per_config']}"


def _campaign() -> str:
    from benchmarks import campaign
    cp = campaign.run()
    return (f"speedup_vs_seq={cp['campaign_speedup']:.2f}x;"
            f"identical_frontiers={cp['identical_frontiers']}")


def _service() -> str:
    from benchmarks import service
    sv = service.run()
    return (f"speedup_vs_solo={sv['service_speedup']:.2f}x;"
            f"identical_frontiers={sv['identical_frontiers']}")


def _condense() -> str:
    from benchmarks import condense
    cd = condense.run()
    return (f"scan_speedup={cd['geomean_speedup_scan']:.2f}x;"
            f"ratio={cd['geomean_condensation_ratio']:.1f}x;"
            f"identical={cd['identical_all']}")


def _mesh() -> str:
    from benchmarks import mesh
    ms = mesh.run()
    return (f"speedup_8v1={ms['geomean_speedup_8v1']:.2f}x;"
            f"cores={ms['usable_cores']};"
            f"identical={ms['identical_all']}")


def _cache_lookup() -> str:
    from benchmarks import cache_lookup
    cl = cache_lookup.run()
    return f"c1024_speedup={cl['batch'][-1]['speedup']:.2f}x"


def _fuzz() -> str:
    from benchmarks import fuzz
    fz = fuzz.run()
    return (f"zero_mismatches={fz['differential']['zero_mismatches']};"
            f"cert_speedup={fz['cert_geomean_speedup']:.2f}x")


def _bounds() -> str:
    from benchmarks import bounds
    bd = bounds.run()
    return (f"probe_reduction={bd['probe_reduction_geomean']:.2f}x;"
            f"identical={bd['identical_depths_all']};"
            f"bracket={bd['bracket_all']}")


def _load() -> str:
    from benchmarks import load
    ld = load.run()
    s, o = ld["steady"], ld["overload"]
    return (f"p99_s={s['p99_s']};throughput={s['throughput_per_s']}/s;"
            f"shed={o['rejected']};cap_respected={o['cap_respected']}")


def _chaos() -> str:
    from benchmarks import chaos
    ch = chaos.run()
    pc, sf = ch["pool_crash"], ch["service_faults"]
    return (f"pool_identical={pc['identical_frontiers']};"
            f"respawns={pc['respawns']};"
            f"quarantine_exact="
            f"{ch['snapshot_corruption']['quarantined_only_damaged']};"
            f"resume_identical={ch['kill_resume']['identical_frontiers']};"
            f"timeout_isolated={sf['peer_identical']}")


def _pruning() -> str:
    from benchmarks import pruning
    k = pruning.run()["k15mmtree"]
    return (f"k15mmtree_random_dead:{k['random_raw']['dead']}->"
            f"{k['random_pruned']['dead']}")


def _roofline() -> str:
    from benchmarks import roofline
    rows = roofline.load()
    if not rows:
        return "no_dryrun_records"
    rep = roofline.pick_hillclimb_cells(rows)["paper_representative"]
    return f"cells={len(rows)};rep={rep['arch']}x{rep['shape']}"


#: canonical order — ``--only`` subsets preserve it
STEPS = [
    ("accuracy", _accuracy),
    ("improvement", _improvement),
    ("runtime", _runtime),
    ("pareto_fronts", _pareto_fronts),
    ("convergence", _convergence),
    ("case_study", _case_study),
    ("batched_eval", _batched_eval),
    ("campaign", _campaign),
    ("service", _service),
    ("condense", _condense),
    ("mesh", _mesh),
    ("cache_lookup", _cache_lookup),
    ("load", _load),
    ("fuzz", _fuzz),
    ("bounds", _bounds),
    ("chaos", _chaos),
    ("pruning", _pruning),
    ("roofline", _roofline),
]


def parse_args(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Run the benchmark suite (QUICK=1 / FULL=1 envs "
                    "select budgets).")
    p.add_argument("--only", action="append", default=None,
                   metavar="NAME",
                   help="run only this benchmark (repeatable, or "
                        "comma-separated); order stays canonical")
    p.add_argument("--list", action="store_true",
                   help="print benchmark names and exit")
    return p.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    names = [n for n, _ in STEPS]
    if args.list:
        print("\n".join(names))
        return 0
    selected = None
    if args.only:
        selected = [n.strip() for arg in args.only
                    for n in arg.split(",") if n.strip()]
        unknown = sorted(set(selected) - set(names))
        if unknown:
            print(f"unknown benchmark(s): {', '.join(unknown)}; "
                  f"available: {', '.join(names)}", file=sys.stderr)
            return 2
    print("name,seconds,derived")
    for name, fn in STEPS:
        if selected is not None and name not in selected:
            continue
        t0 = time.perf_counter()
        derived = fn()
        print(f"{name},{time.perf_counter() - t0:.2f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
