"""Service throughput: concurrent sessions vs sequential solo runs.

Measures the same client workload two ways:

``service``   one :class:`AdvisoryService` serving N sessions at once —
              shared design registry (trace once per design), shared
              per-design caches, cross-session merge/dedup of each
              round's evaluation rows
``solo``      the status quo an advisory service replaces: each client
              runs its own ``FifoAdvisor(design).run(optimizer)`` —
              fresh trace, fresh cache, one at a time

Per-session results must be BIT-IDENTICAL between the two modes
(asserted: configs, latencies, frontiers, hypervolumes); the service
only reroutes evaluation, it never changes what a client gets back.
Budget accounting ``n_evals`` counts cache misses and therefore shrinks
under sharing — it is reported, not compared.

Timing protocol (same as ``benchmarks/campaign.py``): every repeat
measures both modes back-to-back, the order alternates between repeats,
the speedup is computed per repeat (same-window ratio), and the reported
number is the median across repeats — shared CI hosts are noisy.

Session mix: row-count-budgeted optimizers only (random/SA families),
so trajectories are independent of cache hit/miss history and both
modes provably walk identical searches.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import budget, design_set, full_mode, save_json

OPTIMIZERS = ("grouped_sa", "grouped_random")


def session_mix(designs: List[str]) -> List[Tuple[str, str, int]]:
    """N = len(designs) x len(OPTIMIZERS) sessions, seeds staggered so
    no two sessions are identical twins."""
    return [(d, o, si)
            for si, d in enumerate(designs) for o in OPTIMIZERS]


def _frontier_key(d, o, s):
    return f"{d}:{o}:s{s}"


def run_service(mix, bdg, progress: bool) -> Dict:
    from repro.core.service import AdvisoryService
    t0 = time.perf_counter()
    with AdvisoryService(progress_events=progress) as svc:
        sids = [svc.open_session(d, optimizer=o, budget=bdg, seed=s).id
                for d, o, s in mix]
        svc.run_until_idle()
        results = {_frontier_key(*spec): svc.result(sid)
                   for sid, spec in zip(sids, mix)}
        wall = time.perf_counter() - t0
        return {"wall_s": wall, "results": results,
                "rounds": svc.batcher.rounds,
                "n_evals": sum(r.result.n_evals
                               for r in results.values())}


def run_solo(mix, bdg) -> Dict:
    from repro.core import FifoAdvisor
    from repro.designs import make_design
    t0 = time.perf_counter()
    results = {}
    for d, o, s in mix:
        adv = FifoAdvisor(make_design(d))
        results[_frontier_key(d, o, s)] = adv.run(o, budget=bdg, seed=s)
    return {"wall_s": time.perf_counter() - t0, "results": results,
            "n_evals": sum(r.result.n_evals for r in results.values())}


def assert_identical(a: Dict, b: Dict) -> None:
    assert set(a) == set(b)
    for k in a:
        ra, rb = a[k], b[k]
        assert np.array_equal(ra.result.configs, rb.result.configs), k
        assert np.array_equal(ra.result.latency, rb.result.latency), k
        assert np.array_equal(ra.frontier_points, rb.frontier_points), k
        assert ra.hypervolume() == rb.hypervolume(), k


def run(repeats: int = 3) -> Dict:
    designs = design_set()
    if not full_mode():
        designs = designs[:2]   # 2 designs x 2 optimizers = 4 sessions
    bdg = budget()
    mix = session_mix(designs)

    modes = {
        "service": lambda: run_service(mix, bdg, progress=True),
        "solo": lambda: run_solo(mix, bdg),
    }
    order = list(modes)
    walls: Dict[str, list] = {m: [] for m in modes}
    reference = None
    for rep in range(repeats):
        seq = order if rep % 2 == 0 else order[::-1]
        for mode in seq:
            out = modes[mode]()
            walls[mode].append(out["wall_s"])
            if reference is None:
                reference = out
            else:
                assert_identical(out["results"], reference["results"])

    ratios = [ws / wb for ws, wb in zip(walls["solo"], walls["service"])]
    speedup = float(np.median(ratios))

    summary = {
        "designs": list(designs),
        "optimizers": list(OPTIMIZERS),
        "budget": bdg,
        "n_sessions": len(mix),
        "repeats": repeats,
        "wall_s": {m: [round(w, 3) for w in ws]
                   for m, ws in walls.items()},
        "median_wall_s": {m: round(float(np.median(ws)), 3)
                          for m, ws in walls.items()},
        "per_repeat_speedup": [round(r, 3) for r in ratios],
        "service_speedup": round(speedup, 3),
        "identical_frontiers": True,   # asserted above
        "hypervolumes": {k: float(v.hypervolume())
                         for k, v in reference["results"].items()},
    }
    save_json("service.json", summary)
    return summary


def main():
    out = run()
    print(f"service benchmark: {out['n_sessions']} concurrent sessions "
          f"({len(out['designs'])} designs x "
          f"{len(out['optimizers'])} optimizers, budget "
          f"{out['budget']}), {out['repeats']} repeats\n")
    for mode, med in out["median_wall_s"].items():
        print(f"  {mode:8s} median {med:7.2f}s   runs "
              f"{out['wall_s'][mode]}")
    print(f"\n  per-session results bit-identical to solo runs: "
          f"{out['identical_frontiers']}")
    print(f"  per-repeat speedups: {out['per_repeat_speedup']}")
    print(f"  headline service_speedup: {out['service_speedup']:.2f}x")


if __name__ == "__main__":
    main()
