"""Mesh-sharded evaluation benchmark: configs/sec vs shard count.

Measures, per design, batched-evaluation throughput of the sharded scan
backend (``backend="mesh"``, docs/mesh.md) at 1/2/4/8 shards of an
8-device host-platform CPU mesh, against the solo jit fixpoint —
asserting bit-identical results at every shard count.

Device count is fixed at jax backend initialization, so this benchmark
needs ``--xla_force_host_platform_device_count=8`` set before jax's
first computation.  Run standalone it arranges that itself; invoked from
``benchmarks.run`` (where earlier benchmarks already initialized jax on
1 device) it re-execs itself in a subprocess with the flag set.

Scaling expectations are host-dependent: host-platform devices are
threads, so wall-clock speedup is bounded by real cores.  The recorded
``usable_cores`` lets ``check_regression.py``'s ``check_mesh`` gate
scale its expectation (~0.375 x min(shards, cores), i.e. the ISSUE's
3x-at-8-devices criterion wherever 8 cores exist).  Even at 1 core the
8-shard split beats 1-shard: each shard's vmapped fixpoint retires when
its OWN slowest row converges instead of the global worst case.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

if "jax" not in sys.modules:     # standalone: arm the flag pre-import
    from repro.launch.mesh import ensure_host_platform_devices
    ensure_host_platform_devices(8)

import numpy as np

from benchmarks.common import (RESULTS_DIR, Timer, geomean, quick_mode,
                               save_json)

SHARD_COUNTS = (1, 2, 4, 8)
MAX_SHARDS = SHARD_COUNTS[-1]
#: scaling shape is design-independent (pure row partitioning), so the
#: quick and full sets coincide — two designs of very different size
DESIGNS = ["gemm", "FeedForward"]


def _configs(g, C: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = g.upper_bounds
    return np.stack([np.maximum(
        2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
        for _ in range(C)])


def _bench(ev, cfgs, reps: int):
    ev.evaluate(cfgs[:2])                 # warm / compile
    ev.evaluate(cfgs)                     # warm the batch bucket
    best, result = float("inf"), None
    for _ in range(reps):
        with Timer() as t:
            result = ev.evaluate(cfgs)
        best = min(best, t.s)
    return best, result


def _measure(seed: int = 0) -> Dict:
    from repro.core import EvalConfig, build_simgraph
    from repro.core.simulate import BatchedEvaluator
    from repro.designs import make_design

    C = 64 if quick_mode() else 256
    reps = 2 if quick_mode() else 3
    out: Dict = {"designs": {}, "batch": C,
                 "max_shards": MAX_SHARDS,
                 "usable_cores": os.cpu_count() or 1}
    speedups = []
    identical_all = True
    for name in DESIGNS:
        g = build_simgraph(make_design(name))
        cfgs = _configs(g, C, seed)
        # condensation off isolates the sharded evaluator itself (the
        # cascade rungs shard identically via spawn())
        t_solo, r_solo = _bench(
            BatchedEvaluator(
                g, EvalConfig(backend="jax", max_iters=64,
                              condense=None)), cfgs, reps)
        row: Dict = {"solo_us_per_config": round(1e6 * t_solo / C, 1),
                     "shards": {}}
        t_by_shards = {}
        for s in SHARD_COUNTS:
            t_s, r_s = _bench(
                BatchedEvaluator(g, EvalConfig(backend="mesh", max_iters=64,
                                               shards=s),
                                 condense=None), cfgs, reps)
            identical = all((a == b).all() for a, b in zip(r_solo, r_s))
            identical_all &= identical
            t_by_shards[s] = t_s
            row["shards"][str(s)] = dict(
                us_per_config=round(1e6 * t_s / C, 1),
                configs_per_s=round(C / t_s, 1),
                identical=identical)
        # production-path identity too: full cascade, sharded vs solo
        ev_m = BatchedEvaluator(
            g, EvalConfig(backend="mesh", max_iters=64,
                          shards=MAX_SHARDS))
        ev_j = BatchedEvaluator(g, EvalConfig(backend="jax", max_iters=64))
        identical = all((a == b).all() for a, b in
                        zip(ev_j.evaluate(cfgs), ev_m.evaluate(cfgs)))
        identical_all &= identical
        row["cascade_identical"] = identical
        speedup = t_by_shards[1] / max(t_by_shards[MAX_SHARDS], 1e-12)
        row["speedup_8v1"] = round(speedup, 2)
        speedups.append(speedup)
        out["designs"][name] = row
    out["geomean_speedup_8v1"] = round(geomean(speedups), 2)
    out["identical_all"] = bool(identical_all)
    return out


def run(seed: int = 0) -> Dict:
    """Measure (re-execing under an 8-device mesh if needed) and save."""
    import jax
    if jax.device_count() < MAX_SHARDS:
        # jax already initialized on fewer devices (benchmarks.run
        # imports it long before us): measure in a fresh process
        env = dict(os.environ)
        flag = f"--xla_force_host_platform_device_count={MAX_SHARDS}"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.mesh"],
            env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"mesh benchmark subprocess failed:\n{proc.stderr}")
        name = "mesh.quick.json" if quick_mode() else "mesh.json"
        with open(os.path.join(RESULTS_DIR, name)) as f:
            return json.load(f)
    out = _measure(seed)
    save_json("mesh.json", out)
    return out


def main():
    out = run()
    for name, d in out["designs"].items():
        cols = "  ".join(f"s{s}={v['configs_per_s']:.0f}/s"
                         for s, v in d["shards"].items())
        print(f"{name:14s} solo={d['solo_us_per_config']}us {cols} "
              f"8v1={d['speedup_8v1']}x "
              f"identical={d['cascade_identical']}")
    print(f"geomean 8v1 speedup {out['geomean_speedup_8v1']}x on "
          f"{out['usable_cores']} core(s), "
          f"identical={out['identical_all']}")


if __name__ == "__main__":
    main()
