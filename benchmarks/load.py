"""Service load harness: open-loop arrivals, latency SLOs, overload shed.

Two phases against an in-process :class:`AdvisoryService` (the same
engine the TCP/stdio server fronts — ``repro.launch.serve`` adds only
transport):

``steady``    an *open-loop* arrival process: session open times are
              drawn up front from a seeded Poisson process and never
              react to completions (closed-loop harnesses hide overload
              by slowing the clients down — the classic coordinated-
              omission trap).  Each session's latency is measured from
              its *scheduled* arrival to observed completion, so queue
              buildup is charged to the service, not forgiven.  Reports
              p50/p99 latency and sustained throughput.

``overload``  a burst of opens against a small ``max_sessions`` cap.
              The service must shed with ``E_OVERLOADED`` + a positive
              ``retry_after_s`` hint (never queue invisibly), keep
              running sessions at or under the cap, and recover: every
              shed client retries per the hint and eventually finishes.

The SLO gate (``check_load`` in ``benchmarks/check_regression.py``)
holds p99 under a hard ceiling and overload behavior exact.

Transport faults (a server hard-closing a connection mid-stream, e.g.
under a ``drop_conn`` :class:`~repro.core.faults.FaultPlan`) are
*recorded*, never fatal: a ``ConnectionResetError``/``BrokenPipeError``
on a session interaction counts that session as dropped (``conn_drops``
in the report) and the harness keeps driving the rest — a load harness
that dies on the first reset cannot measure behavior under faults.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Timer, full_mode, quick_mode, save_json

OPTIMIZERS = ("grouped_sa", "grouped_random")


def _params() -> Dict:
    if quick_mode():
        return dict(n_sessions=60, budget=12, rate_per_s=40.0)
    if full_mode():
        return dict(n_sessions=400, budget=60, rate_per_s=60.0)
    return dict(n_sessions=150, budget=30, rate_per_s=50.0)


#: transport-level failures a load harness must survive, not die on
CONN_ERRORS = (ConnectionResetError, BrokenPipeError)


def _mix(n: int, seed: int) -> List[tuple]:
    """(design, optimizer, seed) per session, cycled over the quick set."""
    from repro.designs import QUICK_DESIGNS
    designs = sorted(QUICK_DESIGNS)
    rng = np.random.default_rng(seed)
    return [(designs[i % len(designs)], OPTIMIZERS[i % len(OPTIMIZERS)],
             int(rng.integers(0, 1 << 16))) for i in range(n)]


def steady_phase(seed: int = 0) -> Dict:
    from repro.core.service import AdvisoryService

    p = _params()
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / p["rate_per_s"],
                                         p["n_sessions"]))
    mix = _mix(p["n_sessions"], seed)

    done_at: Dict[str, float] = {}
    sched: Dict[str, float] = {}
    conn_drops = 0
    with AdvisoryService(progress_events=False) as svc:
        for d in sorted({m[0] for m in mix}):
            svc.registry.register(d)        # trace cost off the clock
        with Timer() as t:
            nxt = 0
            while len(done_at) + conn_drops < p["n_sessions"]:
                now = time.perf_counter() - t.t0
                # open-loop: admit every arrival whose time has come,
                # regardless of how far behind the service is
                while nxt < p["n_sessions"] and arrivals[nxt] <= now:
                    d, o, s = mix[nxt]
                    try:
                        sess = svc.open_session(d, optimizer=o,
                                                budget=p["budget"],
                                                seed=s)
                        sched[sess.id] = float(arrivals[nxt])
                    except CONN_ERRORS:
                        conn_drops += 1     # dropped, not fatal
                    nxt += 1
                try:
                    advanced = svc.step()
                except CONN_ERRORS:
                    conn_drops += 1
                    advanced = 1            # keep driving the rest
                if not advanced and nxt < p["n_sessions"]:
                    time.sleep(max(0.0, arrivals[nxt] - (
                        time.perf_counter() - t.t0)))
                now = time.perf_counter() - t.t0
                for sid in list(sched):
                    if svc.session(sid).done and sid not in done_at:
                        done_at[sid] = now
        lat = np.array([done_at[sid] - sched[sid] for sid in done_at])
        stats = svc.stats()
    return {
        "n_sessions": p["n_sessions"], "budget": p["budget"],
        "offered_rate_per_s": p["rate_per_s"],
        "wall_s": round(t.s, 3),
        "throughput_per_s": round(p["n_sessions"] / t.s, 2),
        "p50_s": round(float(np.percentile(lat, 50)), 4),
        "p99_s": round(float(np.percentile(lat, 99)), 4),
        "max_s": round(float(lat.max()), 4),
        "rounds": stats["batcher"]["rounds"],
        "conn_drops": conn_drops,
        "all_completed": len(done_at) + conn_drops == p["n_sessions"],
    }


def overload_phase(seed: int = 1) -> Dict:
    from repro.core.service import AdvisoryService, ServiceOverloaded

    cap = 8
    n_burst = 5 * cap
    mix = _mix(n_burst, seed)
    rejected = 0
    conn_drops = 0
    retry_hints: List[float] = []
    max_running = 0
    with AdvisoryService(progress_events=False, max_sessions=cap) as svc:
        for d in sorted({m[0] for m in mix}):
            svc.registry.register(d)
        pending = list(mix)
        with Timer() as t:
            while pending or svc.running:
                admitted = []
                for spec in pending:
                    d, o, s = spec
                    try:
                        svc.open_session(d, optimizer=o, budget=12, seed=s)
                        admitted.append(spec)
                    except ServiceOverloaded as exc:
                        rejected += 1
                        retry_hints.append(exc.retry_after_s)
                        break          # back off until the hinted retry
                    except CONN_ERRORS:
                        conn_drops += 1      # dropped, not fatal
                        admitted.append(spec)
                for spec in admitted:
                    pending.remove(spec)
                max_running = max(max_running, len(svc.running))
                try:
                    svc.step()
                except CONN_ERRORS:
                    conn_drops += 1
        stats = svc.stats()
    return {
        "max_sessions": cap, "burst": n_burst,
        "wall_s": round(t.s, 3),
        "rejected": rejected,
        "conn_drops": conn_drops,
        "rejected_counter": stats["rejected"],
        "max_running_observed": max_running,
        "cap_respected": max_running <= cap,
        "min_retry_after_s": round(min(retry_hints), 5) if retry_hints
        else None,
        "all_completed": stats["n_sessions"] + conn_drops == n_burst,
        "shed_and_recovered": bool(rejected and stats["n_sessions"]
                                   + conn_drops == n_burst),
    }


def run(seed: int = 0) -> Dict:
    out = {"steady": steady_phase(seed),
           "overload": overload_phase(seed + 1)}
    save_json("load.json", out)
    return out


def main():
    out = run()
    s, o = out["steady"], out["overload"]
    print(f"steady: {s['n_sessions']} sessions @ "
          f"{s['offered_rate_per_s']:.0f}/s offered -> "
          f"{s['throughput_per_s']:.1f}/s served, "
          f"p50={s['p50_s'] * 1e3:.1f}ms p99={s['p99_s'] * 1e3:.1f}ms")
    print(f"overload: burst {o['burst']} vs cap {o['max_sessions']}: "
          f"{o['rejected']} shed (retry_after>="
          f"{o['min_retry_after_s']}s), max_running="
          f"{o['max_running_observed']}, recovered={o['all_completed']}")


if __name__ == "__main__":
    main()
