"""Campaign throughput vs the sequential per-(design, optimizer) loop.

Measures the same workload — every design x optimizer pair at the same
budget/seed — three ways:

``campaign_pool``    the campaign engine, parallel worklist workers
``campaign_inline``  the campaign engine, single-process evaluation
``seq_fresh``        the status quo this PR replaces: one
                     ``FifoAdvisor(design).run(optimizer)`` at a time
                     (fresh advisor per pair — no shared trace, no shared
                     cache)
``seq_shared``       a stronger hand-rolled loop: one advisor per design
                     reused across optimizers (shared trace + cache)

All modes must produce IDENTICAL per-task frontiers (asserted) — the
campaign only reroutes evaluation, it never changes results.

Timing protocol: the host may be noisy, so every repeat measures all
modes back-to-back, the order alternates between repeats, speedups are
computed per repeat (same-window ratio), and the reported number is the
median across repeats.

Optimizer set: row-count-budgeted optimizers only, so budget accounting
(and therefore the search trajectory) is independent of cache hit/miss
history and every mode provably walks the same trajectory.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import budget, design_set, full_mode, save_json

OPTIMIZERS = ("grouped_sa", "grouped_random", "sa", "random")


def _campaign(designs, opts, bdg, workers: int) -> Dict:
    from repro.core.campaign import Campaign, CampaignSpec
    spec = CampaignSpec(designs=tuple(designs), optimizers=tuple(opts),
                        budget=bdg, seed=0, workers=workers)
    t0 = time.perf_counter()
    store = Campaign(spec).run()
    wall = time.perf_counter() - t0
    return {"wall_s": wall,
            "frontiers": {k: store[k].frontier_points
                          for k in store.keys()},
            "hypervolumes": store.hypervolumes(),
            "n_evals": store.total_evals()}


def _sequential(designs, opts, bdg, shared: bool) -> Dict:
    from repro.core import FifoAdvisor
    from repro.designs import make_design
    t0 = time.perf_counter()
    frontiers, hvs, n_evals = {}, {}, 0
    if shared:
        for d in designs:
            adv = FifoAdvisor(make_design(d))
            for o in opts:
                r = adv.run(o, budget=bdg, seed=0)
                frontiers[f"{d}:{o}:s0"] = r.frontier_points
                hvs[f"{d}:{o}:s0"] = r.hypervolume()
                n_evals += r.result.n_evals
    else:
        for d in designs:
            for o in opts:
                adv = FifoAdvisor(make_design(d))
                r = adv.run(o, budget=bdg, seed=0)
                frontiers[f"{d}:{o}:s0"] = r.frontier_points
                hvs[f"{d}:{o}:s0"] = r.hypervolume()
                n_evals += r.result.n_evals
    return {"wall_s": time.perf_counter() - t0, "frontiers": frontiers,
            "hypervolumes": hvs, "n_evals": n_evals}


def run(repeats: int = 3) -> Dict:
    from repro.core.campaign import default_workers
    designs = design_set()
    if not full_mode():
        designs = designs[:4]   # campaigns over the full set take long
    bdg = budget()
    workers = default_workers()

    modes = {
        "campaign_pool": lambda: _campaign(designs, OPTIMIZERS, bdg,
                                           workers),
        "campaign_inline": lambda: _campaign(designs, OPTIMIZERS, bdg, 0),
        "seq_fresh": lambda: _sequential(designs, OPTIMIZERS, bdg,
                                         shared=False),
        "seq_shared": lambda: _sequential(designs, OPTIMIZERS, bdg,
                                          shared=True),
    }
    order = list(modes)
    walls: Dict[str, list] = {m: [] for m in modes}
    reference = None
    for rep in range(repeats):
        # alternate order so slow host periods hit every mode equally
        seq = order if rep % 2 == 0 else order[::-1]
        for mode in seq:
            out = modes[mode]()
            walls[mode].append(out["wall_s"])
            if reference is None:
                reference = out
            else:
                assert set(out["frontiers"]) == set(
                    reference["frontiers"])
                for k, pts in out["frontiers"].items():
                    assert np.array_equal(pts, reference["frontiers"][k]), \
                        f"frontier mismatch in {mode} for {k}"

    def median(xs):
        return float(np.median(xs))

    # per-repeat same-window ratios, then the median ratio
    def ratio(a: str, b: str):
        return median([wa / wb for wa, wb in zip(walls[a], walls[b])])

    summary = {
        "designs": list(designs),
        "optimizers": list(OPTIMIZERS),
        "budget": bdg,
        "workers": workers,
        "repeats": repeats,
        "n_tasks": len(designs) * len(OPTIMIZERS),
        "wall_s": {m: [round(w, 3) for w in ws]
                   for m, ws in walls.items()},
        "median_wall_s": {m: round(median(ws), 3)
                          for m, ws in walls.items()},
        "speedup_pool_vs_seq_fresh": round(
            ratio("seq_fresh", "campaign_pool"), 3),
        "speedup_inline_vs_seq_fresh": round(
            ratio("seq_fresh", "campaign_inline"), 3),
        "speedup_pool_vs_seq_shared": round(
            ratio("seq_shared", "campaign_pool"), 3),
        "speedup_inline_vs_seq_shared": round(
            ratio("seq_shared", "campaign_inline"), 3),
        "identical_frontiers": True,   # asserted above
        "hypervolumes": {k: float(v) for k, v in
                         reference["hypervolumes"].items()},
    }
    summary["campaign_speedup"] = max(
        summary["speedup_pool_vs_seq_fresh"],
        summary["speedup_inline_vs_seq_fresh"])
    save_json("campaign.json", summary)
    return summary


def main():
    out = run()
    print(f"campaign benchmark: {out['n_tasks']} tasks "
          f"({len(out['designs'])} designs x "
          f"{len(out['optimizers'])} optimizers, budget "
          f"{out['budget']}), {out['repeats']} repeats\n")
    for mode, med in out["median_wall_s"].items():
        print(f"  {mode:18s} median {med:7.2f}s   runs "
              f"{out['wall_s'][mode]}")
    print(f"\n  identical per-task frontiers across all modes: "
          f"{out['identical_frontiers']}")
    print(f"  campaign vs sequential per-pair loop:  "
          f"pooled {out['speedup_pool_vs_seq_fresh']:.2f}x   "
          f"inline {out['speedup_inline_vs_seq_fresh']:.2f}x")
    print(f"  campaign vs shared-advisor loop:       "
          f"pooled {out['speedup_pool_vs_seq_shared']:.2f}x   "
          f"inline {out['speedup_inline_vs_seq_shared']:.2f}x")
    print(f"  headline campaign_speedup: {out['campaign_speedup']:.2f}x")


if __name__ == "__main__":
    main()
