"""Beyond-paper: evaluator backend throughput (the paper's '<1 ms amortized'
incremental-simulation claim, plus our batched formulations).

numpy  — event-driven worklist (the paper's CPU execution model)
jax    — vmapped Jacobi + segmented-scan fixpoint (TPU-native formulation)
pallas — the fifo_eval kernel in interpret mode (correctness-grade only on
         CPU; on TPU the jax/pallas path evaluates O(1000) configs/call)
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import Timer, full_mode, save_json
from repro.core import EvalConfig, build_simgraph
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design

DESIGNS = ["gemm", "FeedForward", "k15mmseq"]


def run() -> Dict:
    out = {}
    C = 128 if full_mode() else 64
    for name in DESIGNS:
        g = build_simgraph(make_design(name))
        rng = np.random.default_rng(0)
        u = g.upper_bounds
        # feasible-leaning batch (DSE steady state)
        cfgs = np.stack([np.maximum(
            2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
            for _ in range(C)])
        row = {}
        events_condensed = None
        for backend in ["numpy", "jax"]:
            ev = BatchedEvaluator(g, EvalConfig(backend=backend, max_iters=64))
            ev.evaluate(cfgs[:2])             # warm / compile
            ev.evaluate(cfgs)                 # warm the batch bucket
            with Timer() as t:
                ev.evaluate(cfgs)
            row[backend] = dict(
                batch=C, total_s=round(t.s, 4),
                us_per_config=round(1e6 * t.s / C, 1),
                fallbacks=ev.stats.n_fallbacks,
                condensed_rows=ev.stats.n_condensed)
            info = ev.condensation_info()
            if info:
                events_condensed = min(r["events_condensed"] for r in info)
        # raw AND condensed event counts keep the perf trajectory
        # comparable across PRs (see benchmarks/condense.py)
        out[name] = dict(events=g.n_events,
                         events_condensed=events_condensed,
                         fifos=g.n_fifos, **row)
    save_json("batched_eval.json", out)
    return out


def main():
    out = run()
    for name, r in out.items():
        print(f"{name:14s} E={r['events']:6d} "
              f"numpy={r['numpy']['us_per_config']:9.1f}us/cfg "
              f"jax={r['jax']['us_per_config']:9.1f}us/cfg")


if __name__ == "__main__":
    main()
