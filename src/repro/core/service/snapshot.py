"""Warm-restart snapshots: registry state -> versioned, checksummed files.

A service restart used to cost what cold start costs: re-trace every
design, rebuild every simgraph, re-run condensation, re-certify deadlock
floors, and re-simulate everything the evaluation caches had already
paid for.  This module serializes exactly those artifacts so a restarted
server answers its first request in milliseconds:

* the collected :class:`~repro.core.tracer.Trace` (op streams per task),
* the packed :class:`~repro.core.simgraph.SimGraph` arrays,
* every condensation rung (:class:`~repro.core.condense.CondensedGraph`)
  with its index maps and certificate tables,
* the deadlock :class:`~repro.core.deadlock.CertificationResult` and
  pruning bound caches, and
* the full :class:`~repro.core.backends.ConfigCache` contents in
  insertion order.

Format: one ``<design>.snap.npz`` per design (named numpy arrays plus an
embedded JSON ``meta`` record) under a ``MANIFEST.json`` carrying the
snapshot version, the registry's :class:`~repro.core.config.EvalConfig`,
and a SHA-256 per design file.  Loads verify the version and every
checksum before touching a byte of array data; any mismatch raises
:class:`SnapshotError` — a torn or tampered snapshot degrades to a cold
start, never to silently wrong state.

Restored advisors are *bit-identical* to freshly traced ones in every
observable (frontiers, histories, certificates); only wall-clock and
``n_evals`` differ, because cache hits are not re-simulated
(``tests/test_snapshot.py`` asserts this).

Custom designs (registered with an explicit :class:`Design` object) are
skipped: a fresh process cannot rebuild the design callable by name, and
an advisor without its design cannot serve ``explain_deadlock`` or
re-trace.  The manifest records them under ``"skipped"`` so operators
see the gap.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core.advisor import Baseline, FifoAdvisor
from repro.core.condense import CondensedGraph
from repro.core.config import EvalConfig
from repro.core.deadlock.certify import CertificationResult
from repro.core.service.registry import DesignRegistry
from repro.core.simgraph import SimGraph
from repro.core.tracer import TaskTrace, Trace

__all__ = ["SNAPSHOT_VERSION", "SnapshotError", "save_snapshot",
           "load_snapshot"]

#: bump on any incompatible change to the array layout or meta schema;
#: loaders reject other versions outright (cold start beats guessing)
SNAPSHOT_VERSION = 1

MANIFEST = "MANIFEST.json"


class SnapshotError(RuntimeError):
    """The snapshot directory is unreadable, tampered, or incompatible."""


class _BlobReader:
    """Named-array access over one contiguous buffer.

    Restores read ~50 arrays per design; going through the npz zip
    member machinery per array costs more than the data itself, so the
    on-disk layout is a single ``blob`` plus a ``{name: dtype/shape/
    offset}`` index in the meta record.  Arrays are copied out (not
    viewed) so restored state is writable and owns its memory.
    """

    def __init__(self, blob: np.ndarray, index: Dict[str, dict]):
        self._buf = np.ascontiguousarray(blob)
        self._index = index

    def __contains__(self, name: str) -> bool:
        return name in self._index

    def __getitem__(self, name: str) -> np.ndarray:
        e = self._index[name]
        dtype = np.dtype(e["dtype"])
        count = int(np.prod(e["shape"], dtype=np.int64)) if e["shape"] \
            else 1
        a = np.frombuffer(self._buf, dtype=dtype, count=count,
                          offset=e["offset"])
        return a.reshape(e["shape"]).copy()


def _pack_blob(arrays: Dict[str, np.ndarray]) -> tuple:
    """Concatenate named arrays into (blob, index) for :class:`_BlobReader`."""
    parts: List[bytes] = []
    index: Dict[str, dict] = {}
    offset = 0
    for name, a in arrays.items():
        a = np.ascontiguousarray(a)
        raw = a.tobytes()
        index[name] = {"dtype": a.dtype.str, "shape": list(a.shape),
                       "offset": offset}
        parts.append(raw)
        offset += len(raw)
    return np.frombuffer(b"".join(parts), dtype=np.uint8), index


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _array_fields(cls) -> List[str]:
    """Dataclass fields that hold numpy arrays (everything except the
    design/raw back-references and scalar metadata)."""
    skip = {"design", "raw", "unbounded_latency", "_bound", "tag"}
    return [f.name for f in dataclasses.fields(cls) if f.name not in skip]


# ----------------------------------------------------------------- save
def _pack_advisor(adv: FifoAdvisor) -> tuple:
    """(arrays dict, meta dict) for one advisor."""
    arrays: Dict[str, np.ndarray] = {}
    meta: dict = {
        "version": SNAPSHOT_VERSION,
        "design": adv.design.name,
        "config": adv.evaluator.config.to_dict(),
        "graph": {"unbounded_latency": int(adv.graph.unbounded_latency)},
        "baseline_max": _pack_baseline(adv.baseline_max, "bmax", arrays),
        "baseline_min": _pack_baseline(adv.baseline_min, "bmin", arrays),
    }

    # trace: per-task op streams, concatenated with per-task counts
    tr = adv.trace
    arrays["tr_kinds"] = np.concatenate(
        [t.kinds for t in tr.tasks]) if tr.tasks else np.zeros(0, np.int8)
    arrays["tr_fifos"] = np.concatenate(
        [t.fifos for t in tr.tasks]) if tr.tasks else np.zeros(0, np.int32)
    arrays["tr_deltas"] = np.concatenate(
        [t.deltas for t in tr.tasks]) if tr.tasks else np.zeros(0, np.int64)
    arrays["tr_ops"] = np.asarray([t.n_ops for t in tr.tasks], np.int64)
    arrays["tr_task"] = np.asarray([t.task for t in tr.tasks], np.int64)
    arrays["tr_end"] = np.asarray([t.end_delay for t in tr.tasks], np.int64)
    arrays["tr_writes"] = tr.write_counts
    arrays["tr_reads"] = tr.read_counts

    for name in _array_fields(SimGraph):
        arrays[f"g_{name}"] = getattr(adv.graph, name)

    rungs = [cg for cg, _impl in adv.evaluator.condensation]
    meta["rungs"] = []
    for i, cg in enumerate(rungs):
        meta["rungs"].append({
            "tag": cg.tag, "bound": int(cg._bound),
            "unbounded_latency": int(cg.unbounded_latency)})
        for name in _array_fields(CondensedGraph):
            arrays[f"cg{i}_{name}"] = getattr(cg, name)

    cache = adv.cache
    n = len(cache)
    arrays["cache_rows"] = cache._rows[:n]
    arrays["cache_lat"] = cache._lat[:n]
    arrays["cache_bram"] = cache._bram[:n]
    arrays["cache_dead"] = cache._dead[:n]

    if adv._upper_bounds is not None:
        arrays["upper_bounds"] = np.asarray(adv._upper_bounds, np.int64)
    if adv._lb_cache is not None:
        arrays["lb_cache"] = adv._lb_cache
    cert = adv._certification
    if cert is not None:
        arrays["cert_depths"] = cert.depths
        arrays["cert_start"] = cert.start
        meta["certification"] = {
            "latency": int(cert.latency), "bram": int(cert.bram),
            "n_probes": int(cert.n_probes),
            "n_cache_hits": int(cert.n_cache_hits),
            "wall_s": float(cert.wall_s)}
    return arrays, meta


def _pack_baseline(b: Baseline, prefix: str, arrays: dict) -> dict:
    arrays[f"{prefix}_depths"] = np.asarray(b.depths, np.int64)
    return {"latency": int(b.latency), "bram": int(b.bram),
            "deadlocked": bool(b.deadlocked)}


def save_snapshot(registry: DesignRegistry, directory: str) -> dict:
    """Write a warm-restart snapshot of every registered design.

    Returns the manifest dict that was written to ``MANIFEST.json``.
    Files are written before the manifest, so a crash mid-save leaves no
    manifest referencing missing data; re-saving overwrites in place.
    """
    os.makedirs(directory, exist_ok=True)
    manifest = {"version": SNAPSHOT_VERSION,
                "config": registry.config.to_dict(),
                "designs": {}, "skipped": sorted(registry.custom_names)}
    for name in registry.names():
        if name in registry.custom_names:
            continue
        arrays, meta = _pack_advisor(registry[name])
        blob, meta["arrays"] = _pack_blob(arrays)
        fname = f"{name}.snap.npz"
        path = os.path.join(directory, fname)
        with open(path, "wb") as f:
            np.savez(f, blob=blob, meta=np.frombuffer(
                json.dumps(meta).encode("utf-8"), dtype=np.uint8))
        manifest["designs"][name] = {"file": fname, "sha256": _sha256(path)}
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


# ----------------------------------------------------------------- load
def _unpack_advisor(name: str, z, meta: dict) -> FifoAdvisor:
    from repro.designs import make_design
    design = make_design(name)
    config = EvalConfig.from_dict(meta["config"])

    ops = z["tr_ops"]
    splits = np.cumsum(ops)[:-1]
    kinds = np.split(z["tr_kinds"], splits)
    fifos = np.split(z["tr_fifos"], splits)
    deltas = np.split(z["tr_deltas"], splits)
    tasks = [TaskTrace(task=int(z["tr_task"][i]), kinds=kinds[i],
                       fifos=fifos[i], deltas=deltas[i],
                       end_delay=int(z["tr_end"][i]))
             for i in range(len(ops))]
    # functional results are only consumed on freshly collected traces
    # (the fuzzer's differential oracle); a restored trace serves timing
    trace = Trace(design=design, tasks=tasks, results={},
                  write_counts=z["tr_writes"], read_counts=z["tr_reads"])

    graph = SimGraph(
        design=design,
        unbounded_latency=int(meta["graph"]["unbounded_latency"]),
        **{f: z[f"g_{f}"] for f in _array_fields(SimGraph)})

    rungs = []
    for i, rm in enumerate(meta.get("rungs", [])):
        rungs.append(CondensedGraph(
            raw=graph, tag=rm["tag"], _bound=int(rm["bound"]),
            unbounded_latency=int(rm["unbounded_latency"]),
            **{f: z[f"cg{i}_{f}"] for f in _array_fields(CondensedGraph)}))

    cert = None
    if "certification" in meta:
        cm = meta["certification"]
        cert = CertificationResult(
            depths=z["cert_depths"], start=z["cert_start"],
            latency=cm["latency"], bram=cm["bram"],
            n_probes=cm["n_probes"], wall_s=cm["wall_s"],
            n_cache_hits=cm.get("n_cache_hits", 0))

    def baseline(prefix: str, key: str) -> Baseline:
        bm = meta[key]
        return Baseline(depths=z[f"{prefix}_depths"], latency=bm["latency"],
                        bram=bm["bram"], deadlocked=bm["deadlocked"])

    return FifoAdvisor.restore(
        design, trace=trace, graph=graph, config=config,
        upper_bounds=z["upper_bounds"] if "upper_bounds" in z else None,
        rungs=rungs,
        baseline_max=baseline("bmax", "baseline_max"),
        baseline_min=baseline("bmin", "baseline_min"),
        certification=cert,
        lb_cache=z["lb_cache"] if "lb_cache" in z else None,
        cache_data=(z["cache_rows"], z["cache_lat"],
                    z["cache_bram"], z["cache_dead"]))


def load_snapshot(directory: str,
                  registry: Optional[DesignRegistry] = None
                  ) -> DesignRegistry:
    """Restore a :class:`DesignRegistry` from a snapshot directory.

    Verifies the manifest version and every per-file SHA-256 *before*
    deserializing any array data.  When ``registry`` is given, restored
    advisors are adopted into it (its config must match the snapshot's);
    otherwise a fresh registry is built from the snapshot's config.
    """
    mpath = os.path.join(directory, MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise SnapshotError(f"unreadable snapshot manifest {mpath}: {e}")
    version = manifest.get("version")
    if version != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"snapshot version {version!r} != supported {SNAPSHOT_VERSION}")
    config = EvalConfig.from_dict(manifest["config"])
    if registry is None:
        registry = DesignRegistry(config)
    elif registry.config != config:
        raise SnapshotError(
            f"snapshot config {config} != registry config {registry.config}")
    entries = manifest.get("designs", {})
    for name, entry in entries.items():
        path = os.path.join(directory, entry["file"])
        if not os.path.exists(path):
            raise SnapshotError(f"snapshot file missing: {path}")
        digest = _sha256(path)
        if digest != entry["sha256"]:
            raise SnapshotError(
                f"checksum mismatch for {entry['file']}: manifest "
                f"{entry['sha256'][:12]}..., file {digest[:12]}...")
    for name, entry in entries.items():
        with np.load(os.path.join(directory, entry["file"])) as npz:
            meta = json.loads(bytes(npz["meta"]).decode("utf-8"))
            if meta.get("version") != SNAPSHOT_VERSION:
                raise SnapshotError(
                    f"design {name}: snapshot version "
                    f"{meta.get('version')!r} != {SNAPSHOT_VERSION}")
            z = _BlobReader(npz["blob"], meta["arrays"])
        registry.adopt(name, _unpack_advisor(name, z, meta))
    return registry
