"""Warm-restart snapshots: registry state -> versioned, checksummed files.

A service restart used to cost what cold start costs: re-trace every
design, rebuild every simgraph, re-run condensation, re-certify deadlock
floors, and re-simulate everything the evaluation caches had already
paid for.  This module serializes exactly those artifacts so a restarted
server answers its first request in milliseconds:

* the collected :class:`~repro.core.tracer.Trace` (op streams per task),
* the packed :class:`~repro.core.simgraph.SimGraph` arrays,
* every condensation rung (:class:`~repro.core.condense.CondensedGraph`)
  with its index maps and certificate tables,
* the deadlock :class:`~repro.core.deadlock.CertificationResult` and
  pruning bound caches, and
* the full :class:`~repro.core.backends.ConfigCache` contents in
  insertion order.

Format: one ``<design>.<sha12>.snap.npz`` per design (named numpy arrays
plus an embedded JSON ``meta`` record) under a ``MANIFEST.json`` carrying
the snapshot version, the registry's
:class:`~repro.core.config.EvalConfig`, and a SHA-256 per design file.

Crash consistency: every file is written to a temp name and published
with ``os.replace`` (file fsync'd before the rename, directory fsync'd
after it, so the renames themselves are durable in order), member files
are *content-addressed* (their name embeds their hash, so a re-save
never overwrites a file the previous manifest still references), and
the manifest is replaced last — a crash at ANY point mid-save leaves
the previous snapshot fully loadable.  Garbage collection runs only
after the new manifest is durably in place and spares the superseded
manifest's members too, so one concurrent reader that picked up the
previous manifest (a warm restart racing an auto-snapshot) can finish
its restore; older generations are reclaimed by the next save.
Concurrent *writers* are not coordinated — point each server at its
own snapshot directory.

Loads verify the manifest version and each member's checksum before
deserializing it.  A member that fails (missing file, checksum mismatch,
torn write) is *quarantined* by default: the healthy designs restore
warm and the quarantined ones simply re-trace on first use, with the
report attached as ``registry.restore_report``.  ``strict=True``
restores the old all-or-nothing behaviour (any mismatch raises
:class:`SnapshotError`); manifest-level problems (unreadable, wrong
version, config mismatch) always raise — a snapshot degrades to a cold
start, never to silently wrong state.

Restored advisors are *bit-identical* to freshly traced ones in every
observable (frontiers, histories, certificates); only wall-clock and
``n_evals`` differ, because cache hits are not re-simulated
(``tests/test_snapshot.py`` asserts this).

Custom designs (registered with an explicit :class:`Design` object) are
skipped: a fresh process cannot rebuild the design callable by name, and
an advisor without its design cannot serve ``explain_deadlock`` or
re-trace.  The manifest records them under ``"skipped"`` so operators
see the gap.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import tempfile
from typing import Dict, List, Optional

import numpy as np

from repro.core.advisor import Baseline, FifoAdvisor
from repro.core.condense import CondensedGraph
from repro.core.config import EvalConfig
from repro.core.deadlock.certify import CertificationResult
from repro.core.faults import FaultPlan, InjectedFault, resolve_plan
from repro.core.service.registry import DesignRegistry
from repro.core.simgraph import SimGraph
from repro.core.tracer import TaskTrace, Trace

__all__ = ["SNAPSHOT_VERSION", "SnapshotError", "save_snapshot",
           "load_snapshot"]

#: bump on any incompatible change to the array layout or meta schema;
#: loaders reject other versions outright (cold start beats guessing)
SNAPSHOT_VERSION = 1

MANIFEST = "MANIFEST.json"


class SnapshotError(RuntimeError):
    """The snapshot directory is unreadable, tampered, or incompatible."""


class _BlobReader:
    """Named-array access over one contiguous buffer.

    Restores read ~50 arrays per design; going through the npz zip
    member machinery per array costs more than the data itself, so the
    on-disk layout is a single ``blob`` plus a ``{name: dtype/shape/
    offset}`` index in the meta record.  Arrays are copied out (not
    viewed) so restored state is writable and owns its memory.
    """

    def __init__(self, blob: np.ndarray, index: Dict[str, dict]):
        self._buf = np.ascontiguousarray(blob)
        self._index = index

    def __contains__(self, name: str) -> bool:
        return name in self._index

    def __getitem__(self, name: str) -> np.ndarray:
        e = self._index[name]
        dtype = np.dtype(e["dtype"])
        count = int(np.prod(e["shape"], dtype=np.int64)) if e["shape"] \
            else 1
        a = np.frombuffer(self._buf, dtype=dtype, count=count,
                          offset=e["offset"])
        return a.reshape(e["shape"]).copy()


def _pack_blob(arrays: Dict[str, np.ndarray]) -> tuple:
    """Concatenate named arrays into (blob, index) for :class:`_BlobReader`."""
    parts: List[bytes] = []
    index: Dict[str, dict] = {}
    offset = 0
    for name, a in arrays.items():
        a = np.ascontiguousarray(a)
        raw = a.tobytes()
        index[name] = {"dtype": a.dtype.str, "shape": list(a.shape),
                       "offset": offset}
        parts.append(raw)
        offset += len(raw)
    return np.frombuffer(b"".join(parts), dtype=np.uint8), index


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _array_fields(cls) -> List[str]:
    """Dataclass fields that hold numpy arrays (everything except the
    design/raw back-references and scalar metadata)."""
    skip = {"design", "raw", "unbounded_latency", "_bound", "tag"}
    return [f.name for f in dataclasses.fields(cls) if f.name not in skip]


# ----------------------------------------------------------------- save
def _pack_advisor(adv: FifoAdvisor) -> tuple:
    """(arrays dict, meta dict) for one advisor."""
    arrays: Dict[str, np.ndarray] = {}
    meta: dict = {
        "version": SNAPSHOT_VERSION,
        "design": adv.design.name,
        "config": adv.evaluator.config.to_dict(),
        "graph": {"unbounded_latency": int(adv.graph.unbounded_latency)},
        "baseline_max": _pack_baseline(adv.baseline_max, "bmax", arrays),
        "baseline_min": _pack_baseline(adv.baseline_min, "bmin", arrays),
    }

    # trace: per-task op streams, concatenated with per-task counts
    tr = adv.trace
    arrays["tr_kinds"] = np.concatenate(
        [t.kinds for t in tr.tasks]) if tr.tasks else np.zeros(0, np.int8)
    arrays["tr_fifos"] = np.concatenate(
        [t.fifos for t in tr.tasks]) if tr.tasks else np.zeros(0, np.int32)
    arrays["tr_deltas"] = np.concatenate(
        [t.deltas for t in tr.tasks]) if tr.tasks else np.zeros(0, np.int64)
    arrays["tr_ops"] = np.asarray([t.n_ops for t in tr.tasks], np.int64)
    arrays["tr_task"] = np.asarray([t.task for t in tr.tasks], np.int64)
    arrays["tr_end"] = np.asarray([t.end_delay for t in tr.tasks], np.int64)
    arrays["tr_writes"] = tr.write_counts
    arrays["tr_reads"] = tr.read_counts

    for name in _array_fields(SimGraph):
        arrays[f"g_{name}"] = getattr(adv.graph, name)

    rungs = [cg for cg, _impl in adv.evaluator.condensation]
    meta["rungs"] = []
    for i, cg in enumerate(rungs):
        meta["rungs"].append({
            "tag": cg.tag, "bound": int(cg._bound),
            "unbounded_latency": int(cg.unbounded_latency)})
        for name in _array_fields(CondensedGraph):
            arrays[f"cg{i}_{name}"] = getattr(cg, name)

    cache = adv.cache
    n = len(cache)
    arrays["cache_rows"] = cache._rows[:n]
    arrays["cache_lat"] = cache._lat[:n]
    arrays["cache_bram"] = cache._bram[:n]
    arrays["cache_dead"] = cache._dead[:n]

    if adv._upper_bounds is not None:
        arrays["upper_bounds"] = np.asarray(adv._upper_bounds, np.int64)
    if adv._lb_cache is not None:
        arrays["lb_cache"] = adv._lb_cache
    cert = adv._certification
    if cert is not None:
        arrays["cert_depths"] = cert.depths
        arrays["cert_start"] = cert.start
        meta["certification"] = {
            "latency": int(cert.latency), "bram": int(cert.bram),
            "n_probes": int(cert.n_probes),
            "n_cache_hits": int(cert.n_cache_hits),
            "wall_s": float(cert.wall_s)}
    return arrays, meta


def _pack_baseline(b: Baseline, prefix: str, arrays: dict) -> dict:
    arrays[f"{prefix}_depths"] = np.asarray(b.depths, np.int64)
    return {"latency": int(b.latency), "bram": int(b.bram),
            "deadlocked": bool(b.deadlocked)}


def _atomic_write(directory: str, fname: str, data: bytes) -> str:
    """Publish ``data`` at ``directory/fname`` via tmp + fsync +
    ``os.replace`` (the checkpoint pattern from ``campaign/state.py``):
    readers only ever see the old file or the complete new one.

    The directory is fsync'd after the replace so the *rename itself*
    is durable before we return — member renames therefore hit disk
    before the manifest rename that references them, and a power loss
    cannot persist a manifest whose members evaporated."""
    path = os.path.join(directory, fname)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(directory)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _fsync_dir(directory: str) -> None:
    """Make a completed rename in ``directory`` durable (no-op where
    directories cannot be opened for fsync, e.g. Windows)."""
    try:
        dfd = os.open(directory, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir-open
        return
    try:
        os.fsync(dfd)
    except OSError:  # pragma: no cover - fs without dir fsync
        pass
    finally:
        os.close(dfd)


def save_snapshot(registry: DesignRegistry, directory: str,
                  faults: Optional["FaultPlan"] = None) -> dict:
    """Write a warm-restart snapshot of every registered design.

    Returns the manifest dict that was written to ``MANIFEST.json``.
    Member files are content-addressed (``<name>.<sha12>.snap.npz``) and
    every write is atomic, with the manifest replaced last — so a crash
    anywhere mid-save leaves the previous snapshot fully loadable.
    Member files referenced by neither the new manifest nor the one it
    superseded are garbage-collected after the new manifest is in place
    (the superseded generation survives one save for concurrent
    readers).

    ``faults`` (chaos testing) may schedule ``crash_save`` — abort with
    :class:`~repro.core.faults.InjectedFault` before writing member
    ``at`` (``at == n_designs`` aborts just before the manifest) — and
    ``corrupt_snapshot`` — flip byte ``value`` of member ``at`` *after*
    its checksum was recorded, i.e. a torn write the loader must catch.
    """
    if faults is None:
        faults = resolve_plan(registry.config)
    os.makedirs(directory, exist_ok=True)
    # remember what the manifest being superseded references: its
    # members survive this save's GC so a reader holding that manifest
    # (a warm restart racing an auto-snapshot) never has files
    # unlinked out from under it mid-load
    prior = None
    try:
        with open(os.path.join(directory, MANIFEST)) as f:
            prior = json.load(f)
    except (OSError, ValueError):
        pass
    manifest = {"version": SNAPSHOT_VERSION,
                "config": registry.config.to_dict(),
                "designs": {}, "skipped": sorted(registry.custom_names)}
    saved = [n for n in registry.names()
             if n not in registry.custom_names]
    for i, name in enumerate(saved):
        if faults is not None and faults.take(
                "crash_save", at=i, targets=(name,)) is not None:
            raise InjectedFault(
                f"injected crash before writing snapshot member {i} "
                f"({name})")
        arrays, meta = _pack_advisor(registry[name])
        blob, meta["arrays"] = _pack_blob(arrays)
        buf = io.BytesIO()
        np.savez(buf, blob=blob, meta=np.frombuffer(
            json.dumps(meta).encode("utf-8"), dtype=np.uint8))
        data = buf.getvalue()
        digest = hashlib.sha256(data).hexdigest()
        fname = f"{name}.{digest[:12]}.snap.npz"
        path = _atomic_write(directory, fname, data)
        manifest["designs"][name] = {"file": fname, "sha256": digest}
        if faults is not None:
            f = faults.take("corrupt_snapshot", at=i, targets=(name,))
            if f is not None:
                _flip_byte(path, int(f.value))
    if faults is not None and faults.take(
            "crash_save", at=len(saved)) is not None:
        raise InjectedFault(
            "injected crash before publishing the snapshot manifest")
    _atomic_write(directory, MANIFEST, json.dumps(
        manifest, indent=1, sort_keys=True).encode("utf-8"))
    _collect_garbage(directory, manifest, prior)
    return manifest


def _flip_byte(path: str, offset: int) -> None:
    """Corrupt one byte in place (the ``corrupt_snapshot`` fault)."""
    size = os.path.getsize(path)
    offset = offset % max(size, 1)
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def _collect_garbage(directory: str, manifest: dict,
                     prior: Optional[dict] = None) -> None:
    """Remove member files neither the freshly published manifest nor
    the one it superseded reference (older generations, aborted saves).
    Keeping the prior generation's members lets a reader that loaded
    the previous manifest finish its restore even while this save runs;
    they are reclaimed by the *next* save."""
    live = {e["file"] for e in manifest.get("designs", {}).values()}
    if prior is not None:
        live |= {e.get("file") for e
                 in prior.get("designs", {}).values()
                 if isinstance(e, dict)}
    for fname in os.listdir(directory):
        if fname.endswith(".snap.npz") and fname not in live:
            try:
                os.unlink(os.path.join(directory, fname))
            except OSError:  # pragma: no cover - raced with another save
                pass


# ----------------------------------------------------------------- load
def _unpack_advisor(name: str, z, meta: dict) -> FifoAdvisor:
    from repro.designs import make_design
    design = make_design(name)
    config = EvalConfig.from_dict(meta["config"])

    ops = z["tr_ops"]
    splits = np.cumsum(ops)[:-1]
    kinds = np.split(z["tr_kinds"], splits)
    fifos = np.split(z["tr_fifos"], splits)
    deltas = np.split(z["tr_deltas"], splits)
    tasks = [TaskTrace(task=int(z["tr_task"][i]), kinds=kinds[i],
                       fifos=fifos[i], deltas=deltas[i],
                       end_delay=int(z["tr_end"][i]))
             for i in range(len(ops))]
    # functional results are only consumed on freshly collected traces
    # (the fuzzer's differential oracle); a restored trace serves timing
    trace = Trace(design=design, tasks=tasks, results={},
                  write_counts=z["tr_writes"], read_counts=z["tr_reads"])

    graph = SimGraph(
        design=design,
        unbounded_latency=int(meta["graph"]["unbounded_latency"]),
        **{f: z[f"g_{f}"] for f in _array_fields(SimGraph)})

    rungs = []
    for i, rm in enumerate(meta.get("rungs", [])):
        rungs.append(CondensedGraph(
            raw=graph, tag=rm["tag"], _bound=int(rm["bound"]),
            unbounded_latency=int(rm["unbounded_latency"]),
            **{f: z[f"cg{i}_{f}"] for f in _array_fields(CondensedGraph)}))

    cert = None
    if "certification" in meta:
        cm = meta["certification"]
        cert = CertificationResult(
            depths=z["cert_depths"], start=z["cert_start"],
            latency=cm["latency"], bram=cm["bram"],
            n_probes=cm["n_probes"], wall_s=cm["wall_s"],
            n_cache_hits=cm.get("n_cache_hits", 0))

    def baseline(prefix: str, key: str) -> Baseline:
        bm = meta[key]
        return Baseline(depths=z[f"{prefix}_depths"], latency=bm["latency"],
                        bram=bm["bram"], deadlocked=bm["deadlocked"])

    return FifoAdvisor.restore(
        design, trace=trace, graph=graph, config=config,
        upper_bounds=z["upper_bounds"] if "upper_bounds" in z else None,
        rungs=rungs,
        baseline_max=baseline("bmax", "baseline_max"),
        baseline_min=baseline("bmin", "baseline_min"),
        certification=cert,
        lb_cache=z["lb_cache"] if "lb_cache" in z else None,
        cache_data=(z["cache_rows"], z["cache_lat"],
                    z["cache_bram"], z["cache_dead"]))


def _verify_member(directory: str, name: str, entry: dict):
    """Checksum-verify and deserialize one snapshot member; returns the
    reason string when the member is damaged (the quarantine path)."""
    path = os.path.join(directory, entry["file"])
    if not os.path.exists(path):
        return None, f"snapshot file missing: {path}"
    digest = _sha256(path)
    if digest != entry["sha256"]:
        return None, (
            f"checksum mismatch for {entry['file']}: manifest "
            f"{entry['sha256'][:12]}..., file {digest[:12]}...")
    try:
        with np.load(path) as npz:
            meta = json.loads(bytes(npz["meta"]).decode("utf-8"))
            if meta.get("version") != SNAPSHOT_VERSION:
                return None, (
                    f"design {name}: snapshot version "
                    f"{meta.get('version')!r} != {SNAPSHOT_VERSION}")
            z = _BlobReader(npz["blob"], meta["arrays"])
        return _unpack_advisor(name, z, meta), None
    except Exception as e:   # a checksum-clean file that still fails to
        # deserialize means writer/reader drift — quarantine, don't die
        return None, f"design {name}: failed to deserialize: {e}"


def load_snapshot(directory: str,
                  registry: Optional[DesignRegistry] = None,
                  strict: bool = False) -> DesignRegistry:
    """Restore a :class:`DesignRegistry` from a snapshot directory.

    Verifies the manifest version, then checksum-verifies and restores
    each member.  A damaged member (missing file, checksum mismatch,
    torn write, deserialization failure) is *quarantined*: the healthy
    designs restore warm and the damaged ones are skipped — they simply
    re-trace on first use.  The outcome is attached to the returned
    registry as ``registry.restore_report``::

        {"restored": [names...], "quarantined": {name: reason, ...}}

    ``strict=True`` turns any damaged member into a
    :class:`SnapshotError` instead (the pre-quarantine behaviour).
    Manifest-level problems — unreadable manifest, version mismatch,
    config mismatch with a caller-supplied ``registry`` — always raise.

    When ``registry`` is given, restored advisors are adopted into it
    (its config must match the snapshot's); otherwise a fresh registry
    is built from the snapshot's config.
    """
    mpath = os.path.join(directory, MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise SnapshotError(f"unreadable snapshot manifest {mpath}: {e}")
    version = manifest.get("version")
    if version != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"snapshot version {version!r} != supported {SNAPSHOT_VERSION}")
    config = EvalConfig.from_dict(manifest["config"])
    if registry is None:
        registry = DesignRegistry(config)
    elif registry.config != config:
        raise SnapshotError(
            f"snapshot config {config} != registry config {registry.config}")
    report = {"restored": [], "quarantined": {}}
    for name, entry in manifest.get("designs", {}).items():
        advisor, reason = _verify_member(directory, name, entry)
        if reason is not None:
            if strict:
                raise SnapshotError(reason)
            report["quarantined"][name] = reason
            continue
        registry.adopt(name, advisor)
        report["restored"].append(name)
    registry.restore_report = report
    return registry
