"""Cross-session hetero batching: many clients, one evaluation round.

:class:`AdvisoryService` is the always-on counterpart of the batch
campaign engine.  Where a :class:`~repro.core.campaign.Campaign` is
handed its full task list up front, the service accepts sessions at any
time, on any design — tracing new designs lazily through the
:class:`~repro.core.service.registry.DesignRegistry` — and still packs
every outstanding :class:`~repro.core.optimizers.EvalRequest` from
*different* clients and *different* designs into single routed
dispatches via the shared
:class:`~repro.core.campaign.router.RoundRouter`:

* same-design rows from different sessions are merged and deduplicated
  (two clients probing the same corner cost ONE solve, and both hit the
  design's shared cache forever after);
* incremental-eligible rows keep the LightningSim fast path;
* with ``hetero=True``, full-solve rows across designs are packed into
  one lane-aligned fixpoint dispatch
  (:class:`~repro.core.backends.HeteroDispatcher`), whose envelope grows
  lazily as new designs register.

The batching is *routing only*: every path is exact, so each session's
history is bit-identical to a solo ``FifoAdvisor.run()`` with the same
seed — batching changes wall-clock, never results.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.advisor import DseResult
from repro.core.campaign.router import RoundRouter, RoutedRequest
from repro.core.config import EvalConfig, resolve_config
from repro.core.faults import FaultPlan, resolve_plan
from repro.core.service.registry import DesignRegistry
from repro.core.service.session import Session

__all__ = ["AdvisoryService", "CrossSessionBatcher", "ServiceOverloaded"]


class ServiceOverloaded(RuntimeError):
    """Admission refused: the service is at its concurrent-session cap.

    ``retry_after_s`` is the service's live estimate of when capacity
    frees up (a few batched rounds at the current measured round time);
    the wire protocol surfaces it verbatim in the ``E_OVERLOADED``
    error frame so clients can back off instead of hammering.
    """

    def __init__(self, max_sessions: int, retry_after_s: float):
        self.max_sessions = int(max_sessions)
        self.retry_after_s = float(retry_after_s)
        super().__init__(
            f"service at capacity ({max_sessions} running sessions); "
            f"retry in {retry_after_s:.3f}s")


class CrossSessionBatcher:
    """Routes one round of session proposals through shared engines.

    Owns the :class:`RoundRouter` plus the optional cross-design
    :class:`HeteroDispatcher` and :class:`WorkerPool`, keeping both in
    sync with the registry as designs appear.
    """

    def __init__(self, registry: DesignRegistry, hetero: bool = False,
                 workers: int = 0, shards: Optional[int] = None,
                 faults: Optional[FaultPlan] = None):
        self.registry = registry
        #: installed fault plan (chaos testing; None = no injection)
        self.faults = faults
        self.want_hetero = bool(hetero)
        # hetero owns every full-solve row in this process (same rule as
        # CampaignSpec.hetero): a pool would only idle, so the two are
        # mutually exclusive — normalized here, surfaced by the CLI
        self.workers = 0 if hetero else int(workers)
        #: shard the hetero dispatch over this many jax devices
        #: (docs/mesh.md); only meaningful with hetero=True
        self.shards = shards
        self.router = RoundRouter(registry)
        self.rounds = 0
        #: EWMA of the wall time of one batched round, feeding the
        #: overload replies' retry-after estimate
        self.round_ewma_s = 0.0
        self._pool_designs: set = set()   # designs the pool was built with

    @property
    def n_lanes(self) -> int:
        return self.router.n_lanes

    def add_design(self, name: str) -> None:
        """Keep the hetero envelope / worker pool aware of ``name``.

        Hetero mode extends the dispatcher's operand envelope in place.
        Pool mode must keep every worker able to evaluate the design:
        custom ``Design`` objects are pinned to lane 0 (a fresh worker
        process cannot rebuild them by name), and a *named* design that
        arrives after the pool exists rebuilds the pool so the workers
        pick up its graph — sessions are rare next to rounds, so the
        respawn cost is noise.
        """
        adv = self.registry[name]
        if self.want_hetero:
            if self.router.hetero is None:
                from repro.core.backends.dispatch import HeteroDispatcher
                self.router.hetero = HeteroDispatcher(
                    {}, max_iters=self.registry.max_iters,
                    shards=self.shards)
            self.router.hetero.add_design(
                name, adv.graph, getattr(adv.evaluator, "_worklist", None))
        elif self.workers > 0:
            if name in self.registry.custom_names:
                self.router.inline_only.add(name)
            elif (self.router.pool is None
                  or name not in self._pool_designs):
                from repro.core.campaign.pool import WorkerPool
                if self.router.pool is not None:
                    self.router.pool.close()
                self._pool_designs = {
                    k for k in self.registry
                    if k not in self.registry.custom_names}
                self.router.pool = WorkerPool(
                    self.workers, max_iters=self.registry.max_iters,
                    graphs={k: self.registry[k].graph
                            for k in self._pool_designs},
                    faults=self.faults)

    def step(self, sessions: List[Session]) -> int:
        """One cross-session round over the given *running* sessions.

        Collects each session's outstanding proposal, screens it against
        the design's shared cache, routes every miss in one
        :meth:`RoundRouter.route` call, and hands the results back to
        each session (history, budget, optimizer step, progress events).
        Returns the number of sessions that advanced.
        """
        t0 = time.perf_counter()
        pending: List[RoutedRequest] = []
        for sess in sessions:
            req = sess.propose()
            if req is None:
                continue
            lat, bram, dead, miss = sess.advisor.cache.lookup(req.depths)
            pending.append(RoutedRequest(
                key=sess.design, req=req, lat=lat, bram=bram, dead=dead,
                miss_rows=np.flatnonzero(miss), lane=sess.lane, tag=sess))
        self.router.route(pending)
        if self.faults is not None:
            for p in pending:
                sess = p.tag
                f = self.faults.take("hang_eval", at=sess.rounds,
                                     targets=(sess.id, sess.design))
                if f is not None:
                    # a wedged evaluation: real wall-clock stall, real
                    # attributed eval time — the session's deadline (if
                    # any) fails it with E_TIMEOUT in complete_round
                    time.sleep(f.value)
                    p.eval_s += f.value
        for p in pending:
            p.tag.complete_round(p)
        self.rounds += 1
        dt = time.perf_counter() - t0
        self.round_ewma_s = (dt if self.round_ewma_s == 0.0
                             else 0.8 * self.round_ewma_s + 0.2 * dt)
        return len(pending)

    def stats(self) -> dict:
        out = {"rounds": self.rounds, "lanes": self.n_lanes,
               "hetero": self.want_hetero}
        if self.router.hetero is not None:
            hs = self.router.hetero.stats
            out["hetero_stats"] = {
                "n_dispatches": hs.n_dispatches, "n_rows": hs.n_rows,
                "n_pad_rows": hs.n_pad_rows,
                "n_fallbacks": hs.n_fallbacks,
                "wall_s": round(hs.wall_s, 4)}
        return out

    def close(self) -> None:
        if self.router.pool is not None:
            self.router.pool.close()
            self.router.pool = None


class AdvisoryService:
    """The FIFO-sizing advisory service core (synchronous, deterministic).

    Holds the design registry, the open sessions, and the cross-session
    batcher; :meth:`step` advances every running session by one batched
    round.  The asyncio server (``repro.launch.serve``) and the
    in-process :class:`~repro.core.service.protocol.AdvisorClient` are
    both thin drivers over this class, so everything observable —
    histories, frontiers, events — is independent of the transport.

    Args:
        registry: a shared :class:`DesignRegistry` (one is built when
            omitted).
        config: :class:`EvalConfig` for the registry when building it
            (the deprecated ``backend=``/``max_iters=`` keywords still
            map onto it).
        hetero: pack cross-design full-solve rows into one fixpoint
            dispatch (the TPU-native path; on CPU the worklist is faster).
        workers: worklist worker processes for parallel lanes (0 =
            evaluate inline).
        shards: shard the hetero dispatch over this many jax devices
            (``docs/mesh.md``); requires ``hetero=True`` to matter.
        progress_events: default per-session progress streaming flag.
        max_sessions: admission-control cap on concurrently *running*
            sessions; :meth:`open_session` raises
            :class:`ServiceOverloaded` (with a live retry-after
            estimate) above it.  None = unbounded.
        faults: a :class:`~repro.core.faults.FaultPlan` to install
            (chaos testing); defaults to whatever the registry config /
            ``REPRO_FAULTS`` env resolves to — i.e. None.
    """

    def __init__(self, registry: Optional[DesignRegistry] = None,
                 config: Optional[EvalConfig] = None,
                 hetero: bool = False, workers: int = 0,
                 shards: Optional[int] = None,
                 progress_events: bool = True,
                 max_sessions: Optional[int] = None,
                 faults: Optional[FaultPlan] = None, **legacy):
        if registry is None:
            registry = DesignRegistry(
                resolve_config(config, legacy, "AdvisoryService"))
        elif legacy:
            resolve_config(config, legacy, "AdvisoryService")
        self.registry = registry
        self.faults = faults if faults is not None \
            else resolve_plan(self.registry.config)
        self.batcher = CrossSessionBatcher(self.registry, hetero=hetero,
                                           workers=workers, shards=shards,
                                           faults=self.faults)
        self.progress_events = bool(progress_events)
        self.max_sessions = None if max_sessions is None else int(max_sessions)
        self.rejected = 0              # admissions refused while at capacity
        self.sessions: Dict[str, Session] = {}
        self._next_sid = 0
        #: idempotent open: request id -> session id, so a client that
        #: lost the open reply can safely re-send the same open
        self._open_requests: Dict[str, str] = {}

    @property
    def config(self) -> EvalConfig:
        return self.registry.config

    def retry_after_s(self) -> float:
        """How long an overloaded client should wait before retrying:
        a few batched rounds at the current measured round time, floored
        so cold services never advertise a zero backoff."""
        return max(0.01, 4.0 * self.batcher.round_ewma_s)

    # ---------------------------------------------------------- sessions
    def open_session(self, design: str, optimizer: str = "grouped_sa",
                     budget: int = 300, seed: int = 0,
                     design_obj=None, progress_events: Optional[bool] = None,
                     deadline_s: Optional[float] = None,
                     request_id: Optional[str] = None,
                     **opt_kwargs) -> Session:
        """Open a DSE session (tracing the design on first use).

        Raises :class:`ServiceOverloaded` when ``max_sessions`` running
        sessions already exist — admission is checked *before* the
        (potentially expensive) first-use trace, so overload replies
        stay cheap even under a thundering herd of new designs.

        ``request_id`` makes the open idempotent: re-sending an open
        with an id the service has already honoured returns the session
        it created then, instead of opening a duplicate — the reconnect
        path for a client whose connection died before the open reply
        arrived.  ``deadline_s`` is the per-round evaluation deadline
        (see :class:`Session`).
        """
        if request_id is not None:
            sid = self._open_requests.get(request_id)
            if sid is not None and sid in self.sessions:
                return self.sessions[sid]
        if (self.max_sessions is not None
                and len(self.running) >= self.max_sessions):
            self.rejected += 1
            raise ServiceOverloaded(self.max_sessions, self.retry_after_s())
        advisor = self.registry.register(design, design_obj)
        self.batcher.add_design(design)
        sid = f"s{self._next_sid}"
        self._next_sid += 1
        lane = len(self.sessions) % max(self.batcher.n_lanes, 1)
        sess = Session(sid, design, advisor, optimizer=optimizer,
                       budget=budget, seed=seed, opt_kwargs=opt_kwargs,
                       lane=lane,
                       progress_events=(self.progress_events
                                        if progress_events is None
                                        else progress_events),
                       deadline_s=deadline_s)
        self.sessions[sid] = sess
        if request_id is not None:
            self._open_requests[request_id] = sid
        return sess

    def session(self, sid: str) -> Session:
        try:
            return self.sessions[sid]
        except KeyError:
            raise KeyError(f"unknown session {sid!r}") from None

    def cancel(self, sid: str) -> Session:
        """Cancel a session; its evaluated history becomes the result."""
        sess = self.session(sid)
        sess.cancel()
        return sess

    def release(self, sid: str) -> Session:
        """Drop a session from the service (cancelling it first if it
        is still running).  An always-on server must be able to forget
        finished sessions, or memory grows with every client ever
        served; the session object itself stays valid for the caller."""
        sess = self.session(sid)
        sess.cancel()
        del self.sessions[sid]
        # drop the idempotent-open entries that resolve to this session,
        # or the map grows with every open a long-lived server ever saw
        # (a re-sent open for a released session should open fresh anyway)
        self._open_requests = {rid: s for rid, s
                               in self._open_requests.items() if s != sid}
        return sess

    def result(self, sid: str) -> DseResult:
        """The session's :class:`DseResult` (snapshot if still running)."""
        return self.session(sid).dse_result()

    @property
    def running(self) -> List[Session]:
        return [s for s in self.sessions.values() if not s.done]

    # ------------------------------------------------------------ driving
    def step(self) -> int:
        """Advance every running session one batched round; returns the
        number of sessions that advanced (0 = service idle)."""
        active = self.running
        if not active:
            return 0
        return self.batcher.step(active)

    def run_until_idle(self, max_rounds: Optional[int] = None) -> int:
        """Drive :meth:`step` until no session is running (or the round
        cap); returns the number of rounds executed."""
        rounds = 0
        while self.step():
            rounds += 1
            if max_rounds is not None and rounds >= max_rounds:
                break
        return rounds

    # ------------------------------------------------------------- admin
    def drain_events(self, sid: Optional[str] = None) -> List[dict]:
        """Pop queued events — one session's, or every session's in
        session order."""
        if sid is not None:
            return self.session(sid).drain_events()
        out: List[dict] = []
        for sess in self.sessions.values():
            out.extend(sess.drain_events())
        return out

    def stats(self) -> dict:
        """JSON-ready service snapshot: sessions, batcher, registry."""
        states: Dict[str, int] = {}
        for s in self.sessions.values():
            states[s.state] = states.get(s.state, 0) + 1
        return {"n_sessions": len(self.sessions),
                "session_states": states,
                "max_sessions": self.max_sessions,
                "rejected": self.rejected,
                "round_ewma_s": round(self.batcher.round_ewma_s, 6),
                "batcher": self.batcher.stats(),
                "designs": self.registry.stats()}

    def close(self) -> None:
        self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
