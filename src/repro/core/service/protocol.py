"""Advisory-service wire protocol: JSON lines, transport-agnostic.

One message per line, one JSON object per message.  Requests carry an
``op`` and an optional ``id`` (echoed back verbatim, so clients can
correlate responses over a shared connection); responses carry
``ok: true/false``; server-pushed events carry an ``event`` key instead
of ``ok``.  The full message reference lives in ``docs/service.md``.

The :class:`ProtocolHandler` maps request dicts to response dicts
against an :class:`~repro.core.service.batcher.AdvisoryService` — the
asyncio server (``repro.launch.serve``), the stdio loop, and the
in-process :class:`AdvisorClient` all share it, so the protocol is
exercised end-to-end even in fully in-process tests.
"""

from __future__ import annotations

import json
from typing import List, Optional

from repro.core.service.batcher import AdvisoryService

__all__ = ["AdvisorClient", "ProtocolError", "ProtocolHandler",
           "decode_line", "encode_line"]

#: requests the handler understands (anything else is a protocol error)
OPS = ("open", "run", "step", "cancel", "close", "status", "result",
       "designs", "stats", "shutdown")


class ProtocolError(ValueError):
    """Malformed or unanswerable client message."""


def encode_line(msg: dict) -> str:
    """One message -> one newline-terminated JSON line."""
    return json.dumps(msg, separators=(",", ":")) + "\n"


def decode_line(line) -> dict:
    """One line -> one message dict (:class:`ProtocolError` if not)."""
    if isinstance(line, bytes):
        line = line.decode("utf-8", errors="replace")
    try:
        msg = json.loads(line)
    except json.JSONDecodeError as exc:
        raise ProtocolError(f"not valid JSON: {exc}") from None
    if not isinstance(msg, dict):
        raise ProtocolError("message must be a JSON object")
    return msg


class ProtocolHandler:
    """Maps one decoded request to one response dict.

    Stateless beyond the service it fronts; safe to share across
    connections (sessions are service-global — a connection may query
    any session id it knows).
    """

    def __init__(self, service: AdvisoryService):
        self.service = service

    def handle(self, msg: dict) -> dict:
        """Answer one request; never raises — errors become
        ``{"ok": false, "error": ...}`` responses."""
        rid = msg.get("id")
        try:
            out = self._dispatch(msg)
        except ProtocolError as exc:
            out = {"ok": False, "error": str(exc)}
        except Exception as exc:   # noqa: BLE001 — server boundary: an
            # engine failure (worker death, bad optimizer kwargs) must
            # become an error frame, never a dropped connection
            out = {"ok": False, "error": f"{type(exc).__name__}: {exc}"}
        if rid is not None:
            out["id"] = rid
        return out

    def poll_events(self, sid: Optional[str] = None) -> List[dict]:
        """Drain queued progress/done events (push frames)."""
        return self.service.drain_events(sid)

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, msg: dict) -> dict:
        op = msg.get("op")
        if op not in OPS:
            raise ProtocolError(
                f"unknown op {op!r}; expected one of {list(OPS)}")
        return getattr(self, f"_op_{op}")(msg)

    def _session_of(self, msg: dict):
        sid = msg.get("session")
        if not sid:
            raise ProtocolError(f"op {msg.get('op')!r} needs a 'session'")
        return self.service.session(sid)

    def _op_open(self, msg: dict) -> dict:
        design = msg.get("design")
        if not design:
            raise ProtocolError("op 'open' needs a 'design'")
        kwargs = msg.get("kwargs") or {}
        if not isinstance(kwargs, dict):
            raise ProtocolError("'kwargs' must be an object")
        sess = self.service.open_session(
            design, optimizer=msg.get("optimizer", "grouped_sa"),
            budget=int(msg.get("budget", 300)),
            seed=int(msg.get("seed", 0)),
            progress_events=msg.get("progress"), **kwargs)
        return {"ok": True, "session": sess.id, "design": sess.design,
                "optimizer": sess.optimizer, "budget": sess.budget,
                "seed": sess.seed, "state": sess.state}

    def _op_run(self, msg: dict) -> dict:
        rounds = self.service.run_until_idle(msg.get("max_rounds"))
        return {"ok": True, "rounds": rounds,
                "running": len(self.service.running)}

    def _op_step(self, msg: dict) -> dict:
        return {"ok": True, "advanced": self.service.step(),
                "running": len(self.service.running)}

    def _op_cancel(self, msg: dict) -> dict:
        sess = self._session_of(msg)
        sess.cancel()
        return {"ok": True, "session": sess.id, "state": sess.state,
                "n_evals": int(sess.ctx.n_evals)}

    def _op_close(self, msg: dict) -> dict:
        """Release a session entirely (fetch ``result`` first — the id
        becomes unknown afterwards)."""
        sess = self._session_of(msg)
        self.service.release(sess.id)
        return {"ok": True, "session": sess.id, "state": sess.state,
                "released": True}

    def _op_status(self, msg: dict) -> dict:
        return {"ok": True, **self._session_of(msg).status()}

    def _op_result(self, msg: dict) -> dict:
        sess = self._session_of(msg)
        dse = sess.dse_result()
        alpha = float(msg.get("alpha", 0.7))
        out = dse.summary(alpha)
        out["frontier"] = dse.frontier_points.tolist()
        out["hypervolume"] = float(dse.hypervolume())
        sel = dse.selected(alpha)
        if sel is not None:
            out["selected_depths"] = [int(d) for d in sel[1]]
        return {"ok": True, "session": sess.id, "state": sess.state,
                "result": out}

    def _op_designs(self, msg: dict) -> dict:
        return {"ok": True, "designs": self.service.registry.stats()}

    def _op_stats(self, msg: dict) -> dict:
        return {"ok": True, "stats": self.service.stats()}

    def _op_shutdown(self, msg: dict) -> dict:
        return {"ok": True, "shutdown": True}


class AdvisorClient:
    """In-process client for tests, examples, and benchmarks.

    Speaks the same request/response dicts as the wire protocol (so
    protocol coverage comes for free) but drives the service loop
    itself — there is no server; :meth:`run` is a synchronous
    open-and-drive call returning the real
    :class:`~repro.core.advisor.DseResult` object.
    """

    def __init__(self, service: Optional[AdvisoryService] = None,
                 **service_kwargs):
        self.service = service or AdvisoryService(**service_kwargs)
        self.handler = ProtocolHandler(self.service)

    def request(self, msg: dict) -> dict:
        """Send one protocol request; raises on an error response."""
        out = self.handler.handle(msg)
        if not out.get("ok"):
            raise ProtocolError(out.get("error", "request failed"))
        return out

    # ------------------------------------------------------- conveniences
    def open(self, design: str, optimizer: str = "grouped_sa",
             budget: int = 300, seed: int = 0, **kwargs) -> str:
        """Open a session; returns its id."""
        msg = {"op": "open", "design": design, "optimizer": optimizer,
               "budget": budget, "seed": seed}
        if kwargs:
            msg["kwargs"] = kwargs
        return self.request(msg)["session"]

    def drive(self, max_rounds: Optional[int] = None) -> int:
        """Advance the service until idle; returns rounds executed."""
        return self.request({"op": "run", "max_rounds": max_rounds})[
            "rounds"]

    def run(self, design: str, optimizer: str = "grouped_sa",
            budget: int = 300, seed: int = 0, **kwargs):
        """Open + drive to completion; returns the session's
        :class:`DseResult` (bit-identical to ``FifoAdvisor.run``)."""
        sid = self.open(design, optimizer=optimizer, budget=budget,
                        seed=seed, **kwargs)
        self.drive()
        return self.result(sid)

    def events(self, sid: Optional[str] = None) -> List[dict]:
        """Drain queued progress/done events."""
        return self.handler.poll_events(sid)

    def cancel(self, sid: str) -> dict:
        return self.request({"op": "cancel", "session": sid})

    def release(self, sid: str) -> dict:
        """Forget a session server-side (fetch results first)."""
        return self.request({"op": "close", "session": sid})

    def status(self, sid: str) -> dict:
        return self.request({"op": "status", "session": sid})

    def result(self, sid: str):
        """The real :class:`DseResult` object (in-process privilege)."""
        return self.service.result(sid)

    def result_json(self, sid: str, alpha: float = 0.7) -> dict:
        """The wire-protocol result payload for the session."""
        return self.request({"op": "result", "session": sid,
                             "alpha": alpha})["result"]

    def close(self) -> None:
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
