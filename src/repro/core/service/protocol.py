"""Advisory-service wire protocol v2: JSON lines, transport-agnostic.

One message per line, one JSON object per message.  Requests carry an
``op`` and an optional ``id`` (echoed back verbatim, so clients can
correlate responses over a shared connection); responses carry
``ok: true/false``; server-pushed events carry an ``event`` key instead
of ``ok``.  The full message reference and the v1 -> v2 migration table
live in ``docs/service.md``.

Protocol v2 adds, on top of v1:

* a ``hello`` handshake (``{"op": "hello", "proto": 2}``) that
  negotiates the protocol version and advertises the server's ops —
  clients that skip it are treated as v1;
* **stable error codes**: every error frame carries a ``code`` from
  :data:`ERROR_CODES` next to the human-readable ``error`` string, so
  clients branch on codes, not message prose;
* **explicit backpressure**: when the service is at its session cap,
  ``open`` fails fast with ``E_OVERLOADED`` and a ``retry_after_s``
  hint measured from live round times — clients back off instead of
  queueing invisibly;
* ``release`` as the canonical name for dropping a session, and a
  ``snapshot`` op that persists the registry for warm restarts
  (``docs/architecture.md``).

Protocol v1 remains fully accepted: :func:`adapt_v1` rewrites the one
renamed op (``close`` -> ``release``) and v1 clients simply ignore the
extra ``code`` key in error frames (v1's ``error`` string is still
always present).

The :class:`ProtocolHandler` maps request dicts to response dicts
against an :class:`~repro.core.service.batcher.AdvisoryService` — the
asyncio server (``repro.launch.serve``), the stdio loop, and the
in-process :class:`AdvisorClient` all share it, so the protocol is
exercised end-to-end even in fully in-process tests.
"""

from __future__ import annotations

import json
import warnings
from typing import Iterator, List, Optional

from repro.core.service.batcher import AdvisoryService, ServiceOverloaded

__all__ = ["AdvisorClient", "ERROR_CODES", "PROTO", "ProtocolError",
           "ProtocolHandler", "SessionHandle", "SUPPORTED_PROTOS",
           "adapt_v1", "decode_line", "encode_line"]

#: current protocol version; ``hello`` negotiates within SUPPORTED_PROTOS
PROTO = 2
SUPPORTED_PROTOS = (1, 2)

#: requests the handler understands (anything else is E_PROTO).
#: ``close`` is the deprecated v1 spelling of ``release``.
#: ``attach`` is the reconnect/resume path: replay a session's event
#: suffix after a dropped connection (``docs/robustness.md``).
OPS = ("hello", "open", "attach", "run", "step", "cancel", "release",
       "close", "status", "result", "designs", "stats", "snapshot",
       "shutdown")

# ------------------------------------------------------------ error codes
#: the stable error vocabulary; codes never change meaning across
#: releases (new codes may be added), so clients can branch on them
E_PROTO = "E_PROTO"              # malformed frame / unknown op / bad proto
E_BAD_REQUEST = "E_BAD_REQUEST"  # well-formed op, invalid arguments
E_BAD_DESIGN = "E_BAD_DESIGN"    # unknown design name
E_BAD_OPTIMIZER = "E_BAD_OPTIMIZER"  # unknown optimizer name
E_BAD_SESSION = "E_BAD_SESSION"  # unknown/released session id
E_OVERLOADED = "E_OVERLOADED"    # admission refused; see retry_after_s
E_INTERNAL = "E_INTERNAL"        # engine failure behind a valid request
E_TIMEOUT = "E_TIMEOUT"          # evaluation exceeded the session deadline

ERROR_CODES = (E_PROTO, E_BAD_REQUEST, E_BAD_DESIGN, E_BAD_OPTIMIZER,
               E_BAD_SESSION, E_OVERLOADED, E_INTERNAL, E_TIMEOUT)


class ProtocolError(ValueError):
    """Malformed or unanswerable client message.

    ``code`` is the stable :data:`ERROR_CODES` entry for the error
    frame; ``extra`` keys (e.g. ``retry_after_s``) are merged into it.
    """

    def __init__(self, message: str, code: str = E_PROTO, **extra):
        super().__init__(message)
        self.code = code
        self.extra = extra


def encode_line(msg: dict) -> str:
    """One message -> one newline-terminated JSON line."""
    return json.dumps(msg, separators=(",", ":")) + "\n"


def decode_line(line) -> dict:
    """One line -> one message dict (:class:`ProtocolError` if not)."""
    if isinstance(line, bytes):
        line = line.decode("utf-8", errors="replace")
    try:
        msg = json.loads(line)
    except json.JSONDecodeError as exc:
        raise ProtocolError(f"not valid JSON: {exc}") from None
    if not isinstance(msg, dict):
        raise ProtocolError("message must be a JSON object")
    return msg


def adapt_v1(msg: dict) -> dict:
    """Rewrite a protocol-v1 request as its v2 equivalent.

    v1 differs from v2 only in naming (``close`` -> ``release``) and in
    lacking ``hello``/``snapshot``; every v1 frame therefore maps 1:1
    and old clients keep working unchanged against a v2 server.
    """
    if msg.get("op") == "close":
        msg = dict(msg, op="release")
    return msg


class ProtocolHandler:
    """Maps one decoded request to one response dict.

    Stateless beyond the service it fronts; safe to share across
    connections (sessions are service-global — a connection may query
    any session id it knows).

    Args:
        service: the :class:`AdvisoryService` to front.
        snapshot_dir: default directory for the ``snapshot`` op (the
            op's ``dir`` argument overrides it; with neither, the op
            fails with ``E_BAD_REQUEST``).
    """

    def __init__(self, service: AdvisoryService,
                 snapshot_dir: Optional[str] = None):
        self.service = service
        self.snapshot_dir = snapshot_dir

    def handle(self, msg: dict) -> dict:
        """Answer one request; never raises — errors become
        ``{"ok": false, "code": ..., "error": ...}`` frames."""
        rid = msg.get("id")
        try:
            out = self._dispatch(adapt_v1(msg))
        except ProtocolError as exc:
            out = {"ok": False, "code": exc.code, "error": str(exc),
                   **exc.extra}
        except ServiceOverloaded as exc:
            out = {"ok": False, "code": E_OVERLOADED, "error": str(exc),
                   "retry_after_s": exc.retry_after_s,
                   "max_sessions": exc.max_sessions}
        except Exception as exc:   # noqa: BLE001 — server boundary: an
            # engine failure (worker death, bad optimizer kwargs) must
            # become an error frame, never a dropped connection
            out = {"ok": False, "code": E_INTERNAL,
                   "error": f"{type(exc).__name__}: {exc}"}
        if rid is not None:
            out["id"] = rid
        return out

    def poll_events(self, sid: Optional[str] = None) -> List[dict]:
        """Drain queued progress/done events (push frames)."""
        return self.service.drain_events(sid)

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, msg: dict) -> dict:
        op = msg.get("op")
        if op not in OPS:
            raise ProtocolError(
                f"unknown op {op!r}; expected one of {list(OPS)}")
        return getattr(self, f"_op_{op}")(msg)

    def _session_of(self, msg: dict):
        sid = msg.get("session")
        if not sid:
            raise ProtocolError(f"op {msg.get('op')!r} needs a 'session'",
                                code=E_BAD_REQUEST)
        try:
            return self.service.session(sid)
        except KeyError as exc:
            raise ProtocolError(str(exc), code=E_BAD_SESSION) from None

    def _op_hello(self, msg: dict) -> dict:
        proto = msg.get("proto", 1)
        if proto not in SUPPORTED_PROTOS:
            raise ProtocolError(
                f"unsupported proto {proto!r}; server supports "
                f"{list(SUPPORTED_PROTOS)}")
        return {"ok": True, "proto": int(proto), "server": "fifoadvisor",
                "ops": [o for o in OPS if o != "close"],
                "max_sessions": self.service.max_sessions}

    def _op_open(self, msg: dict) -> dict:
        design = msg.get("design")
        if not design:
            raise ProtocolError("op 'open' needs a 'design'",
                                code=E_BAD_REQUEST)
        kwargs = msg.get("kwargs") or {}
        if not isinstance(kwargs, dict):
            raise ProtocolError("'kwargs' must be an object",
                                code=E_BAD_REQUEST)
        deadline = msg.get("deadline")
        try:
            sess = self.service.open_session(
                design, optimizer=msg.get("optimizer", "grouped_sa"),
                budget=int(msg.get("budget", 300)),
                seed=int(msg.get("seed", 0)),
                progress_events=msg.get("progress"),
                deadline_s=None if deadline is None else float(deadline),
                request_id=msg.get("req"), **kwargs)
        except KeyError as exc:
            code = (E_BAD_OPTIMIZER if "optimizer" in str(exc)
                    else E_BAD_DESIGN)
            raise ProtocolError(str(exc), code=code) from None
        return {"ok": True, "session": sess.id, "design": sess.design,
                "optimizer": sess.optimizer, "budget": sess.budget,
                "seed": sess.seed, "state": sess.state}

    def _op_attach(self, msg: dict) -> dict:
        """Reconnect/resume: replay the session's retained event-stream
        suffix after the last ``seq`` the client saw (``after_seq``;
        -1 replays everything retained).  ``replay_complete`` is false
        when events between ``after_seq`` and the log floor already
        aged out of the bounded log — the client should then fall back
        to ``status``/``result`` for ground truth."""
        sess = self._session_of(msg)
        after = int(msg.get("after_seq", -1))
        events = sess.events_after(after)
        complete = not (sess.event_log
                        and sess.replay_floor > after + 1)
        return {"ok": True, "session": sess.id, "state": sess.state,
                "events": events, "replay_complete": complete,
                "next_seq": sess.status()["next_seq"]}

    def _op_run(self, msg: dict) -> dict:
        rounds = self.service.run_until_idle(msg.get("max_rounds"))
        return {"ok": True, "rounds": rounds,
                "running": len(self.service.running)}

    def _op_step(self, msg: dict) -> dict:
        return {"ok": True, "advanced": self.service.step(),
                "running": len(self.service.running)}

    def _op_cancel(self, msg: dict) -> dict:
        sess = self._session_of(msg)
        sess.cancel()
        return {"ok": True, "session": sess.id, "state": sess.state,
                "n_evals": int(sess.ctx.n_evals)}

    def _op_release(self, msg: dict) -> dict:
        """Release a session entirely (fetch ``result`` first — the id
        becomes unknown afterwards)."""
        sess = self._session_of(msg)
        self.service.release(sess.id)
        return {"ok": True, "session": sess.id, "state": sess.state,
                "released": True}

    def _op_status(self, msg: dict) -> dict:
        return {"ok": True, **self._session_of(msg).status()}

    def _op_result(self, msg: dict) -> dict:
        sess = self._session_of(msg)
        dse = sess.dse_result()
        alpha = float(msg.get("alpha", 0.7))
        out = dse.summary(alpha)
        out["frontier"] = dse.frontier_points.tolist()
        out["hypervolume"] = float(dse.hypervolume())
        sel = dse.selected(alpha)
        if sel is not None:
            out["selected_depths"] = [int(d) for d in sel[1]]
        return {"ok": True, "session": sess.id, "state": sess.state,
                "result": out}

    def _op_designs(self, msg: dict) -> dict:
        return {"ok": True, "designs": self.service.registry.stats()}

    def _op_stats(self, msg: dict) -> dict:
        return {"ok": True, "stats": self.service.stats()}

    def _op_snapshot(self, msg: dict) -> dict:
        directory = msg.get("dir") or self.snapshot_dir
        if not directory:
            raise ProtocolError(
                "op 'snapshot' needs a 'dir' (or a server --snapshot-dir)",
                code=E_BAD_REQUEST)
        from repro.core.service.snapshot import save_snapshot
        manifest = save_snapshot(self.service.registry, directory)
        return {"ok": True, "dir": directory,
                "designs": sorted(manifest["designs"]),
                "skipped": manifest["skipped"]}

    def _op_shutdown(self, msg: dict) -> dict:
        return {"ok": True, "shutdown": True}


class SessionHandle(str):
    """A live session: the v2 client-side handle.

    Subclasses ``str`` (its value IS the session id), so every API that
    accepted a sid string — including JSON encoding and the deprecated
    sid-based client methods — keeps working on a handle unchanged,
    while new code gets methods scoped to the one session:

        with client.open("gemm", budget=300) as h:
            for event in h.stream():
                ...
            dse = h.result()

    Exiting the ``with`` block releases the session server-side.
    """

    def __new__(cls, sid: str, client: "AdvisorClient"):
        self = super().__new__(cls, sid)
        self._client = client
        return self

    def status(self) -> dict:
        return self._client._status(str(self))

    def stream(self, max_rounds: Optional[int] = None) -> Iterator[dict]:
        """Drive the service and yield this session's events as they
        appear, until the session finishes (or ``max_rounds``)."""
        rounds = 0
        while True:
            self._client.request({"op": "step"})
            rounds += 1
            yield from self._client.events(str(self))
            if self.status()["state"] != "running":
                yield from self._client.events(str(self))
                return
            if max_rounds is not None and rounds >= max_rounds:
                return

    def result(self):
        """The real :class:`DseResult` object (in-process privilege)."""
        return self._client._result(str(self))

    def result_json(self, alpha: float = 0.7) -> dict:
        return self._client._result_json(str(self), alpha)

    def cancel(self) -> dict:
        return self._client._cancel(str(self))

    def release(self) -> dict:
        """Forget the session server-side (fetch results first)."""
        return self._client._release(str(self))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class AdvisorClient:
    """In-process client for tests, examples, and benchmarks.

    Speaks the same request/response dicts as the wire protocol (so
    protocol coverage comes for free) but drives the service loop
    itself — there is no server; :meth:`run` is a synchronous
    open-and-drive call returning the real
    :class:`~repro.core.advisor.DseResult` object.

    :meth:`open` returns a :class:`SessionHandle`; the pre-v2 sid-string
    methods (``client.status(sid)`` etc.) still work but emit a
    :class:`DeprecationWarning` — use the handle's methods.
    """

    def __init__(self, service: Optional[AdvisoryService] = None,
                 **service_kwargs):
        self.service = service or AdvisoryService(**service_kwargs)
        self.handler = ProtocolHandler(self.service)
        #: protocol version negotiated with the handler (always the
        #: newest here; TCP clients get it from their hello reply)
        self.proto = self.request({"op": "hello", "proto": PROTO})["proto"]

    def request(self, msg: dict) -> dict:
        """Send one protocol request; raises on an error response (the
        raised :class:`ProtocolError` carries the frame's ``code``)."""
        out = self.handler.handle(msg)
        if not out.get("ok"):
            extra = {k: v for k, v in out.items()
                     if k not in ("ok", "code", "error", "id")}
            raise ProtocolError(out.get("error", "request failed"),
                                code=out.get("code", E_INTERNAL), **extra)
        return out

    # ------------------------------------------------------- conveniences
    def open(self, design: str, optimizer: str = "grouped_sa",
             budget: int = 300, seed: int = 0,
             progress: Optional[bool] = None, **kwargs) -> SessionHandle:
        """Open a session; returns its :class:`SessionHandle`."""
        msg = {"op": "open", "design": design, "optimizer": optimizer,
               "budget": budget, "seed": seed}
        if progress is not None:
            msg["progress"] = progress
        if kwargs:
            msg["kwargs"] = kwargs
        return SessionHandle(self.request(msg)["session"], self)

    def drive(self, max_rounds: Optional[int] = None) -> int:
        """Advance the service until idle; returns rounds executed."""
        return self.request({"op": "run", "max_rounds": max_rounds})[
            "rounds"]

    def run(self, design: str, optimizer: str = "grouped_sa",
            budget: int = 300, seed: int = 0, **kwargs):
        """Open + drive to completion; returns the session's
        :class:`DseResult` (bit-identical to ``FifoAdvisor.run``)."""
        handle = self.open(design, optimizer=optimizer, budget=budget,
                           seed=seed, **kwargs)
        self.drive()
        return handle.result()

    def events(self, sid: Optional[str] = None) -> List[dict]:
        """Drain queued progress/done events."""
        return self.handler.poll_events(sid)

    def attach(self, sid: str, after_seq: int = -1) -> dict:
        """Reconnect to a session: replay its event suffix after
        ``after_seq`` (see the ``attach`` op)."""
        return self.request({"op": "attach", "session": sid,
                             "after_seq": after_seq})

    # ------------------------------------------- private per-sid backends
    def _cancel(self, sid: str) -> dict:
        return self.request({"op": "cancel", "session": sid})

    def _release(self, sid: str) -> dict:
        return self.request({"op": "release", "session": sid})

    def _status(self, sid: str) -> dict:
        return self.request({"op": "status", "session": sid})

    def _result(self, sid: str):
        return self.service.result(sid)

    def _result_json(self, sid: str, alpha: float = 0.7) -> dict:
        return self.request({"op": "result", "session": sid,
                             "alpha": alpha})["result"]

    # --------------------------------------- deprecated sid-string methods
    def _deprecated_sid(self, name: str):
        warnings.warn(
            f"AdvisorClient.{name}(sid) is deprecated; use the "
            f"SessionHandle returned by open() — handle.{name}()",
            DeprecationWarning, stacklevel=3)

    def cancel(self, sid: str) -> dict:
        self._deprecated_sid("cancel")
        return self._cancel(sid)

    def release(self, sid: str) -> dict:
        """Deprecated: use ``handle.release()``."""
        self._deprecated_sid("release")
        return self._release(sid)

    def status(self, sid: str) -> dict:
        self._deprecated_sid("status")
        return self._status(sid)

    def result(self, sid: str):
        """Deprecated: use ``handle.result()``."""
        self._deprecated_sid("result")
        return self._result(sid)

    def result_json(self, sid: str, alpha: float = 0.7) -> dict:
        self._deprecated_sid("result_json")
        return self._result_json(sid, alpha)

    def close(self) -> None:
        self.service.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
