"""Client sessions: one stepwise optimizer run per connected client.

A :class:`Session` wraps the campaign engine's stepwise
``propose()/observe()`` optimizer protocol
(:mod:`repro.core.optimizers.base`) for service use: it owns a fresh
:class:`~repro.core.optimizers.EvalContext` bound to the registry's
shared advisor (shared evaluator + shared design-wide cache), exposes
the outstanding :class:`~repro.core.optimizers.EvalRequest` to the
cross-session batcher, and turns every completed round into streaming
progress events — frontier/hypervolume *deltas*, so an interactive
client sees the Pareto front sharpen round by round instead of polling
a final blob.

Lifecycle::

    running --(generator exhausts)--> done
    running --(cancel())-----------> cancelled   (partial result kept)
    running --(deadline exceeded)--> failed      (partial result kept,
                                                  error code E_TIMEOUT)

Every queued event carries a monotonically increasing ``seq`` number,
and a bounded replay log retains the most recent events even after they
are drained — so a client that loses its connection can re-attach and
replay the exact suffix of its event stream from the last ``seq`` it
saw (``events_after``; ``docs/robustness.md``).

Because evaluation is exact and the optimizer is a deterministic
function of ``(seed, observed results)``, a session's history — and
therefore its frontier and hypervolume — is bit-identical to a solo
``FifoAdvisor.run()`` with the same seed, no matter how many other
sessions were batched alongside it (asserted in
``tests/test_service.py`` and ``benchmarks/service.py``).
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, Optional

import numpy as np

from repro.core.advisor import DseResult, FifoAdvisor
from repro.core.campaign.router import RoutedRequest
from repro.core.optimizers import OPTIMIZERS, EvalRequest
from repro.core.pareto import hypervolume_2d

__all__ = ["Session"]

RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"
FAILED = "failed"

#: per-session event-queue bound.  A client that never drains its
#: progress stream must not grow server memory without limit; beyond
#: this the oldest events are dropped (counted in ``events_dropped``
#: and surfaced via ``status()``).  The terminal done/cancelled event
#: is always the newest append, so completion is never the one lost.
MAX_QUEUED_EVENTS = 1024


class Session:
    """One client's DSE run, drivable one batched round at a time.

    Args:
        sid: service-unique session id (``"s0"``, ``"s1"``, ...).
        design: registry key of the design being sized.
        advisor: the registry's shared :class:`FifoAdvisor` for it.
        optimizer: registered optimizer name (see ``OPTIMIZERS``).
        budget: evaluation budget (simulated rows, i.e. cache misses).
        seed: RNG seed; determines the whole trajectory.
        opt_kwargs: extra optimizer constructor keywords.
        lane: sticky evaluation-lane affinity (pool routing).
        progress_events: emit per-round frontier/hypervolume deltas
            (costs one frontier recomputation per round — cheap, but
            off-switchable for throughput benchmarking).
        deadline_s: per-round evaluation deadline.  A round whose
            attributed evaluation time exceeds this fails the session
            with the stable ``E_TIMEOUT`` error code (the evaluated
            history up to that round is kept as a partial result).
            None — the default — disables the deadline.
    """

    def __init__(self, sid: str, design: str, advisor: FifoAdvisor,
                 optimizer: str = "grouped_sa", budget: int = 300,
                 seed: int = 0, opt_kwargs: Optional[dict] = None,
                 lane: int = 0, progress_events: bool = True,
                 deadline_s: Optional[float] = None):
        if optimizer not in OPTIMIZERS:
            raise KeyError(
                f"unknown optimizer {optimizer!r}; registered: "
                f"{sorted(OPTIMIZERS)}")
        self.id = sid
        self.design = design
        self.advisor = advisor
        self.optimizer = optimizer
        self.budget = int(budget)
        self.seed = int(seed)
        self.lane = int(lane)
        self.progress_events = bool(progress_events)
        self.ctx = advisor.make_context(seed=seed)
        self.opt = OPTIMIZERS[optimizer](self.ctx, budget=budget,
                                         **dict(opt_kwargs or {}))
        self.state = RUNNING
        self.rounds = 0
        self.eval_s = 0.0
        self.deadline_s = None if deadline_s is None else float(deadline_s)
        self.error_code: Optional[str] = None
        self.error: Optional[str] = None
        self.opened_at = time.perf_counter()
        self.last_event_at = self.opened_at   # heartbeat for liveness
        self.events: Deque[dict] = deque(maxlen=MAX_QUEUED_EVENTS)
        #: drained events are retained here (same bound) so a
        #: reconnecting client can replay its exact stream suffix
        self.event_log: Deque[dict] = deque(maxlen=MAX_QUEUED_EVENTS)
        self.events_dropped = 0
        self._next_seq = 0
        self._last_hv = 0.0
        self._last_frontier = 0
        self._result: Optional[DseResult] = None

    # ------------------------------------------------------ round driving
    def propose(self) -> Optional[EvalRequest]:
        """The outstanding batch, or None (finalizing if exhausted)."""
        if self.state != RUNNING:
            return None
        req = self.opt.propose()
        if req is None:
            self._finish(DONE)
        return req

    def complete_round(self, routed: RoutedRequest) -> None:
        """Absorb one routed round: cache-insert the simulated rows,
        record history/budget, step the optimizer, emit progress."""
        rows = routed.miss_rows
        if rows.size:
            self.advisor.cache.insert(
                routed.req.depths[rows], routed.lat[rows],
                routed.bram[rows], routed.dead[rows])
        self.eval_s += routed.eval_s
        self.ctx.record(routed.req.depths, routed.lat, routed.bram,
                        routed.dead, rows.size)
        self.opt.observe(routed.lat, routed.bram, routed.dead)
        self.rounds += 1
        if self.progress_events:
            self._emit_progress(int(rows.size))
        # deadline AFTER absorbing the round: the evaluation did finish,
        # so the history prefix stays identical to the solo run — the
        # session just refuses to keep paying for a wedged backend
        if (self.state == RUNNING and self.deadline_s is not None
                and routed.eval_s > self.deadline_s):
            self.fail("E_TIMEOUT",
                      f"evaluation round {self.rounds} took "
                      f"{routed.eval_s:.3f}s > deadline "
                      f"{self.deadline_s:g}s")

    def cancel(self) -> None:
        """Stop the session now; evaluated history becomes the result."""
        if self.state != RUNNING:
            return
        self.opt.close()
        self._finish(CANCELLED)

    def fail(self, code: str, message: str) -> None:
        """Fail the session with a stable error code; the evaluated
        history up to the failure is kept as a partial result."""
        if self.state != RUNNING:
            return
        self.opt.close()
        self.error_code = code
        self.error = message
        self._finish(FAILED)

    # ---------------------------------------------------------- results
    @property
    def done(self) -> bool:
        return self.state != RUNNING

    def dse_result(self) -> DseResult:
        """The session's :class:`DseResult` (partial when cancelled)."""
        if self._result is None:
            # an in-flight snapshot (status queries on a running session)
            return self._make_result()
        return self._result

    def _make_result(self) -> DseResult:
        res = self.ctx.result(self.opt.name, self.opt.step_s + self.eval_s)
        return DseResult(design_name=self.design,
                         optimizer=self.optimizer, result=res,
                         baseline_max=self.advisor.baseline_max,
                         baseline_min=self.advisor.baseline_min,
                         trace_time_s=self.advisor.trace_time_s)

    def _finish(self, state: str) -> None:
        self.state = state
        self._result = self._make_result()
        event = {
            "event": state, "session": self.id,
            "n_evals": int(self.ctx.n_evals),
            "rounds": self.rounds,
            "frontier_size": int(
                self._result.frontier_points.shape[0]),
            "hypervolume": float(self._result.hypervolume()),
        }
        if state == FAILED:
            event["code"] = self.error_code
            event["error"] = self.error
        self._queue_event(event)

    # ----------------------------------------------------------- events
    def _queue_event(self, event: dict) -> None:
        if len(self.events) == MAX_QUEUED_EVENTS:
            self.events_dropped += 1     # deque(maxlen) evicts the oldest
        event = dict(event, seq=self._next_seq)
        self._next_seq += 1
        self.last_event_at = time.perf_counter()
        self.events.append(event)
        self.event_log.append(event)

    def _hypervolume(self, pts: np.ndarray) -> float:
        return hypervolume_2d(pts,
                              self.advisor.baseline_max.hv_reference())

    def _emit_progress(self, n_simulated: int) -> None:
        """Queue a progress event when the frontier moved this round."""
        pts, _ = self.ctx.result(self.opt.name, 0.0).frontier()
        hv = self._hypervolume(pts)
        if (pts.shape[0] == self._last_frontier
                and hv == self._last_hv and self.rounds > 1):
            return
        self._queue_event({
            "event": "progress", "session": self.id,
            "round": self.rounds,
            "n_evals": int(self.ctx.n_evals),
            "n_simulated": n_simulated,
            "frontier_size": int(pts.shape[0]),
            "frontier_delta": int(pts.shape[0] - self._last_frontier),
            "hypervolume": float(hv),
            "hv_delta": float(hv - self._last_hv),
        })
        self._last_frontier = int(pts.shape[0])
        self._last_hv = float(hv)

    def drain_events(self):
        """Pop and return every queued event (oldest first)."""
        out = list(self.events)
        self.events.clear()
        return out

    def events_after(self, seq: int):
        """Replay the retained event-stream suffix after ``seq`` (the
        reconnect path: a client re-attaches with the last seq it saw
        and receives exactly what it missed).  The undelivered queue is
        cleared — every undelivered event is in the replayed suffix, so
        leaving it would deliver duplicates."""
        out = [e for e in self.event_log if e["seq"] > seq]
        self.events.clear()
        return out

    @property
    def replay_floor(self) -> int:
        """Smallest seq still replayable (events before it aged out of
        the bounded log)."""
        return self.event_log[0]["seq"] if self.event_log else 0

    def status(self) -> dict:
        """JSON-ready snapshot of the session."""
        out = {
            "session": self.id, "design": self.design,
            "optimizer": self.optimizer, "state": self.state,
            "seed": self.seed, "budget": self.budget,
            "rounds": self.rounds, "n_evals": int(self.ctx.n_evals),
            "eval_s": round(self.eval_s, 4),
            "events_dropped": self.events_dropped,
            "next_seq": self._next_seq,
        }
        if self.deadline_s is not None:
            out["deadline_s"] = self.deadline_s
        if self.error_code is not None:
            out["code"] = self.error_code
            out["error"] = self.error
        return out
