"""Async FIFO-sizing advisory service with cross-session hetero batching.

The service layer turns the repo from a batch tool into a server: a
:class:`DesignRegistry` traces each design once and advises on it
forever; each client :class:`Session` is a stepwise optimizer driven by
the ``propose()/observe()`` protocol; the
:class:`CrossSessionBatcher` packs outstanding evaluation requests from
*different* clients and *different* designs into single routed
dispatches (sharing :class:`~repro.core.campaign.router.RoundRouter`
with the campaign engine); and :class:`AdvisorClient` /
``python -m repro.launch.serve`` expose it in-process and over
JSON-lines TCP/stdio.  See ``docs/service.md``.

Everything here is exact: a session's frontier is bit-identical to a
solo ``FifoAdvisor.run()`` with the same seed, regardless of batching.
"""

from repro.core.config import EvalConfig
from repro.core.faults import Fault, FaultPlan, InjectedFault
from repro.core.service.batcher import (AdvisoryService,
                                        CrossSessionBatcher,
                                        ServiceOverloaded)
from repro.core.service.protocol import (ERROR_CODES, PROTO, AdvisorClient,
                                         ProtocolError, ProtocolHandler,
                                         SessionHandle, adapt_v1,
                                         decode_line, encode_line)
from repro.core.service.registry import DesignRegistry
from repro.core.service.session import Session
from repro.core.service.snapshot import (SnapshotError, load_snapshot,
                                         save_snapshot)

__all__ = [
    "AdvisorClient", "AdvisoryService", "CrossSessionBatcher",
    "DesignRegistry", "ERROR_CODES", "EvalConfig", "Fault", "FaultPlan",
    "InjectedFault", "PROTO", "ProtocolError", "ProtocolHandler",
    "ServiceOverloaded", "Session", "SessionHandle", "SnapshotError",
    "adapt_v1", "decode_line", "encode_line", "load_snapshot",
    "save_snapshot",
]
