"""Design registry: trace each design once, advise on it forever.

The registry is the service's only stateful view of a design.  The first
session that names a design pays the trace + simgraph build + baseline
evaluation (one-time, ~100 ms-scale); every later session on the same
design reuses the built :class:`~repro.core.advisor.FifoAdvisor` — its
evaluator, pruned candidate grids, baselines, and the advisor-wide
:class:`~repro.core.backends.ConfigCache`, so sessions share evaluation
hits with each other exactly as campaign tasks do.

Registry entries expose ``.evaluator`` and ``.graph`` (they ARE
``FifoAdvisor`` instances), so the registry mapping plugs directly into
:class:`~repro.core.campaign.router.RoundRouter` as its design table.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

from repro.core.advisor import FifoAdvisor
from repro.core.config import EvalConfig, resolve_config
from repro.core.design import Design

__all__ = ["DesignRegistry"]


class DesignRegistry:
    """Mapping of design name -> cached :class:`FifoAdvisor`.

    Args:
        config: the :class:`EvalConfig` every advisor is built with
            (defaults to ``EvalConfig()``).  The deprecated
            ``backend=``/``max_iters=`` keywords still map onto it.
        advisor_kwargs: extra *runtime-only* keyword arguments forwarded
            to every :class:`FifoAdvisor` (e.g. ``mesh=...``).
    """

    def __init__(self, config: Optional[EvalConfig] = None,
                 advisor_kwargs: Optional[dict] = None, **legacy):
        self.config = resolve_config(config, legacy, "DesignRegistry")
        self.advisor_kwargs = dict(advisor_kwargs or {})
        self._advisors: Dict[str, FifoAdvisor] = {}
        #: names registered with an explicit Design object — these are
        #: NOT rebuildable via ``make_design`` in a fresh process, which
        #: matters to engines that re-trace by name (the worker pool)
        self.custom_names: set = set()
        #: set by the snapshot loader: {"restored": [...],
        #: "quarantined": {name: reason}} — None until a restore ran
        self.restore_report: Optional[dict] = None

    @property
    def backend(self) -> str:
        return self.config.backend

    @property
    def max_iters(self) -> int:
        return self.config.max_iters

    def register(self, name: str,
                 design: Optional[Design] = None) -> FifoAdvisor:
        """Return the advisor for ``name``, building it on first use.

        ``design`` optionally supplies an explicit :class:`Design` object
        (for custom, non-benchmark designs); otherwise the name is
        resolved through ``repro.designs.make_design``.  Re-registering
        an existing name returns the cached advisor untouched.
        """
        adv = self._advisors.get(name)
        if adv is not None:
            return adv
        if design is None:
            from repro.designs import make_design
            design = make_design(name)
        else:
            self.custom_names.add(name)
        adv = FifoAdvisor(design, self.config, **self.advisor_kwargs)
        self._advisors[name] = adv
        return adv

    def adopt(self, name: str, advisor: FifoAdvisor,
              custom: bool = False) -> FifoAdvisor:
        """Install a prebuilt advisor (the snapshot warm-restart path).

        Re-adopting an existing name replaces the cached advisor; the
        snapshot loader uses this to hand the registry fully restored
        advisors without re-tracing.
        """
        self._advisors[name] = advisor
        if custom:
            self.custom_names.add(name)
        return advisor

    # --------------------------------------------------- mapping protocol
    def __getitem__(self, name: str) -> FifoAdvisor:
        return self._advisors[name]

    def __contains__(self, name: str) -> bool:
        return name in self._advisors

    def __len__(self) -> int:
        return len(self._advisors)

    def __iter__(self) -> Iterator[str]:
        return iter(self._advisors)

    def names(self):
        """Registered design names, in registration order."""
        return list(self._advisors)

    def stats(self) -> Dict[str, dict]:
        """Per-design registry statistics (JSON-ready): trace time,
        graph size, baselines, and shared-cache hit counters."""
        out = {}
        for name, adv in self._advisors.items():
            cs = adv.cache_stats()
            out[name] = {
                "n_fifos": int(adv.graph.n_fifos),
                "n_events": int(adv.graph.n_events),
                "trace_time_s": round(adv.trace_time_s, 4),
                "baseline_max": (adv.baseline_max.latency,
                                 adv.baseline_max.bram),
                "cache": {"hits": cs.hits, "misses": cs.misses,
                          "hit_rate": round(cs.hit_rate, 4)},
            }
        return out
