"""Beyond-paper pruning: SOUND per-FIFO depth lower bounds.

The paper prunes the search space to BRAM breakpoints (§III-C).  We add a
second, orthogonal pruning: for each writer/reader task pair, consider the
SUBGRAPH containing only those two tasks' events and the FIFOs between
them, with every other cross-task constraint dropped.  Dropping
constraints only removes cycles, so

    pair-subgraph deadlocks at depth vector d  =>  full design deadlocks
    for EVERY configuration that is pointwise <= d on the pair's FIFOs.

Hence the smallest d for which (fifo f = d, siblings at their upper
bounds) is pair-feasible is a sound LOWER bound on f's useful depths: all
smaller candidates are deadlocked in every configuration and can be
removed from the grid.  On reorder-hazard designs (k15mmtree: transposed
operand consumption) this eliminates ~all deadlocked proposals, which
otherwise burn most of a random/SA budget (EXPERIMENTS.md §1.6).

Single-FIFO pairs are always feasible at any depth >= the structural
minimum (rank-to-rank matching cannot reorder), so the analysis only does
work where multiple FIFOs connect the same task pair (stream arrays —
exactly where the hazard lives).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.design import READ, WRITE
from repro.core.simgraph import SimGraph


def _segments(g: SimGraph) -> Tuple[np.ndarray, np.ndarray]:
    starts = np.flatnonzero(g.seg_start)
    bounds = np.concatenate([starts, [g.n_events]]).astype(np.int64)
    seg_of_evt = np.searchsorted(starts, np.arange(g.n_events),
                                 side="right") - 1
    return bounds, seg_of_evt


def task_pairs(g: SimGraph) -> Dict[Tuple[int, int], List[int]]:
    """(writer_seg, reader_seg) -> fifo indices connecting them."""
    _, seg_of_evt = _segments(g)
    writer = {}
    reader = {}
    for e in range(g.n_events):
        f = int(g.fifo[e])
        if g.kind[e] == WRITE:
            writer[f] = int(seg_of_evt[e])
        else:
            reader[f] = int(seg_of_evt[e])
    pairs: Dict[Tuple[int, int], List[int]] = {}
    for f in range(g.n_fifos):
        if f in writer and f in reader:
            pairs.setdefault((writer[f], reader[f]), []).append(f)
    return pairs


def pair_feasible(g: SimGraph, pair: Tuple[int, int], fifos: List[int],
                  depths: Dict[int, int]) -> bool:
    """Count-only Kahn over the two segments with ONLY ``fifos`` bounded.

    Reads of third-party FIFOs are treated as instantly available and
    writes to third parties as never blocking (constraints dropped —
    that's what makes the bound sound).
    """
    bounds, _ = _segments(g)
    fset = set(fifos)
    segs = [pair[0], pair[1]] if pair[0] != pair[1] else [pair[0]]
    ev = {s: list(range(bounds[s], bounds[s + 1])) for s in segs}
    cursor = {s: 0 for s in segs}
    wcount = {f: 0 for f in fset}
    rcount = {f: 0 for f in fset}
    progress = True
    while progress:
        progress = False
        for s in segs:
            evs = ev[s]
            while cursor[s] < len(evs):
                e = evs[cursor[s]]
                f = int(g.fifo[e])
                if f in fset:
                    r = int(g.rank[e])
                    if g.kind[e] == READ:
                        if r >= wcount[f]:
                            break
                        rcount[f] += 1
                    else:
                        if r >= rcount[f] + depths[f]:
                            break
                        wcount[f] += 1
                cursor[s] += 1
                progress = True
    return all(cursor[s] == len(ev[s]) for s in segs)


def local_lower_bounds(g: SimGraph,
                       candidates: List[np.ndarray]) -> np.ndarray:
    """Per-FIFO minimal candidate depth that is pair-feasible with all
    sibling FIFOs at their largest candidates.  Returns (n_fifos,) depths
    (2 where no pruning applies)."""
    out = np.full(g.n_fifos, 2, dtype=np.int64)
    for pair, fifos in task_pairs(g).items():
        if len(fifos) < 2:
            continue        # single-FIFO pairs cannot reorder-deadlock
        top = {f: int(candidates[f][-1]) for f in fifos}
        for f in fifos:
            grid = candidates[f]
            # bisect the first feasible candidate (feasibility is monotone)
            lo, hi = 0, len(grid) - 1
            if pair_feasible(g, pair, fifos, {**top, f: int(grid[0])}):
                out[f] = int(grid[0])
                continue
            while hi - lo > 1:
                mid = (lo + hi) // 2
                if pair_feasible(g, pair, fifos, {**top, f: int(grid[mid])}):
                    hi = mid
                else:
                    lo = mid
            out[f] = int(grid[hi])
    return out
