"""EvalConfig: the one frozen, serializable evaluation configuration.

Every engine that evaluates depth configurations — ``FifoAdvisor``,
``BatchedEvaluator``, the service ``DesignRegistry``, campaign specs,
and the launch CLIs — used to grow its own copy of the same kwarg
sprawl (``backend/max_iters/condense/shards/use_pallas/...``).  This
module consolidates them into one frozen dataclass that

* round-trips through JSON (:meth:`EvalConfig.to_dict` /
  :meth:`EvalConfig.from_dict`) so snapshots and campaign checkpoints
  can persist it verbatim;
* hashes and compares by value (``frozen=True``), so registries and
  caches can key on it;
* carries only *serializable* knobs.  Runtime-only objects stay
  explicit keyword arguments on the consumers: a ``jax.sharding.Mesh``
  and per-design ``upper_bounds`` arrays on ``FifoAdvisor``, prebuilt
  ``CondensedGraph`` rung lists (``rungs=``) on ``BatchedEvaluator``.

The legacy keyword spellings still work for one release through
:func:`resolve_config`, which maps them 1:1 onto an ``EvalConfig`` and
emits a :class:`DeprecationWarning`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

__all__ = ["EvalConfig", "resolve_config"]


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """How to evaluate candidate depth configurations.

    Args:
        backend: evaluation backend — ``"numpy"``/``"worklist"`` (CPU
            fast path with incremental re-simulation), ``"jax"`` /
            ``"fixpoint"``, ``"pallas"``, ``"mesh"``, or ``"auto"``
            (one-shot per-design calibration probe).  See
            ``docs/backends.md``.
        max_iters: fixpoint iteration cap for the batched backends.
        condense: event-graph condensation — ``"auto"`` condenses once
            per design and routes batches through the certified rung
            cascade; ``None`` disables it (``docs/performance.md``).
        shards: shard batched evaluation over this many jax devices
            (forces the mesh backend; ``docs/mesh.md``).  None =
            unsharded.
        occupancy_cap: collapse candidates above observed occupancy
            (beyond-paper pruning; behaviour-preserving).
        local_bounds: sound per-FIFO lower bounds from task-pair
            feasibility (beyond-paper pruning).
        channel_bounds: sound per-FIFO lower bounds from the analytical
            channel-bounds pass (``docs/bounds.md``) — strictly more
            global than ``local_bounds`` (it follows transitive
            cross-task coupling) and free once the design is traced.
        certified_floor: clamp every search to depths at or above the
            certified minimal safe depths (``docs/fuzzing.md``).
        faults: JSON of a :class:`~repro.core.faults.FaultPlan` to
            install for this run (chaos testing; ``docs/robustness.md``).
            None — the default, and the only value used outside chaos
            suites — makes every injection point a no-op.
    """

    backend: str = "numpy"
    max_iters: int = 256
    condense: Optional[str] = "auto"
    shards: Optional[int] = None
    occupancy_cap: bool = False
    local_bounds: bool = False
    channel_bounds: bool = False
    certified_floor: bool = False
    faults: Optional[str] = None

    def __post_init__(self):
        if self.condense not in ("auto", None):
            raise ValueError(
                f"EvalConfig.condense must be 'auto' or None, got "
                f"{self.condense!r} (pass prebuilt rungs via the "
                f"evaluator's rungs= argument instead)")
        object.__setattr__(self, "max_iters", int(self.max_iters))
        if self.shards is not None:
            object.__setattr__(self, "shards", int(self.shards))

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        """JSON-ready dict; ``from_dict`` round-trips it exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EvalConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown EvalConfig field(s) {sorted(unknown)}; "
                f"expected a subset of {sorted(known)}")
        return cls(**d)

    def replace(self, **changes) -> "EvalConfig":
        """A copy with the given fields replaced."""
        return dataclasses.replace(self, **changes)


#: legacy keyword -> EvalConfig field (1:1 except use_pallas)
_LEGACY_KEYS = ("backend", "max_iters", "condense", "shards",
                "occupancy_cap", "local_bounds", "certified_floor",
                "use_pallas")


def resolve_config(config: Optional[EvalConfig], legacy: dict,
                   where: str, default: Optional[EvalConfig] = None,
                   stacklevel: int = 3) -> EvalConfig:
    """Merge deprecated keyword arguments into an :class:`EvalConfig`.

    ``legacy`` is the consumer's ``**kwargs`` dict.  Unknown keys raise
    ``TypeError`` (same contract as a plain signature); known legacy
    keys emit one :class:`DeprecationWarning` and map onto a fresh
    config (``use_pallas=True`` maps to ``backend="pallas"``).  Passing
    both ``config`` and legacy keywords is an error — silently merging
    them would hide which one wins.
    """
    unknown = [k for k in legacy if k not in _LEGACY_KEYS]
    if unknown:
        raise TypeError(
            f"{where}() got unexpected keyword argument(s) "
            f"{sorted(unknown)}")
    if not legacy:
        return config if config is not None else (default or EvalConfig())
    if config is not None:
        raise TypeError(
            f"{where}(): pass either config=EvalConfig(...) or the "
            f"deprecated keyword(s) {sorted(legacy)}, not both")
    warnings.warn(
        f"{where}({', '.join(sorted(legacy))}=...) is deprecated; pass "
        f"config=EvalConfig(...) instead (the keywords map 1:1; "
        f"use_pallas=True becomes backend='pallas')",
        DeprecationWarning, stacklevel=stacklevel)
    base = default or EvalConfig()
    fields = {k: v for k, v in legacy.items() if k != "use_pallas"}
    if legacy.get("use_pallas"):
        fields["backend"] = "pallas"
    return dataclasses.replace(base, **fields)
