"""Batched fixpoint backends: jit/vmap max-plus scan and the Pallas kernel.

Both compute event times as the least fixpoint of a monotone max-plus map;
each Jacobi step is

    cross-edge gathers (data edges + depth-dependent back-pressure)
    -> segmented max-plus *associative scan* along each task's ops

vmapped over a batch of candidate depth vectors and jit-compiled.  A true
deadlock is a positive cycle: iterates grow strictly, provably never
converging; rows are flagged DEADLOCK as soon as any time exceeds the
design's schedule upper bound, and anything still unresolved at the
iteration cap is reported UNRESOLVED for the dispatch policy to escalate to
the worklist arbiter.

The two backends share all operand preparation
(:mod:`repro.core.backends.operands`) and the whole jit wrapper
(:func:`repro.kernels.fifo_eval.ops.make_batched_eval`); they differ only
in the inner fixpoint implementation:

``FixpointBackend``  ``lax.associative_scan`` + ``lax.while_loop`` in stock
                     jnp (the TPU-native formulation, DESIGN.md §6)
``PallasBackend``    the hand-rolled Hillis-Steele kernel in
                     :mod:`repro.kernels.fifo_eval` (interpret mode on CPU)

Numeric domain: times are exact in float32 while below 2**24; the façade
asserts the design's schedule upper bound stays below ~1.5e7 cycles.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.simgraph import SimGraph

from repro.core.backends.base import EvalBackend, register_backend
from repro.core.backends.operands import get_operands

#: minimum condensation ratio for a kernel backend to fuse the
#: certificate into the evaluation launch (aggressive rungs run 25-150x;
#: the 2-3x safe rung keeps the scan path + host verifier)
FUSED_MIN_COMPRESSION = 8.0


class _ScanBackend(EvalBackend):
    """Common wrapper: shared operands + one jitted batched callable."""

    use_ref = True
    interpret = True
    wants_bucketing = True
    #: a jax.sharding.Mesh to shard the config-row axis over (None = solo
    #: jit on the default device); set by the MeshBackend subclass
    mesh = None

    @property
    def shard_multiple(self) -> int:
        """Row counts must be a multiple of this (the mesh size)."""
        return int(self.mesh.devices.size) if self.mesh is not None else 1

    def _pad_shards(self, m: np.ndarray) -> Tuple[np.ndarray, int]:
        """Pad rows (repeating the last) to a shard multiple; returns the
        padded matrix and the real row count to slice results back to."""
        c = m.shape[0]
        k = self.shard_multiple
        if k > 1 and c % k:
            m = np.concatenate([m, np.repeat(m[-1:], k - c % k, axis=0)])
        return m, c

    def prepare(self, g: SimGraph):
        from repro.kernels.fifo_eval.ops import (make_batched_eval,
                                                 make_condensed_eval)
        self.g = g
        self.ops = get_operands(g)
        self._call = make_batched_eval(
            g, interpret=self.interpret, use_ref=self.use_ref,
            max_iters=self.max_iters, mesh=self.mesh)
        self._call_times = None
        # kernel-backed backends prepared on a CondensedGraph fuse the
        # exactness certificate into the evaluation launch (the rung
        # cascade then never ships event times to the host); the jnp
        # scan reference keeps the host verifier as the cross-check.
        # Fusion only pays on high-compression rungs where the condensed
        # tiles are narrow — low-compression rungs (the 2-3x safe rung)
        # stream nearly raw-width tiles per row block, so they stay on
        # the scan path where the host verifier's cost is bounded by the
        # few escalated rows that reach them.
        self._fused = None
        if not self.use_ref:
            from repro.core.condense import CondensedGraph
            if (isinstance(g, CondensedGraph)
                    and g.compression >= FUSED_MIN_COMPRESSION):
                self._fused = make_condensed_eval(
                    g, interpret=self.interpret, max_iters=self.max_iters,
                    mesh=self.mesh)
        return self.ops

    @property
    def fused_certificate(self) -> bool:
        return getattr(self, "_fused", None) is not None

    def evaluate_certified(self, depth_matrix: np.ndarray
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray]:
        """(C, F) depths -> (latency i64, bram i64, status i8, cert bool)
        in ONE device dispatch: the kernel evaluates the condensed
        fixpoint and checks every folded cross constraint in the same
        launch (``verify_rows`` semantics — cert is True only on
        CONVERGED rows whose expansion is provably the raw least
        fixpoint).  Only valid when :attr:`fused_certificate`."""
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int32))
        m, c = self._pad_shards(m)
        lat, bram, status, cert = self._fused(m)
        lat = np.asarray(np.rint(lat[:c]), dtype=np.int64)
        bram = np.asarray(bram[:c], dtype=np.int64)
        return (lat, bram, np.asarray(status[:c], dtype=np.int8),
                np.asarray(cert[:c], dtype=bool))

    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int32))
        m, c = self._pad_shards(m)
        lat, bram, status = self._call(m)
        lat = np.asarray(np.rint(lat[:c]), dtype=np.int64)
        bram = np.asarray(bram[:c], dtype=np.int64)
        return lat, bram, np.asarray(status[:c], dtype=np.int8)

    def evaluate_with_times(self, depth_matrix: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                       np.ndarray]:
        """Like :meth:`evaluate`, also returning the (C, E_pad) final
        event times (int64) — the condensation certificate's input."""
        if self._call_times is None:
            from repro.kernels.fifo_eval.ops import make_batched_eval
            self._call_times = make_batched_eval(
                self.g, interpret=self.interpret, use_ref=self.use_ref,
                max_iters=self.max_iters, with_times=True, mesh=self.mesh)
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int32))
        m, c = self._pad_shards(m)
        lat, bram, status, times = self._call_times(m)
        lat = np.asarray(np.rint(lat[:c]), dtype=np.int64)
        bram = np.asarray(bram[:c], dtype=np.int64)
        times = np.asarray(np.rint(times[:c]), dtype=np.int64)
        return lat, bram, np.asarray(status[:c], dtype=np.int8), times


@register_backend
class FixpointBackend(_ScanBackend):
    """jit(vmap) Jacobi + segmented-scan fixpoint in stock jnp."""

    name = "fixpoint"
    aliases = ("jax",)
    use_ref = True
