"""Batched fixpoint backends: jit/vmap max-plus scan and the Pallas kernel.

Both compute event times as the least fixpoint of a monotone max-plus map;
each Jacobi step is

    cross-edge gathers (data edges + depth-dependent back-pressure)
    -> segmented max-plus *associative scan* along each task's ops

vmapped over a batch of candidate depth vectors and jit-compiled.  A true
deadlock is a positive cycle: iterates grow strictly, provably never
converging; rows are flagged DEADLOCK as soon as any time exceeds the
design's schedule upper bound, and anything still unresolved at the
iteration cap is reported UNRESOLVED for the dispatch policy to escalate to
the worklist arbiter.

The two backends share all operand preparation
(:mod:`repro.core.backends.operands`) and the whole jit wrapper
(:func:`repro.kernels.fifo_eval.ops.make_batched_eval`); they differ only
in the inner fixpoint implementation:

``FixpointBackend``  ``lax.associative_scan`` + ``lax.while_loop`` in stock
                     jnp (the TPU-native formulation, DESIGN.md §6)
``PallasBackend``    the hand-rolled Hillis-Steele kernel in
                     :mod:`repro.kernels.fifo_eval` (interpret mode on CPU)

Numeric domain: times are exact in float32 while below 2**24; the façade
asserts the design's schedule upper bound stays below ~1.5e7 cycles.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.simgraph import SimGraph

from repro.core.backends.base import EvalBackend, register_backend
from repro.core.backends.operands import get_operands


class _ScanBackend(EvalBackend):
    """Common wrapper: shared operands + one jitted batched callable."""

    use_ref = True
    interpret = True
    wants_bucketing = True

    def prepare(self, g: SimGraph):
        from repro.kernels.fifo_eval.ops import make_batched_eval
        self.g = g
        self.ops = get_operands(g)
        self._call = make_batched_eval(
            g, interpret=self.interpret, use_ref=self.use_ref,
            max_iters=self.max_iters)
        self._call_times = None
        return self.ops

    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int32))
        lat, bram, status = self._call(m)
        lat = np.asarray(np.rint(lat), dtype=np.int64)
        bram = np.asarray(bram, dtype=np.int64)
        return lat, bram, np.asarray(status, dtype=np.int8)

    def evaluate_with_times(self, depth_matrix: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                       np.ndarray]:
        """Like :meth:`evaluate`, also returning the (C, E_pad) final
        event times (int64) — the condensation certificate's input."""
        if self._call_times is None:
            from repro.kernels.fifo_eval.ops import make_batched_eval
            self._call_times = make_batched_eval(
                self.g, interpret=self.interpret, use_ref=self.use_ref,
                max_iters=self.max_iters, with_times=True)
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int32))
        lat, bram, status, times = self._call_times(m)
        lat = np.asarray(np.rint(lat), dtype=np.int64)
        bram = np.asarray(bram, dtype=np.int64)
        times = np.asarray(np.rint(times), dtype=np.int64)
        return lat, bram, np.asarray(status, dtype=np.int8), times


@register_backend
class FixpointBackend(_ScanBackend):
    """jit(vmap) Jacobi + segmented-scan fixpoint in stock jnp."""

    name = "fixpoint"
    aliases = ("jax",)
    use_ref = True
