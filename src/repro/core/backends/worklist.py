"""Event-driven Kahn-worklist backend (the LightningSim CPU primitive).

Exact longest-path solve of one configuration at a time, O(E + wakeups).
This is the reference evaluator, the arbiter for rows the batched backends
cannot classify within their iteration cap, and — crucially — the home of
the *incremental* fast path that makes FIFO sizing tractable as black-box
DSE: given a solved base configuration and a change to k FIFOs, only the
task segments whose timing actually diverges from the base solve re-run.

Incremental soundness.  Segments interact only through FIFO streams: a
segment's event times depend on the write times of FIFOs it reads (data
edges) and the read times of FIFOs it writes (back-pressure edges), each
consumed in rank order.  The delta solve re-runs the changed FIFOs'
endpoint segments from scratch and propagates *by observed difference*:

- a re-run segment reads streams of un-rerun producers straight out of the
  base solution (their inputs are unchanged, so their times stand);
- every value a re-run segment appends to a stream is compared against the
  base solution at the same rank — the consumer is only woken (and itself
  re-run from scratch) when the value differs or did not exist in the base;
- at quiescence, any re-run segment that produced *fewer* stream entries
  than the base forces its consumer to re-run (the base entries it consumed
  no longer exist).

A segment that is never woken therefore sees bit-identical inputs to the
base solve and keeps its base event times verbatim — including segments
that were incomplete (deadlocked) in the base.  The result is the same
least fixpoint the full worklist computes, at the cost of only the
divergent region; a depth change that does not move any event time costs
O(changed segments) instead of O(E).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Tuple

import numpy as np

from repro.core.bram import (design_bram_np, fifo_read_latency,
                             read_latency_np)
from repro.core.design import READ
from repro.core.simgraph import SimGraph

from repro.core.backends.base import (CONVERGED, DEADLOCK, EvalBackend,
                                      register_backend)


def _worklist_tables(g: SimGraph):
    """Cached per-graph tables for the event-driven worklist."""
    cached = getattr(g, "_worklist_cache", None)
    if cached is not None:
        return cached
    E = g.n_events
    starts = np.flatnonzero(g.seg_start)
    bounds = np.concatenate([starts, [E]]).astype(np.int64)
    n_segs = len(starts)
    # segment of each event
    seg_of_evt = np.searchsorted(starts, np.arange(E), side="right") - 1
    F = g.n_fifos
    reader_seg = np.full(F, -1, dtype=np.int64)
    writer_seg = np.full(F, -1, dtype=np.int64)
    # the owning segment of each fifo endpoint is the LAST event touching
    # it; seg_of_evt is nondecreasing, so last-touched == max over touches
    fifo_idx = g.fifo.astype(np.int64)
    is_read = g.kind == READ
    np.maximum.at(reader_seg, fifo_idx[is_read], seg_of_evt[is_read])
    np.maximum.at(writer_seg, fifo_idx[~is_read], seg_of_evt[~is_read])
    kind = g.kind.astype(np.int64)
    fifo = g.fifo.astype(np.int64)
    delta = g.delta.astype(np.int64)
    rank = g.rank.astype(np.int64)
    cached = (bounds, n_segs, kind, fifo, delta, rank, reader_seg, writer_seg)
    g._worklist_cache = cached
    return cached


def _delta_tables(g: SimGraph):
    """Cached tables for the incremental solver: per-fifo per-RANK event
    and offset tables (every rank maps to the event that determines its
    stream time — itself on a raw graph, its covering anchor plus a
    delta-chain offset on a condensed one), per-segment owned fifos, and
    the raw owner segment of each fifo's streams."""
    cached = getattr(g, "_delta_cache", None)
    if cached is not None:
        return cached
    (bounds, n_segs, kind, fifo, _, _, reader_seg, writer_seg) = \
        _worklist_tables(g)
    F = g.n_fifos
    starts = bounds[:-1]
    if getattr(g, "cov_ptr", None) is None:
        write_events: List[List[int]] = [[] for _ in range(F)]
        for e in range(g.n_events):
            if kind[e] != READ:
                write_events[int(g.fifo[e])].append(e)
        write_evt = [np.asarray(w, dtype=np.int64) for w in write_events]
        read_evt = [np.asarray(
            g.read_evt_flat[g.read_base[f]: g.read_base[f] + g.n_reads[f]],
            dtype=np.int64) for f in range(F)]
        w_off = [np.zeros(len(w), dtype=np.int64) for w in write_evt]
        r_off = [np.zeros(len(r), dtype=np.int64) for r in read_evt]
        owner_wseg = writer_seg
        owner_rseg = reader_seg
    else:
        write_evt = [np.asarray(
            g.w_anchor_flat[g.w_base[f]: g.w_base[f] + g.n_writes[f]],
            dtype=np.int64) for f in range(F)]
        w_off = [np.asarray(
            g.w_off_flat[g.w_base[f]: g.w_base[f] + g.n_writes[f]],
            dtype=np.int64) for f in range(F)]
        read_evt = [np.asarray(
            g.read_evt_flat[g.read_base[f]: g.read_base[f] + g.n_reads[f]],
            dtype=np.int64) for f in range(F)]
        r_off = [np.asarray(
            g.read_off_flat[g.read_base[f]: g.read_base[f] + g.n_reads[f]],
            dtype=np.int64) for f in range(F)]
        # raw owner segment via the first rank's covering anchor (a fifo
        # whose ops are ALL folded has no anchor-level reader/writer seg)
        def _seg_of(ci: int) -> int:
            return int(np.searchsorted(starts, ci, side="right") - 1)
        owner_wseg = np.asarray(
            [_seg_of(int(write_evt[f][0])) if g.n_writes[f] else -1
             for f in range(F)], dtype=np.int64)
        owner_rseg = np.asarray(
            [_seg_of(int(read_evt[f][0])) if g.n_reads[f] else -1
             for f in range(F)], dtype=np.int64)
    reads_of_seg: List[List[int]] = [[] for _ in range(n_segs)]
    writes_of_seg: List[List[int]] = [[] for _ in range(n_segs)]
    for f in range(F):
        if owner_rseg[f] >= 0:
            reads_of_seg[int(owner_rseg[f])].append(f)
        if owner_wseg[f] >= 0:
            writes_of_seg[int(owner_wseg[f])].append(f)
    cached = (write_evt, read_evt, w_off, r_off,
              reads_of_seg, writes_of_seg, owner_wseg, owner_rseg)
    g._delta_cache = cached
    return cached


@dataclasses.dataclass
class WorklistState:
    """Reusable artifact of one solve — the base for later deltas."""

    depths: np.ndarray        # (F,) int64 the config this state solves
    t: np.ndarray             # (E,) int64 event completion times
    seg_cursor: np.ndarray    # (S,) int64 ops completed per segment
    seg_complete: np.ndarray  # (S,) bool  per-segment completion
    latency: int              # -1 when deadlocked
    deadlocked: bool


def _latency(g: SimGraph, t) -> int:
    le = g.last_evt
    t = np.asarray(t)
    if t.size == 0:
        return int(g.end_delay.max(initial=0))
    base = np.where(le >= 0, t[np.clip(le, 0, t.size - 1)], 0)
    return int((base + g.end_delay).max(initial=0))


def _vector_tables(g: SimGraph):
    """Extra cached tables for the vectorized stretch solver: flat
    per-fifo stream layouts (write/read times indexed by op rank), the
    per-event boolean kind, and python-list mirrors for the scalar
    fallback path (list indexing is ~3x cheaper than numpy scalar
    indexing inside an interpreter loop)."""
    cached = getattr(g, "_vector_cache", None)
    if cached is not None:
        return cached
    (bounds, n_segs, kind, fifo, delta, rank, _, _) = _worklist_tables(g)
    F = g.n_fifos
    is_write = kind != READ
    # per-fifo RAW stream sizes: on a CondensedGraph only anchors appear
    # as events, but streams keep full rank-dense layout (folded entries
    # are bulk-scattered when their covering anchor completes)
    n_writes = g.n_writes.astype(np.int64)
    wbase = np.zeros(F, dtype=np.int64)
    np.cumsum(n_writes[:-1], out=wbase[1:])
    rbase = g.read_base.astype(np.int64)
    total_w = int(n_writes.sum())
    total_r = int(g.n_reads.sum())
    is_read = ~is_write
    cached = (is_read, wbase, total_w, rbase, total_r,
              fifo.tolist(), rank.tolist(), delta.tolist(),
              is_read.tolist(), wbase.tolist(), rbase.tolist())
    g._vector_cache = cached
    return cached


def _cov_tables(g):
    """Cached folded-op scatter tables for a CondensedGraph (None for a
    raw SimGraph).  Vector path: flat arrays indexed by ``cov_ptr``
    anchor slices; scalar/delta paths: per-anchor python lists of
    ``(is_read, fifo, stream_slot, offset)``."""
    cov_ptr = getattr(g, "cov_ptr", None)
    if cov_ptr is None:
        return None
    cached = getattr(g, "_cov_cache", None)
    if cached is not None:
        return cached
    (is_read, wbase, _, rbase, _, *_rest) = _vector_tables(g)
    base = np.where(g.cov_is_read, rbase[g.cov_fifo], wbase[g.cov_fifo])
    cov_slot = base + g.cov_rank
    per_anchor = []
    for ci in range(g.n_events):
        lo, hi = int(cov_ptr[ci]), int(cov_ptr[ci + 1])
        per_anchor.append([
            (bool(g.cov_is_read[k]), int(g.cov_fifo[k]), int(cov_slot[k]),
             int(g.cov_off[k])) for k in range(lo, hi)])
    cached = (cov_ptr.astype(np.int64), g.cov_is_read, g.cov_fifo,
              cov_slot.astype(np.int64), g.cov_off.astype(np.int64),
              per_anchor)
    g._cov_cache = cached
    return cached


#: sentinel "no cross-edge" time for the stretch scan (stays far below
#: any real time after the prefix-max, far above int64 underflow)
_NO_CROSS = -(2 ** 62)

#: initial availability-scan window (galloped geometrically)
_GALLOP0 = 64


def solve(g: SimGraph, depths: np.ndarray) -> WorklistState:
    """Full exact solve of one depth vector, returning a reusable state.

    Event-driven over task segments like the classic worklist, but each
    segment *run* is solved as one vectorized stretch instead of an
    event-at-a-time python loop:

    1. gallop an availability scan to find how far the segment can run
       with the streams produced so far (a read needs its rank'th write,
       a write at rank >= depth needs its back-pressure slot freed);
    2. gather every cross-edge time for the stretch in two fancy-index
       reads (write stream + read-latency for reads, read stream + 1 for
       back-pressured writes);
    3. close the intra-segment chain recurrence
       ``t_i = max(t_{i-1} + delta_i, cross_i)`` in closed form:
       ``t = D + max(pt, cummax(cross - D))`` with ``D = cumsum(delta)``;
    4. scatter the new stream times and wake the coupled segments.

    Feasible configs run in a handful of long stretches (hundreds of
    events each on the benchmark designs), so the python-interpreter cost
    per event collapses (2.5-3.5x end to end).  Heavily back-pressured
    configs ping-pong in short stretches where the vector setup overhead
    loses to the plain loop — each segment ADAPTS: a blocked-early vector
    run demotes that segment to the event-at-a-time scalar path for the
    rest of the solve.
    """
    depths = np.asarray(depths, dtype=np.int64)
    E = g.n_events
    F = g.n_fifos
    widths = np.asarray(g.widths, dtype=np.int64)
    rd_lat_f = read_latency_np(depths, widths).astype(np.int64)
    (bounds, n_segs, kind, fifo, delta, rank,
     reader_seg, writer_seg) = _worklist_tables(g)
    (is_read, wbase, total_w, rbase, total_r,
     fifol, rankl, deltal, is_readl, wbasel, rbasel) = _vector_tables(g)
    cov = _cov_tables(g)
    cov_lists = cov[5] if cov is not None else None
    depths_l = depths.tolist()
    rd_lat_l = rd_lat_f.tolist()

    t = np.zeros(E, dtype=np.int64)
    wtimes = np.zeros(total_w, dtype=np.int64)
    rtimes = np.zeros(total_r, dtype=np.int64)
    # stream cursors as python lists: shared by both paths, converted to
    # arrays only inside vector runs (F is small)
    wcount = [0] * F
    rcount = [0] * F
    cursor = [0] * n_segs
    prev_t = [0] * n_segs
    vec_ok = [True] * n_segs      # adaptive path choice per segment
    boundsl = bounds.tolist()
    queue = deque(range(n_segs))
    queued = [True] * n_segs

    while queue:
        s = queue.popleft()
        queued[s] = False
        lo = boundsl[s] + cursor[s]
        hi = boundsl[s + 1]
        if lo >= hi:
            continue

        if not vec_ok[s]:
            # ---------------- scalar path: event at a time until blocked
            i = lo
            pt = prev_t[s]
            woke_r: set = set()
            woke_w: set = set()
            while i < hi:
                f = fifol[i]
                r = rankl[i]
                ti = pt + deltal[i]
                if is_readl[i]:
                    if r >= wcount[f]:
                        break
                    cross = int(wtimes[wbasel[f] + r]) + rd_lat_l[f]
                    if cross > ti:
                        ti = cross
                    rtimes[rbasel[f] + r] = ti
                    rcount[f] = r + 1
                    woke_r.add(f)
                else:
                    dd = depths_l[f]
                    if r >= dd:
                        if r - dd >= rcount[f]:
                            break
                        slot = int(rtimes[rbasel[f] + r - dd]) + 1
                        if slot > ti:
                            ti = slot
                    wtimes[wbasel[f] + r] = ti
                    wcount[f] = r + 1
                    woke_w.add(f)
                t[i] = ti
                pt = ti
                if cov_lists is not None and cov_lists[i]:
                    # bulk-complete the folded ops this anchor covers
                    for cisr, f2, slot2, off2 in cov_lists[i]:
                        if cisr:
                            rtimes[slot2] = ti + off2
                            rcount[f2] += 1
                            woke_r.add(f2)
                        else:
                            wtimes[slot2] = ti + off2
                            wcount[f2] += 1
                            woke_w.add(f2)
                i += 1
            n = i - lo
            if n:
                cursor[s] += n
                prev_t[s] = pt
                for f in woke_r:           # freed slots -> wake writer
                    ws = writer_seg[f]
                    if ws >= 0 and not queued[ws]:
                        queue.append(ws)
                        queued[ws] = True
                for f in woke_w:           # new data -> wake reader
                    rseg = reader_seg[f]
                    if rseg >= 0 and not queued[rseg]:
                        queue.append(rseg)
                        queued[rseg] = True
            continue

        # ------------------- vector path -----------------------------
        # 1. availability gallop: find the stretch end
        wc = np.asarray(wcount, dtype=np.int64)
        rc = np.asarray(rcount, dtype=np.int64)
        window = _GALLOP0
        stop = lo
        while True:
            end = min(lo + window, hi)
            ks = is_read[lo:end]
            fs = fifo[lo:end]
            rs = rank[lo:end]
            ds = depths[fs]
            avail = np.where(ks, rs < wc[fs],
                             (rs < ds) | (rs - ds < rc[fs]))
            blocked = np.flatnonzero(~avail)
            if blocked.size:
                stop = lo + int(blocked[0])
                break
            stop = end
            if end == hi:
                break
            window *= 4
        n = stop - lo
        if n < _GALLOP0 and stop < hi:
            vec_ok[s] = False    # ping-pong segment: demote permanently
        if n == 0:
            continue

        # 2. cross-edge gather for the stretch
        ks = is_read[lo:stop]
        fs = fifo[lo:stop]
        rs = rank[lo:stop]
        cross = np.full(n, _NO_CROSS, dtype=np.int64)
        r_idx = np.flatnonzero(ks)
        if r_idx.size:
            fr = fs[r_idx]
            cross[r_idx] = wtimes[wbase[fr] + rs[r_idx]] + rd_lat_f[fr]
        w_idx = np.flatnonzero(~ks & (rs >= depths[fs]))
        if w_idx.size:
            fw = fs[w_idx]
            cross[w_idx] = rtimes[rbase[fw] + rs[w_idx]
                                  - depths[fw]] + 1

        # 3. chain recurrence in closed form
        D = np.cumsum(delta[lo:stop])
        ts = D + np.maximum(np.maximum.accumulate(cross - D), prev_t[s])
        t[lo:stop] = ts

        # 4. scatter stream times, advance, wake coupled segments
        #    (bincount over the touched fifos: one C-level pass replaces
        #    the per-fifo np.unique loop — this epilogue is the fixed
        #    per-stretch cost that bounds condensed-graph speedups)
        r_cnt = w_cnt = None
        if r_idx.size:
            fr = fs[r_idx]
            rtimes[rbase[fr] + rs[r_idx]] = ts[r_idx]
            r_cnt = np.bincount(fr, minlength=F)
        aw_idx = np.flatnonzero(~ks)
        if aw_idx.size:
            fw = fs[aw_idx]
            wtimes[wbase[fw] + rs[aw_idx]] = ts[aw_idx]
            w_cnt = np.bincount(fw, minlength=F)

        # 5. bulk-scatter the folded ops covered by the stretch anchors
        if cov is not None:
            cptr, _, cov_f, cov_slot, cov_off, _ = cov
            c0, c1 = int(cptr[lo]), int(cptr[stop])
            if c1 > c0:
                ctimes = (np.repeat(ts, np.diff(cptr[lo:stop + 1]))
                          + cov_off[c0:c1])
                cisr = g.cov_is_read[c0:c1]
                cf = cov_f[c0:c1]
                cslot = cov_slot[c0:c1]
                rsel = np.flatnonzero(cisr)
                if rsel.size:
                    rtimes[cslot[rsel]] = ctimes[rsel]
                    cnt = np.bincount(cf[rsel], minlength=F)
                    r_cnt = cnt if r_cnt is None else r_cnt + cnt
                wsel = np.flatnonzero(~cisr)
                if wsel.size:
                    wtimes[cslot[wsel]] = ctimes[wsel]
                    cnt = np.bincount(cf[wsel], minlength=F)
                    w_cnt = cnt if w_cnt is None else w_cnt + cnt

        if r_cnt is not None:
            for f in np.flatnonzero(r_cnt):
                rcount[f] += int(r_cnt[f])
                ws = writer_seg[f]         # freed slots -> wake writer
                if ws >= 0 and not queued[ws]:
                    queue.append(ws)
                    queued[ws] = True
        if w_cnt is not None:
            for f in np.flatnonzero(w_cnt):
                wcount[f] += int(w_cnt[f])
                rseg = reader_seg[f]       # new data -> wake reader
                if rseg >= 0 and not queued[rseg]:
                    queue.append(rseg)
                    queued[rseg] = True
        cursor[s] += n
        prev_t[s] = int(ts[-1])

    cursor_a = np.asarray(cursor, dtype=np.int64)
    complete = cursor_a + bounds[:-1] >= bounds[1:]
    deadlocked = not bool(complete.all())
    lat = -1 if deadlocked else _latency(g, t)
    return WorklistState(depths=depths.copy(), t=t,
                         seg_cursor=cursor_a, seg_complete=complete,
                         latency=lat, deadlocked=deadlocked)


def solve_delta(g: SimGraph, base: WorklistState, depths: np.ndarray,
                counters: Optional[list] = None) -> WorklistState:
    """Incremental re-solve against a solved base configuration.

    Re-runs the changed FIFOs' endpoint segments and whatever the observed
    timing differences transitively wake; everything else keeps its base
    event times.  ``counters``, when given, is a 1-element list incremented
    by the number of segments re-run (for stats/benchmarks).
    """
    depths = np.asarray(depths, dtype=np.int64)
    changed = np.flatnonzero(base.depths != depths)
    if changed.size == 0:
        return base

    (bounds, n_segs, kind, fifo, delta, rank,
     reader_seg, writer_seg) = _worklist_tables(g)
    (write_evt, read_evt, w_off, r_off, reads_of_seg, writes_of_seg,
     owner_wseg, owner_rseg) = _delta_tables(g)
    cov = _cov_tables(g)
    cov_lists = cov[5] if cov is not None else None
    rd_lat = [fifo_read_latency(int(d), int(w))
              for d, w in zip(depths, g.widths)]
    dl = depths.tolist()
    kindl = kind.tolist()
    fifol = fifo.tolist()
    deltal = delta.tolist()
    rankl = rank.tolist()
    boundsl = bounds.tolist()
    reader_segl = reader_seg.tolist()
    writer_segl = writer_seg.tolist()
    base_t = base.t
    base_cursor = base.seg_cursor

    # the solve loop only reads FIFO streams, never t: a numpy copy with
    # per-event scalar writes beats a full tolist/asarray round-trip
    t = base_t.copy()
    cursor = base_cursor.tolist()
    prev_t = [0] * n_segs
    visited = [False] * n_segs
    F = g.n_fifos
    # Authoritative streams: the base snapshot while the owner is not
    # re-run, swapped for a fresh list the moment the owner is visited.
    # ``base_w/base_r`` keep the base snapshots for the diff checks.
    cur_w: List[Optional[List[int]]] = [None] * F
    cur_r: List[Optional[List[int]]] = [None] * F
    base_w: List[Optional[List[int]]] = [None] * F
    base_r: List[Optional[List[int]]] = [None] * F

    def base_wstream(f: int) -> List[int]:
        s = base_w[f]
        if s is None:
            ev = write_evt[f]
            ws = int(owner_wseg[f])
            end = boundsl[ws] + cursor_base_l[ws] if ws >= 0 else 0
            # a rank's value exists in the base once its determining
            # event (its covering anchor on condensed graphs) completed
            n = int(np.searchsorted(ev, end))
            s = (base_t[ev[:n]] + w_off[f][:n]).tolist()
            base_w[f] = s
            if cur_w[f] is None:
                cur_w[f] = s
        return s

    def base_rstream(f: int) -> List[int]:
        s = base_r[f]
        if s is None:
            ev = read_evt[f]
            rs = int(owner_rseg[f])
            end = boundsl[rs] + cursor_base_l[rs] if rs >= 0 else 0
            n = int(np.searchsorted(ev, end))
            s = (base_t[ev[:n]] + r_off[f][:n]).tolist()
            base_r[f] = s
            if cur_r[f] is None:
                cur_r[f] = s
        return s

    cursor_base_l = base_cursor.tolist()
    queue = deque()
    queued = [False] * n_segs

    def visit(s: int):
        """Add segment s to the re-run set, restarting it from scratch.

        Restart cascades through already-visited consumers: a visited
        segment may have consumed s's *base* stream values (s was not
        being re-run when it read them), and those values are about to be
        re-produced — everything downstream of a reset stream restarts.
        Unvisited consumers are untouched; they join later only if the
        re-produced values actually differ from the base (wake-on-diff).

        Every stream a visited segment can touch is materialized here, so
        the hot loop below only ever does plain list indexing.
        """
        visited[s] = True
        stack = [s]
        seen = {s}
        while stack:
            x = stack.pop()
            cursor[x] = 0
            prev_t[x] = 0
            for f in writes_of_seg[x]:
                base_wstream(f)          # snapshot before the rebuild
                base_rstream(f)          # back-pressure stream x consumes
                cur_w[f] = []            # rebuilt from scratch
                rs = reader_segl[f]
                if rs >= 0 and visited[rs] and rs not in seen:
                    seen.add(rs)
                    stack.append(rs)
            for f in reads_of_seg[x]:
                base_rstream(f)
                base_wstream(f)          # data stream x consumes
                cur_r[f] = []
                ws = writer_segl[f]
                if ws >= 0 and visited[ws] and ws not in seen:
                    seen.add(ws)
                    stack.append(ws)
            if not queued[x]:
                queue.append(x)
                queued[x] = True
        return seen

    for f in changed:
        for s in (reader_segl[f], writer_segl[f]):
            if s >= 0 and not visited[s]:
                visit(s)

    while True:
        while queue:
            s = queue.popleft()
            queued[s] = False
            i = boundsl[s] + cursor[s]
            hi = boundsl[s + 1]
            pt = prev_t[s]
            wake: set = set()
            restarted = False
            while i < hi:
                f = fifol[i]
                ready = pt + deltal[i]
                if kindl[i] == READ:
                    wt = cur_w[f]
                    if len(wt) <= rankl[i]:
                        break
                    ti = wt[rankl[i]] + rd_lat[f]
                    if ready > ti:
                        ti = ready
                    rf = cur_r[f]
                    k = len(rf)
                    rf.append(ti)
                    ws = writer_segl[f]
                    if ws >= 0:
                        if visited[ws]:
                            wake.add(ws)
                        else:
                            bs = base_r[f]
                            if k >= len(bs) or bs[k] != ti:
                                # timing diverged: pull the writer into
                                # the re-run set (visit() enqueues it)
                                if s in visit(ws):
                                    restarted = True
                                    break
                else:
                    j = rankl[i]
                    d = dl[f]
                    ti = ready
                    if j >= d:
                        rt = cur_r[f]
                        if len(rt) <= j - d:
                            break
                        slot = rt[j - d] + 1
                        if slot > ti:
                            ti = slot
                    wf = cur_w[f]
                    k = len(wf)
                    wf.append(ti)
                    rs = reader_segl[f]
                    if rs >= 0:
                        if visited[rs]:
                            wake.add(rs)
                        else:
                            bs = base_w[f]
                            if k >= len(bs) or bs[k] != ti:
                                if s in visit(rs):
                                    restarted = True
                                    break
                t[i] = ti
                pt = ti
                if cov_lists is not None and cov_lists[i]:
                    # append the folded ops this anchor covers, with the
                    # same wake-on-diff propagation as own ops
                    for cisr, f2, _slot2, off2 in cov_lists[i]:
                        tv = ti + off2
                        if cisr:
                            rf2 = cur_r[f2]
                            k2 = len(rf2)
                            rf2.append(tv)
                            ws2 = writer_segl[f2]
                            if ws2 >= 0:
                                if visited[ws2]:
                                    wake.add(ws2)
                                else:
                                    bs2 = base_r[f2]
                                    if k2 >= len(bs2) or bs2[k2] != tv:
                                        if s in visit(ws2):
                                            restarted = True
                                            break
                        else:
                            wf2 = cur_w[f2]
                            k2 = len(wf2)
                            wf2.append(tv)
                            rs2 = reader_segl[f2]
                            if rs2 >= 0:
                                if visited[rs2]:
                                    wake.add(rs2)
                                else:
                                    bs2 = base_w[f2]
                                    if k2 >= len(bs2) or bs2[k2] != tv:
                                        if s in visit(rs2):
                                            restarted = True
                                            break
                    if restarted:
                        break
                cursor[s] += 1
                i += 1
            if not restarted:
                # a cascade that restarted s already reset its cursor and
                # re-queued it; committing pt would corrupt that state
                prev_t[s] = pt
            for n in wake:
                if not queued[n]:
                    queue.append(n)
                    queued[n] = True

        # Shortfall pass: a re-run producer that ended with fewer stream
        # entries than the base invalidates its consumer's base prefix.
        progressed = False
        for s in range(n_segs):
            if not visited[s]:
                continue
            for f in writes_of_seg[s]:
                rs = reader_segl[f]
                if rs >= 0 and not visited[rs] \
                        and len(cur_w[f]) < len(base_w[f]):
                    visit(rs)
                    progressed = True
            for f in reads_of_seg[s]:
                ws = writer_segl[f]
                if ws >= 0 and not visited[ws] \
                        and len(cur_r[f]) < len(base_r[f]):
                    visit(ws)
                    progressed = True
        if not progressed:
            break

    if counters is not None:
        counters[0] += sum(visited)

    cursor_a = np.asarray(cursor, dtype=np.int64)
    complete = cursor_a + bounds[:-1] >= bounds[1:]
    deadlocked = not bool(complete.all())
    lat = -1 if deadlocked else _latency(g, t)
    return WorklistState(depths=depths.copy(), t=t,
                         seg_cursor=cursor_a, seg_complete=complete,
                         latency=lat, deadlocked=deadlocked)


def evaluate_np(g: SimGraph, depths: np.ndarray) -> Tuple[int, bool]:
    """Exact (latency, deadlocked) for one depth vector (full solve)."""
    st = solve(g, depths)
    return st.latency, st.deadlocked


def affected_segments(g: SimGraph, changed_fifos: np.ndarray) -> np.ndarray:
    """Structural upper bound on the segments a delta can re-run: the
    forward closure of the changed FIFOs' endpoints over data and
    back-pressure edges.  The observed-difference propagation in
    :func:`solve_delta` typically re-runs far fewer."""
    (_, n_segs, _, _, _, _, _, _) = _worklist_tables(g)
    (_, _, _, _, reads_of_seg, writes_of_seg,
     writer_seg, reader_seg) = _delta_tables(g)
    seen = np.zeros(n_segs, dtype=bool)
    stack = []
    for f in np.asarray(changed_fifos):
        for s in (int(reader_seg[f]), int(writer_seg[f])):
            if s >= 0 and not seen[s]:
                seen[s] = True
                stack.append(s)
    while stack:
        s = stack.pop()
        for f in writes_of_seg[s]:
            n = int(reader_seg[f])
            if n >= 0 and not seen[n]:
                seen[n] = True
                stack.append(n)
        for f in reads_of_seg[s]:
            n = int(writer_seg[f])
            if n >= 0 and not seen[n]:
                seen[n] = True
                stack.append(n)
    return np.flatnonzero(seen)


@dataclasses.dataclass
class IncrementalStats:
    n_full: int = 0           # full solves
    n_delta: int = 0          # incremental solves
    segs_resolved: int = 0    # segments re-run across all deltas
    segs_total: int = 0       # segments a full solve would have run

    @property
    def resolve_fraction(self) -> float:
        return self.segs_resolved / max(self.segs_total, 1)


@register_backend
class WorklistBackend(EvalBackend):
    """Numpy Kahn worklist: exact, one config at a time, no iteration cap."""

    name = "worklist"
    aliases = ("numpy",)
    wants_bucketing = False

    def __init__(self, max_iters: int = 64):
        super().__init__(max_iters)
        self.incr_stats = IncrementalStats()

    def prepare(self, g: SimGraph):
        self.g = g
        return _worklist_tables(g)

    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        C = m.shape[0]
        lat = np.zeros(C, dtype=np.int64)
        status = np.zeros(C, dtype=np.int8)
        for i in range(C):
            li, dead = evaluate_np(self.g, m[i])
            lat[i] = li
            status[i] = DEADLOCK if dead else CONVERGED
        bram = design_bram_np(m, np.asarray(self.g.widths))
        return lat, bram, status

    def evaluate_with_times(self, depth_matrix: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                       np.ndarray]:
        """Like :meth:`evaluate`, also returning the (C, E) final event
        times — the condensation certificate's input."""
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        C = m.shape[0]
        lat = np.zeros(C, dtype=np.int64)
        status = np.zeros(C, dtype=np.int8)
        times = np.zeros((C, self.g.n_events), dtype=np.int64)
        for i in range(C):
            st = solve(self.g, m[i])
            lat[i] = st.latency
            status[i] = DEADLOCK if st.deadlocked else CONVERGED
            times[i] = st.t
        bram = design_bram_np(m, np.asarray(self.g.widths))
        return lat, bram, status, times

    # ---------------------------------------------------- incremental API
    def solve(self, depths: np.ndarray) -> WorklistState:
        self.incr_stats.n_full += 1
        return solve(self.g, depths)

    def solve_delta(self, base: WorklistState,
                    depths: np.ndarray) -> WorklistState:
        counters = [0]
        st = solve_delta(self.g, base, depths, counters=counters)
        self.incr_stats.n_delta += 1
        self.incr_stats.segs_total += int(base.seg_cursor.shape[0])
        self.incr_stats.segs_resolved += counters[0]
        return st
