"""Vectorized evaluation cache over (C, F) depth matrices.

DSE optimizers revisit configurations constantly (annealing plateaus,
frontier refinement, shared baselines), and several optimizers run against
the same design in one advisor session.  This cache memoizes exact
``(latency, bram, deadlock)`` triples keyed by the full depth row, shared
across every optimizer via :class:`~repro.core.advisor.FifoAdvisor`.

Lookups are batched: a whole (C, F) matrix is hashed in one vectorized
pass (multiply-accumulate over uint64 lanes), then resolved through an
int-keyed dict with exact row verification against the stored config
matrix — hash collisions degrade to misses, never to wrong results.
Results live in flat, geometrically-grown arrays, so hits are gathered
with one fancy-index per batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

_HASH_SEED = 0x9E3779B97F4A7C15


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    collisions: int = 0       # true hash collisions (counted as misses)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


class ConfigCache:
    """Exact result memo over depth vectors, shared across optimizers."""

    def __init__(self, n_fifos: int, initial_capacity: int = 1024):
        self.n_fifos = int(n_fifos)
        self.stats = CacheStats()
        # odd multipliers -> bijective per-lane mixing before the fold
        rng = np.random.default_rng(0xF1F0)
        self._mults = (rng.integers(1, 2**63, size=max(self.n_fifos, 1),
                                    dtype=np.int64).astype(np.uint64)
                       | np.uint64(1))
        self._map: Dict[int, int] = {}
        self._n = 0
        cap = max(int(initial_capacity), 16)
        self._rows = np.zeros((cap, self.n_fifos), dtype=np.int64)
        self._lat = np.zeros(cap, dtype=np.int64)
        self._bram = np.zeros(cap, dtype=np.int64)
        self._dead = np.zeros(cap, dtype=bool)
        self._hashes = np.zeros(cap, dtype=np.uint64)
        # lazily (re)built sorted hash index for vectorized lookups;
        # entries in [_tail_start, _n) are not indexed yet
        self._sorted_h: np.ndarray = np.zeros(0, dtype=np.uint64)
        self._sorted_idx: np.ndarray = np.zeros(0, dtype=np.int64)
        self._tail_start = 0

    def __len__(self) -> int:
        return self._n

    # ------------------------------------------------------------- hashing
    def _hash_rows(self, m: np.ndarray) -> np.ndarray:
        """(C, F) int64 -> (C,) uint64 row hashes, fully vectorized.

        Multiply-shift per lane folded with one wrapping column sum (no
        per-column python loop), then a murmur-style finalizer.  Exact
        row verification backs every hit, so hash quality only affects
        the collision-miss rate, never correctness.
        """
        u = m.astype(np.uint64, copy=False)
        h = (u * self._mults[None, :]).sum(axis=1, dtype=np.uint64)
        h ^= np.uint64(_HASH_SEED)
        h ^= h >> np.uint64(33)
        h *= np.uint64(0xFF51AFD7ED558CCD)
        h ^= h >> np.uint64(29)
        return h

    # ------------------------------------------------------------- lookup
    def lookup(self, depth_matrix: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) depths -> (lat, bram, dead, miss_mask).

        Hit rows are filled from the cache; rows flagged in ``miss_mask``
        must be evaluated and then recorded via :meth:`insert`.
        """
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        C = m.shape[0]
        lat = np.zeros(C, dtype=np.int64)
        bram = np.zeros(C, dtype=np.int64)
        dead = np.zeros(C, dtype=bool)
        miss = np.ones(C, dtype=bool)
        if self._n:
            hashes = self._hash_rows(m)
            # vectorized hit resolution: one searchsorted over the lazily
            # maintained sorted hash index replaces the per-row dict loop
            # (the stable sort keeps the first-inserted entry first, so a
            # duplicate hash resolves to the same winner the insert-time
            # dict keeps)
            sh, sidx = self._index()
            if sh.size:
                pos = np.minimum(np.searchsorted(sh, hashes), sh.size - 1)
                idx = np.where(sh[pos] == hashes, sidx[pos], -1)
            else:
                idx = np.full(C, -1, dtype=np.int64)
            if self._tail_start < self._n:
                # entries inserted since the last index rebuild: resolve
                # the (few) rows the sorted part missed through the dict
                for i in np.flatnonzero(idx < 0):
                    idx[i] = self._map.get(int(hashes[i]), -1)
            cand = np.flatnonzero(idx >= 0)
            if cand.size:
                # exact verification: collisions fall back to miss
                ok = (self._rows[idx[cand]] == m[cand]).all(axis=1)
                self.stats.collisions += int((~ok).sum())
                hit_rows = cand[ok]
                src = idx[hit_rows]
                lat[hit_rows] = self._lat[src]
                bram[hit_rows] = self._bram[src]
                dead[hit_rows] = self._dead[src]
                miss[hit_rows] = False
        n_miss = int(miss.sum())
        self.stats.misses += n_miss
        self.stats.hits += C - n_miss
        return lat, bram, dead, miss

    def _index(self):
        """The sorted hash index, rebuilt lazily and AMORTIZED: a rebuild
        only happens once the unsorted insert tail outgrows an eighth of
        the indexed part — small tails are resolved through the dict in
        :meth:`lookup`, so the miss-heavy DSE pattern (lookup ->
        evaluate -> insert, every round) never pays an O(n log n) argsort
        per round."""
        tail = self._n - self._tail_start
        if tail > max(256, self._tail_start // 8):
            order = np.argsort(self._hashes[: self._n], kind="stable")
            self._sorted_h = self._hashes[: self._n][order]
            self._sorted_idx = order.astype(np.int64)
            self._tail_start = self._n
        return self._sorted_h, self._sorted_idx

    # ------------------------------------------------------------- insert
    def _grow_to(self, n: int):
        cap = self._rows.shape[0]
        if n <= cap:
            return
        new_cap = cap
        while new_cap < n:
            new_cap *= 2
        for name in ("_rows", "_lat", "_bram", "_dead", "_hashes"):
            old = getattr(self, name)
            shape = (new_cap,) + old.shape[1:]
            new = np.zeros(shape, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)

    def load_rows(self, rows: np.ndarray, lat: np.ndarray,
                  bram: np.ndarray, dead: np.ndarray) -> None:
        """Bulk-restore cache contents (the snapshot warm-start path).

        ``rows`` must be the insertion-order contents of a previously
        populated cache (as snapshotted from ``_rows[:_n]``) — already
        deduplicated, so every row hash is unique and the restored
        first-winner ``_map`` matches the original insert order exactly.
        One vectorized pass instead of :meth:`insert`'s per-row loop;
        the sorted lookup index is rebuilt eagerly so the first lookup
        after a warm restart pays no argsort.
        """
        if self._n:
            raise ValueError("load_rows requires an empty cache")
        m = np.atleast_2d(np.asarray(rows, dtype=np.int64))
        C = m.shape[0]
        if C == 0:
            return
        self._grow_to(C)
        hashes = self._hash_rows(m)
        self._rows[:C] = m
        self._lat[:C] = np.asarray(lat, dtype=np.int64)
        self._bram[:C] = np.asarray(bram, dtype=np.int64)
        self._dead[:C] = np.asarray(dead, dtype=bool)
        self._hashes[:C] = hashes
        self._n = C
        self._map = {}
        for i, h in enumerate(hashes.tolist()):
            self._map.setdefault(int(h), i)
        order = np.argsort(hashes, kind="stable")
        self._sorted_h = hashes[order]
        self._sorted_idx = order.astype(np.int64)
        self._tail_start = C

    def insert(self, depth_matrix: np.ndarray, lat: np.ndarray,
               bram: np.ndarray, dead: np.ndarray):
        """Record evaluated rows (duplicates of cached rows are skipped)."""
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        C = m.shape[0]
        self._grow_to(self._n + C)
        hashes = self._hash_rows(m)
        for i in range(C):
            h = int(hashes[i])
            j = self._map.get(h)
            if j is not None:
                # already present (or a collision slot: keep first winner)
                continue
            j = self._n
            self._rows[j] = m[i]
            self._lat[j] = lat[i]
            self._bram[j] = bram[i]
            self._dead[j] = dead[i]
            self._hashes[j] = hashes[i]
            self._map[h] = j
            self._n += 1
