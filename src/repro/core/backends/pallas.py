"""Pallas-kernel backend: condensation-native evaluation behind the
shared operand/dispatch machinery (interpret mode on CPU, native on TPU).

Two kernels back this registry entry, selected by what ``prepare`` is
given (the rung cascade spawns one backend per rung via
``EvalBackend.spawn()`` and prepares it on that rung's graph):

* a **CondensedGraph** selects the fused mega-kernel
  (:mod:`repro.kernels.fifo_eval.condensed`): row-blocked condensed
  tiles through VMEM, fixpoint + exactness certificate in ONE launch,
  ``evaluate_certified`` exposed to the cascade so accepted/escalated
  rows never ship event times to the host;
* a raw **SimGraph** keeps the one-row-per-program Hillis-Steele kernel
  (:mod:`repro.kernels.fifo_eval.fifo_eval`) as the backstop engine.
"""

from __future__ import annotations

from repro.core.backends.base import register_backend
from repro.core.backends.fixpoint import _ScanBackend


@register_backend
class PallasBackend(_ScanBackend):
    """The :mod:`repro.kernels.fifo_eval` kernels (see module docstring).

    Raw graphs launch one grid program per configuration, so batch
    padding buys nothing there — bucketing is disabled.  The fused
    condensed path buckets anyway (inside the cascade): its row-blocked
    grid is batch-shaped, so jit-cache reuse pays exactly like the scan
    backends.
    """

    name = "pallas"
    use_ref = False
    wants_bucketing = False
