"""Pallas-kernel backend: the fifo_eval TPU kernel behind the shared
operand/dispatch machinery (interpret mode on CPU, native on TPU)."""

from __future__ import annotations

from repro.core.backends.base import register_backend
from repro.core.backends.fixpoint import _ScanBackend


@register_backend
class PallasBackend(_ScanBackend):
    """The :mod:`repro.kernels.fifo_eval` Hillis-Steele kernel.

    The kernel launches one grid program per configuration, so batch
    padding buys nothing — bucketing is disabled.
    """

    name = "pallas"
    use_ref = False
    wants_bucketing = False
