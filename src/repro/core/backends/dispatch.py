"""Tiered dispatch policy: bucketing, jit-cache reuse, and escalation.

Owns the three batch-shaping concerns that used to be tangled into
``BatchedEvaluator``:

1. **Bucketing** — backends whose compiled callable specializes on the
   batch dimension (``wants_bucketing``) receive batches padded up to a
   small fixed set of sizes, so the jit cache holds at most
   ``len(BUCKETS)`` entries per graph instead of one per distinct C.
   Padding repeats the final row; pad results are sliced off.
2. **Status resolution** — DEADLOCK rows become infeasible (-1 latency);
   CONVERGED rows pass through.
3. **Escalation** — UNRESOLVED rows (the iteration cap fired before the
   fixpoint converged: deadlocks never converge by construction, and rare
   feasible rows converge slowly) are re-solved exactly by the worklist
   arbiter, counted in ``stats.n_fallbacks``.

:class:`RungCascade` owns the condensation escalation ladder (moved here
from ``BatchedEvaluator``): route each row through the most aggressive
admissible rung, accept rows whose exactness certificate passes (or whose
relaxed solve already proves deadlock), and fall through rung by rung to
the raw dispatch backstop.  Kernel-backed rung evaluators certify
on-device (``fused_certificate``); the rest return event times for the
host-side ``condense.verify_rows``.

:class:`HeteroDispatcher` extends the same concerns across *designs*: it
packs rows from many SimGraphs into one lane-aligned hetero batch (shared
E*/F*/R* envelope, one jit cache for the whole campaign instead of one
per graph), with per-design worklist escalation.  jax is imported lazily
so this module stays importable in numpy-only worker processes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backends.base import (CONVERGED, DEADLOCK, F32_EXACT_LIMIT,
                                      EvalBackend, UNRESOLVED)
from repro.core.backends.worklist import WorklistBackend
from repro.core.simgraph import SimGraph

BUCKETS = (1, 8, 32, 128, 512, 2048)


class DispatchPolicy:
    """Routes depth batches through a backend and resolves every row.

    ``shard_multiple`` (the backend's device-mesh size; 1 = unsharded)
    rounds every padded batch up to a shard multiple so the sharded
    evaluators split rows evenly across devices without growing their
    jit cache beyond the bucketed shape set.
    """

    def __init__(self, worklist: WorklistBackend,
                 buckets: Tuple[int, ...] = BUCKETS,
                 shard_multiple: int = 1):
        self.worklist = worklist
        self.buckets = tuple(buckets)
        self.shard_multiple = max(1, int(shard_multiple))

    def bucket_size(self, c: int) -> Optional[int]:
        return next((b for b in self.buckets if b >= c), None)

    def pad_batch(self, m: np.ndarray) -> np.ndarray:
        """Pad C up to the covering bucket (rounded to a shard multiple)
        by repeating the last row."""
        c = m.shape[0]
        bucket = self.bucket_size(c)
        target = c if bucket is None else bucket
        k = self.shard_multiple
        target = -(-target // k) * k
        if target == c:
            return m
        pad = np.repeat(m[-1:], target - c, axis=0)
        return np.concatenate([m, pad], axis=0)

    def dispatch(self, backend: EvalBackend, depth_matrix: np.ndarray,
                 stats=None) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) depths -> (latency int64, bram int64, deadlock bool)."""
        m = np.atleast_2d(np.asarray(depth_matrix))
        C = m.shape[0]
        batch = self.pad_batch(m) if backend.wants_bucketing else m
        lat, bram, status = backend.evaluate(batch)
        lat, bram, status = lat[:C], bram[:C], status[:C]

        dead = status == DEADLOCK
        unresolved = np.flatnonzero(status == UNRESOLVED)
        if unresolved.size:
            wl_lat, _, wl_status = self.worklist.evaluate(m[unresolved])
            lat[unresolved] = wl_lat
            dead[unresolved] = wl_status == DEADLOCK
            if stats is not None:
                stats.n_fallbacks += int(unresolved.size)
        lat = np.where(dead, -1, lat)
        return lat, bram, dead


class RungCascade:
    """The condensation escalation ladder over certified rungs.

    ``rungs`` is the ordered ``[(CondensedGraph, prepared backend), ...]``
    list (most aggressive first); ``policy`` the shared
    :class:`DispatchPolicy`; ``primary`` the raw-graph backend used as
    the unconditional backstop.  Per rung, rows inside the rung's
    routing box are evaluated on the condensed stream and accepted when

    * the relaxed solve proves DEADLOCK (sound: the condensed fixpoint
      is a lower bound of the raw one), or
    * the row CONVERGED and its exactness certificate passes.

    Certification runs one of two ways:

    * **fused** — kernel-backed rung evaluators
      (``backend.fused_certificate``) evaluate and certify in ONE device
      program via ``evaluate_certified``; the event-time matrix never
      reaches the host, so a fully-certifying batch costs exactly one
      dispatch (asserted by the device-residency regression tests);
    * **host** — scan/worklist evaluators return per-anchor times
      (``evaluate_with_times``) and ``condense.verify_rows`` checks the
      folded cross constraints on the host.

    Everything still pending after the last rung goes to the raw
    dispatch backstop (bucketing + UNRESOLVED worklist escalation).
    """

    def __init__(self, rungs, policy: DispatchPolicy,
                 primary: EvalBackend):
        self.rungs = list(rungs)
        self.policy = policy
        self.primary = primary

    def evaluate(self, m: np.ndarray, stats=None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Unique (C, F) rows -> exact ``(latency i64, deadlock bool)``
        with -1 latency on deadlocked rows."""
        from repro.core.condense import verify_rows
        m = np.asarray(m, dtype=np.int64)
        C = m.shape[0]
        lat = np.zeros(C, dtype=np.int64)
        dead = np.zeros(C, dtype=bool)
        pending = np.ones(C, dtype=bool)
        for cg, impl in self.rungs:
            sel = np.flatnonzero(pending & cg.in_box(m))
            if not sel.size:
                continue
            rows = m[sel]
            fused = impl.fused_certificate
            if impl.wants_bucketing or fused:
                # the fused kernel path buckets too: its jit cache is
                # keyed on the padded batch shape like any scan backend
                batch = self.policy.pad_batch(rows)
            else:
                batch = rows
            if fused:
                rlat, _, rstatus, ok = impl.evaluate_certified(batch)
                rlat = rlat[: sel.size]
                rstatus = rstatus[: sel.size]
                ok = ok[: sel.size]
                dl = rstatus == DEADLOCK   # sound: relaxed system stalls
            else:
                rlat, _, rstatus, times = impl.evaluate_with_times(batch)
                rlat = rlat[: sel.size]
                rstatus = rstatus[: sel.size]
                times = times[: sel.size, : cg.n_events]
                dl = rstatus == DEADLOCK
                ok = np.zeros(sel.size, dtype=bool)
                conv = rstatus == CONVERGED
                if conv.any():
                    ci = np.flatnonzero(conv)
                    ok[ci] = verify_rows(cg, rows[ci], times[ci])
            acc = dl | ok
            if stats is not None:
                stats.n_cond_fail += int(sel.size - acc.sum())
            if acc.any():
                idx = sel[acc]
                lat[idx] = np.where(dl[acc], -1, rlat[acc])
                dead[idx] = dl[acc]
                pending[idx] = False
                if stats is not None:
                    stats.n_condensed += int(acc.sum())
            if not pending.any():
                break
        rem = np.flatnonzero(pending)
        if rem.size:
            rlat, _, rdead = self.policy.dispatch(
                self.primary, m[rem], stats)
            lat[rem] = rlat
            dead[rem] = rdead
        return lat, dead


@dataclasses.dataclass
class HeteroStats:
    n_dispatches: int = 0
    n_rows: int = 0          # real rows evaluated
    n_pad_rows: int = 0      # bucket-padding overhead rows
    n_fallbacks: int = 0     # UNRESOLVED rows escalated to a worklist
    wall_s: float = 0.0


class HeteroDispatcher:
    """One vectorized dispatch for rows spanning MANY designs.

    Built once per campaign from every participating
    :class:`~repro.core.simgraph.SimGraph`: computes the shared
    ``(E*, F*, R*)`` envelope, re-pads each design's operands to it, and
    compiles ONE jitted fixpoint whose cache is keyed only on the bucketed
    total row count — where per-design dispatch would compile
    ``len(BUCKETS)`` variants per graph, a campaign compiles
    ``len(buckets)`` variants total.  UNRESOLVED rows are escalated to the
    owning design's worklist arbiter, exactly like
    :class:`DispatchPolicy`.
    """

    #: finer-grained than BUCKETS: cross-design batches vary more in size
    BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)

    def __init__(self, graphs: Dict[str, SimGraph],
                 worklists: Optional[Dict[str, WorklistBackend]] = None,
                 max_iters: int = 64,
                 buckets: Sequence[int] = BUCKETS,
                 mesh=None, shards: Optional[int] = None):
        from repro.kernels.fifo_eval.ops import make_hetero_batched_eval
        self.max_iters = int(max_iters)
        self.e_pad = 0
        self.f_max = 0
        self.r_max = 0
        self._base: Dict[str, object] = {}   # per-design raw operands
        self._ext: Dict[str, object] = {}    # envelope-padded operands
        self.worklists: Dict[str, WorklistBackend] = {}
        # design-parallel sharding: rows are stacked design-major, so
        # partitioning the packed batch over the mesh's devices spreads
        # whole-design blocks across the fleet (2-D campaign meshes put
        # contiguous designs on contiguous device groups)
        if mesh is None and shards is not None:
            from repro.launch.mesh import make_eval_mesh
            mesh = make_eval_mesh(shards)
        self.mesh = mesh
        self.shard_multiple = (int(mesh.devices.size)
                               if mesh is not None else 1)
        self._call = make_hetero_batched_eval(max_iters, mesh=mesh)
        self.buckets = tuple(buckets)
        self.stats = HeteroStats()
        worklists = worklists or {}
        if graphs:
            # pre-compute the shared envelope so registering N designs
            # pads each exactly once (growth re-pads would be O(N^2))
            from repro.core.backends.operands import get_operands
            opses = [get_operands(g) for g in graphs.values()]
            self.e_pad = max(o.e_pad for o in opses)
            self.f_max = max(o.n_fifos for o in opses)
            self.r_max = max(o.n_flat_reads for o in opses)
        for k, g in graphs.items():
            self.add_design(k, g, worklists.get(k))

    def add_design(self, key: str, graph: SimGraph,
                   worklist: Optional[WorklistBackend] = None) -> None:
        """Register a design after construction (idempotent per key).

        The advisory service traces designs lazily — the first session on
        a new design lands mid-campaign — so the shared envelope must be
        able to grow.  If the new design fits the current ``(E*, F*, R*)``
        envelope, only its own operands are padded; if it exceeds it,
        every registered design is re-padded from its raw operands (the
        jitted evaluator is shape-polymorphic via its cache, so growth
        costs one recompile on the next dispatch, nothing else).
        """
        if key in self._ext:
            return
        from repro.core.backends.operands import (extend_operands,
                                                  get_operands)
        # same guard as BatchedEvaluator: the f32 fixpoint is only
        # exact while times stay below 2**24
        if graph.latency_upper_bound() > F32_EXACT_LIMIT:
            raise ValueError(
                f"design {key!r}: schedule bound exceeds the "
                "float32-exact domain; split the design or reduce "
                "trip counts")
        ops = get_operands(graph)
        self._base[key] = ops
        grew = (ops.e_pad > self.e_pad or ops.n_fifos > self.f_max
                or ops.n_flat_reads > self.r_max)
        self.e_pad = max(self.e_pad, ops.e_pad)
        self.f_max = max(self.f_max, ops.n_fifos)
        self.r_max = max(self.r_max, ops.n_flat_reads)
        if grew:
            self._ext = {k: extend_operands(o, self.e_pad, self.f_max,
                                            self.r_max)
                         for k, o in self._base.items()}
        else:
            self._ext[key] = extend_operands(ops, self.e_pad, self.f_max,
                                             self.r_max)
        if worklist is None:
            worklist = WorklistBackend(max_iters=self.max_iters)
            worklist.prepare(graph)
        self.worklists[key] = worklist

    def _pad_rows(self, batch: dict, c: int) -> Tuple[dict, int]:
        bucket = next((b for b in self.buckets if b >= c), None)
        target = c if bucket is None else bucket
        k = self.shard_multiple
        target = -(-target // k) * k           # sharded: even device split
        if target == c:
            return batch, c
        pad = target - c
        return {k_: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                for k_, v in batch.items()}, target

    def dispatch(self, items: List[Tuple[str, np.ndarray]]
                 ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """``[(design_key, (c_i, F_i) depths), ...]`` -> per-item results.

        Every returned triple is exact ``(latency i64, bram i64,
        deadlock bool)`` with -1 latency on deadlocked rows.
        """
        from repro.core.backends.operands import stack_hetero
        t_start = time.perf_counter()
        mats = [np.atleast_2d(np.asarray(m, dtype=np.int64))
                for _, m in items]
        batch = stack_hetero(
            [(self._ext[k], m) for (k, _), m in zip(items, mats)])
        C = batch["depths"].shape[0]
        padded, c_padded = self._pad_rows(batch, C)
        lat, bram, status = self._call(padded)
        lat, bram, status = lat[:C], bram[:C], status[:C]

        out = []
        row0 = 0
        for (key, _), m in zip(items, mats):
            c = m.shape[0]
            sl = slice(row0, row0 + c)
            row0 += c
            lat_i, bram_i = lat[sl].copy(), bram[sl].copy()
            dead_i = status[sl] == DEADLOCK
            unresolved = np.flatnonzero(status[sl] == UNRESOLVED)
            if unresolved.size:
                wl_lat, _, wl_status = self.worklists[key].evaluate(
                    m[unresolved])
                lat_i[unresolved] = wl_lat
                dead_i[unresolved] = wl_status == DEADLOCK
                self.stats.n_fallbacks += int(unresolved.size)
            lat_i = np.where(dead_i, -1, lat_i)
            out.append((lat_i, bram_i, dead_i))
        self.stats.n_dispatches += 1
        self.stats.n_rows += C
        self.stats.n_pad_rows += c_padded - C
        self.stats.wall_s += time.perf_counter() - t_start
        return out
