"""Tiered dispatch policy: bucketing, jit-cache reuse, and escalation.

Owns the three batch-shaping concerns that used to be tangled into
``BatchedEvaluator``:

1. **Bucketing** — backends whose compiled callable specializes on the
   batch dimension (``wants_bucketing``) receive batches padded up to a
   small fixed set of sizes, so the jit cache holds at most
   ``len(BUCKETS)`` entries per graph instead of one per distinct C.
   Padding repeats the final row; pad results are sliced off.
2. **Status resolution** — DEADLOCK rows become infeasible (-1 latency);
   CONVERGED rows pass through.
3. **Escalation** — UNRESOLVED rows (the iteration cap fired before the
   fixpoint converged: deadlocks never converge by construction, and rare
   feasible rows converge slowly) are re-solved exactly by the worklist
   arbiter, counted in ``stats.n_fallbacks``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.backends.base import DEADLOCK, EvalBackend, UNRESOLVED
from repro.core.backends.worklist import WorklistBackend

BUCKETS = (1, 8, 32, 128, 512, 2048)


class DispatchPolicy:
    """Routes depth batches through a backend and resolves every row."""

    def __init__(self, worklist: WorklistBackend,
                 buckets: Tuple[int, ...] = BUCKETS):
        self.worklist = worklist
        self.buckets = tuple(buckets)

    def bucket_size(self, c: int) -> Optional[int]:
        return next((b for b in self.buckets if b >= c), None)

    def pad_batch(self, m: np.ndarray) -> np.ndarray:
        """Pad C up to the covering bucket by repeating the last row."""
        c = m.shape[0]
        bucket = self.bucket_size(c)
        if bucket is None or bucket == c:
            return m
        pad = np.repeat(m[-1:], bucket - c, axis=0)
        return np.concatenate([m, pad], axis=0)

    def dispatch(self, backend: EvalBackend, depth_matrix: np.ndarray,
                 stats=None) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) depths -> (latency int64, bram int64, deadlock bool)."""
        m = np.atleast_2d(np.asarray(depth_matrix))
        C = m.shape[0]
        batch = self.pad_batch(m) if backend.wants_bucketing else m
        lat, bram, status = backend.evaluate(batch)
        lat, bram, status = lat[:C], bram[:C], status[:C]

        dead = status == DEADLOCK
        unresolved = np.flatnonzero(status == UNRESOLVED)
        if unresolved.size:
            wl_lat, _, wl_status = self.worklist.evaluate(m[unresolved])
            lat[unresolved] = wl_lat
            dead[unresolved] = wl_status == DEADLOCK
            if stats is not None:
                stats.n_fallbacks += int(unresolved.size)
        lat = np.where(dead, -1, lat)
        return lat, bram, dead
