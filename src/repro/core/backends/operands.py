"""Shared operand preparation for every evaluation backend.

Every backend consumes the same packed :class:`repro.core.simgraph.SimGraph`
but needs it massaged into padded, lane-aligned tensors (the fixpoint scan
and the Pallas kernel both want 128-lane event vectors).  Historically that
padding logic was duplicated between ``core/simulate.py`` and
``kernels/fifo_eval/ops.py``; this module is now the single source of truth:

``GraphOperands``
    The depth-INDEPENDENT operands: event tensors padded to a 128-lane
    multiple, segment-start / read masks, data-edge gather indices, the
    per-event ``end_bonus`` (task end delay at each task's last event), and
    the flattened read-event table for back-pressure gathers.  Built exactly
    once per graph (cached on the graph object) and shared by the fixpoint
    and Pallas backends — and by any future accelerator backend.

``depth_operands``
    The depth-DEPENDENT operands for a batch of candidate configurations:
    per-event read latencies, back-pressure gather indices/masks, and the
    structural-deadlock flag.  Pure jnp, traceable under jit/vmap, shared
    verbatim by the fixpoint scan, the jnp reference oracle, and the Pallas
    kernel wrapper.

``HeteroOperands`` / ``extend_operands`` / ``stack_hetero``
    The hetero-batch packer: one design's operands re-padded to a
    campaign-wide ``(E*, F*, R*)`` envelope (numpy, built once per design
    per campaign), and the per-round stacking of rows from *different*
    designs into one lane-aligned cross-design batch for the fixpoint
    backend (``repro.kernels.fifo_eval.ops.make_hetero_batched_eval``).
    Unlike :class:`GraphOperands`, every per-event table is materialized
    per row so a single vmapped dispatch can mix graphs.

Padding contract (identical to the Pallas kernel's expectations): events are
padded to ``E_pad`` (a multiple of 128, minimum 128); the first padded event
opens a fresh segment (``seg_start[E] = 1``) so the pad chain can never leak
times into real events; padded events carry ``delta = 0``, no data edge, no
back-pressure edge, and ``end_bonus = NEG`` so they contribute nothing to
the latency reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.backends.jaxcfg import configure_jax
from repro.core.bram import BRAM18K_CONFIGS, SRL_BITS, SRL_DEPTH

# arm the opt-in persistent compilation cache (REPRO_JIT_CACHE_DIR)
# before any backend's first jit trace — this module is the first jax
# import on every backend path
configure_jax()
from repro.core.design import READ, WRITE
from repro.core.simgraph import SimGraph

LANES = 128
NEG = np.float32(-1e9)


def bram_count_jnp(depths: jnp.ndarray, widths: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1, jnp-vectorized (mirrors bram.bram_count_np)."""
    d = depths.astype(jnp.int32)
    w0 = jnp.broadcast_to(widths.astype(jnp.int32), d.shape)
    n = jnp.zeros_like(d)
    w = w0
    for d_i, w_i in BRAM18K_CONFIGS:
        n = n + (w // w_i) * (-(-d // d_i))
        w = w % w_i
        fits = (w > 0) & (d <= d_i)
        n = n + fits.astype(jnp.int32)
        w = jnp.where(fits, 0, w)
    srl = (d <= SRL_DEPTH) | (d * w0 <= SRL_BITS)
    return jnp.where(srl, 0, n)


@dataclasses.dataclass(frozen=True)
class GraphOperands:
    """Depth-independent, lane-aligned event tensors for one SimGraph."""

    n_events: int            # E, real events
    e_pad: int               # E padded to a LANES multiple (>= LANES)
    n_fifos: int
    n_flat_reads: int        # R, length of the padded read_evt_flat table
    bound: float             # schedule upper bound (deadlock threshold)
    taskless_lat: float      # latency floor from tasks with no FIFO events
    # (1, E_pad) f32 — shaped for the Pallas kernel's shared operands
    delta: jnp.ndarray
    seg_start: jnp.ndarray
    is_read: jnp.ndarray
    has_data: jnp.ndarray
    end_bonus: jnp.ndarray
    # (1, E_pad) i32
    data_idx: jnp.ndarray
    # (E_pad,) per-event tables for the depth-dependent gathers
    fifo: jnp.ndarray        # i32 fifo of each event
    rank: jnp.ndarray        # i32 per-fifo op rank
    is_write: jnp.ndarray    # bool
    evt_read_base: jnp.ndarray   # i32 read_base[fifo[e]]
    evt_n_reads: jnp.ndarray     # i32 n_reads[fifo[e]]
    # (F,) / (R,)
    widths: jnp.ndarray      # i32
    read_evt_flat: jnp.ndarray   # i32
    # condensation offsets (all-zero on a raw SimGraph): the delta-chain
    # offset of a data source / back-pressure partner relative to its
    # covering anchor (see repro.core.condense)
    data_off: jnp.ndarray        # (E_pad,) f32
    read_off_flat: jnp.ndarray   # (R,) f32


def _pad_to(a: np.ndarray, n: int, fill, dtype) -> np.ndarray:
    out = np.full(n, fill, dtype=dtype)
    out[: len(a)] = a
    return out


def build_operands(g: SimGraph) -> GraphOperands:
    """Build the padded event tensors for ``g`` (use :func:`get_operands`)."""
    E = g.n_events
    e_pad = max(LANES, -(-max(E, 1) // LANES) * LANES)
    real = np.arange(e_pad) < E

    kind = _pad_to(g.kind, e_pad, READ, np.int32)   # pad kind is irrelevant
    fifo = _pad_to(g.fifo, e_pad, 0, np.int64)
    delta = _pad_to(g.delta, e_pad, 0, np.float32)
    seg_start = _pad_to(g.seg_start, e_pad, 0, np.float32)
    if E < e_pad:
        seg_start[E] = 1.0                          # isolate the pad chain
    rank = _pad_to(g.rank, e_pad, 0, np.int64)
    data_src = _pad_to(g.data_src, e_pad, -1, np.int64)

    is_read = ((kind == READ) & real).astype(np.float32)
    is_write = (kind == WRITE) & real
    has_data = ((data_src >= 0) & (is_read > 0)).astype(np.float32)
    data_idx = np.clip(data_src, 0, e_pad - 1).astype(np.int32)

    end_bonus = np.full(e_pad, float(NEG), dtype=np.float32)
    taskless_lat = 0.0
    for t in range(g.n_tasks):
        le = int(g.last_evt[t])
        if le >= 0:
            end_bonus[le] = float(g.end_delay[t])
        else:
            taskless_lat = max(taskless_lat, float(g.end_delay[t]))

    R = max(int(g.n_reads.sum()), 1)
    read_evt_flat = np.zeros(R, dtype=np.int64)
    read_evt_flat[: len(g.read_evt_flat)] = g.read_evt_flat

    # condensation offsets (zeros on a raw SimGraph)
    data_off_src = getattr(g, "data_off", None)
    data_off = np.zeros(e_pad, dtype=np.float32)
    if data_off_src is not None:
        data_off[:E] = data_off_src
    read_off_src = getattr(g, "read_off_flat", None)
    read_off_flat = np.zeros(R, dtype=np.float32)
    if read_off_src is not None:
        read_off_flat[: len(read_off_src)] = read_off_src

    return GraphOperands(
        n_events=E,
        e_pad=e_pad,
        n_fifos=g.n_fifos,
        n_flat_reads=R,
        bound=float(g.latency_upper_bound()),
        taskless_lat=taskless_lat,
        delta=jnp.asarray(delta)[None, :],
        seg_start=jnp.asarray(seg_start)[None, :],
        is_read=jnp.asarray(is_read)[None, :],
        has_data=jnp.asarray(has_data)[None, :],
        end_bonus=jnp.asarray(end_bonus)[None, :],
        data_idx=jnp.asarray(data_idx)[None, :],
        fifo=jnp.asarray(fifo, dtype=jnp.int32),
        rank=jnp.asarray(rank, dtype=jnp.int32),
        is_write=jnp.asarray(is_write),
        evt_read_base=jnp.asarray(g.read_base.astype(np.int64)[fifo],
                                  dtype=jnp.int32),
        evt_n_reads=jnp.asarray(g.n_reads.astype(np.int64)[fifo],
                                dtype=jnp.int32),
        widths=jnp.asarray(g.widths, dtype=jnp.int32),
        read_evt_flat=jnp.asarray(read_evt_flat, dtype=jnp.int32),
        data_off=jnp.asarray(data_off),
        read_off_flat=jnp.asarray(read_off_flat),
    )


def get_operands(g: SimGraph) -> GraphOperands:
    """Cached :class:`GraphOperands` for ``g`` (built once per graph)."""
    cached = getattr(g, "_operands_cache", None)
    if cached is None:
        cached = build_operands(g)
        g._operands_cache = cached
    return cached


@dataclasses.dataclass(frozen=True)
class HeteroOperands:
    """One design's event tables re-padded to a shared hetero envelope.

    All arrays are numpy (the per-round stacking is a host-side gather;
    the stacked batch is shipped to the device once per dispatch).  The
    extension region ``[own e_pad, E*)`` follows the standard padding
    contract: it opens a fresh segment, carries no edges, zero delta, and
    ``end_bonus = NEG``, so it can never leak times into real events.
    Padded FIFO columns get width 1 (with depth padded to 2 they are SRL
    by construction, contributing zero BRAM), and padded read-table slots
    are never gathered because ``evt_n_reads`` masks them out.
    """

    e_pad: int               # shared E* (lane-aligned)
    n_fifos_max: int         # shared F*
    n_flat_reads_max: int    # shared R*
    n_fifos: int             # this design's real F
    n_flat_reads: int        # this design's real R
    bound: float
    taskless_lat: float
    # (E*,) event tables
    delta: np.ndarray        # f32
    seg_start: np.ndarray    # f32
    is_read: np.ndarray      # f32
    has_data: np.ndarray     # f32
    end_bonus: np.ndarray    # f32
    data_idx: np.ndarray     # i32
    fifo: np.ndarray         # i32
    rank: np.ndarray         # i32
    is_write: np.ndarray     # bool
    evt_read_base: np.ndarray    # i32
    evt_n_reads: np.ndarray      # i32
    # (F*,) / (R*,)
    widths: np.ndarray       # i32
    read_evt_flat: np.ndarray    # i32


def _extend(a: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full(n, fill, dtype=a.dtype)
    out[: len(a)] = a
    return out


def extend_operands(ops: GraphOperands, e_pad: int, f_max: int,
                    r_max: int) -> HeteroOperands:
    """Re-pad one design's :class:`GraphOperands` to a shared envelope."""
    assert e_pad % LANES == 0 and e_pad >= ops.e_pad
    assert f_max >= ops.n_fifos and r_max >= ops.n_flat_reads
    seg_start = _extend(np.asarray(ops.seg_start)[0], e_pad, 0.0)
    if e_pad > ops.e_pad:
        seg_start[ops.e_pad] = 1.0     # isolate the extension chain
    return HeteroOperands(
        e_pad=e_pad,
        n_fifos_max=f_max,
        n_flat_reads_max=r_max,
        n_fifos=ops.n_fifos,
        n_flat_reads=ops.n_flat_reads,
        bound=ops.bound,
        taskless_lat=ops.taskless_lat,
        delta=_extend(np.asarray(ops.delta)[0], e_pad, 0.0),
        seg_start=seg_start,
        is_read=_extend(np.asarray(ops.is_read)[0], e_pad, 0.0),
        has_data=_extend(np.asarray(ops.has_data)[0], e_pad, 0.0),
        end_bonus=_extend(np.asarray(ops.end_bonus)[0], e_pad, float(NEG)),
        data_idx=_extend(np.asarray(ops.data_idx)[0], e_pad, 0),
        fifo=_extend(np.asarray(ops.fifo), e_pad, 0),
        rank=_extend(np.asarray(ops.rank), e_pad, 0),
        is_write=_extend(np.asarray(ops.is_write), e_pad, False),
        evt_read_base=_extend(np.asarray(ops.evt_read_base), e_pad, 0),
        evt_n_reads=_extend(np.asarray(ops.evt_n_reads), e_pad, 0),
        widths=_extend(np.asarray(ops.widths), f_max, 1),
        read_evt_flat=_extend(np.asarray(ops.read_evt_flat), r_max, 0),
    )


#: fields of :class:`HeteroOperands` broadcast per row by the stacker
_HETERO_ROW_FIELDS = ("delta", "seg_start", "is_read", "has_data",
                      "end_bonus", "data_idx", "fifo", "rank", "is_write",
                      "evt_read_base", "evt_n_reads", "widths",
                      "read_evt_flat")


def stack_hetero(entries) -> dict:
    """Stack ``[(HeteroOperands, (c_i, F_i) depths), ...]`` into one batch.

    Returns the dict of (C, ...) arrays consumed by
    ``make_hetero_batched_eval``; rows from different designs are simply
    concatenated — every row carries its own event tables, bound, and
    latency floor.  Depth rows are padded to F* with depth 2 (zero-BRAM
    SRL columns that no event references).
    """
    entries = [(h, np.atleast_2d(np.asarray(m, dtype=np.int64)))
               for h, m in entries]
    batch = {}
    for name in _HETERO_ROW_FIELDS:
        batch[name] = np.concatenate([
            np.broadcast_to(getattr(h, name),
                            (m.shape[0],) + getattr(h, name).shape)
            for h, m in entries], axis=0)
    batch["bound"] = np.concatenate(
        [np.full(m.shape[0], h.bound, dtype=np.float32)
         for h, m in entries])
    batch["taskless"] = np.concatenate(
        [np.full(m.shape[0], h.taskless_lat, dtype=np.float32)
         for h, m in entries])
    batch["n_flat_reads"] = np.concatenate(
        [np.full(m.shape[0], h.n_flat_reads, dtype=np.int32)
         for h, m in entries])
    depths = []
    for h, m in entries:
        pad = np.full((m.shape[0], h.n_fifos_max), 2, dtype=np.int64)
        pad[:, : m.shape[1]] = m
        depths.append(pad)
    batch["depths"] = np.concatenate(depths, axis=0)
    return batch


def depth_operands(ops: GraphOperands, depths: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                              jnp.ndarray, jnp.ndarray]:
    """Depth-dependent per-config operands (jnp, jit/vmap traceable).

    depths: (C, F) integer depth matrix.  Returns

    - ``rd_lat_e``  (C, E_pad) f32: read latency at each event's fifo
      (1 cycle SRL, 2 cycles BRAM — depends on the candidate depth) plus
      the condensation data-source offset (zero on raw graphs),
    - ``bp_idx``    (C, E_pad) i32: back-pressure gather index — write j of
      fifo f waits on read event ``j - d_f`` (its covering anchor on a
      condensed graph),
    - ``bp_valid``  (C, E_pad) f32: mask of writes with an active
      back-pressure edge,
    - ``bp_base``   (C, E_pad) f32: additive term of the back-pressure
      edge — 1.0 on raw graphs, 1.0 + covering-anchor offset on
      condensed ones,
    - ``structural`` (C,) bool: config deadlocks structurally (a write's
      back-pressure partner read does not exist).
    """
    depths = depths.astype(jnp.int32)
    is_bram = ~((depths <= SRL_DEPTH) | (depths * ops.widths <= SRL_BITS))
    rd_lat_f = 1.0 + is_bram.astype(jnp.float32)          # (C, F)
    rd_lat_e = rd_lat_f[:, ops.fifo] + ops.data_off[None, :]

    bp_pos = ops.rank[None, :] - depths[:, ops.fifo]      # (C, E_pad)
    overrun = ops.is_write[None, :] & (bp_pos >= ops.evt_n_reads[None, :])
    structural = jnp.any(overrun, axis=1)                 # (C,)
    bp_valid = (ops.is_write[None, :] & (bp_pos >= 0) & ~overrun
                ).astype(jnp.float32)
    flat = jnp.clip(ops.evt_read_base[None, :] + bp_pos, 0,
                    ops.n_flat_reads - 1)
    bp_idx = ops.read_evt_flat[flat]                      # (C, E_pad)
    bp_base = ops.read_off_flat[flat] + 1.0               # (C, E_pad)
    return rd_lat_e, bp_idx, bp_valid, bp_base, structural


# --------------------------------------------------------------------------
# fused exactness-certificate tables (condensed graphs only)
# --------------------------------------------------------------------------
#
# ``repro.core.condense.verify_rows`` checks, per depth row, every folded
# event's dropped cross constraint against the *expanded* raw-space times
# ``t_hat[e] = t_cond[cond_of[e]] + off_of[e]``.  Every one of those
# checks only ever compares two expanded times plus a per-row integer, so
# it rewrites into CONDENSED anchor space as a flat list of slots
#
#     violated  iff  valid and  t_cond[src] - t_cond[dst] > thr
#
# * folded read r (raw data source s):  src = cond_of[s],
#   dst = cond_of[r], thr = (off_of[r] - off_of[s]) - rd_lat[row, fifo_r]
#   — the read-latency term is the only depth-dependent part;
# * folded write w at rank j of fifo f with depth d:  active iff j >= d;
#   its partner read slot is ``pos = read_base[f] + j - d`` whose
#   condensed anchor/offset are exactly ``read_evt_flat[pos]`` /
#   ``read_off_flat[pos]`` (GraphOperands already carries both), so
#   src = read_evt_flat[pos], dst = cond_of[w],
#   thr = off_of[w] - read_off_flat[pos] - 1;  a write whose partner
#   read does not exist (``j - d >= n_reads[f]``) is a structural
#   deadlock at that row and is encoded as a forced-fail slot
#   (src = dst = 0, thr = -1: ``t - t > -1`` always fires).
#
# All quantities are integers below the f32-exact limit (the evaluator
# façade asserts the schedule bound < 2**24), so evaluating the slots in
# float32 *inside the kernel* is bit-for-bit equal to the int64 host
# check — the kernel can certify its own fixpoint in the same launch.


@dataclasses.dataclass(frozen=True)
class CertTables:
    """Depth-independent certificate slots for one CondensedGraph.

    Slots are padded to ``v_pad`` (a LANES multiple) with ``valid = 0``;
    the depth-dependent parts (read latencies, write activation and
    partner gathers) are filled per row by :func:`cert_row_operands`.
    """

    n_read: int              # folded-read slot count
    n_write: int             # folded-write slot count
    v_pad: int               # total slots padded to a LANES multiple
    # folded reads: static anchors, depth-dependent threshold
    r_src: jnp.ndarray       # (Nr,) i32 cond_of[data_src]
    r_dst: jnp.ndarray       # (Nr,) i32 cond_of[read]
    r_base: jnp.ndarray      # (Nr,) f32 off_of[read] - off_of[data_src]
    r_fifo: jnp.ndarray      # (Nr,) i32
    # folded writes: depth-dependent partner anchor AND threshold
    w_dst: jnp.ndarray       # (Nw,) i32 cond_of[write]
    w_dst_off: jnp.ndarray   # (Nw,) f32 off_of[write]
    w_fifo: jnp.ndarray      # (Nw,) i32
    w_rank: jnp.ndarray      # (Nw,) i32
    w_read_base: jnp.ndarray     # (Nw,) i32 read_base[fifo]
    w_n_reads: jnp.ndarray       # (Nw,) i32 n_reads[fifo]


def build_cert_tables(cg) -> Optional[CertTables]:
    """Certificate slots for a CondensedGraph (use :func:`get_cert_tables`).

    Returns None when the graph's folded tables cannot be expressed as
    gather slots (a folded read without a data source would index
    ``t_hat[:, -1]`` on the host — numpy wraps where jnp clips, so such
    graphs keep the host verifier).
    """
    vr_src = np.asarray(cg.vr_src, dtype=np.int64)
    if vr_src.size and (vr_src < 0).any():
        return None
    cond_of = np.asarray(cg.cond_of, dtype=np.int64)
    off_of = np.asarray(cg.off_of, dtype=np.float32)
    vr_idx = np.asarray(cg.vr_idx, dtype=np.int64)
    vw_idx = np.asarray(cg.vw_idx, dtype=np.int64)
    vw_fifo = np.asarray(cg.vw_fifo, dtype=np.int64)
    n_read, n_write = vr_idx.size, vw_idx.size
    v_pad = max(LANES, -(-max(n_read + n_write, 1) // LANES) * LANES)
    g = cg.raw
    return CertTables(
        n_read=n_read,
        n_write=n_write,
        v_pad=v_pad,
        r_src=jnp.asarray(cond_of[vr_src], dtype=jnp.int32),
        r_dst=jnp.asarray(cond_of[vr_idx], dtype=jnp.int32),
        r_base=jnp.asarray(off_of[vr_idx] - off_of[vr_src],
                           dtype=jnp.float32),
        r_fifo=jnp.asarray(cg.vr_fifo, dtype=jnp.int32),
        w_dst=jnp.asarray(cond_of[vw_idx], dtype=jnp.int32),
        w_dst_off=jnp.asarray(off_of[vw_idx], dtype=jnp.float32),
        w_fifo=jnp.asarray(vw_fifo, dtype=jnp.int32),
        w_rank=jnp.asarray(cg.vw_rank, dtype=jnp.int32),
        w_read_base=jnp.asarray(g.read_base[vw_fifo], dtype=jnp.int32),
        w_n_reads=jnp.asarray(g.n_reads[vw_fifo], dtype=jnp.int32),
    )


_CERT_MISS = object()


def get_cert_tables(cg) -> Optional[CertTables]:
    """Cached :class:`CertTables` for ``cg`` (None = host verify only)."""
    cached = getattr(cg, "_cert_tables_cache", _CERT_MISS)
    if cached is _CERT_MISS:
        cached = build_cert_tables(cg)
        cg._cert_tables_cache = cached
    return cached


def cert_row_operands(ops: GraphOperands, ct: CertTables,
                      depths: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                 jnp.ndarray]:
    """Per-row certificate slots (jnp, jit traceable).

    depths: (C, F) int.  Returns ``(src i32, dst i32, thr f32, valid
    f32)``, each (C, v_pad): slot ``v`` of row ``c`` is violated iff
    ``valid > 0`` and ``t[src] - t[dst] > thr`` at that row's condensed
    fixpoint — exactly the constraint ``verify_rows`` checks in raw
    index space.
    """
    depths = depths.astype(jnp.int32)
    C = depths.shape[0]
    srcs, dsts, thrs, vals = [], [], [], []
    if ct.n_read:
        is_bram = ~((depths <= SRL_DEPTH) | (depths * ops.widths <= SRL_BITS))
        rd_lat_f = 1.0 + is_bram.astype(jnp.float32)          # (C, F)
        srcs.append(jnp.broadcast_to(ct.r_src[None, :], (C, ct.n_read)))
        dsts.append(jnp.broadcast_to(ct.r_dst[None, :], (C, ct.n_read)))
        thrs.append(ct.r_base[None, :] - rd_lat_f[:, ct.r_fifo])
        vals.append(jnp.ones((C, ct.n_read), dtype=jnp.float32))
    if ct.n_write:
        d = depths[:, ct.w_fifo]                              # (C, Nw)
        j = ct.w_rank[None, :]
        act = j >= d
        overrun = act & (j - d >= ct.w_n_reads[None, :])
        pos = jnp.clip(ct.w_read_base[None, :] + j - d, 0,
                       ops.n_flat_reads - 1)
        src = jnp.where(overrun, 0, ops.read_evt_flat[pos])
        dst = jnp.where(overrun, 0,
                        jnp.broadcast_to(ct.w_dst[None, :], d.shape))
        thr = jnp.where(overrun, jnp.float32(-1.0),
                        ct.w_dst_off[None, :]
                        - ops.read_off_flat[pos] - 1.0)
        srcs.append(src)
        dsts.append(dst)
        thrs.append(thr)
        vals.append(act.astype(jnp.float32))
    n = ct.n_read + ct.n_write
    pad = ct.v_pad - n
    if pad:
        srcs.append(jnp.zeros((C, pad), dtype=jnp.int32))
        dsts.append(jnp.zeros((C, pad), dtype=jnp.int32))
        thrs.append(jnp.zeros((C, pad), dtype=jnp.float32))
        vals.append(jnp.zeros((C, pad), dtype=jnp.float32))
    return (jnp.concatenate(srcs, axis=1).astype(jnp.int32),
            jnp.concatenate(dsts, axis=1).astype(jnp.int32),
            jnp.concatenate(thrs, axis=1),
            jnp.concatenate(vals, axis=1))
