"""Opt-in jax persistent compilation cache (warm-restart, first slice).

A restarted advisory server or campaign re-traces its designs in
milliseconds but historically re-jitted every evaluator from scratch.
Setting ``REPRO_JIT_CACHE_DIR`` points jax's persistent compilation
cache at a directory that survives the process, so the second launch
deserializes its XLA executables instead of recompiling them:

    REPRO_JIT_CACHE_DIR=~/.cache/repro-jit python -m repro.launch.serve ...

:func:`configure_jax` is called by :mod:`repro.core.backends.operands`
— the single module every jax-backed backend imports first — so the
cache is armed before the first ``jax.jit`` trace no matter which
backend compiles first.  With the variable unset this module does
nothing, and it never imports jax on its own (the numpy worklist path
must stay jax-free).

The thresholds are zeroed because our kernels are small and fast to
compile *individually* — it is the dozens of (graph, bucket) jit-cache
entries a warm campaign accumulates that make a cold restart slow, and
the default "only cache slow compiles" heuristic would skip all of them.
"""

from __future__ import annotations

import os

ENV_VAR = "REPRO_JIT_CACHE_DIR"

_configured = False


def configure_jax(force: bool = False) -> bool:
    """Arm jax's persistent compilation cache when ``REPRO_JIT_CACHE_DIR``
    is set.  Idempotent (re-runs only with ``force=True``); returns
    whether a cache directory is active.  Safe to call at any point
    before or after jax initializes — the cache is consulted at compile
    time, not at backend-init time."""
    global _configured
    if _configured and not force:
        return bool(os.environ.get(ENV_VAR))
    _configured = True
    cache_dir = os.environ.get(ENV_VAR)
    if not cache_dir:
        return False
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    os.makedirs(cache_dir, exist_ok=True)
    import jax
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # cache everything: restart latency is dominated by the *number* of
    # re-jits, not by any single slow compile
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return True
