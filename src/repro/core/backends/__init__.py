"""Unified evaluation-backend subsystem.

One shared operand-preparation layer (:mod:`.operands`), an abstract
:class:`.EvalBackend` protocol with a registry, three exact implementations
(numpy worklist, jit/vmap fixpoint scan, Pallas kernel), a tiered
:class:`.DispatchPolicy` (bucketing + UNRESOLVED-row escalation), the
vectorized :class:`.ConfigCache`, and the incremental re-simulation fast
path (:func:`.solve_delta` — the LightningSim primitive).

``repro.core.simulate.BatchedEvaluator`` is a thin façade over this
package; new backends only need ``@register_backend``.
"""

from repro.core.backends.base import (BACKENDS, BIG, CONVERGED, DEADLOCK,
                                      F32_EXACT_LIMIT, UNRESOLVED,
                                      EvalBackend, available_backends,
                                      get_backend, register_backend)
from repro.core.backends.cache import CacheStats, ConfigCache
from repro.core.backends.dispatch import BUCKETS, DispatchPolicy
from repro.core.backends.fixpoint import FixpointBackend
from repro.core.backends.operands import (GraphOperands, bram_count_jnp,
                                          build_operands, depth_operands,
                                          get_operands)
from repro.core.backends.pallas import PallasBackend
from repro.core.backends.worklist import (IncrementalStats, WorklistBackend,
                                          WorklistState, affected_segments,
                                          evaluate_np, solve, solve_delta)

__all__ = [
    "BACKENDS", "BIG", "BUCKETS", "CONVERGED", "CacheStats", "ConfigCache",
    "DEADLOCK", "DispatchPolicy", "EvalBackend", "F32_EXACT_LIMIT",
    "FixpointBackend", "GraphOperands", "IncrementalStats", "PallasBackend",
    "UNRESOLVED", "WorklistBackend", "WorklistState", "affected_segments",
    "available_backends", "bram_count_jnp", "build_operands",
    "depth_operands", "evaluate_np", "get_backend", "get_operands",
    "register_backend", "solve", "solve_delta",
]
