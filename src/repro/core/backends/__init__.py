"""Unified evaluation-backend subsystem.

One shared operand-preparation layer (:mod:`.operands`), an abstract
:class:`.EvalBackend` protocol with a registry, three exact implementations
(numpy worklist, jit/vmap fixpoint scan, Pallas kernel), a tiered
:class:`.DispatchPolicy` (bucketing + UNRESOLVED-row escalation), the
cross-design :class:`.HeteroDispatcher`, the vectorized
:class:`.ConfigCache`, and the incremental re-simulation fast path
(:func:`.solve_delta` — the LightningSim primitive).

``repro.core.simulate.BatchedEvaluator`` is a thin façade over this
package; new backends only need ``@register_backend``.

The jax-backed pieces (operands, fixpoint, pallas) are imported LAZILY via
PEP 562 so that numpy-only consumers — notably the campaign worker
processes, which only ever run the worklist — can import this package
without paying the jax import (or touching XLA at all).  ``get_backend``
resolves the lazy backends by name on first use.
"""

import importlib

from repro.core.backends.base import (BACKENDS, BIG, CONVERGED, DEADLOCK,
                                      F32_EXACT_LIMIT, UNRESOLVED,
                                      EvalBackend, available_backends,
                                      get_backend, register_backend)
from repro.core.backends.cache import CacheStats, ConfigCache
from repro.core.backends.dispatch import (BUCKETS, DispatchPolicy,
                                          HeteroDispatcher, HeteroStats,
                                          RungCascade)
from repro.core.backends.worklist import (IncrementalStats, WorklistBackend,
                                          WorklistState, affected_segments,
                                          evaluate_np, solve, solve_delta)

#: names resolved on attribute access from jax-importing submodules
_LAZY_ATTRS = {
    "FixpointBackend": "repro.core.backends.fixpoint",
    "MeshBackend": "repro.core.backends.mesh",
    "PallasBackend": "repro.core.backends.pallas",
    "GraphOperands": "repro.core.backends.operands",
    "HeteroOperands": "repro.core.backends.operands",
    "bram_count_jnp": "repro.core.backends.operands",
    "build_operands": "repro.core.backends.operands",
    "depth_operands": "repro.core.backends.operands",
    "extend_operands": "repro.core.backends.operands",
    "get_operands": "repro.core.backends.operands",
    "stack_hetero": "repro.core.backends.operands",
}


def __getattr__(name):
    module = _LAZY_ATTRS.get(name)
    if module is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(module), name)


__all__ = [
    "BACKENDS", "BIG", "BUCKETS", "CONVERGED", "CacheStats", "ConfigCache",
    "DEADLOCK", "DispatchPolicy", "EvalBackend", "F32_EXACT_LIMIT",
    "FixpointBackend", "GraphOperands", "HeteroDispatcher", "HeteroOperands",
    "HeteroStats", "IncrementalStats", "MeshBackend", "PallasBackend",
    "RungCascade", "UNRESOLVED",
    "WorklistBackend", "WorklistState", "affected_segments",
    "available_backends", "bram_count_jnp", "build_operands",
    "depth_operands", "evaluate_np", "extend_operands", "get_backend",
    "get_operands", "register_backend", "solve", "solve_delta",
    "stack_hetero",
]
