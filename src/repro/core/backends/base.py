"""Evaluation-backend protocol and registry.

A backend turns a :class:`~repro.core.simgraph.SimGraph` plus a batch of
candidate depth vectors into exact ``(latency, bram, status)`` triples:

    backend = get_backend("fixpoint")(max_iters=64)
    backend.prepare(graph)                    # -> operands, built once
    lat, bram, status = backend.evaluate(depth_matrix)   # (C, F) ints

``status`` is per-row: CONVERGED rows carry an exact latency, DEADLOCK rows
are infeasible, UNRESOLVED rows hit an iteration cap and must be escalated
to the worklist arbiter (see :mod:`repro.core.backends.dispatch`).  All
registered backends are exact and cross-validated in ``tests/test_backends``.

Registering a new backend is one decorator::

    @register_backend
    class MyBackend(EvalBackend):
        name = "mine"
        def prepare(self, g): ...
        def evaluate(self, depth_matrix): ...
"""

from __future__ import annotations

import abc
from typing import Dict, Tuple, Type

import numpy as np

from repro.core.simgraph import SimGraph

BIG = np.float32(1e9)
F32_EXACT_LIMIT = 1.5e7

# per-row status codes
CONVERGED = 0
DEADLOCK = 1
UNRESOLVED = 2


class EvalBackend(abc.ABC):
    """One evaluation strategy over a prepared simulation graph."""

    #: registry key; subclasses may also list aliases
    name: str = "abstract"
    aliases: Tuple[str, ...] = ()
    #: whether the dispatch policy should pad batches to bucket sizes so the
    #: backend's jit cache sees a small, reusable set of batch shapes
    wants_bucketing: bool = False
    #: True when (prepared on a CondensedGraph) the backend fuses the
    #: exactness certificate into evaluation: it then exposes
    #: ``evaluate_certified(m) -> (lat, bram, status, cert)`` and the
    #: rung cascade skips the host-side ``verify_rows`` entirely
    fused_certificate: bool = False

    def __init__(self, max_iters: int = 64):
        self.max_iters = int(max_iters)
        self.g: SimGraph = None

    @abc.abstractmethod
    def prepare(self, g: SimGraph):
        """Bind ``g`` and build (cached) operands; returns the operands."""

    @abc.abstractmethod
    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) int depths -> (latency int64, bram int64, status int8).

        Latency entries are only meaningful on CONVERGED rows.
        """

    def spawn(self) -> "EvalBackend":
        """A fresh, unprepared instance with the same configuration.

        The condensation rung cascade prepares one evaluator per rung;
        backends with extra construction state (e.g. the mesh backend's
        device mesh) override this so rung evaluators inherit it."""
        return type(self)(max_iters=self.max_iters)


BACKENDS: Dict[str, Type[EvalBackend]] = {}


def register_backend(cls: Type[EvalBackend]) -> Type[EvalBackend]:
    """Class decorator: add ``cls`` to the registry under its ``name``
    and every alias, making it selectable as
    ``FifoAdvisor(design, backend=<name>)``.  Returns ``cls``."""
    BACKENDS[cls.name] = cls
    for alias in cls.aliases:
        BACKENDS[alias] = cls
    return cls


#: backends whose defining module is imported on first request, so the
#: numpy-only worklist path never pays the jax import
_LAZY_BACKEND_MODULES = {
    "worklist": "repro.core.backends.worklist",
    "numpy": "repro.core.backends.worklist",
    "fixpoint": "repro.core.backends.fixpoint",
    "jax": "repro.core.backends.fixpoint",
    "pallas": "repro.core.backends.pallas",
    "mesh": "repro.core.backends.mesh",
    "sharded": "repro.core.backends.mesh",
}


def get_backend(name: str) -> Type[EvalBackend]:
    """Resolve a registry name (or alias) to its backend class,
    importing lazy jax-backed modules on first request; raises
    ``ValueError`` with the available names on a miss."""
    if name not in BACKENDS and name in _LAZY_BACKEND_MODULES:
        import importlib
        importlib.import_module(_LAZY_BACKEND_MODULES[name])
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: "
            f"{sorted(set(BACKENDS) | set(_LAZY_BACKEND_MODULES))}"
            ) from None


def available_backends() -> Tuple[str, ...]:
    """Canonical backend names usable in this environment (lazy jax
    backends are advertised only when jax is actually importable)."""
    import importlib.util
    names = {cls.name for cls in BACKENDS.values()}
    names.add("worklist")
    if importlib.util.find_spec("jax") is not None:
        names.update({"fixpoint", "pallas", "mesh"})
    return tuple(sorted(names))
