"""Device-mesh sharded evaluation backend.

Candidate depth rows are embarrassingly parallel — one independent
max-plus fixpoint per row — so the batched scan evaluators scale across
a jax device mesh by pure row partitioning: pad the batch to a shard
multiple, ``shard_map`` the unchanged jitted fixpoint over a config-batch
axis, and gather latencies / deadlock verdicts back.  No collectives, no
replication, and therefore *bit-identical* results to the solo path (the
per-shard computation is the very same jit-compiled program over a row
subset; padding rows repeat the final row and are sliced off).

:class:`MeshBackend` is a drop-in :class:`~repro.core.backends.base
.EvalBackend` (registry name ``"mesh"``): the dispatch policy, the
condensation rung cascade, UNRESOLVED-row worklist escalation, and the
ConfigCache all compose with it unchanged.  Select it directly —

    BatchedEvaluator(g, backend="mesh", shards=8)
    FifoAdvisor(design, backend="mesh")          # all devices

— or let ``backend="auto"`` calibration race it against the solo
backends and pick it up only where sharding actually pays (it rarely
does on a single-core host; it wins ~linearly once real cores or chips
back the mesh devices).

A per-shard bonus even on narrow hosts: the vmapped fixpoint iterates
until the *slowest row of the shard* converges, so splitting a batch
lets easy shards retire early instead of riding along for the global
worst case.

On CPU hosts, get a many-device mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or
:func:`repro.launch.mesh.ensure_host_platform_devices` before jax
initializes).
"""

from __future__ import annotations

from repro.core.backends.base import register_backend
from repro.core.backends.fixpoint import _ScanBackend


@register_backend
class MeshBackend(_ScanBackend):
    """Config-batch-sharded scan evaluation over a jax device mesh.

    Args:
        max_iters: fixpoint iteration cap (same semantics as every
            scan backend; UNRESOLVED rows escalate to the worklist).
        mesh: an explicit :class:`jax.sharding.Mesh`; rows are
            partitioned jointly over ALL of its axes, so both a 1-D
            ``("eval",)`` mesh and a 2-D ``("design", "eval")`` campaign
            mesh work.
        shards: shorthand — build a 1-D eval mesh over this many devices
            (default: every device).  Ignored when ``mesh`` is given.
        inner: ``"fixpoint"`` (the jnp associative-scan reference, the
            default) or ``"pallas"`` (the hand-rolled kernels; interpret
            mode on CPU).  With ``inner="pallas"`` the condensation rung
            cascade rides the FUSED condensed kernel sharded over the
            mesh: each device evaluates and certifies its row shard in
            one launch (``evaluate_certified`` composes with
            ``shard_map`` unchanged), bit-identical to the solo path.
    """

    name = "mesh"
    aliases = ("sharded",)
    wants_bucketing = True

    def __init__(self, max_iters: int = 64, mesh=None,
                 shards: int = None, inner: str = "fixpoint"):
        super().__init__(max_iters=max_iters)
        if inner not in ("fixpoint", "pallas"):
            raise ValueError(
                f"MeshBackend inner must be 'fixpoint' or 'pallas', "
                f"got {inner!r}")
        self.inner = inner
        self.use_ref = inner == "fixpoint"
        if mesh is None:
            from repro.launch.mesh import make_eval_mesh
            mesh = make_eval_mesh(shards)
        self.mesh = mesh

    @property
    def n_shards(self) -> int:
        return self.shard_multiple

    def spawn(self) -> "MeshBackend":
        """Same-configuration clone — keeps the condensation rung
        cascade's per-rung evaluators on the same mesh."""
        return type(self)(max_iters=self.max_iters, mesh=self.mesh,
                          inner=self.inner)
