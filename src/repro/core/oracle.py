"""Cycle-accurate discrete-event simulation of a design with bounded FIFOs.

This is the reproduction's stand-in for Vitis HLS C/RTL co-simulation: an
*independent* evaluator that executes the task generators directly against
bounded FIFO queues (values and data-dependent control flow included) and
resolves op completion times with a Kahn-style worklist over the dependency
structure.  It shares no code with the trace-based evaluator in
:mod:`repro.core.simulate`; Table-II-style accuracy numbers compare the two.

Timing semantics (shared contract, see DESIGN.md §2.1):

* op ``i`` of a task may not complete before ``t[i-1] + delta[i]``;
* the k-th READ of fifo ``f`` may not complete before
  ``t(write_k) + rd_lat(f)`` where ``rd_lat`` is 1 for shift-register FIFOs
  and 2 for BRAM-backed FIFOs (the Vitis extra read-latency cycle — this is
  what makes *shrinking* a FIFO below the SRL threshold occasionally
  *reduce* latency, the paper's footnote 2);
* the j-th WRITE (0-indexed) to fifo ``f`` of depth ``d`` may not complete
  before ``t(read_{j-d}) + 1`` (a slot frees one cycle after its read);
* task end = last op completion + trailing delay; design latency = max.

Deadlock is reported when unfinished tasks exist but none can progress.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.design import DELAY, Design, READ, TaskCtx
from repro.core.bram import fifo_read_latency


@dataclasses.dataclass
class SimResult:
    latency: int                 # total cycles (valid iff not deadlocked)
    deadlocked: bool
    blocked_tasks: List[str]     # names of tasks stuck at deadlock
    results: Dict[str, Any]      # functional outputs (ctx.result)
    #: per blocked task: (task_name, op_kind READ/WRITE, fifo_index) of the
    #: FIFO op it is stuck on — the raw material for wait-for-graph
    #: extraction (:mod:`repro.core.deadlock`)
    blocked_ops: List[Tuple[str, int, int]] = \
        dataclasses.field(default_factory=list)

    def ok(self) -> bool:
        return not self.deadlocked


class _TaskState:
    __slots__ = ("task", "gen", "done", "time", "pending_delay", "next_op",
                 "send_value")

    def __init__(self, task, gen):
        self.task = task
        self.gen = gen
        self.done = False
        self.time = 0            # completion time of the last FIFO op
        self.pending_delay = 0   # accumulated DELAY cycles since last op
        self.next_op = None      # the FIFO op we are blocked on (or None)
        self.send_value: Any = None


def simulate(design: Design, depths: Sequence[int],
             widths: Optional[Sequence[int]] = None) -> SimResult:
    """Run the discrete-event simulation with the given FIFO depths."""
    depths = [int(d) for d in depths]
    if len(depths) != design.n_fifos:
        raise ValueError("depths length mismatch")
    if any(d < 1 for d in depths):
        raise ValueError("FIFO depths must be >= 1")
    if widths is None:
        widths = design.widths()
    rd_lat = [fifo_read_latency(d, w) for d, w in zip(depths, widths)]

    results: Dict[str, Any] = {}
    ctx = TaskCtx(design, design.args, results)

    # Per-fifo completed op timelines and live value queues.
    write_times: List[List[int]] = [[] for _ in range(design.n_fifos)]
    read_times: List[List[int]] = [[] for _ in range(design.n_fifos)]
    values: List[deque] = [deque() for _ in range(design.n_fifos)]

    states: List[_TaskState] = []
    for task in design.tasks:
        st = _TaskState(task, task.program(ctx))
        states.append(st)
        _advance_to_next_fifo_op(st)

    end_times: Dict[int, int] = {}

    def op_ready(st: _TaskState) -> bool:
        op = st.next_op
        if op.kind == READ:
            return len(write_times[op.fifo]) > len(read_times[op.fifo])
        j = len(write_times[op.fifo])          # rank of this write
        d = depths[op.fifo]
        return j < d or len(read_times[op.fifo]) > j - d

    # Kahn-style worklist: repeatedly execute any task whose next FIFO op has
    # all dependencies resolved.  Completion times only ever reference ops
    # already executed, so any execution order yields the same times.
    progress = True
    while progress:
        progress = False
        for st in states:
            while not st.done and st.next_op is not None and op_ready(st):
                op = st.next_op
                ready = st.time + st.pending_delay
                if op.kind == READ:
                    k = len(read_times[op.fifo])
                    t = max(ready, write_times[op.fifo][k] + rd_lat[op.fifo])
                    read_times[op.fifo].append(t)
                    st.send_value = values[op.fifo].popleft()
                else:  # WRITE
                    j = len(write_times[op.fifo])
                    d = depths[op.fifo]
                    t = ready
                    if j >= d:
                        t = max(t, read_times[op.fifo][j - d] + 1)
                    write_times[op.fifo].append(t)
                    values[op.fifo].append(op.value)
                st.time = t
                st.pending_delay = 0
                _advance_to_next_fifo_op(st)
                progress = True
            if st.done and st.task.index not in end_times:
                end_times[st.task.index] = st.time + st.pending_delay

    blocked = [st.task.name for st in states if not st.done]
    if blocked:
        blocked_ops = [(st.task.name, int(st.next_op.kind), int(st.next_op.fifo))
                       for st in states
                       if not st.done and st.next_op is not None]
        return SimResult(latency=-1, deadlocked=True, blocked_tasks=blocked,
                         results=results, blocked_ops=blocked_ops)
    latency = max(end_times.values()) if end_times else 0
    return SimResult(latency=int(latency), deadlocked=False,
                     blocked_tasks=[], results=results)


def _advance_to_next_fifo_op(st: _TaskState) -> None:
    """Drive the generator until it yields a FIFO op (or finishes),
    folding DELAY ops into ``pending_delay``."""
    while True:
        try:
            op = st.gen.send(st.send_value)
        except StopIteration:
            st.done = True
            st.next_op = None
            return
        st.send_value = None
        if op.kind == DELAY:
            st.pending_delay += op.cycles
        else:
            st.next_op = op
            return


def batch_simulate(design: Design, depth_matrix: np.ndarray) -> np.ndarray:
    """Evaluate many configs with the DES.  Returns (lat, deadlock) arrays.

    Intentionally naive (one full simulation per config): this is the
    "co-simulation search" cost model for Table-III-style benchmarks.
    """
    n = depth_matrix.shape[0]
    lat = np.zeros(n, dtype=np.int64)
    dead = np.zeros(n, dtype=bool)
    for i in range(n):
        r = simulate(design, depth_matrix[i])
        lat[i] = r.latency
        dead[i] = r.deadlocked
    return lat, dead
