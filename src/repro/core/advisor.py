"""FIFOAdvisor: the top-level push-button DSE API (paper Fig. 1).

    advisor = FifoAdvisor(design)                  # trace once
    dse = advisor.run("grouped_sa", budget=1000)   # search
    dse.frontier_points                            # Pareto (latency, BRAM)
    dse.selected(alpha=0.7)                        # the paper's ★ point
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.backends import ConfigCache
from repro.core.config import EvalConfig, resolve_config
from repro.core.design import Design
from repro.core.optimizers import OPTIMIZERS, EvalContext, OptResult
from repro.core.pareto import hypervolume_2d, select_alpha_point
from repro.core.simgraph import SimGraph, build_simgraph
from repro.core.simulate import BatchedEvaluator
from repro.core.tracer import Trace, collect_trace


@dataclasses.dataclass
class Baseline:
    """One reference configuration: its depths and evaluated objectives.

    ``baseline_max`` (declared/observed upper bounds — always feasible)
    and ``baseline_min`` (all-depth-2 — the paper's deadlock probe) are
    the two the advisor evaluates up front.
    """

    depths: np.ndarray
    latency: int
    bram: int
    deadlocked: bool

    def hv_reference(self) -> Tuple[float, float]:
        """Hypervolume reference point anchored at this baseline (2x
        both objectives, nudged off the axes so boundary points count).
        The single definition used by results, campaign traces, and
        service progress events — they must never disagree."""
        return (self.latency * 2.0 + 1.0, self.bram * 2.0 + 2.0)


@dataclasses.dataclass
class DseResult:
    """The outcome of one DSE search: history, frontier, selection.

    Wraps the optimizer's raw :class:`OptResult` with the design's
    baselines so frontier queries, the paper's alpha-point selection,
    and hypervolume all resolve without re-touching the advisor.  The
    single-run API, the campaign store, and the advisory service all
    return this same type.
    """

    design_name: str
    optimizer: str
    result: OptResult
    baseline_max: Baseline
    baseline_min: Baseline
    trace_time_s: float

    @property
    def frontier_points(self) -> np.ndarray:
        """(M, 2) Pareto-optimal (latency, BRAM) points, deduplicated."""
        return self.result.frontier()[0]

    @property
    def frontier_configs(self) -> np.ndarray:
        """(M, F) depth vectors realizing :attr:`frontier_points`."""
        return self.result.frontier()[1]

    def selected(self, alpha: float = 0.7
                 ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """The paper's ★: frontier point minimizing the alpha score vs
        Baseline-Max.  Returns ((latency, bram), depths) or None."""
        pts, idx = self.result.feasible_points()
        if pts.shape[0] == 0:
            return None
        sel = select_alpha_point(
            pts, (self.baseline_max.latency, self.baseline_max.bram), alpha)
        if sel is None:
            return None
        return pts[sel], self.result.configs[idx[sel]]

    def hypervolume(self) -> float:
        """2-D dominated hypervolume of the frontier vs the fixed
        reference point derived from Baseline-Max (larger = better)."""
        return hypervolume_2d(self.frontier_points,
                              self.baseline_max.hv_reference())

    def summary(self, alpha: float = 0.7) -> Dict:
        """JSON-ready digest: budgets, baselines, frontier size, and the
        alpha-selected point with its vs-Baseline-Max ratios."""
        sel = self.selected(alpha)
        out = {
            "design": self.design_name,
            "optimizer": self.optimizer,
            "n_evals": self.result.n_evals,
            "runtime_s": round(self.result.runtime_s, 3),
            "trace_time_s": round(self.trace_time_s, 3),
            "frontier_size": int(self.frontier_points.shape[0]),
            "baseline_max": (self.baseline_max.latency,
                             self.baseline_max.bram),
            "baseline_min": (self.baseline_min.latency,
                             self.baseline_min.bram,
                             self.baseline_min.deadlocked),
            "n_deadlocked_samples": int(self.result.deadlock.sum()),
        }
        if sel is not None:
            (lat, bram), _ = sel
            out["selected"] = (int(lat), int(bram))
            out["lat_vs_max"] = round(
                lat / max(self.baseline_max.latency, 1), 4)
            out["bram_reduction_vs_max"] = round(
                1.0 - bram / max(self.baseline_max.bram, 1), 4)
        return out


class FifoAdvisor:
    """Traces the design once; runs any number of DSE searches on it.

    Construction is the expensive part (trace + simgraph build + the two
    baseline evaluations); afterwards every :meth:`run`, stepwise
    context (:meth:`make_context`), and incremental probe shares the
    trace, the pruned candidate grids, and one advisor-wide
    :class:`ConfigCache`.  Long-lived advisors are how the design
    registry (:mod:`repro.core.service`) serves many clients per trace.

    Args:
        design: the dataflow design to size.
        config: an :class:`~repro.core.config.EvalConfig` — backend,
            iteration cap, condensation, sharding, and the pruning
            flags, in one frozen serializable object (the same one the
            service registry, campaign specs, and snapshots carry).
        upper_bounds: per-FIFO depth caps (default: declared/observed).
            A runtime array, so it stays outside ``EvalConfig``.
        mesh: an explicit :class:`jax.sharding.Mesh` to shard batched
            evaluation over (``docs/mesh.md``); forces the mesh
            backend.  Runtime-only, like ``upper_bounds``.

    The pre-``EvalConfig`` keyword spellings (``backend=``,
    ``max_iters=``, ``condense=``, ``shards=``, ``use_pallas=``,
    ``occupancy_cap=``, ``local_bounds=``, ``certified_floor=``) still
    work for one release and emit a :class:`DeprecationWarning`.
    """

    def __init__(self, design: Design, config: Optional[EvalConfig] = None,
                 *, upper_bounds: Optional[np.ndarray] = None,
                 mesh=None, **legacy):
        if config is not None and not isinstance(config, EvalConfig):
            # pre-EvalConfig signature: the second positional argument
            # was the upper_bounds array
            warnings.warn(
                "FifoAdvisor(design, upper_bounds) positional form is "
                "deprecated; pass upper_bounds= by keyword",
                DeprecationWarning, stacklevel=2)
            upper_bounds, config = np.asarray(config), None
        self.config = resolve_config(config, legacy, "FifoAdvisor")
        t0 = time.perf_counter()
        self.design = design
        self.trace: Trace = collect_trace(design)
        self.graph: SimGraph = build_simgraph(design, self.trace)
        self.evaluator = BatchedEvaluator(self.graph, self.config,
                                          mesh=mesh)
        # One evaluation cache for the whole advisor session: every
        # optimizer run (and the baselines) shares hits.
        self.cache = ConfigCache(self.graph.n_fifos)
        self.trace_time_s = time.perf_counter() - t0
        self._upper_bounds = upper_bounds
        self._certification = None   # cached CertificationResult
        self._lb_cache: Optional[np.ndarray] = None
        self._channel_bounds = None  # cached ChannelBounds
        self._incr_base: Optional[np.ndarray] = None
        # Shared baselines (evaluated outside any optimizer's budget).
        ctx = self._fresh_ctx(seed=0)
        self.baseline_max = self._baseline(ctx.baseline_max())
        self.baseline_min = self._baseline(ctx.baseline_min())

    @classmethod
    def restore(cls, design: Design, *, trace: Trace, graph: SimGraph,
                config: EvalConfig, upper_bounds=None, rungs=None,
                baseline_max: "Baseline", baseline_min: "Baseline",
                certification=None, lb_cache=None,
                cache_data=None) -> "FifoAdvisor":
        """Rebuild an advisor from previously computed parts.

        The warm-restart constructor behind
        :mod:`repro.core.service.snapshot`: the expensive artifacts —
        trace, simgraph, condensation ``rungs``, deadlock
        ``certification``, and the evaluation-cache contents
        (``cache_data`` = ``(rows, lat, bram, dead)`` in insertion
        order) — are handed in instead of recomputed, so construction
        is milliseconds.  A restored advisor is bit-identical to a
        freshly traced one in everything observable but wall-clock
        (``trace_time_s`` records the restore time) and ``n_evals``
        (cache hits are not re-simulated).
        """
        t0 = time.perf_counter()
        self = cls.__new__(cls)
        self.config = config
        self.design = design
        self.trace = trace
        self.graph = graph
        self.evaluator = BatchedEvaluator(graph, config, rungs=rungs)
        self.cache = ConfigCache(graph.n_fifos)
        if cache_data is not None:
            self.cache.load_rows(*cache_data)
        self._upper_bounds = upper_bounds
        self._certification = certification
        self._lb_cache = lb_cache
        self._channel_bounds = None
        self._incr_base = None
        self.baseline_max = baseline_max
        self.baseline_min = baseline_min
        self.trace_time_s = time.perf_counter() - t0
        return self

    # Read-only views kept for the pre-EvalConfig attribute spellings.
    @property
    def _occupancy_cap(self) -> bool:
        return self.config.occupancy_cap

    @property
    def _local_bounds(self) -> bool:
        return self.config.local_bounds

    @property
    def _certified_floor(self) -> bool:
        return self.config.certified_floor

    def make_context(self, seed: int = 0) -> EvalContext:
        """A fresh :class:`EvalContext` sharing this advisor's evaluator,
        candidate pruning, and design-wide evaluation cache.  This is the
        hook the campaign scheduler uses to drive optimizers stepwise
        outside :meth:`run`."""
        return self._fresh_ctx(seed)

    def _fresh_ctx(self, seed: int) -> EvalContext:
        if self._local_bounds and self._lb_cache is None:
            from repro.core.prune import local_lower_bounds
            base = EvalContext(self.graph, self.evaluator,
                               upper_bounds=self._upper_bounds,
                               occupancy_cap=self._occupancy_cap, seed=0)
            self._lb_cache = local_lower_bounds(self.graph, base.candidates)
        lb = self._lb_cache
        if self.config.channel_bounds:
            # Analytical lower bounds are sound the same way local
            # bounds are: below them every configuration deadlocks, so
            # pruning those candidates never loses a feasible point.
            analytical = self.channel_bounds().lower
            lb = analytical if lb is None else np.maximum(lb, analytical)
        floor = self.min_safe_depths() if self._certified_floor else None
        return EvalContext(self.graph, self.evaluator,
                           upper_bounds=self._upper_bounds,
                           occupancy_cap=self._occupancy_cap,
                           lower_bounds=lb,
                           feasible_floor=floor, seed=seed,
                           cache=self.cache)

    def _baseline(self, depths: np.ndarray) -> Baseline:
        m = np.asarray(depths, dtype=np.int64)[None, :]
        lat, bram, dead, miss = self.cache.lookup(m)
        if miss.any():
            lat, bram, dead = self.evaluator.evaluate(m)
            self.cache.insert(m, lat, bram, dead)
        return Baseline(depths=depths, latency=int(lat[0]),
                        bram=int(bram[0]), deadlocked=bool(dead[0]))

    def incremental_latency(self, depths: np.ndarray,
                            base: Optional[np.ndarray] = None
                            ) -> Tuple[int, bool]:
        """One incremental re-simulation (the LightningSim primitive).

        Re-solves only the task segments coupled to the FIFOs that changed
        vs ``base`` (default: the previous ``incremental_latency`` config;
        the first call is a full solve whose state seeds the cache).
        """
        depths = np.asarray(depths, dtype=np.int64).reshape(-1)
        if base is None:
            base = self._incr_base
        lat, _, dead = self.evaluator.evaluate_incremental(
            base, depths[None, :])
        self._incr_base = depths.copy()
        return int(lat[0]), bool(dead[0])

    def channel_bounds(self):
        """Analytical per-channel depth bounds + taxonomy for this design.

        One O(E·F) static pass over the packed trace
        (:func:`repro.core.bounds.channel_bounds`): classifies every FIFO
        (in-order rate-matched / rate-mismatched / reorder /
        data-dependent) and derives sound closed-form ``(lower, upper)``
        bounds that bracket the certified minimal depths.  Computed once
        per advisor; :meth:`min_safe_depths` seeds certification with it
        (same certified vector, a fraction of the probes), and
        ``EvalConfig(channel_bounds=True)`` clamps every optimizer's
        candidate grids with the lower bounds.
        """
        if self._channel_bounds is None:
            from repro.core.bounds import channel_bounds
            self._channel_bounds = channel_bounds(self.graph)
        return self._channel_bounds

    def min_safe_depths(self) -> np.ndarray:
        """Certified minimal deadlock-free depths (coordinate-wise).

        The returned vector is verified deadlock-free and no single FIFO
        can be lowered below it without deadlocking; any configuration at
        or above it *everywhere* is deadlock-free by depth monotonicity,
        so optimizers and the advisory service can seed searches at it or
        clamp their candidate grids with it (``certified_floor=True``).

        Computed once per advisor via monotone binary search over the
        incremental ``solve_delta`` / shared-cache fast path
        (:func:`repro.core.deadlock.certify_min_depths`), seeded by the
        analytical :meth:`channel_bounds` (identical vector, typically
        a fraction of the probes); subsequent calls return the cached
        vector.  When the advisor was built with
        explicit ``upper_bounds``, certification descends from them (so
        the certificate respects the caps) — and raises ``ValueError``
        when no deadlock-free configuration exists under those caps.
        """
        if self._certification is None:
            from repro.core.deadlock import certify_min_depths
            self._certification = certify_min_depths(
                self.graph, self.evaluator, cache=self.cache,
                upper=self._upper_bounds, bounds=self.channel_bounds())
        return self._certification.depths.copy()

    @property
    def certification(self):
        """The full :class:`~repro.core.deadlock.CertificationResult`
        behind :meth:`min_safe_depths` (None until first computed)."""
        return self._certification

    def explain_deadlock(self, depths: np.ndarray):
        """Diagnose one configuration: run the DES oracle at ``depths``
        and return its :class:`~repro.core.deadlock.WaitForGraph`
        (``.blame()`` names the FIFOs on the blocking cycle; the graph
        is empty when the configuration is deadlock-free)."""
        from repro.core.deadlock import extract_wait_graph
        from repro.core.oracle import simulate
        result = simulate(self.design, np.asarray(depths, dtype=np.int64))
        return extract_wait_graph(self.design, result, trace=self.trace)

    def cache_stats(self):
        """Shared evaluation-cache statistics for this advisor session."""
        return self.cache.stats

    def run(self, optimizer: str = "grouped_sa", budget: int = 1000,
            seed: int = 0, **kwargs) -> DseResult:
        """One blocking DSE search; returns its :class:`DseResult`.

        ``optimizer`` is a registry name (``docs/optimizers.md``),
        ``budget`` is in simulated rows, ``kwargs`` go to the optimizer
        constructor.  Repeated runs share this advisor's cache.
        """
        cls = OPTIMIZERS[optimizer]
        ctx = self._fresh_ctx(seed)
        opt = cls(ctx, budget=budget, **kwargs)
        res = opt.run()
        return DseResult(design_name=self.design.name, optimizer=optimizer,
                         result=res, baseline_max=self.baseline_max,
                         baseline_min=self.baseline_min,
                         trace_time_s=self.trace_time_s)

    def run_all(self, optimizers=None, budget: int = 1000,
                seed: int = 0) -> Dict[str, DseResult]:
        """Run several optimizers back to back (default: the paper's
        five) and return ``{name: DseResult}``.  For many designs at
        once, prefer a campaign (``docs/campaign.md``)."""
        from repro.core.optimizers import PAPER_OPTIMIZERS
        names = optimizers or PAPER_OPTIMIZERS
        return {n: self.run(n, budget=budget, seed=seed) for n in names}
