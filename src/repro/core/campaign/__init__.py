"""Cross-design DSE campaign engine.

Runs many ``(design, optimizer, seed)`` tasks as one scheduled workload:
stepwise optimizers interleaved round-robin, cache-aware routing into
pooled worklist workers or one cross-design hetero-batched fixpoint
dispatch, persistent ``.npz`` checkpoints with deterministic replay
resume, and a result store tracking per-task frontiers and hypervolume.

Attributes resolve lazily (PEP 562) so the numpy-only worker processes
can import ``repro.core.campaign.pool`` without dragging in the advisor
(and with it jax).
"""

import importlib

_ATTRS = {
    "Campaign": "repro.core.campaign.scheduler",
    "CampaignSpec": "repro.core.campaign.scheduler",
    "CampaignTask": "repro.core.campaign.scheduler",
    "DesignContext": "repro.core.campaign.scheduler",
    "QUICK_DESIGNS": "repro.core.campaign.scheduler",
    "TaskSpec": "repro.core.campaign.scheduler",
    "default_workers": "repro.core.campaign.scheduler",
    "RoundRouter": "repro.core.campaign.router",
    "RoutedRequest": "repro.core.campaign.router",
    "WorkerPool": "repro.core.campaign.pool",
    "ResultStore": "repro.core.campaign.store",
    "CheckpointMismatch": "repro.core.campaign.state",
    "load_checkpoint": "repro.core.campaign.state",
    "replay": "repro.core.campaign.state",
    "save_checkpoint": "repro.core.campaign.state",
}


def __getattr__(name):
    module = _ATTRS.get(name)
    if module is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(module), name)


def __dir__():
    return sorted(set(globals()) | set(_ATTRS))


__all__ = [
    "Campaign", "CampaignSpec", "CampaignTask", "CheckpointMismatch",
    "DesignContext", "QUICK_DESIGNS", "ResultStore", "RoundRouter",
    "RoutedRequest", "TaskSpec", "WorkerPool", "default_workers",
    "load_checkpoint", "replay", "save_checkpoint",
]
