"""Worklist worker pool for campaign evaluation.

Workers are persistent processes running ONLY the numpy evaluation chain
(designs -> trace -> SimGraph -> worklist).  Each worker keeps, per
design, a :class:`~repro.core.backends.worklist.WorklistBackend` plus an
LRU of solved :class:`WorklistState`'s so the incremental re-simulation
fast path works inside the worker exactly as it does in
:class:`~repro.core.simulate.BatchedEvaluator` (the scheduler keeps each
task sticky to one worker for state locality).

Start method: ``fork`` when available and jax has not been imported in
this process — children then inherit the campaign's already-built graphs
and worklist tables for free (the whole evaluation chain is jax-free, so
there are no XLA threads to trip over).  Once jax IS loaded (hetero mode,
test suites), the pool falls back to ``spawn``: clean ~0.3 s numpy-only
interpreter per worker that re-traces its designs on first use.

Supervision: a lane that crashes or stops answering within
``recv_timeout_s`` is detected (EOF on its pipe, or the recv deadline
expiring), killed, and respawned; its in-flight jobs are re-dispatched
to the fresh process, and a job that has already burned
``max_retries`` lanes is executed inline in the parent instead — so a
round always completes and never hangs on a dead worker.  All results
are exact and every retry re-evaluates the same pure function, so
parallel evaluation — crashes included — is bit-identical to the
sequential path: campaign frontiers do not depend on worker count or on
worker failures.  Fault schedules for chaos testing are injected via
:class:`~repro.core.faults.FaultPlan` (``docs/robustness.md``).
"""

from __future__ import annotations

import multiprocessing as mp
import os
import sys
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import FaultPlan, check_worker_faults

#: cap on queued-but-undrained jobs per worker: bounds the result-pipe
#: backlog so neither side of the pipe pair can fill and deadlock (see
#: WorkerPool.submit) — and bounds how many jobs a lane death can put
#: back in flight
MAX_OUTSTANDING = 8

#: a lane that answers nothing for this long is declared dead (the
#: numpy worklist evaluates a full batch in milliseconds; minutes of
#: silence means the process is gone or wedged)
DEFAULT_RECV_TIMEOUT_S = 60.0


class LaneFailure(RuntimeError):
    """Internal: lane ``lane`` died or went silent; callers of
    ``_recv`` recover by respawning the lane and requeueing."""

    def __init__(self, lane: int, reason: str):
        super().__init__(f"worker lane {lane}: {reason}")
        self.lane = lane
        self.reason = reason


class _WorkerDesign:
    """One design's evaluation engine inside a worker process — a plain
    :class:`~repro.core.simulate.BatchedEvaluator` on the numpy worklist
    (same dispatch policy, in-batch dedup, incremental state LRU as the
    scheduler's own evaluators; the whole chain imports jax-free)."""

    def __init__(self, name: str, max_iters: int, graph=None):
        from repro.core.simulate import BatchedEvaluator

        if graph is None:
            from repro.core.simgraph import build_simgraph
            from repro.core.tracer import collect_trace
            from repro.designs import make_design
            design = make_design(name)
            graph = build_simgraph(design, collect_trace(design))
        from repro.core.config import EvalConfig
        self.ev = BatchedEvaluator(
            graph, EvalConfig(backend="numpy", max_iters=max_iters))

    def evaluate(self, depths: np.ndarray, base: Optional[np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if base is None:
            return self.ev.evaluate(depths)
        return self.ev.evaluate_incremental(base, depths)


def _worker_main(conn, max_iters: int, graphs: Optional[Dict] = None,
                 faults: Optional[List[dict]] = None):
    designs: Dict[str, _WorkerDesign] = {}
    graphs = graphs or {}
    faults = list(faults or [])
    n_jobs = 0
    try:
        while True:
            msg = conn.recv()
            if msg is None:
                break
            name, depths, base = msg
            if faults:
                check_worker_faults(faults, n_jobs)
            n_jobs += 1
            try:
                wd = designs.get(name)
                if wd is None:
                    wd = designs[name] = _WorkerDesign(
                        name, max_iters, graphs.get(name))
                t0 = time.perf_counter()
                lat, bram, dead = wd.evaluate(depths, base)
                conn.send(
                    ("ok", lat, bram, dead, time.perf_counter() - t0))
            except BrokenPipeError:  # lane already written off
                break
            except Exception as exc:  # surfaced in the parent
                conn.send(("err", f"{type(exc).__name__}: {exc}"))
    except (EOFError, KeyboardInterrupt, BrokenPipeError, OSError):
        pass  # parent died / interrupt / lane already written off
    finally:
        conn.close()


def pick_start_method() -> str:
    """fork when it is free of XLA-thread hazards, else spawn."""
    if "fork" in mp.get_all_start_methods() and "jax" not in sys.modules:
        return "fork"
    return "spawn"


class WorkerPool:
    """A fixed set of persistent worklist workers fed round by round,
    supervised against crashes and hangs.

    Args:
        n_workers: lane count.
        max_iters: fixpoint cap forwarded to each worker's evaluator.
        start_method: force ``fork``/``spawn``; default picks.
        graphs: prebuilt ``{name: SimGraph}`` — rides to fork children
            via copy-on-write, and backs the parent's inline-escalation
            evaluators under either start method.
        faults: a :class:`FaultPlan` to exercise recovery paths
            (chaos testing only; None = no injection).
        recv_timeout_s: silence window after which a lane is declared
            dead (``REPRO_POOL_TIMEOUT_S`` overrides the default).
        max_retries: worker attempts per job before the parent runs it
            inline.
    """

    def __init__(self, n_workers: int, max_iters: int = 64,
                 start_method: Optional[str] = None,
                 graphs: Optional[Dict] = None,
                 faults: Optional[FaultPlan] = None,
                 recv_timeout_s: Optional[float] = None,
                 max_retries: int = 2):
        self.n_workers = int(n_workers)
        self.max_iters = int(max_iters)
        self.start_method = start_method or pick_start_method()
        self.faults = faults
        if recv_timeout_s is None:
            recv_timeout_s = float(os.environ.get(
                "REPRO_POOL_TIMEOUT_S", DEFAULT_RECV_TIMEOUT_S))
        self.recv_timeout_s = float(recv_timeout_s)
        self.max_retries = int(max_retries)
        #: how long close() waits for a clean exit before escalating
        self.join_timeout_s = 5.0
        self._graphs = graphs or {}
        # graphs can only ride along through fork's copy-on-write pages;
        # spawn workers rebuild their designs by name on first use
        self._payload = self._graphs if self.start_method == "fork" \
            else None
        self._ctx = mp.get_context(self.start_method)
        self._local: Dict[str, _WorkerDesign] = {}  # inline escalation
        self.stats = {"respawns": 0, "requeued": 0, "escalated": 0,
                      "recovery_s": 0.0}
        self._pipes: List = [None] * self.n_workers
        self._procs: List = [None] * self.n_workers
        for w in range(self.n_workers):
            self._spawn_lane(w)

    # ----------------------------------------------------- lane lifecycle
    def _spawn_lane(self, w: int):
        wf = self.faults.worker_payload(w) if self.faults else None
        parent_conn, child_conn = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_worker_main,
            args=(child_conn, self.max_iters, self._payload, wf),
            daemon=True)
        proc.start()
        child_conn.close()
        self._pipes[w] = parent_conn
        self._procs[w] = proc

    def _revive(self, w: int):
        """Kill whatever is left of lane ``w`` and spawn a replacement."""
        t0 = time.perf_counter()
        proc = self._procs[w]
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=2)
            if proc.is_alive():  # pragma: no cover - stuck in syscall
                proc.kill()
        proc.join(timeout=2)
        try:
            self._pipes[w].close()
        except OSError:  # pragma: no cover - already closed
            pass
        if self.faults is not None:
            # the fault that felled this incarnation is spent: the
            # replacement is shipped only the remaining schedule
            self.faults.consume_worker_fault(w)
        self._spawn_lane(w)
        self.stats["respawns"] += 1
        self.stats["recovery_s"] += time.perf_counter() - t0

    def _recv(self, w: int):
        pipe = self._pipes[w]
        if not pipe.poll(self.recv_timeout_s):
            raise LaneFailure(
                w, f"no result within {self.recv_timeout_s:g}s")
        try:
            msg = pipe.recv()
        except (EOFError, OSError):
            raise LaneFailure(w, "process died") from None
        if msg[0] == "err":
            raise RuntimeError(f"campaign worker {w} failed: {msg[1]}")
        return msg[1:]

    # ------------------------------------------------------ job movement
    def _eval_inline(self, job) -> Tuple:
        """Last resort for a job that keeps killing workers: evaluate in
        the parent on a cached worklist evaluator (exact same engine, so
        results stay bit-identical)."""
        _, name, depths, base = job
        wd = self._local.get(name)
        if wd is None:
            wd = self._local[name] = _WorkerDesign(
                name, self.max_iters, self._graphs.get(name))
        t0 = time.perf_counter()
        lat, bram, dead = wd.evaluate(depths, base)
        return (lat, bram, dead, time.perf_counter() - t0)

    def _dispatch(self, handle: Dict, w: int, j: int):
        """Ship job ``j`` to lane ``w``, recovering the lane if the send
        itself hits a dead process."""
        _, name, depths, base = handle["jobs"][j]
        if self.faults is not None:
            f = self.faults.take("delay_dispatch", lane=w, at=j)
            if f is not None:
                time.sleep(f.value)
        try:
            self._pipes[w].send((name, depths, base))
        except (BrokenPipeError, OSError):
            self._recover(handle, w)
            self._pipes[w].send((name, depths, base))
        handle["per_worker"].setdefault(w, deque()).append(j)

    def _recover(self, handle: Dict, w: int):
        """Lane ``w`` failed: respawn it and re-dispatch its in-flight
        jobs (inline once a job exceeds ``max_retries``)."""
        # clear in place, never replace: submit()'s backpressure loop
        # holds a reference to this deque while it drains, and swapping
        # in a fresh object would leave that loop watching a queue no
        # _collect_one will ever shrink again
        queue = handle["per_worker"].setdefault(w, deque())
        outstanding = list(queue)
        queue.clear()
        self._revive(w)
        retries = handle["retries"]
        requeue, inline = [], []
        for j in outstanding:
            retries[j] = retries.get(j, 0) + 1
            (inline if retries[j] > self.max_retries
             else requeue).append(j)
        self.stats["requeued"] += len(requeue)
        for j in requeue:
            self._dispatch(handle, w, j)
        for j in inline:
            self.stats["escalated"] += 1
            handle["results"][j] = self._eval_inline(handle["jobs"][j])

    def _collect_one(self, handle: Dict, w: int):
        """Blocking-receive the oldest outstanding result from lane
        ``w``; a dead/silent lane is recovered instead (its results then
        arrive from the re-dispatch or inline escalation)."""
        queue = handle["per_worker"][w]
        try:
            res = self._recv(w)
        except LaneFailure:
            self._recover(handle, w)
            return
        handle["results"][queue.popleft()] = res

    def _drain_ready(self, handle: Dict):
        """Collect any results already sitting in the pipes (non-blocking)
        so a worker's result-send can never back up against our job-send
        — the classic pipe-pair deadlock."""
        for w in list(handle["per_worker"]):
            while (handle["per_worker"][w]
                   and self._pipes[w].poll()):
                self._collect_one(handle, w)

    def submit(self, jobs: List[Tuple[int, str, np.ndarray,
                                      Optional[np.ndarray]]]) -> Dict:
        """Ship ``(worker, design, depths, base)`` jobs to their workers
        and return a collection handle; the caller may do its own work
        before :meth:`collect` blocks on the results.

        Flow control: before each send, ready results are drained, and a
        worker with :data:`MAX_OUTSTANDING` queued jobs is blocking-drained
        first — so the per-worker result backlog stays far below the pipe
        buffer and neither side can block on a full pipe simultaneously.
        """
        handle = {"jobs": list(jobs), "per_worker": {}, "results": {},
                  "retries": {}, "n": len(jobs)}
        for j, (w, name, depths, base) in enumerate(jobs):
            self._drain_ready(handle)
            handle["per_worker"].setdefault(w, deque())
            # re-read the deque each pass: _collect_one may recover a
            # dead lane, which rewrites the lane's outstanding queue
            while len(handle["per_worker"][w]) >= MAX_OUTSTANDING:
                self._collect_one(handle, w)
            self._dispatch(handle, w, j)
        return handle

    def collect(self, handle: Dict) -> List[Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, float]]:
        """Results in the submission order of the ``submit`` jobs; each
        is ``(lat, bram, dead, worker_eval_seconds)``."""
        per_worker = handle["per_worker"]
        # drain in round-robin so no single worker's pipe backs up
        while any(per_worker.values()):
            for w in list(per_worker):
                if per_worker[w]:
                    self._collect_one(handle, w)
        out: List = [None] * handle["n"]
        for j, res in handle["results"].items():
            out[j] = res
        return out

    def run_jobs(self, jobs) -> List:
        """submit + collect in one blocking call."""
        return self.collect(self.submit(jobs))

    def close(self):
        """Shut every lane down, escalating join -> terminate -> kill so
        a wedged worker can never outlive the pool as a zombie."""
        for pipe in self._pipes:
            try:
                pipe.send(None)
                pipe.close()
            except (BrokenPipeError, OSError):  # already gone
                pass
        for proc in self._procs:
            if proc is None:
                continue
            proc.join(timeout=self.join_timeout_s)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=2)
            if proc.is_alive():  # pragma: no cover - stuck in syscall
                proc.kill()
            proc.join(timeout=2)
        self._pipes, self._procs = [], []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
