"""Worklist worker pool for campaign evaluation.

Workers are persistent processes running ONLY the numpy evaluation chain
(designs -> trace -> SimGraph -> worklist).  Each worker keeps, per
design, a :class:`~repro.core.backends.worklist.WorklistBackend` plus an
LRU of solved :class:`WorklistState`'s so the incremental re-simulation
fast path works inside the worker exactly as it does in
:class:`~repro.core.simulate.BatchedEvaluator` (the scheduler keeps each
task sticky to one worker for state locality).

Start method: ``fork`` when available and jax has not been imported in
this process — children then inherit the campaign's already-built graphs
and worklist tables for free (the whole evaluation chain is jax-free, so
there are no XLA threads to trip over).  Once jax IS loaded (hetero mode,
test suites), the pool falls back to ``spawn``: clean ~0.3 s numpy-only
interpreter per worker that re-traces its designs on first use.

All results are exact, so parallel evaluation is bit-identical to the
sequential path — campaign frontiers do not depend on worker count.
"""

from __future__ import annotations

import multiprocessing as mp
import sys
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

#: cap on queued-but-undrained jobs per worker: bounds the result-pipe
#: backlog so neither side of the pipe pair can fill and deadlock (see
#: WorkerPool.submit)
MAX_OUTSTANDING = 8


class _WorkerDesign:
    """One design's evaluation engine inside a worker process — a plain
    :class:`~repro.core.simulate.BatchedEvaluator` on the numpy worklist
    (same dispatch policy, in-batch dedup, incremental state LRU as the
    scheduler's own evaluators; the whole chain imports jax-free)."""

    def __init__(self, name: str, max_iters: int, graph=None):
        from repro.core.simulate import BatchedEvaluator

        if graph is None:
            from repro.core.simgraph import build_simgraph
            from repro.core.tracer import collect_trace
            from repro.designs import make_design
            design = make_design(name)
            graph = build_simgraph(design, collect_trace(design))
        from repro.core.config import EvalConfig
        self.ev = BatchedEvaluator(
            graph, EvalConfig(backend="numpy", max_iters=max_iters))

    def evaluate(self, depths: np.ndarray, base: Optional[np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if base is None:
            return self.ev.evaluate(depths)
        return self.ev.evaluate_incremental(base, depths)


def _worker_main(conn, max_iters: int, graphs: Optional[Dict] = None):
    designs: Dict[str, _WorkerDesign] = {}
    graphs = graphs or {}
    try:
        while True:
            msg = conn.recv()
            if msg is None:
                break
            name, depths, base = msg
            try:
                wd = designs.get(name)
                if wd is None:
                    wd = designs[name] = _WorkerDesign(
                        name, max_iters, graphs.get(name))
                t0 = time.perf_counter()
                lat, bram, dead = wd.evaluate(depths, base)
                conn.send(
                    ("ok", lat, bram, dead, time.perf_counter() - t0))
            except Exception as exc:  # surfaced in the parent
                conn.send(("err", f"{type(exc).__name__}: {exc}"))
    except (EOFError, KeyboardInterrupt):  # parent died / interrupt
        pass
    finally:
        conn.close()


def pick_start_method() -> str:
    """fork when it is free of XLA-thread hazards, else spawn."""
    if "fork" in mp.get_all_start_methods() and "jax" not in sys.modules:
        return "fork"
    return "spawn"


class WorkerPool:
    """A fixed set of persistent worklist workers fed round by round."""

    def __init__(self, n_workers: int, max_iters: int = 64,
                 start_method: Optional[str] = None,
                 graphs: Optional[Dict] = None):
        self.n_workers = int(n_workers)
        self.start_method = start_method or pick_start_method()
        # graphs can only ride along through fork's copy-on-write pages;
        # spawn workers rebuild their designs by name on first use
        payload = graphs if self.start_method == "fork" else None
        ctx = mp.get_context(self.start_method)
        self._pipes = []
        self._procs = []
        for _ in range(self.n_workers):
            parent_conn, child_conn = ctx.Pipe()
            proc = ctx.Process(target=_worker_main,
                               args=(child_conn, max_iters, payload),
                               daemon=True)
            proc.start()
            child_conn.close()
            self._pipes.append(parent_conn)
            self._procs.append(proc)

    def _recv(self, w: int):
        msg = self._pipes[w].recv()
        if msg[0] == "err":
            raise RuntimeError(f"campaign worker {w} failed: {msg[1]}")
        return msg[1:]

    def _drain_ready(self, handle: Dict):
        """Collect any results already sitting in the pipes (non-blocking)
        so a worker's result-send can never back up against our job-send
        — the classic pipe-pair deadlock."""
        for w, queue in handle["per_worker"].items():
            while queue and self._pipes[w].poll():
                handle["results"][queue.popleft()] = self._recv(w)

    def submit(self, jobs: List[Tuple[int, str, np.ndarray,
                                      Optional[np.ndarray]]]) -> Dict:
        """Ship ``(worker, design, depths, base)`` jobs to their workers
        and return a collection handle; the caller may do its own work
        before :meth:`collect` blocks on the results.

        Flow control: before each send, ready results are drained, and a
        worker with :data:`MAX_OUTSTANDING` queued jobs is blocking-drained
        first — so the per-worker result backlog stays far below the pipe
        buffer and neither side can block on a full pipe simultaneously.
        """
        per_worker: Dict[int, deque] = {}
        handle = {"per_worker": per_worker, "results": {}, "n": len(jobs)}
        for j, (w, name, depths, base) in enumerate(jobs):
            self._drain_ready(handle)
            queue = per_worker.setdefault(w, deque())
            while len(queue) >= MAX_OUTSTANDING:
                handle["results"][queue.popleft()] = self._recv(w)
            self._pipes[w].send((name, depths, base))
            queue.append(j)
        return handle

    def collect(self, handle: Dict) -> List[Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, float]]:
        """Results in the submission order of the ``submit`` jobs; each
        is ``(lat, bram, dead, worker_eval_seconds)``."""
        per_worker = handle["per_worker"]
        out: List = [None] * handle["n"]
        for j, res in handle["results"].items():
            out[j] = res
        # drain in round-robin so no single worker's pipe backs up
        while any(per_worker.values()):
            for w, queue in per_worker.items():
                if queue:
                    out[queue.popleft()] = self._recv(w)
        return out

    def run_jobs(self, jobs) -> List:
        """submit + collect in one blocking call."""
        return self.collect(self.submit(jobs))

    def close(self):
        for pipe in self._pipes:
            try:
                pipe.send(None)
                pipe.close()
            except (BrokenPipeError, OSError):  # already gone
                pass
        for proc in self._procs:
            proc.join(timeout=5)
            if proc.is_alive():  # pragma: no cover - defensive
                proc.terminate()
        self._pipes, self._procs = [], []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
