"""Campaign checkpoint format and deterministic resume.

One campaign checkpoint is a single ``.npz`` file holding

* ``manifest`` — a JSON document: spec, task list, per-task status,
  per-step simulated-row counts, accumulated timings, and the exact
  numpy ``Generator`` bit-state of every task's RNG;
* per-task history arrays — ``t{i}_configs/lat/bram/dead`` (the full
  evaluation history) and ``t{i}_steps`` (per-``observe`` batch lengths).

Resume does NOT pickle generator frames.  Optimizers are deterministic
functions of (seed, observed results), so :func:`replay` rebuilds every
task from its spec and *re-drives* the generator, feeding back the
recorded result batches step by step.  The recorded rows are inserted
into each design's shared cache first, so the post-replay cache equals
the uninterrupted run's cache at the same round — every later lookup,
budget counter, and RNG draw proceeds identically, which makes resumed
frontiers and hypervolumes byte-identical to an uninterrupted run.  Two
guards enforce this: each replayed proposal must match the recorded
configs exactly, and the replayed RNG bit-state must equal the
checkpointed one (:class:`CheckpointMismatch` otherwise).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Dict

import numpy as np

#: version 2 records the evaluation knobs as one ``eval`` EvalConfig
#: dict; version-1 checkpoints (flat backend/max_iters/shards keys) are
#: still loadable — ``Campaign.resume`` folds them into an EvalConfig
CHECKPOINT_VERSION = 2
_READABLE_VERSIONS = (1, 2)


class CheckpointMismatch(RuntimeError):
    """Replay diverged from the checkpoint (code or data drift)."""


def _rng_state_jsonable(state: Dict) -> Dict:
    # PCG64 state is plain ints/strs; round-trip through JSON is exact
    return json.loads(json.dumps(state))


def save_checkpoint(campaign, path: str) -> str:
    """Atomically write ``campaign``'s full deterministic state."""
    spec = campaign.spec
    manifest = {
        "version": CHECKPOINT_VERSION,
        "round": campaign.round,
        "spec": {
            "designs": list(spec.designs),
            "optimizers": list(spec.optimizers),
            "budget": spec.budget,
            "seed": spec.seed,
            "eval": spec.eval.to_dict(),
            "workers": spec.workers,
            "hetero": spec.hetero,
            "checkpoint_every": spec.checkpoint_every,
            "checkpoint_every_s": spec.checkpoint_every_s,
            "track_hypervolume": spec.track_hypervolume,
        },
        "tasks": [],
    }
    arrays = {}
    for i, task in enumerate(campaign.tasks):
        cfgs, lat, bram, dead, steps = task.ctx.history()
        arrays[f"t{i}_configs"] = cfgs
        arrays[f"t{i}_lat"] = lat
        arrays[f"t{i}_bram"] = bram
        arrays[f"t{i}_dead"] = dead
        arrays[f"t{i}_steps"] = steps
        manifest["tasks"].append({
            "design": task.spec.design,
            "optimizer": task.spec.optimizer,
            "seed": task.spec.seed,
            "budget": task.spec.budget,
            "kwargs": [list(kv) for kv in task.spec.kwargs],
            "done": task.done,
            "n_evals": task.ctx.n_evals,
            "step_miss": list(map(int, task.step_miss)),
            "eval_s": task.eval_s,
            "step_s": task.opt.step_s,
            "runtime_s": (task.result.runtime_s if task.done else None),
            "rng_state": _rng_state_jsonable(
                task.ctx.rng.bit_generator.state),
            "hv_trace": [[int(n), float(h)] for n, h in task.hv_trace],
        })
    directory = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, manifest=np.asarray(
                json.dumps(manifest)), **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def load_checkpoint(path: str) -> Dict:
    """Read a checkpoint into ``{spec, round, tasks, histories}``."""
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        if manifest["version"] not in _READABLE_VERSIONS:
            raise CheckpointMismatch(
                f"checkpoint version {manifest['version']} not in "
                f"readable versions {_READABLE_VERSIONS}")
        histories = []
        for i in range(len(manifest["tasks"])):
            histories.append((z[f"t{i}_configs"], z[f"t{i}_lat"],
                              z[f"t{i}_bram"], z[f"t{i}_dead"],
                              z[f"t{i}_steps"]))
    manifest["histories"] = histories
    return manifest


def replay(campaign, data: Dict) -> None:
    """Drive a freshly-built campaign to the checkpointed position."""
    campaign.round = int(data["round"])
    for task, tdata, hist in zip(campaign.tasks, data["tasks"],
                                 data["histories"]):
        cfgs, lat, bram, dead, steps = hist
        if cfgs.shape[0]:
            # seed the design cache with everything evaluated so far, so
            # post-resume lookups see the uninterrupted run's cache state
            task.dctx.cache.insert(cfgs, lat, bram, dead)
        pos = 0
        for si, n in enumerate(steps):
            n = int(n)
            req = task.opt.propose()
            sl = slice(pos, pos + n)
            pos += n
            if req is None or not np.array_equal(req.depths, cfgs[sl]):
                raise CheckpointMismatch(
                    f"task {task.key}: replayed proposal {si} does not "
                    f"match the checkpointed history")
            n_miss = tdata["step_miss"][si]
            task.ctx.record(cfgs[sl], lat[sl], bram[sl], dead[sl], n_miss)
            task.step_miss.append(int(n_miss))
            task.opt.observe(lat[sl], bram[sl], dead[sl])
            if campaign.spec.track_hypervolume:
                task.hv_trace.append(
                    (task.ctx.n_evals, task.running_hypervolume()))
        state = task.ctx.rng.bit_generator.state
        if _rng_state_jsonable(state) != tdata["rng_state"]:
            raise CheckpointMismatch(
                f"task {task.key}: RNG state after replay differs from "
                f"the checkpoint — optimizer code drifted?")
        task.eval_s = float(tdata["eval_s"])
        task.opt.step_s = float(tdata["step_s"])
        if tdata["done"]:
            if task.opt.propose() is not None:
                raise CheckpointMismatch(
                    f"task {task.key}: marked done but proposes more work")
            task.finalize()
            task.result.runtime_s = float(tdata["runtime_s"])
