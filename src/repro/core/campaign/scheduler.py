"""Cross-design DSE campaign scheduler.

A *campaign* runs many ``(design, optimizer, seed)`` tasks as one
scheduled workload.  Every optimizer is driven through the stepwise
``propose()/observe()`` API (``repro.core.optimizers.base``), so one
scheduler round interleaves every active task:

1. collect each task's outstanding :class:`EvalRequest`;
2. resolve cache hits against the task's design-wide
   :class:`~repro.core.backends.ConfigCache`;
3. route the misses through the shared
   :class:`~repro.core.campaign.router.RoundRouter` (also used by the
   advisory service) —
   * incremental-eligible rows (single-FIFO deltas) to the task's sticky
     worklist worker (or inline), preserving the LightningSim fast path,
   * full-solve rows either to the worker pool (rows are split across
     workers for load balance) or, in hetero mode, packed across designs
     into ONE lane-aligned fixpoint dispatch
     (:class:`~repro.core.backends.HeteroDispatcher`);
4. record results into each task's history/budget and ``observe()`` them.

All evaluation paths are exact, so the per-task histories — and therefore
frontiers and hypervolumes — are bit-identical to running each task alone
through ``FifoAdvisor.run()`` with the same seed.  Campaign state
checkpoints to a single ``.npz`` (see ``repro.core.campaign.state``) and
resumes deterministically by replaying the recorded histories through the
generators.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.advisor import FifoAdvisor
from repro.core.campaign.router import RoundRouter, RoutedRequest
from repro.core.config import EvalConfig
from repro.core.optimizers import OPTIMIZERS, OptResult
from repro.core.pareto import hypervolume_2d
from repro.designs import QUICK_DESIGNS, make_design

__all__ = ["Campaign", "CampaignSpec", "CampaignTask", "DesignContext",
           "QUICK_DESIGNS", "TaskSpec", "default_workers"]


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One DSE task: an optimizer run on a design with a seed/budget."""

    design: str
    optimizer: str
    seed: int = 0
    budget: int = 300
    kwargs: Tuple[Tuple[str, object], ...] = ()

    @property
    def key(self) -> str:
        return f"{self.design}:{self.optimizer}:s{self.seed}"


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """What to run and how to evaluate it.

    How to *evaluate* lives in ``eval`` (an
    :class:`~repro.core.config.EvalConfig` — the same object advisors,
    the service registry, and snapshots carry); the remaining fields are
    scheduling concerns.  The pre-``EvalConfig`` spellings
    (``backend=``/``max_iters=``/``shards=`` directly on the spec) still
    construct and read correctly — they emit a
    :class:`DeprecationWarning` and are folded into ``eval``; the
    attributes remain readable as views of it.
    """

    designs: Tuple[str, ...]
    optimizers: Tuple[str, ...]
    budget: int = 300
    seed: int = 0
    #: deprecated spelling of ``eval.backend``
    backend: Optional[str] = None
    #: deprecated spelling of ``eval.max_iters``
    max_iters: Optional[int] = None
    #: worklist worker processes; 0 = evaluate inline in this process
    workers: int = 0
    #: pack cross-design full-solve batches into one fixpoint dispatch
    #: (the TPU-native path; on CPU the pooled worklist is faster).
    #: Hetero dispatch runs in the scheduler process, so ``workers`` is
    #: ignored in this mode (no pool is spawned)
    hetero: bool = False
    #: deprecated spelling of ``eval.shards``.  Hetero campaigns shard
    #: the packed cross-design batch (design-parallel); per-design
    #: campaigns force ``backend="mesh"``.  None = unsharded.
    shards: Optional[int] = None
    #: rounds between automatic checkpoints (when a path is configured)
    checkpoint_every: int = 8
    #: seconds between automatic checkpoints (when a path is
    #: configured) — complements the round cadence for long rounds;
    #: None disables the timer
    checkpoint_every_s: Optional[float] = None
    #: record per-round (n_evals, hypervolume) trajectories per task —
    #: costs a full frontier recomputation per task per round, so it is
    #: off by default and meant for convergence studies
    track_hypervolume: bool = False
    #: how to evaluate candidate configurations (``docs/campaign.md``)
    eval: Optional[EvalConfig] = None

    def __post_init__(self):
        object.__setattr__(self, "designs", tuple(self.designs))
        object.__setattr__(self, "optimizers", tuple(self.optimizers))
        legacy = {k: getattr(self, k)
                  for k in ("backend", "max_iters", "shards")
                  if getattr(self, k) is not None}
        if self.eval is None:
            if legacy:
                import warnings
                warnings.warn(
                    f"CampaignSpec({', '.join(sorted(legacy))}=...) is "
                    f"deprecated; pass eval=EvalConfig(...) instead",
                    DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "eval", EvalConfig(**legacy))
        elif legacy:
            raise TypeError(
                f"CampaignSpec: pass either eval=EvalConfig(...) or the "
                f"deprecated field(s) {sorted(legacy)}, not both")
        # keep the deprecated fields readable as views of ``eval`` (the
        # whole codebase reads spec.backend / spec.max_iters / spec.shards)
        object.__setattr__(self, "backend", self.eval.backend)
        object.__setattr__(self, "max_iters", self.eval.max_iters)
        object.__setattr__(self, "shards", self.eval.shards)

    def tasks(self) -> List[TaskSpec]:
        return [TaskSpec(design=d, optimizer=o, seed=self.seed,
                         budget=self.budget)
                for d in self.designs for o in self.optimizers]


class DesignContext:
    """Shared per-design state: trace, evaluator, cache, baselines."""

    def __init__(self, name: str, spec: CampaignSpec):
        self.name = name
        # hetero campaigns shard the packed cross-design dispatch instead
        # of each per-design evaluator (which only serves incremental and
        # escalation rows there)
        cfg = spec.eval
        if spec.hetero and cfg.shards is not None:
            cfg = cfg.replace(shards=None)
        self.advisor = FifoAdvisor(make_design(name), cfg)

    @property
    def graph(self):
        return self.advisor.graph

    @property
    def cache(self):
        return self.advisor.cache

    @property
    def evaluator(self):
        return self.advisor.evaluator


class CampaignTask:
    """One stepwise optimizer bound to its design context."""

    def __init__(self, spec: TaskSpec, dctx: DesignContext):
        self.spec = spec
        self.dctx = dctx
        self.ctx = dctx.advisor.make_context(seed=spec.seed)
        cls = OPTIMIZERS[spec.optimizer]
        self.opt = cls(self.ctx, budget=spec.budget, **dict(spec.kwargs))
        self.step_miss: List[int] = []   # per-step simulated-row counts
        self.eval_s = 0.0                # attributed evaluation seconds
        self.result: Optional[OptResult] = None
        self.worker: Optional[int] = None    # sticky pool affinity
        self.hv_trace: List[Tuple[int, float]] = []  # (n_evals, hv)

    @property
    def key(self) -> str:
        return self.spec.key

    @property
    def done(self) -> bool:
        return self.result is not None

    def finalize(self):
        self.result = self.ctx.result(
            self.opt.name, self.opt.step_s + self.eval_s)

    def running_hypervolume(self) -> float:
        res = self.ctx.result(self.opt.name, 0.0)
        pts, _ = res.frontier()
        return hypervolume_2d(
            pts, self.dctx.advisor.baseline_max.hv_reference())


class Campaign:
    """Round-robin scheduler over many stepwise DSE tasks.

    Owns task construction, lane assignment, checkpoint cadence, and the
    worker-pool/hetero lifecycle; the per-round evaluation routing itself
    lives in the shared :class:`~repro.core.campaign.router.RoundRouter`
    (also used by the advisory service, :mod:`repro.core.service`).
    """

    def __init__(self, spec: CampaignSpec,
                 tasks: Optional[Sequence[TaskSpec]] = None,
                 checkpoint_path: Optional[str] = None):
        self.spec = spec
        self.checkpoint_path = checkpoint_path
        self.round = 0
        task_specs = list(tasks) if tasks is not None else spec.tasks()
        self.designs: Dict[str, DesignContext] = {}
        for ts in task_specs:
            if ts.design not in self.designs:
                self.designs[ts.design] = DesignContext(ts.design, spec)
        self.tasks = [CampaignTask(ts, self.designs[ts.design])
                      for ts in task_specs]
        self.pool = None
        #: pool recovery counters from the last closed pool (chaos gate)
        self.pool_stats: Optional[Dict] = None
        from repro.core.faults import resolve_plan
        self.faults = resolve_plan(spec.eval)
        if spec.workers > 0 and not spec.hetero:
            # after the design contexts so forked workers inherit the
            # built graphs + worklist tables; before any jax import so
            # the fork start method stays available.  Hetero mode owns
            # every full-solve row in the main process, so a pool would
            # only ever idle — it is not created (incremental rows run
            # inline there).
            from repro.core.campaign.pool import WorkerPool
            self.pool = WorkerPool(
                spec.workers, max_iters=spec.max_iters,
                graphs={k: d.graph for k, d in self.designs.items()},
                faults=self.faults)
        # evaluation lanes: lane 0 is THIS process (overlapped with the
        # pool via submit/collect), lanes 1..workers are pool workers.
        # Stagger the per-design assignment so the same optimizer on
        # different designs lands on different lanes (otherwise every
        # incremental-heavy task can alias onto one lane).
        n_lanes = spec.workers + 1 if self.pool is not None else 1
        design_index = {k: i for i, k in enumerate(self.designs)}
        per_design_count: Dict[str, int] = {}
        for task in self.tasks:
            k = task.spec.design
            c = per_design_count.get(k, 0)
            per_design_count[k] = c + 1
            task.worker = (c + design_index[k]) % n_lanes
        hetero = None
        if spec.hetero:
            from repro.core.backends.dispatch import HeteroDispatcher
            graphs = {k: d.graph for k, d in self.designs.items()}
            worklists = {k: d.evaluator._worklist
                         for k, d in self.designs.items()}
            hetero = HeteroDispatcher(graphs, worklists,
                                      max_iters=spec.max_iters,
                                      shards=spec.shards)
        self.router = RoundRouter(self.designs, pool=self.pool,
                                  hetero=hetero)

    @property
    def hetero(self):
        return self.router.hetero

    # ------------------------------------------------------------- rounds
    def _round(self) -> int:
        """Advance every active task one step; returns #active tasks."""
        pending: List[RoutedRequest] = []
        for task in self.tasks:
            if task.done:
                continue
            req = task.opt.propose()
            if req is None:
                task.finalize()
                continue
            lat, bram, dead, miss = task.dctx.cache.lookup(req.depths)
            pending.append(RoutedRequest(
                key=task.spec.design, req=req, lat=lat, bram=bram,
                dead=dead, miss_rows=np.flatnonzero(miss),
                lane=task.worker, tag=task))
        self.router.route(pending)
        for p in pending:
            task = p.tag
            rows = p.miss_rows
            if rows.size:
                task.dctx.cache.insert(
                    p.req.depths[rows], p.lat[rows], p.bram[rows],
                    p.dead[rows])
            task.eval_s += p.eval_s
            task.ctx.record(p.req.depths, p.lat, p.bram, p.dead,
                            rows.size)
            task.step_miss.append(int(rows.size))
            task.opt.observe(p.lat, p.bram, p.dead)
            if self.spec.track_hypervolume:
                task.hv_trace.append(
                    (task.ctx.n_evals, task.running_hypervolume()))
        self.round += 1
        return len(pending)

    # -------------------------------------------------------------- runs
    def run(self, max_rounds: Optional[int] = None):
        """Run rounds until every task finishes (or ``max_rounds``).

        Returns the :class:`~repro.core.campaign.store.ResultStore` over
        the finished tasks.  When a checkpoint path is configured, state
        is saved every ``spec.checkpoint_every`` rounds and at exit.
        """
        import time as _time

        from repro.core.campaign.state import save_checkpoint
        self._ensure_pool()
        rounds_done = 0
        last_save = _time.perf_counter()
        try:
            while True:
                active = self._round()
                rounds_done += 1
                due = (self.checkpoint_path is not None
                       and self.spec.checkpoint_every > 0
                       and self.round % self.spec.checkpoint_every == 0)
                every_s = self.spec.checkpoint_every_s
                if (self.checkpoint_path is not None and every_s
                        and _time.perf_counter() - last_save >= every_s):
                    due = True
                if active == 0:
                    break
                if due:
                    save_checkpoint(self, self.checkpoint_path)
                    last_save = _time.perf_counter()
                if max_rounds is not None and rounds_done >= max_rounds:
                    break
            if self.checkpoint_path is not None:
                save_checkpoint(self, self.checkpoint_path)
        finally:
            self.close()
        return self.result_store()

    def result_store(self):
        from repro.core.campaign.store import ResultStore
        store = ResultStore()
        for task in self.tasks:
            if task.done:
                store.add(task)
        return store

    @property
    def finished(self) -> bool:
        return all(t.done for t in self.tasks)

    def _ensure_pool(self):
        """Recreate the worker pool if a previous ``run()`` closed it
        (e.g. a ``max_rounds`` pause) and work remains."""
        if (self.pool is None and self.spec.workers > 0
                and not self.spec.hetero and not self.finished):
            from repro.core.campaign.pool import WorkerPool
            self.pool = WorkerPool(
                self.spec.workers, max_iters=self.spec.max_iters,
                graphs={k: d.graph for k, d in self.designs.items()},
                faults=self.faults)
        self.router.pool = self.pool

    def close(self):
        if self.pool is not None:
            self.pool_stats = dict(self.pool.stats)
            self.pool.close()
            self.pool = None
            self.router.pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------ resume
    @classmethod
    def resume(cls, path: str, workers: Optional[int] = None,
               checkpoint_path: Optional[str] = None) -> "Campaign":
        """Rebuild a campaign from a checkpoint and replay it to the
        recorded position (see ``repro.core.campaign.state``).

        ``workers`` optionally overrides the worker count (a runtime
        concern, not part of the deterministic state); the checkpoint
        keeps being written to ``checkpoint_path`` (default: ``path``).
        """
        from repro.core.campaign.state import load_checkpoint, replay
        data = load_checkpoint(path)
        spec_dict = dict(data["spec"])
        if workers is not None:
            spec_dict["workers"] = workers
        ev = spec_dict.pop("eval", None)
        if ev is not None:
            spec_dict["eval"] = EvalConfig.from_dict(ev)
        else:
            # version-1 checkpoint: the eval knobs were spec fields;
            # fold them into an EvalConfig without a deprecation warning
            # (resuming old state is supported, not deprecated)
            spec_dict["eval"] = EvalConfig(**{
                k: spec_dict.pop(k)
                for k in ("backend", "max_iters", "shards")
                if spec_dict.get(k) is not None})
        spec = CampaignSpec(**spec_dict)
        tasks = [TaskSpec(design=t["design"], optimizer=t["optimizer"],
                          seed=t["seed"], budget=t["budget"],
                          kwargs=tuple(map(tuple, t["kwargs"])))
                 for t in data["tasks"]]
        camp = cls(spec, tasks=tasks,
                   checkpoint_path=checkpoint_path or path)
        replay(camp, data)
        return camp


def default_workers() -> int:
    """Worker count for ``--workers auto``.

    The scheduler's own process is evaluation lane 0, so ``cpu - 1``
    pool workers saturate the machine without oversubscribing (capped —
    campaign rounds rarely keep more than a few lanes busy)."""
    return max(1, min(4, (os.cpu_count() or 2) - 1))
