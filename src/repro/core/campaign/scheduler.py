"""Cross-design DSE campaign scheduler.

A *campaign* runs many ``(design, optimizer, seed)`` tasks as one
scheduled workload.  Every optimizer is driven through the stepwise
``propose()/observe()`` API (``repro.core.optimizers.base``), so one
scheduler round interleaves every active task:

1. collect each task's outstanding :class:`EvalRequest`;
2. resolve cache hits against the task's design-wide
   :class:`~repro.core.backends.ConfigCache`;
3. route the misses —
   * incremental-eligible rows (single-FIFO deltas) to the task's sticky
     worklist worker (or inline), preserving the LightningSim fast path,
   * full-solve rows either to the worker pool (rows are split across
     workers for load balance) or, in hetero mode, packed across designs
     into ONE lane-aligned fixpoint dispatch
     (:class:`~repro.core.backends.HeteroDispatcher`);
4. record results into each task's history/budget and ``observe()`` them.

All evaluation paths are exact, so the per-task histories — and therefore
frontiers and hypervolumes — are bit-identical to running each task alone
through ``FifoAdvisor.run()`` with the same seed.  Campaign state
checkpoints to a single ``.npz`` (see ``repro.core.campaign.state``) and
resumes deterministically by replaying the recorded histories through the
generators.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.advisor import FifoAdvisor
from repro.core.optimizers import OPTIMIZERS, EvalRequest, OptResult
from repro.core.pareto import hypervolume_2d
from repro.designs import QUICK_DESIGNS, make_design

__all__ = ["Campaign", "CampaignSpec", "CampaignTask", "DesignContext",
           "QUICK_DESIGNS", "TaskSpec", "default_workers"]


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One DSE task: an optimizer run on a design with a seed/budget."""

    design: str
    optimizer: str
    seed: int = 0
    budget: int = 300
    kwargs: Tuple[Tuple[str, object], ...] = ()

    @property
    def key(self) -> str:
        return f"{self.design}:{self.optimizer}:s{self.seed}"


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """What to run and how to evaluate it."""

    designs: Tuple[str, ...]
    optimizers: Tuple[str, ...]
    budget: int = 300
    seed: int = 0
    #: per-design evaluator backend ("numpy" worklist is the CPU fast path)
    backend: str = "numpy"
    max_iters: int = 256
    #: worklist worker processes; 0 = evaluate inline in this process
    workers: int = 0
    #: pack cross-design full-solve batches into one fixpoint dispatch
    #: (the TPU-native path; on CPU the pooled worklist is faster).
    #: Hetero dispatch runs in the scheduler process, so ``workers`` is
    #: ignored in this mode (no pool is spawned)
    hetero: bool = False
    #: rounds between automatic checkpoints (when a path is configured)
    checkpoint_every: int = 8
    #: record per-round (n_evals, hypervolume) trajectories per task —
    #: costs a full frontier recomputation per task per round, so it is
    #: off by default and meant for convergence studies
    track_hypervolume: bool = False

    def __post_init__(self):
        object.__setattr__(self, "designs", tuple(self.designs))
        object.__setattr__(self, "optimizers", tuple(self.optimizers))

    def tasks(self) -> List[TaskSpec]:
        return [TaskSpec(design=d, optimizer=o, seed=self.seed,
                         budget=self.budget)
                for d in self.designs for o in self.optimizers]


class DesignContext:
    """Shared per-design state: trace, evaluator, cache, baselines."""

    def __init__(self, name: str, spec: CampaignSpec):
        self.name = name
        self.advisor = FifoAdvisor(make_design(name), backend=spec.backend,
                                   max_iters=spec.max_iters)

    @property
    def graph(self):
        return self.advisor.graph

    @property
    def cache(self):
        return self.advisor.cache

    @property
    def evaluator(self):
        return self.advisor.evaluator


class CampaignTask:
    """One stepwise optimizer bound to its design context."""

    def __init__(self, spec: TaskSpec, dctx: DesignContext):
        self.spec = spec
        self.dctx = dctx
        self.ctx = dctx.advisor.make_context(seed=spec.seed)
        cls = OPTIMIZERS[spec.optimizer]
        self.opt = cls(self.ctx, budget=spec.budget, **dict(spec.kwargs))
        self.step_miss: List[int] = []   # per-step simulated-row counts
        self.eval_s = 0.0                # attributed evaluation seconds
        self.result: Optional[OptResult] = None
        self.worker: Optional[int] = None    # sticky pool affinity
        self.hv_trace: List[Tuple[int, float]] = []  # (n_evals, hv)

    @property
    def key(self) -> str:
        return self.spec.key

    @property
    def done(self) -> bool:
        return self.result is not None

    def finalize(self):
        self.result = self.ctx.result(
            self.opt.name, self.opt.step_s + self.eval_s)

    def running_hypervolume(self) -> float:
        res = self.ctx.result(self.opt.name, 0.0)
        pts, _ = res.frontier()
        bm = self.dctx.advisor.baseline_max
        ref = (bm.latency * 2.0 + 1.0, bm.bram * 2.0 + 2.0)
        return hypervolume_2d(pts, ref)


@dataclasses.dataclass
class _Pending:
    task: CampaignTask
    req: EvalRequest
    lat: np.ndarray
    bram: np.ndarray
    dead: np.ndarray
    miss_rows: np.ndarray


class Campaign:
    """Round-robin scheduler over many stepwise DSE tasks."""

    def __init__(self, spec: CampaignSpec,
                 tasks: Optional[Sequence[TaskSpec]] = None,
                 checkpoint_path: Optional[str] = None):
        self.spec = spec
        self.checkpoint_path = checkpoint_path
        self.round = 0
        task_specs = list(tasks) if tasks is not None else spec.tasks()
        self.designs: Dict[str, DesignContext] = {}
        for ts in task_specs:
            if ts.design not in self.designs:
                self.designs[ts.design] = DesignContext(ts.design, spec)
        self.tasks = [CampaignTask(ts, self.designs[ts.design])
                      for ts in task_specs]
        self.pool = None
        if spec.workers > 0 and not spec.hetero:
            # after the design contexts so forked workers inherit the
            # built graphs + worklist tables; before any jax import so
            # the fork start method stays available.  Hetero mode owns
            # every full-solve row in the main process, so a pool would
            # only ever idle — it is not created (incremental rows run
            # inline there).
            from repro.core.campaign.pool import WorkerPool
            self.pool = WorkerPool(
                spec.workers, max_iters=spec.max_iters,
                graphs={k: d.graph for k, d in self.designs.items()})
        # evaluation lanes: lane 0 is THIS process (overlapped with the
        # pool via submit/collect), lanes 1..workers are pool workers.
        # Stagger the per-design assignment so the same optimizer on
        # different designs lands on different lanes (otherwise every
        # incremental-heavy task can alias onto one lane).
        n_lanes = spec.workers + 1 if self.pool is not None else 1
        design_index = {k: i for i, k in enumerate(self.designs)}
        per_design_count: Dict[str, int] = {}
        for task in self.tasks:
            k = task.spec.design
            c = per_design_count.get(k, 0)
            per_design_count[k] = c + 1
            task.worker = (c + design_index[k]) % n_lanes
        self.hetero = None
        if spec.hetero:
            from repro.core.backends.dispatch import HeteroDispatcher
            graphs = {k: d.graph for k, d in self.designs.items()}
            worklists = {k: d.evaluator._worklist
                         for k, d in self.designs.items()}
            self.hetero = HeteroDispatcher(graphs, worklists,
                                           max_iters=spec.max_iters)

    # ------------------------------------------------------------- rounds
    def _route(self, pending: List[_Pending]):
        """Resolve every pending request's cache-miss rows in place."""
        incr: List[_Pending] = []
        full: List[_Pending] = []
        for p in pending:
            if p.miss_rows.size == 0:
                continue
            ev = p.task.dctx.evaluator
            if p.req.base is not None and ev.prefer_incremental:
                incr.append(p)
            else:
                full.append(p)

        def fill(p: _Pending, rows: np.ndarray, lat, bram, dead):
            p.lat[rows], p.bram[rows], p.dead[rows] = lat, bram, dead

        # full-solve rows: merge per design and dedup across tasks — one
        # scheduler round turns into at most one unique-row batch per
        # design (e.g. every SA variant proposing the Baseline-Max corner
        # in the same round costs ONE solve)
        merged = []
        by_design: Dict[str, List[_Pending]] = {}
        for p in full:
            by_design.setdefault(p.task.dctx.name, []).append(p)
        for name, plist in by_design.items():
            big = np.concatenate(
                [p.req.depths[p.miss_rows] for p in plist], axis=0)
            uniq, inverse = np.unique(big, axis=0, return_inverse=True)
            merged.append((name, plist, uniq, inverse))

        def scatter(name, plist, inverse, ulat, ubram, udead, wall):
            total = len(inverse)
            off = 0
            for p in plist:
                n = p.miss_rows.size
                sel = inverse[off:off + n]
                off += n
                fill(p, p.miss_rows, ulat[sel], ubram[sel], udead[sel])
                p.task.eval_s += wall * n / max(total, 1)

        def incr_inline(p: _Pending):
            rows = p.miss_rows
            t0 = time.perf_counter()
            l, b, dd = p.task.dctx.evaluator.evaluate_incremental(
                p.req.base[rows], p.req.depths[rows])
            p.task.eval_s += time.perf_counter() - t0
            fill(p, rows, l, b, dd)

        if self.hetero is not None and merged:
            for p in incr:
                incr_inline(p)
            t0 = time.perf_counter()
            results = self.hetero.dispatch(
                [(name, uniq) for name, _, uniq, _ in merged])
            dt = time.perf_counter() - t0
            total = sum(u.shape[0] for _, _, u, _ in merged)
            for (name, plist, uniq, inverse), (l, b, dd) in zip(
                    merged, results):
                share = dt * uniq.shape[0] / max(total, 1)
                scatter(name, plist, inverse, l, b, dd, share)
            return

        if self.pool is None:
            for p in incr:
                incr_inline(p)
            for name, plist, uniq, inverse in merged:
                ev = self.designs[name].evaluator
                t0 = time.perf_counter()
                l, b, dd = ev.evaluate(uniq)
                dt = time.perf_counter() - t0
                scatter(name, plist, inverse, l, b, dd, dt)
            return

        # ------- pooled: lane 0 is this process, overlapped with the
        # pool between submit() and collect()
        n_lanes = self.spec.workers + 1
        load = [0.0] * n_lanes
        jobs: List[Tuple[int, str, np.ndarray, Optional[np.ndarray]]] = []
        job_sinks: List[Tuple[_Pending, np.ndarray]] = []
        main_incr: List[_Pending] = []
        for p in incr:
            rows = p.miss_rows
            lane = p.task.worker
            load[lane] += rows.size * p.task.dctx.graph.n_events
            if lane == 0:
                main_incr.append(p)
            else:
                jobs.append((lane - 1, p.task.dctx.name,
                             p.req.depths[rows], p.req.base[rows]))
                job_sinks.append((p, rows))
        # split each design's unique rows into per-lane chunks, balanced
        # by row cost (~ event count of the owning design)
        main_full: List[Tuple[int, np.ndarray]] = []
        pool_full: List[Tuple[int, np.ndarray]] = []  # (merged_idx, sel)
        for mi, (name, _plist, uniq, _inv) in enumerate(merged):
            cost = self.designs[name].graph.n_events
            sel: Dict[int, List[int]] = {}
            for r in range(uniq.shape[0]):
                lane = int(np.argmin(load))
                load[lane] += cost
                sel.setdefault(lane, []).append(r)
            for lane, rsel in sel.items():
                rsel = np.asarray(rsel)
                if lane == 0:
                    main_full.append((mi, rsel))
                else:
                    pool_full.append((mi, rsel))
                    jobs.append((lane - 1, name, uniq[rsel], None))
        handle = self.pool.submit(jobs) if jobs else None

        acc: Dict[int, Tuple] = {}

        def acc_for(mi):
            uniq = merged[mi][2]
            return acc.setdefault(mi, (
                np.zeros(uniq.shape[0], dtype=np.int64),
                np.zeros(uniq.shape[0], dtype=np.int64),
                np.zeros(uniq.shape[0], dtype=bool), [0.0]))

        # main-lane work runs while the pool workers chew on theirs
        for p in main_incr:
            incr_inline(p)
        for mi, rsel in main_full:
            name, _plist, uniq, _inv = merged[mi]
            ev = self.designs[name].evaluator
            t0 = time.perf_counter()
            l, b, dd = ev.evaluate(uniq[rsel])
            st = acc_for(mi)
            st[0][rsel], st[1][rsel], st[2][rsel] = l, b, dd
            st[3][0] += time.perf_counter() - t0

        if handle is not None:
            results = self.pool.collect(handle)
            n_incr_jobs = len(job_sinks)
            for (p, rows), (l, b, dd, dt) in zip(
                    job_sinks, results[:n_incr_jobs]):
                fill(p, rows, l, b, dd)
                p.task.eval_s += dt
            for (mi, rsel), (l, b, dd, dt) in zip(
                    pool_full, results[n_incr_jobs:]):
                st = acc_for(mi)
                st[0][rsel], st[1][rsel], st[2][rsel] = l, b, dd
                st[3][0] += dt
        for mi, (ulat, ubram, udead, wall) in acc.items():
            name, plist, uniq, inverse = merged[mi]
            scatter(name, plist, inverse, ulat, ubram, udead, wall[0])

    def _round(self) -> int:
        """Advance every active task one step; returns #active tasks."""
        pending: List[_Pending] = []
        for task in self.tasks:
            if task.done:
                continue
            req = task.opt.propose()
            if req is None:
                task.finalize()
                continue
            lat, bram, dead, miss = task.dctx.cache.lookup(req.depths)
            pending.append(_Pending(task, req, lat, bram, dead,
                                    np.flatnonzero(miss)))
        self._route(pending)
        for p in pending:
            rows = p.miss_rows
            if rows.size:
                p.task.dctx.cache.insert(
                    p.req.depths[rows], p.lat[rows], p.bram[rows],
                    p.dead[rows])
            p.task.ctx.record(p.req.depths, p.lat, p.bram, p.dead,
                              rows.size)
            p.task.step_miss.append(int(rows.size))
            p.task.opt.observe(p.lat, p.bram, p.dead)
            if self.spec.track_hypervolume:
                p.task.hv_trace.append(
                    (p.task.ctx.n_evals, p.task.running_hypervolume()))
        self.round += 1
        return len(pending)

    # -------------------------------------------------------------- runs
    def run(self, max_rounds: Optional[int] = None):
        """Run rounds until every task finishes (or ``max_rounds``).

        Returns the :class:`~repro.core.campaign.store.ResultStore` over
        the finished tasks.  When a checkpoint path is configured, state
        is saved every ``spec.checkpoint_every`` rounds and at exit.
        """
        from repro.core.campaign.state import save_checkpoint
        self._ensure_pool()
        rounds_done = 0
        try:
            while True:
                active = self._round()
                rounds_done += 1
                due = (self.checkpoint_path is not None
                       and self.spec.checkpoint_every > 0
                       and self.round % self.spec.checkpoint_every == 0)
                if active == 0:
                    break
                if due:
                    save_checkpoint(self, self.checkpoint_path)
                if max_rounds is not None and rounds_done >= max_rounds:
                    break
            if self.checkpoint_path is not None:
                save_checkpoint(self, self.checkpoint_path)
        finally:
            self.close()
        return self.result_store()

    def result_store(self):
        from repro.core.campaign.store import ResultStore
        store = ResultStore()
        for task in self.tasks:
            if task.done:
                store.add(task)
        return store

    @property
    def finished(self) -> bool:
        return all(t.done for t in self.tasks)

    def _ensure_pool(self):
        """Recreate the worker pool if a previous ``run()`` closed it
        (e.g. a ``max_rounds`` pause) and work remains."""
        if (self.pool is None and self.spec.workers > 0
                and not self.spec.hetero and not self.finished):
            from repro.core.campaign.pool import WorkerPool
            self.pool = WorkerPool(
                self.spec.workers, max_iters=self.spec.max_iters,
                graphs={k: d.graph for k, d in self.designs.items()})

    def close(self):
        if self.pool is not None:
            self.pool.close()
            self.pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------ resume
    @classmethod
    def resume(cls, path: str, workers: Optional[int] = None,
               checkpoint_path: Optional[str] = None) -> "Campaign":
        """Rebuild a campaign from a checkpoint and replay it to the
        recorded position (see ``repro.core.campaign.state``).

        ``workers`` optionally overrides the worker count (a runtime
        concern, not part of the deterministic state); the checkpoint
        keeps being written to ``checkpoint_path`` (default: ``path``).
        """
        from repro.core.campaign.state import load_checkpoint, replay
        data = load_checkpoint(path)
        spec_dict = dict(data["spec"])
        if workers is not None:
            spec_dict["workers"] = workers
        spec = CampaignSpec(**spec_dict)
        tasks = [TaskSpec(design=t["design"], optimizer=t["optimizer"],
                          seed=t["seed"], budget=t["budget"],
                          kwargs=tuple(map(tuple, t["kwargs"])))
                 for t in data["tasks"]]
        camp = cls(spec, tasks=tasks,
                   checkpoint_path=checkpoint_path or path)
        replay(camp, data)
        return camp


def default_workers() -> int:
    """Worker count for ``--workers auto``.

    The scheduler's own process is evaluation lane 0, so ``cpu - 1``
    pool workers saturate the machine without oversubscribing (capped —
    campaign rounds rarely keep more than a few lanes busy)."""
    return max(1, min(4, (os.cpu_count() or 2) - 1))
