"""Campaign result store: per-task frontiers, hypervolumes, summaries.

Wraps each finished :class:`~repro.core.campaign.scheduler.CampaignTask`
in the same :class:`~repro.core.advisor.DseResult` the single-run API
returns, so everything downstream (alpha-point selection, summaries,
benchmark plotting) works identically for campaign output.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.core.advisor import DseResult


class ResultStore:
    """Ordered map of task key -> :class:`DseResult` (+ campaign extras)."""

    def __init__(self):
        self.results: Dict[str, DseResult] = {}
        self.hv_traces: Dict[str, List] = {}

    def add(self, task) -> DseResult:
        """Wrap one finished campaign task as a :class:`DseResult`."""
        adv = task.dctx.advisor
        dse = DseResult(design_name=task.spec.design,
                        optimizer=task.spec.optimizer,
                        result=task.result,
                        baseline_max=adv.baseline_max,
                        baseline_min=adv.baseline_min,
                        trace_time_s=adv.trace_time_s)
        return self.add_result(task.key, dse, task.hv_trace)

    def add_result(self, key: str, dse: DseResult,
                   hv_trace=None) -> DseResult:
        """Store an already-built :class:`DseResult` under ``key`` —
        the hook for non-campaign producers (the advisory service, ad
        hoc scripts) to reuse the summary/JSON machinery."""
        self.results[key] = dse
        self.hv_traces[key] = list(hv_trace or [])
        return dse

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, key: str) -> DseResult:
        return self.results[key]

    def keys(self):
        return self.results.keys()

    def frontiers(self) -> Dict[str, np.ndarray]:
        """Per-task Pareto frontier points (latency, BRAM)."""
        return {k: r.frontier_points for k, r in self.results.items()}

    def hypervolumes(self) -> Dict[str, float]:
        return {k: r.hypervolume() for k, r in self.results.items()}

    def total_evals(self) -> int:
        return sum(r.result.n_evals for r in self.results.values())

    def summary(self, alpha: float = 0.7) -> Dict:
        """JSON-ready per-task summaries + campaign totals."""
        tasks = {}
        for key, dse in self.results.items():
            entry = dse.summary(alpha)
            entry["hypervolume"] = dse.hypervolume()
            entry["frontier"] = dse.frontier_points.tolist()
            entry["hv_trace"] = self.hv_traces.get(key, [])
            tasks[key] = entry
        return {
            "n_tasks": len(self.results),
            "total_evals": self.total_evals(),
            "total_runtime_s": round(sum(
                r.result.runtime_s for r in self.results.values()), 3),
            "tasks": tasks,
        }

    def save_json(self, path: str, alpha: float = 0.7,
                  extra: Optional[Dict] = None) -> str:
        payload = self.summary(alpha)
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=_np_default)
        return path


def _np_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))
