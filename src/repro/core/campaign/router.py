"""Shared request-routing round for the campaign engine AND the service.

One scheduler/service round produces a list of outstanding
:class:`~repro.core.optimizers.EvalRequest`'s, one per active task or
client session, each already screened against its design's
:class:`~repro.core.backends.ConfigCache`.  :class:`RoundRouter` owns
everything that happens between that screening and ``observe()``:

* incremental-eligible rows (``req.base`` set, evaluator prefers the
  worklist) run on their sticky lane — inline or on a pool worker —
  preserving the LightningSim incremental fast path;
* full-solve rows are merged **per design** and deduplicated across
  requesters (two sessions proposing the same corner in the same round
  cost ONE solve), then either split across worker lanes balanced by row
  cost or, in hetero mode, packed across designs into a single
  lane-aligned fixpoint dispatch
  (:class:`~repro.core.backends.HeteroDispatcher`);
* wall time is attributed back to each requester proportionally to its
  share of the evaluated rows.

The router is deliberately ignorant of *who* is asking: the campaign
scheduler routes :class:`~repro.core.campaign.scheduler.CampaignTask`
batches and the advisory service (:mod:`repro.core.service`) routes
client-session batches through the exact same code, so both inherit the
same exactness guarantee — every path is bit-identical to evaluating each
request alone.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.optimizers import EvalRequest

__all__ = ["RoundRouter", "RoutedRequest"]


@dataclasses.dataclass
class RoutedRequest:
    """One requester's outstanding batch plus its result buffers.

    ``lat/bram/dead`` arrive pre-filled with cache hits;
    :meth:`RoundRouter.route` fills the ``miss_rows`` in place and
    accumulates the attributed evaluation seconds into ``eval_s``.
    ``tag`` is an opaque requester handle (a campaign task, a service
    session) the router never inspects.
    """

    key: str                  # design key into the router's mapping
    req: EvalRequest
    lat: np.ndarray
    bram: np.ndarray
    dead: np.ndarray
    miss_rows: np.ndarray     # row indices still unresolved after cache
    lane: int = 0             # sticky evaluation lane (0 = this process)
    tag: object = None
    eval_s: float = 0.0       # attributed evaluation wall seconds


class RoundRouter:
    """Routes one round of pending requests into evaluation engines.

    ``designs`` maps a design key to any object exposing ``.evaluator``
    (a :class:`~repro.core.simulate.BatchedEvaluator`) and ``.graph``
    (its :class:`~repro.core.simgraph.SimGraph`) — the campaign's
    ``DesignContext`` and the service's ``FifoAdvisor`` registry entries
    both qualify.  ``pool`` (a
    :class:`~repro.core.campaign.pool.WorkerPool`) and ``hetero`` (a
    :class:`~repro.core.backends.HeteroDispatcher`) are optional engines
    the owner wires in and may swap at any time between rounds.
    """

    def __init__(self, designs: Mapping[str, object], pool=None,
                 hetero=None):
        self.designs = designs
        self.pool = pool
        self.hetero = hetero
        #: design keys whose rows must evaluate on lane 0 (this process)
        #: even when a pool is attached — used for designs the pool's
        #: worker processes cannot rebuild (custom Design objects that
        #: ``make_design`` does not know)
        self.inline_only: set = set()

    @property
    def n_lanes(self) -> int:
        """Evaluation lanes: lane 0 is the calling process; lanes
        ``1..n_workers`` are pool workers."""
        return self.pool.n_workers + 1 if self.pool is not None else 1

    # ----------------------------------------------------------- routing
    def route(self, pending: List[RoutedRequest]) -> None:
        """Resolve every pending request's cache-miss rows in place."""
        incr: List[RoutedRequest] = []
        full: List[RoutedRequest] = []
        for p in pending:
            if p.miss_rows.size == 0:
                continue
            ev = self.designs[p.key].evaluator
            if p.req.base is not None and ev.prefer_incremental:
                incr.append(p)
            else:
                full.append(p)

        def fill(p: RoutedRequest, rows: np.ndarray, lat, bram, dead):
            p.lat[rows], p.bram[rows], p.dead[rows] = lat, bram, dead

        # full-solve rows: merge per design and dedup across requesters —
        # one round turns into at most one unique-row batch per design
        # (e.g. every SA variant proposing the Baseline-Max corner in the
        # same round costs ONE solve)
        merged = []
        by_design: Dict[str, List[RoutedRequest]] = {}
        for p in full:
            by_design.setdefault(p.key, []).append(p)
        for name, plist in by_design.items():
            big = np.concatenate(
                [p.req.depths[p.miss_rows] for p in plist], axis=0)
            uniq, inverse = np.unique(big, axis=0, return_inverse=True)
            merged.append((name, plist, uniq, inverse))

        def scatter(name, plist, inverse, ulat, ubram, udead, wall):
            total = len(inverse)
            off = 0
            for p in plist:
                n = p.miss_rows.size
                sel = inverse[off:off + n]
                off += n
                fill(p, p.miss_rows, ulat[sel], ubram[sel], udead[sel])
                p.eval_s += wall * n / max(total, 1)

        def incr_inline(p: RoutedRequest):
            rows = p.miss_rows
            t0 = time.perf_counter()
            l, b, dd = self.designs[p.key].evaluator.evaluate_incremental(
                p.req.base[rows], p.req.depths[rows])
            p.eval_s += time.perf_counter() - t0
            fill(p, rows, l, b, dd)

        if self.hetero is not None and merged:
            for p in incr:
                incr_inline(p)
            t0 = time.perf_counter()
            results = self.hetero.dispatch(
                [(name, uniq) for name, _, uniq, _ in merged])
            dt = time.perf_counter() - t0
            total = sum(u.shape[0] for _, _, u, _ in merged)
            for (name, plist, uniq, inverse), (l, b, dd) in zip(
                    merged, results):
                share = dt * uniq.shape[0] / max(total, 1)
                scatter(name, plist, inverse, l, b, dd, share)
            return

        if self.pool is None:
            for p in incr:
                incr_inline(p)
            for name, plist, uniq, inverse in merged:
                ev = self.designs[name].evaluator
                t0 = time.perf_counter()
                l, b, dd = ev.evaluate(uniq)
                dt = time.perf_counter() - t0
                scatter(name, plist, inverse, l, b, dd, dt)
            return

        # ------- pooled: lane 0 is this process, overlapped with the
        # pool between submit() and collect()
        n_lanes = self.n_lanes
        load = [0.0] * n_lanes
        jobs: List[Tuple[int, str, np.ndarray, Optional[np.ndarray]]] = []
        job_sinks: List[Tuple[RoutedRequest, np.ndarray]] = []
        main_incr: List[RoutedRequest] = []
        for p in incr:
            rows = p.miss_rows
            lane = 0 if p.key in self.inline_only else p.lane
            load[lane] += rows.size * self.designs[p.key].graph.n_events
            if lane == 0:
                main_incr.append(p)
            else:
                jobs.append((lane - 1, p.key,
                             p.req.depths[rows], p.req.base[rows]))
                job_sinks.append((p, rows))
        # split each design's unique rows into per-lane chunks, balanced
        # by row cost (~ event count of the owning design)
        main_full: List[Tuple[int, np.ndarray]] = []
        pool_full: List[Tuple[int, np.ndarray]] = []  # (merged_idx, sel)
        for mi, (name, _plist, uniq, _inv) in enumerate(merged):
            cost = self.designs[name].graph.n_events
            sel: Dict[int, List[int]] = {}
            if name in self.inline_only:
                load[0] += cost * uniq.shape[0]
                sel[0] = list(range(uniq.shape[0]))
            else:
                for r in range(uniq.shape[0]):
                    lane = int(np.argmin(load))
                    load[lane] += cost
                    sel.setdefault(lane, []).append(r)
            for lane, rsel in sel.items():
                rsel = np.asarray(rsel)
                if lane == 0:
                    main_full.append((mi, rsel))
                else:
                    pool_full.append((mi, rsel))
                    jobs.append((lane - 1, name, uniq[rsel], None))
        handle = self.pool.submit(jobs) if jobs else None

        acc: Dict[int, Tuple] = {}

        def acc_for(mi):
            uniq = merged[mi][2]
            return acc.setdefault(mi, (
                np.zeros(uniq.shape[0], dtype=np.int64),
                np.zeros(uniq.shape[0], dtype=np.int64),
                np.zeros(uniq.shape[0], dtype=bool), [0.0]))

        # main-lane work runs while the pool workers chew on theirs
        for p in main_incr:
            incr_inline(p)
        for mi, rsel in main_full:
            name, _plist, uniq, _inv = merged[mi]
            ev = self.designs[name].evaluator
            t0 = time.perf_counter()
            l, b, dd = ev.evaluate(uniq[rsel])
            st = acc_for(mi)
            st[0][rsel], st[1][rsel], st[2][rsel] = l, b, dd
            st[3][0] += time.perf_counter() - t0

        if handle is not None:
            results = self.pool.collect(handle)
            n_incr_jobs = len(job_sinks)
            for (p, rows), (l, b, dd, dt) in zip(
                    job_sinks, results[:n_incr_jobs]):
                fill(p, rows, l, b, dd)
                p.eval_s += dt
            for (mi, rsel), (l, b, dd, dt) in zip(
                    pool_full, results[n_incr_jobs:]):
                st = acc_for(mi)
                st[0][rsel], st[1][rsel], st[2][rsel] = l, b, dd
                st[3][0] += dt
        for mi, (ulat, ubram, udead, wall) in acc.items():
            name, plist, uniq, inverse = merged[mi]
            scatter(name, plist, inverse, ulat, ubram, udead, wall[0])
