"""Pareto-frontier utilities for the dual-objective (latency, BRAM) DSE."""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows of an (N, 2) minimize-both array.

    O(N log N): sort by (f0, f1); sweep keeping the running min of f1.
    Duplicate points are all kept (they are mutually non-dominating).
    """
    pts = np.asarray(points, dtype=np.float64)
    if pts.size == 0:
        return np.zeros(0, dtype=bool)
    n = pts.shape[0]
    order = np.lexsort((pts[:, 1], pts[:, 0]))
    mask = np.zeros(n, dtype=bool)
    best_f1 = np.inf
    i = 0
    while i < n:
        # group rows with identical f0: dominance among them is via f1 only
        j = i
        f0 = pts[order[i], 0]
        while j < n and pts[order[j], 0] == f0:
            j += 1
        grp = order[i:j]
        g1 = pts[grp, 1]
        gmin = g1.min()
        if gmin < best_f1:
            mask[grp[g1 == gmin]] = True
            best_f1 = gmin
        else:
            mask[grp[g1 == best_f1]] = False  # strictly dominated
        i = j
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of the Pareto-optimal rows, sorted by f0 ascending."""
    m = pareto_mask(points)
    idx = np.flatnonzero(m)
    return idx[np.argsort(points[idx, 0], kind="stable")]


def hypervolume_2d(points: np.ndarray, ref: Tuple[float, float]) -> float:
    """Dominated hypervolume (minimize both) w.r.t. reference point ``ref``."""
    pts = np.asarray(points, dtype=np.float64)
    if pts.size == 0:
        return 0.0
    idx = pareto_front(pts)
    front = pts[idx]
    front = front[(front[:, 0] < ref[0]) & (front[:, 1] < ref[1])]
    if front.size == 0:
        return 0.0
    hv = 0.0
    prev_f1 = ref[1]
    for f0, f1 in front:
        f1 = min(f1, prev_f1)
        hv += (ref[0] - f0) * (prev_f1 - f1)
        prev_f1 = f1
    return float(hv)


def alpha_score(points: np.ndarray, baseline: Tuple[float, float],
                alpha: float = 0.7) -> np.ndarray:
    """The paper's §IV-B selection metric, per point:

        alpha * (lat / base_lat) + (1 - alpha) * (bram / base_bram)

    A zero-BRAM baseline degrades the second term to ``bram / 1``.
    """
    pts = np.asarray(points, dtype=np.float64)
    base_lat = max(float(baseline[0]), 1.0)
    base_bram = max(float(baseline[1]), 1.0)
    return alpha * pts[:, 0] / base_lat + (1.0 - alpha) * pts[:, 1] / base_bram


def select_alpha_point(points: np.ndarray, baseline: Tuple[float, float],
                       alpha: float = 0.7) -> Optional[int]:
    """Index of the frontier point minimizing the alpha score (paper's ★)."""
    if np.asarray(points).size == 0:
        return None
    idx = pareto_front(points)
    scores = alpha_score(points[idx], baseline, alpha)
    return int(idx[int(np.argmin(scores))])
