"""FIFOAdvisor <-> distributed-training bridge.

A pipeline-parallel LM is a dataflow design: stages are tasks, microbatch
activations/gradients flow through bounded queues, and queue capacities
trade pipeline-bubble latency against activation memory — exactly the
latency/BRAM trade-off the paper solves for HLS FIFOs.  This module
compiles a stage graph into a :class:`~repro.core.design.Design` so the
UNMODIFIED FIFOAdvisor machinery (trace -> incremental sim -> Pareto DSE)
sizes the queues.

Stage costs can come straight from the dry-run's roofline terms
(``per_layer_flops / PEAK_FLOPS`` -> cycles at some clock), closing the
loop between the two halves of this framework; see
``examples/pipeline_buffer_sizing.py``.

The schedule modelled is GPipe-style (all-forward then all-backward per
stage, FIFO queues for both directions); the "memory" objective reuses
f_bram as a stand-in for per-queue buffer cost with ``width`` = bytes per
microbatch activation (scaled).  This is an analogy-level application of
the paper (DESIGN.md §5) — but every number is derived, not invented.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.design import Design


@dataclasses.dataclass
class PipelineStage:
    name: str
    fwd_cycles: int
    bwd_cycles: int


def pipeline_design(stages: Sequence[PipelineStage], n_microbatches: int,
                    act_width: int = 512, grad_width: int = 512,
                    stash_width: int = 4096) -> Design:
    """Build the dataflow design of a microbatched fwd/bwd pipeline.

    Per stage boundary: ``act_i`` carries stage i -> i+1 activations and
    ``grad_i`` carries i+1 -> i gradients (one element per microbatch).
    Per stage: ``stash_i`` holds the activations stage i must keep for its
    OWN backward — its depth is the pipeline-memory knob: depth
    n_microbatches reproduces GPipe (all-forward run-ahead), depth ~1
    throttles the forward sweep into a 1F1B-like schedule.  FIFOAdvisor's
    latency/memory frontier over these queues IS the microbatch-schedule
    spectrum.

    Forward tasks are declared first and backward tasks in reverse stage
    order, so the design is sequentially executable (traceable).
    """
    S = len(stages)
    d = Design(f"pipeline_{S}stage_{n_microbatches}mb")
    for i in range(S):
        d.fifo(f"stash_{i}", width=stash_width, group="stash")
    for i in range(S - 1):
        d.fifo(f"act_{i}", width=act_width, group="act")
        d.fifo(f"grad_{i}", width=grad_width, group="grad")

    def make_fwd(i: int, st: PipelineStage):
        def prog(ctx, i=i, st=st):
            for m in range(n_microbatches):
                if i > 0:
                    yield ctx.read(f"act_{i - 1}")
                yield ctx.delay(st.fwd_cycles)
                yield ctx.write(f"stash_{i}", m)
                if i < S - 1:
                    yield ctx.write(f"act_{i}", m)
        return prog

    def make_bwd(i: int, st: PipelineStage):
        def prog(ctx, i=i, st=st):
            for m in range(n_microbatches):
                if i < S - 1:
                    yield ctx.read(f"grad_{i}")
                yield ctx.read(f"stash_{i}")
                yield ctx.delay(st.bwd_cycles)
                if i > 0:
                    yield ctx.write(f"grad_{i - 1}", m)
        return prog

    for i, st in enumerate(stages):
        d.add_task(f"{st.name}_fwd", make_fwd(i, st))
    for i in reversed(range(S)):
        d.add_task(f"{stages[i].name}_bwd", make_bwd(i, stages[i]))
    return d


def stages_from_layer_cost(n_stages: int, layers_per_stage: int,
                           cycles_per_layer: int,
                           bwd_ratio: float = 2.0,
                           imbalance: Optional[Sequence[float]] = None
                           ) -> List[PipelineStage]:
    """Derive stage costs (e.g. cycles_per_layer from the dry-run's
    per-layer FLOPs / chip peak at some clock)."""
    out = []
    for i in range(n_stages):
        scale = imbalance[i] if imbalance else 1.0
        fwd = max(1, int(layers_per_stage * cycles_per_layer * scale))
        out.append(PipelineStage(f"stage{i}", fwd,
                                 max(1, int(fwd * bwd_ratio))))
    return out
