"""Event-graph condensation: exact super-event compression of the hot path.

Every evaluator in the repo pays per-event cost on raw traces of 8k-13k
events even though designs have only 32-60 FIFOs: in any realistic
schedule the vast majority of events never stall — they are pure delay
links whose completion time is exactly ``previous event + delta``.  This
module collapses each maximal run of such non-stalling events inside a
task segment into ONE super-event carrying the aggregated delta (the
max-plus composition of the chain), keeping only the *anchors*: segment
starts, task-final events, and the FIFO reads/writes whose cross edge
(data arrival or back-pressure) can actually bind.

Exactness is *not* a property of the anchor choice — it is enforced per
evaluation by a sound O(E) vectorized certificate:

1.  The condensed system is a **relaxation** of the raw one: folded
    events contribute their chain inequality (which always holds) and
    drop their cross constraint, so the condensed least fixpoint is a
    per-event **lower bound** on the raw least fixpoint.
2.  Expanding the condensed solution back to raw index space
    (``t[e] = t_cond[cond_of[e]] + off_of[e]``) and *checking* every
    folded event's dropped cross constraint makes the expansion a
    fixpoint of the **raw** system when all checks pass.  A fixpoint
    that is also a lower bound of the least fixpoint *is* the least
    fixpoint — bit-exact latency, and (since a finite raw fixpoint
    exists iff the design does not deadlock at those depths) an exact
    deadlock verdict, with no assumption on how anchors were picked.
3.  Rows whose certificate fails simply fall through to the next rung of
    the cascade and ultimately to the raw evaluator: condensation can
    only ever change *speed*, never results.

Anchor sets are therefore chosen heuristically, from stall profiles of a
few representative *probe* solves (box corner, upper bounds, occupancy,
random rows) plus a per-FIFO occupancy-profile rule for back-pressure
(a write can only stall when the FIFO can be full near its rank), tuned
for high certificate pass rates on the depth box ``row >= floor``.

See ``docs/performance.md`` for the full exactness argument, the index
mapping semantics, and measured compression/speedup numbers.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bram import read_latency_np as _read_latencies
from repro.core.design import READ, WRITE
from repro.core.simgraph import SimGraph

__all__ = ["CondensedGraph", "condense", "condense_auto", "expand_times",
           "verify_rows"]


@dataclasses.dataclass
class CondensedGraph:
    """A :class:`~repro.core.simgraph.SimGraph` compressed to its anchors.

    Duck-compatible with ``SimGraph`` (same per-event / per-fifo /
    per-task array fields, in *condensed* event space) so the worklist
    solver and the lane-aligned operand builder consume it directly.
    Extra tables carry the folded structure:

    ================  =========  ========================================
    ``orig_of``       (Ec,)      raw index of each anchor
    ``cond_of``       (E,)       covering anchor (condensed idx) per raw
                                 event; every raw event's exact time is
                                 ``t_cond[cond_of[e]] + off_of[e]``
    ``off_of``        (E,)       delta-chain offset from covering anchor
    ``data_off``      (Ec,)      offset of each anchor read's data source
                                 relative to the source's covering anchor
    ``read_off_flat`` (R,)       same, for every raw read slot (the
                                 back-pressure gather table); the paired
                                 ``read_evt_flat`` holds *condensed*
                                 anchor indices
    ``w_anchor_flat``/``w_off_flat``  write-side rank tables (delta path)
    ``cov_*``         (Nfold,)   folded ops grouped by covering anchor —
                                 the worklist scatters their stream times
                                 in bulk when the anchor completes
    ``vr_*`` / ``vw_*``          folded-read / folded-write certificate
                                 tables consumed by :func:`verify_rows`
    ================  =========  ========================================

    ``floor`` is the routing box: rows at or above it (coordinate-wise)
    have a high certificate pass rate; any row may still be attempted —
    exactness never depends on the box.
    """

    raw: SimGraph
    floor: np.ndarray
    # SimGraph-compatible per-event arrays (condensed index space)
    kind: np.ndarray
    fifo: np.ndarray
    delta: np.ndarray
    seg_start: np.ndarray
    rank: np.ndarray
    data_src: np.ndarray
    # per-fifo (raw rank semantics: streams keep full size)
    read_evt_flat: np.ndarray
    read_base: np.ndarray
    n_reads: np.ndarray
    n_writes: np.ndarray
    widths: np.ndarray
    # per-task
    last_evt: np.ndarray
    end_delay: np.ndarray
    # metadata mirrored from raw
    upper_bounds: np.ndarray
    max_occupancy: np.ndarray
    unbounded_latency: int
    # condensation extras
    data_off: np.ndarray
    read_off_flat: np.ndarray
    w_anchor_flat: np.ndarray
    w_off_flat: np.ndarray
    w_base: np.ndarray
    orig_of: np.ndarray
    cond_of: np.ndarray
    off_of: np.ndarray
    cov_ptr: np.ndarray
    cov_is_read: np.ndarray
    cov_fifo: np.ndarray
    cov_rank: np.ndarray
    cov_off: np.ndarray
    vr_idx: np.ndarray
    vr_src: np.ndarray
    vr_fifo: np.ndarray
    vw_idx: np.ndarray
    vw_fifo: np.ndarray
    vw_rank: np.ndarray
    _bound: int
    #: cascade role: "occ" (above-occupancy box: back-pressure waves
    #: vanish, so even the per-row worklist wins), "aggressive" (maximum
    #: compression, scan backends only — the worklist's cost is bound by
    #: wake-wave count, not event count), or "safe" (high pass rate)
    tag: str = "safe"

    @property
    def design(self):
        return self.raw.design

    @property
    def n_events(self) -> int:
        return int(self.kind.shape[0])

    @property
    def n_raw_events(self) -> int:
        return int(self.raw.n_events)

    @property
    def n_fifos(self) -> int:
        return int(self.widths.shape[0])

    @property
    def n_tasks(self) -> int:
        return int(self.last_evt.shape[0])

    @property
    def compression(self) -> float:
        """Raw-to-condensed event ratio (>= 1)."""
        return self.n_raw_events / max(self.n_events, 1)

    def groups(self):
        return self.raw.groups()

    def latency_upper_bound(self) -> int:
        # the RAW bound: the condensed fixpoint is a lower bound on the
        # raw one, so exceeding the raw bound still certifies deadlock,
        # while a smaller condensed-only bound could misflag feasible
        # rows whose (exact) times sit between the two bounds
        return self._bound

    def in_box(self, depth_matrix: np.ndarray) -> np.ndarray:
        """(C, F) rows -> bool mask of rows inside the routing box."""
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        return (m >= self.floor[None, :]).all(axis=1)


def expand_times(cg: CondensedGraph, t_cond: np.ndarray) -> np.ndarray:
    """Condensed anchor times -> exact raw per-event times.

    ``t_cond`` is (Ec,) or (C, Ec); returns (E,) or (C, E).  Only valid
    for solutions whose certificate passed (:func:`verify_rows`).
    """
    t_cond = np.asarray(t_cond)
    if t_cond.ndim == 1:
        return t_cond[cg.cond_of] + cg.off_of
    return t_cond[:, cg.cond_of] + cg.off_of[None, :]


def _stall_profile(g: SimGraph, depths: np.ndarray, state,
                   margin: int) -> Optional[tuple]:
    """Per-event near-stall masks + per-fifo occupancy profiles for one
    solved probe configuration.  Returns None when the probe deadlocked.
    """
    if state.deadlocked:
        return None
    t = state.t
    E = g.n_events
    depths = np.asarray(depths, dtype=np.int64)
    rd_lat = _read_latencies(depths, np.asarray(g.widths, dtype=np.int64))

    chain = np.empty(E, dtype=np.int64)
    chain[0] = g.delta[0]
    chain[1:] = t[:-1] + g.delta[1:]
    seg_heads = np.flatnonzero(g.seg_start)
    chain[seg_heads] = g.delta[seg_heads]

    kind = g.kind
    fifo = g.fifo.astype(np.int64)
    rank = g.rank

    read_stall = np.zeros(E, dtype=bool)
    rmask = kind == READ
    if rmask.any():
        ri = np.flatnonzero(rmask)
        cross = t[g.data_src[ri]] + rd_lat[fifo[ri]]
        read_stall[ri] = cross > chain[ri] - margin

    write_stall = np.zeros(E, dtype=bool)
    wmask = kind == WRITE
    wi = np.flatnonzero(wmask)
    if wi.size:
        f = fifo[wi]
        j = rank[wi]
        d = depths[f]
        act = (j >= d) & (j - d < g.n_reads[f])
        if act.any():
            ai = wi[act]
            fa = f[act]
            pos = g.read_base[fa] + rank[ai] - depths[fa]
            cross = t[g.read_evt_flat[pos]] + 1
            write_stall[ai] = cross > chain[ai] - margin

    # occupancy profile: in-flight element count at completion of each
    # write (rank order); a write can only back-pressure-stall at depth d
    # when the profile can reach d near its rank
    prof: List[np.ndarray] = []
    for f in range(g.n_fifos):
        wsel = wi[fifo[wi] == f]
        tw = t[wsel]                       # rank order (SPSC, one segment)
        tr = np.sort(t[np.flatnonzero(rmask & (fifo == f))])
        done = np.searchsorted(tr, tw, side="left")
        prof.append(np.arange(tw.size, dtype=np.int64) + 1 - done)
    return read_stall, write_stall, prof


def _solve(g: SimGraph, depths: np.ndarray):
    from repro.core.backends.worklist import solve
    return solve(g, depths)


def _default_probes(g: SimGraph, floor: np.ndarray,
                    n_random: int, seed: int) -> List[np.ndarray]:
    """Representative in-box probe rows: box corner, upper bounds,
    midpoint, occupancy, and a few random rows — all clipped to the box
    (stalls of out-of-box schedules would pollute the anchor set with
    events that cannot stall for any admissible row)."""
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    occ = np.maximum(g.max_occupancy, 1)
    rng = np.random.default_rng(seed)
    probes = [floor, np.maximum(u, floor),
              np.maximum((floor + u) // 2, floor),
              np.maximum(occ, floor)]
    for _ in range(n_random):
        frac = rng.uniform(0.0, 1.0, g.n_fifos)
        row = floor + ((np.maximum(u, floor) - floor)
                       * frac).astype(np.int64)
        probes.append(np.maximum(row, floor))
    return probes


def condense(g: SimGraph, floor: Optional[np.ndarray] = None,
             margin: int = 2, occ_slack: int = 2, bp_rule: bool = True,
             probes: Optional[Sequence[np.ndarray]] = None,
             n_random_probes: int = 3, seed: int = 0,
             _solve_cache: Optional[dict] = None) -> CondensedGraph:
    """Build a :class:`CondensedGraph` for the box ``depths >= floor``.

    ``floor`` defaults to ``max(1, upper_bounds // 2)`` — the region DSE
    optimizers spend most of their budget in.  ``margin`` widens the
    near-stall test on probe schedules (guards the ±1-cycle SRL/BRAM
    read-latency wobble between rows); ``bp_rule``/``occ_slack`` control
    the occupancy-profile back-pressure rule (a write's stall *rank*
    moves with its depth, so point probes alone cannot cover it — the
    rule anchors every write whose in-flight profile approaches the
    floor; disabling it trades certificate pass rate for compression).
    ``probes`` overrides the probe configurations.

    The result is exact for EVERY depth row — the per-row certificate,
    not the anchor choice, carries correctness (module docstring).
    """
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    if floor is None:
        floor = np.maximum(1, u // 2)
    floor = np.asarray(floor, dtype=np.int64)
    E = g.n_events
    if E == 0:
        return _build(g, np.zeros(0, dtype=bool), floor)

    if probes is None:
        probes = _default_probes(g, floor, n_random_probes, seed)
    profiles = []
    for p in probes:
        p = np.asarray(p, dtype=np.int64)
        if _solve_cache is not None:
            key = p.tobytes()
            st = _solve_cache.get(key)
            if st is None:
                st = _solve_cache[key] = _solve(g, p)
        else:
            st = _solve(g, p)
        prof = _stall_profile(g, p, st, margin)
        if prof is not None:
            profiles.append(prof)

    anchors = np.zeros(E, dtype=bool)
    anchors[np.flatnonzero(g.seg_start)] = True
    anchors[g.last_evt[g.last_evt >= 0]] = True

    F = g.n_fifos
    prof_max: List[Optional[np.ndarray]] = [None] * F
    for read_stall, write_stall, prof in profiles:
        anchors |= read_stall
        anchors |= write_stall
        for f in range(F):
            prof_max[f] = (prof[f] if prof_max[f] is None
                           else np.maximum(prof_max[f], prof[f]))

    if bp_rule:
        wi = np.flatnonzero(g.kind == WRITE)
        for f in range(F):
            if prof_max[f] is None:
                continue
            ws = wi[g.fifo[wi] == f]
            hot = prof_max[f] + occ_slack >= floor[f]
            anchors[ws[hot[: ws.size]]] = True

    return _build(g, anchors, floor)


def _build(g: SimGraph, anchors: np.ndarray,
           floor: np.ndarray) -> CondensedGraph:
    """Materialize the condensed arrays for a given anchor set."""
    E = g.n_events
    delta = g.delta.astype(np.int64)
    anc_idx = np.flatnonzero(anchors)
    cmap = np.cumsum(anchors) - 1            # raw idx -> condensed idx
    # covering anchor per raw event (always exists: segment heads anchor)
    lastanc = np.maximum.accumulate(np.where(anchors, np.arange(E), -1))
    cond_of = cmap[lastanc]
    D = np.cumsum(delta)
    off_of = D - D[lastanc]

    # condensed deltas: the max-plus composition of the folded chain
    # between consecutive anchors (segment heads keep their own delta)
    delta_c = delta[anc_idx].copy()
    tail = anc_idx[g.seg_start[anc_idx] == 0]
    delta_c[g.seg_start[anc_idx] == 0] = delta[tail] + off_of[tail - 1]

    kind_c = g.kind[anc_idx]
    data_src_raw = g.data_src[anc_idx]
    has = data_src_raw >= 0
    data_src_c = np.where(has, cond_of[np.clip(data_src_raw, 0, E - 1)], -1)
    data_off_c = np.where(has, off_of[np.clip(data_src_raw, 0, E - 1)], 0)

    read_evt_flat_c = cond_of[g.read_evt_flat]
    read_off_flat = off_of[g.read_evt_flat]

    # write-side rank tables (incremental-solver base-stream snapshots)
    wi = np.flatnonzero(g.kind == WRITE)
    order = np.argsort(g.fifo[wi], kind="stable")   # rank order per fifo
    wflat = wi[order]
    w_anchor_flat = cond_of[wflat]
    w_off_flat = off_of[wflat]
    w_base = np.zeros(g.n_fifos, dtype=np.int64)
    np.cumsum(g.n_writes[:-1], out=w_base[1:])

    folded = np.flatnonzero(~anchors)
    cov_anchor = cond_of[folded]                    # nondecreasing
    Ec = anc_idx.size
    counts = np.bincount(cov_anchor, minlength=Ec)
    cov_ptr = np.zeros(Ec + 1, dtype=np.int64)
    np.cumsum(counts, out=cov_ptr[1:])

    fr = folded[g.kind[folded] == READ]
    fw = folded[g.kind[folded] == WRITE]

    last_evt_c = np.where(g.last_evt >= 0,
                          cmap[np.clip(g.last_evt, 0, E - 1)], -1)

    return CondensedGraph(
        raw=g, floor=floor.copy(),
        kind=kind_c.astype(np.int8),
        fifo=g.fifo[anc_idx].astype(np.int32),
        delta=delta_c,
        seg_start=g.seg_start[anc_idx].astype(np.int8),
        rank=g.rank[anc_idx].astype(np.int64),
        data_src=data_src_c.astype(np.int64),
        read_evt_flat=read_evt_flat_c.astype(np.int64),
        read_base=g.read_base.copy(), n_reads=g.n_reads.copy(),
        n_writes=g.n_writes.copy(), widths=g.widths.copy(),
        last_evt=last_evt_c.astype(np.int64), end_delay=g.end_delay.copy(),
        upper_bounds=g.upper_bounds.copy(),
        max_occupancy=g.max_occupancy.copy(),
        unbounded_latency=g.unbounded_latency,
        data_off=data_off_c.astype(np.int64),
        read_off_flat=read_off_flat.astype(np.int64),
        w_anchor_flat=w_anchor_flat.astype(np.int64),
        w_off_flat=w_off_flat.astype(np.int64),
        w_base=w_base,
        orig_of=anc_idx.astype(np.int64),
        cond_of=cond_of.astype(np.int64),
        off_of=off_of.astype(np.int64),
        cov_ptr=cov_ptr,
        cov_is_read=(g.kind[folded] == READ),
        cov_fifo=g.fifo[folded].astype(np.int64),
        cov_rank=g.rank[folded].astype(np.int64),
        cov_off=off_of[folded].astype(np.int64),
        vr_idx=fr.astype(np.int64),
        vr_src=g.data_src[fr].astype(np.int64),
        vr_fifo=g.fifo[fr].astype(np.int64),
        vw_idx=fw.astype(np.int64),
        vw_fifo=g.fifo[fw].astype(np.int64),
        vw_rank=g.rank[fw].astype(np.int64),
        _bound=int(g.latency_upper_bound()),
    )


_VERIFY_CHUNK = 128


def verify_rows(cg: CondensedGraph, depth_matrix: np.ndarray,
                t_cond: np.ndarray) -> np.ndarray:
    """The exactness certificate: (C,) bool, True where the expanded
    condensed solution is provably the raw least fixpoint.

    Checks every folded event's dropped cross constraint against the
    expanded times (module docstring, step 2).  A folded write whose
    back-pressure partner does not exist (structural deadlock at that
    row) fails the certificate, routing the row to the raw evaluator.
    """
    m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
    t_cond = np.atleast_2d(np.asarray(t_cond, dtype=np.int64))
    C = m.shape[0]
    ok = np.ones(C, dtype=bool)
    g = cg.raw
    widths = np.asarray(g.widths, dtype=np.int64)
    for lo in range(0, C, _VERIFY_CHUNK):
        sl = slice(lo, min(lo + _VERIFY_CHUNK, C))
        t_hat = expand_times(cg, t_cond[sl])          # (c, E) int64
        rows = m[sl]
        good = ok[sl]
        if cg.vr_idx.size:
            lat = _read_latencies(rows, widths)       # (c, F)
            cross = t_hat[:, cg.vr_src] + lat[:, cg.vr_fifo]
            good &= ~(cross > t_hat[:, cg.vr_idx]).any(axis=1)
        if cg.vw_idx.size:
            d = rows[:, cg.vw_fifo]                   # (c, Nw)
            j = cg.vw_rank[None, :]
            act = j >= d
            nr = g.n_reads[cg.vw_fifo][None, :]
            overrun = act & (j - d >= nr)
            good &= ~overrun.any(axis=1)
            pos = np.clip(g.read_base[cg.vw_fifo][None, :] + j - d, 0,
                          max(g.read_evt_flat.size - 1, 0))
            pev = g.read_evt_flat[pos] if g.read_evt_flat.size else pos
            cross = np.take_along_axis(t_hat, pev, axis=1) + 1
            good &= ~(act & ~overrun & (cross > t_hat[:, cg.vw_idx])
                      ).any(axis=1)
        ok[sl] = good
    return ok


# --------------------------------------------------------------------------
# the auto cascade
# --------------------------------------------------------------------------

#: (tag, floor-kind, margin, occ_slack, bp_rule) per rung.  Both rungs
#: share the feasible-leaning "half" box and the back-pressure rule;
#: they differ in how wide the near-stall margins are cast:
#: "aggressive" — exact stall profiles only (margin 0, zero bp slack):
#:     25-150x compression, moderate certificate pass rate
#: "safe" — wide margins + generous bp slack: near-total pass rate at
#:     2-3x compression, the pre-raw backstop
_AUTO_RUNGS: Tuple[Tuple[str, str, int, int, bool], ...] = (
    ("aggressive", "half", 0, 0, True),
    ("safe", "half", 6, 8, True),
)


def condense_auto(g: SimGraph,
                  rungs: Sequence[Tuple[str, str, int, int, bool]]
                  = _AUTO_RUNGS,
                  seed: int = 0) -> List[CondensedGraph]:
    """Build the default condensation cascade for ``g``.

    Rungs differ in routing floor and anchor aggressiveness; probe
    solves are shared across rungs through one cache.  The cascade is
    ordered most-aggressive-first: evaluation tries each rung a row's
    box admits, falling through on certificate failure, and lands on the
    raw evaluator as the unconditional backstop.
    """
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    occ = np.maximum(g.max_occupancy, 1)
    floors = {"occ": np.maximum(occ, 2), "half": np.maximum(1, u // 2)}
    cache: dict = {}
    out = []
    for tag, kind, margin, slack, bp in rungs:
        cg = condense(g, floor=floors[kind], margin=margin,
                      occ_slack=slack, bp_rule=bp, seed=seed,
                      _solve_cache=cache)
        cg.tag = tag
        # a rung that barely compresses only adds verification overhead
        if cg.compression >= 1.25:
            out.append(cg)
    return out
