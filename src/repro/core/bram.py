"""FIFO memory model: Algorithm 1 BRAM18K counting + breakpoint pruning.

Targets UltraScale+ style BRAM18K primitives with aspect ratios
1K x 18, 2K x 9, 4K x 4, 8K x 2, 16K x 1.  FIFOs with depth <= 2 or total
bits <= 1024 are implemented as shift registers (SRL) and cost zero BRAM.

The paper's §III-C pruning observation: ``f_bram`` only changes at a small
set of *breakpoints* in depth, so the DSE need only ever sample depths that
maximally utilize their allocated BRAMs.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

# (depth, width) aspect ratios of one BRAM18K, widest first (paper order).
BRAM18K_CONFIGS: Tuple[Tuple[int, int], ...] = (
    (1024, 18), (2048, 9), (4096, 4), (8192, 2), (16384, 1),
)
SRL_BITS = 1024     # depth*width at or under this => shift register
SRL_DEPTH = 2       # depth at or under this => shift register

# Extra read-latency cycle of a BRAM-backed FIFO vs a shift-register FIFO
# (Vitis behaviour; reproduces the paper's footnote-2 effect).
SRL_READ_LATENCY = 1
BRAM_READ_LATENCY = 2


def is_srl(depth: int, width: int) -> bool:
    return depth <= SRL_DEPTH or depth * width <= SRL_BITS


def fifo_read_latency(depth: int, width: int) -> int:
    return SRL_READ_LATENCY if is_srl(depth, width) else BRAM_READ_LATENCY


def read_latency_np(depths: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """Vectorized :func:`fifo_read_latency` over broadcastable arrays —
    the single numpy copy of the SRL/BRAM rule (the evaluators and the
    condensation certificate must agree on it bit for bit)."""
    srl = (depths <= SRL_DEPTH) | (depths * widths <= SRL_BITS)
    return np.where(srl, SRL_READ_LATENCY, BRAM_READ_LATENCY)


def bram_count(depth: int, width: int) -> int:
    """Algorithm 1 from the paper, verbatim."""
    if is_srl(depth, width):
        return 0
    n = 0
    w = width
    for d_i, w_i in BRAM18K_CONFIGS:
        n += (w // w_i) * -(-depth // d_i)   # floor(w/w_i) * ceil(d/d_i)
        w = w % w_i
        if w > 0 and depth <= d_i:
            n += 1
            w = 0
    return n


def bram_count_np(depths: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """Vectorized Algorithm 1 over arbitrary broadcastable int arrays."""
    depths = np.asarray(depths, dtype=np.int64)
    widths = np.asarray(widths, dtype=np.int64)
    n = np.zeros(np.broadcast(depths, widths).shape, dtype=np.int64)
    w = np.broadcast_to(widths, n.shape).copy()
    d = np.broadcast_to(depths, n.shape)
    for d_i, w_i in BRAM18K_CONFIGS:
        n += (w // w_i) * -(-d // d_i)
        w = w % w_i
        fits = (w > 0) & (d <= d_i)
        n += fits
        w = np.where(fits, 0, w)
    srl = (d <= SRL_DEPTH) | (d * np.broadcast_to(widths, n.shape) <= SRL_BITS)
    return np.where(srl, 0, n)


def design_bram_np(depth_matrix: np.ndarray,
                   widths: Sequence[int]) -> np.ndarray:
    """f_bram for a batch of configs: (C, n_fifos) -> (C,) total BRAMs."""
    w = np.asarray(widths, dtype=np.int64)[None, :]
    return bram_count_np(depth_matrix, w).sum(axis=-1)


def breakpoints(width: int, upper: int) -> np.ndarray:
    """All depths in [2, upper] that maximally utilize their BRAM count.

    Returns the sorted, deduplicated set {d : bram(d+1,w) > bram(d,w)}
    ∪ {2, upper} clipped to [2, upper].  These are the only depths the DSE
    should ever sample (any other depth is dominated: same BRAM cost,
    no-larger buffering).
    """
    upper = int(max(2, upper))
    cand = {2, upper}
    # SRL boundary: largest depth still mapped to a shift register.
    srl_edge = SRL_BITS // width
    if SRL_DEPTH < srl_edge < upper:
        cand.add(srl_edge)
    # BRAM row-count boundaries: multiples of each aspect-ratio depth.
    for d_i, _ in BRAM18K_CONFIGS:
        for k in range(1, upper // d_i + 1):
            cand.add(k * d_i)
        if d_i < upper:
            cand.add(d_i)          # the `depth <= d_i` condition flips here
    cand = sorted(c for c in cand if 2 <= c <= upper)
    # Keep only genuine step points (and always keep 2 and upper).
    out: List[int] = []
    for c in cand:
        if c in (2, upper) or bram_count(c + 1, width) > bram_count(c, width):
            out.append(c)
    return np.asarray(sorted(set(out)), dtype=np.int64)


def breakpoints_brute(width: int, upper: int) -> np.ndarray:
    """O(upper) reference used by property tests."""
    upper = int(max(2, upper))
    out = [2]
    for d in range(2, upper):
        if bram_count(d + 1, width) > bram_count(d, width):
            out.append(d)
    out.append(upper)
    return np.asarray(sorted(set(out)), dtype=np.int64)
