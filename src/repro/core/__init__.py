"""FIFOAdvisor core: the paper's contribution as a composable library."""

from repro.core.advisor import Baseline, DseResult, FifoAdvisor
from repro.core.design import Design, Fifo, Task
from repro.core.oracle import SimResult, simulate
from repro.core.simgraph import SimGraph, build_simgraph
from repro.core.simulate import BatchedEvaluator, evaluate_np
from repro.core.tracer import Trace, collect_trace

__all__ = [
    "Baseline", "BatchedEvaluator", "Design", "DseResult", "Fifo",
    "FifoAdvisor", "SimGraph", "SimResult", "Task", "Trace",
    "build_simgraph", "collect_trace", "evaluate_np", "simulate",
]
