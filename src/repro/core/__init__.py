"""FIFOAdvisor core: the paper's contribution as a composable library.

This package imports eagerly but stays jax-free: every jax-backed piece
(operand prep, fixpoint/pallas backends) loads lazily inside
:mod:`repro.core.backends`, so numpy-only consumers — the campaign
worker processes in particular — can import the whole core (worklist
evaluation, advisor, optimizers) without paying the jax/XLA import.
"""

from repro.core.advisor import Baseline, DseResult, FifoAdvisor
from repro.core.backends import (ConfigCache, EvalBackend,
                                 available_backends, get_backend,
                                 register_backend)
from repro.core.condense import (CondensedGraph, condense, condense_auto,
                                 expand_times, verify_rows)
from repro.core.config import EvalConfig, resolve_config
from repro.core.deadlock import (CertificationResult, WaitForGraph,
                                 certify_min_depths, deadlock_blame,
                                 extract_wait_graph)
from repro.core.design import Design, Fifo, Task
from repro.core.oracle import SimResult, simulate
from repro.core.simgraph import SimGraph, build_simgraph
from repro.core.simulate import BatchedEvaluator, evaluate_np
from repro.core.tracer import Trace, collect_trace

__all__ = [
    "Baseline", "BatchedEvaluator", "CertificationResult", "CondensedGraph",
    "ConfigCache", "Design", "DseResult", "EvalBackend", "EvalConfig", "Fifo",
    "FifoAdvisor", "SimGraph", "SimResult", "Task", "Trace", "WaitForGraph",
    "available_backends", "build_simgraph", "certify_min_depths",
    "collect_trace", "condense", "condense_auto", "deadlock_blame",
    "evaluate_np", "expand_times", "extract_wait_graph", "get_backend",
    "register_backend", "resolve_config", "simulate", "verify_rows",
]
