"""Dataflow design IR: the HLS-like object FIFOAdvisor optimizes.

A :class:`Design` is a set of *tasks* (HLS dataflow processes) communicating
through named FIFO *streams*.  Task bodies are plain Python generator
functions so that data-dependent control flow (DDCF) — loop bounds that
depend on values read from FIFOs or on kernel arguments — is expressed
naturally and resolved only at trace-collection time, exactly like
LightningSim executing the C source natively.

Task programs yield :class:`Op` requests and receive read values back::

    @design.task("consumer")
    def consumer(ctx):
        n = ctx.arg("n")
        total = 0
        for _ in range(n):
            v = yield ctx.read("x")
            total += v
            yield ctx.delay(1)
        ctx.result("sum", total)

The same generator is driven by two independent engines:

* :mod:`repro.core.tracer` — HLS *sequential semantics* (tasks run to
  completion in declaration order against unbounded FIFOs) to collect the
  event trace, and
* :mod:`repro.core.oracle` — a cycle-accurate discrete-event simulation
  against *bounded* FIFOs (the stand-in for RTL co-simulation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

# Op kinds (shared integer encoding across tracer / oracle / simulators).
READ = 0
WRITE = 1
DELAY = 2


@dataclasses.dataclass(frozen=True)
class Op:
    """A single request yielded by a task program."""

    kind: int
    fifo: int = -1          # fifo index for READ/WRITE
    cycles: int = 0         # cycle count for DELAY
    value: Any = None       # payload for WRITE


@dataclasses.dataclass
class Fifo:
    """A FIFO stream declaration.

    ``width`` is the element bit-width (drives the BRAM model).  ``group``
    names the HLS array this stream belongs to (``hls::stream<T> v[16]``
    style); grouped optimizers assign one depth per group.  ``depth`` is the
    designer-declared depth, used as one possible per-FIFO upper bound.
    """

    name: str
    index: int
    width: int = 32
    group: Optional[str] = None
    depth: Optional[int] = None


class TaskCtx:
    """Handle passed to task programs for building ops and reading args."""

    def __init__(self, design: "Design", args: Dict[str, Any],
                 results: Dict[str, Any]):
        self._design = design
        self._args = args
        self._results = results

    def arg(self, name: str) -> Any:
        return self._args[name]

    def read(self, fifo: str) -> Op:
        return Op(READ, fifo=self._design.fifo_index(fifo))

    def write(self, fifo: str, value: Any = 0) -> Op:
        return Op(WRITE, fifo=self._design.fifo_index(fifo), value=value)

    def delay(self, cycles: int) -> Op:
        if cycles < 0:
            raise ValueError("delay must be non-negative")
        return Op(DELAY, cycles=int(cycles))

    def result(self, key: str, value: Any) -> None:
        """Record a functional output (used to check design correctness)."""
        self._results[key] = value


TaskProgram = Callable[[TaskCtx], Generator[Op, Any, None]]


@dataclasses.dataclass
class Task:
    """A dataflow process.

    ``data_dependent`` marks tasks whose FIFO access *pattern* (op counts
    or interleaving) depends on values read from FIFOs or on kernel
    arguments — the paper's DDCF processes.  The static channel-bounds
    pass (:mod:`repro.core.bounds`) treats every FIFO touched by such a
    task as instance-specific: its trace-derived bounds still hold for
    the traced argument values, but are not closed-form over all inputs.
    """

    name: str
    index: int
    program: TaskProgram
    data_dependent: bool = False


class Design:
    """A dataflow design: FIFO declarations + task programs + kernel args."""

    def __init__(self, name: str, args: Optional[Dict[str, Any]] = None):
        self.name = name
        self.args: Dict[str, Any] = dict(args or {})
        self.fifos: List[Fifo] = []
        self.tasks: List[Task] = []
        self._fifo_by_name: Dict[str, int] = {}

    # ---------------------------------------------------------------- fifos
    def fifo(self, name: str, width: int = 32, group: Optional[str] = None,
             depth: Optional[int] = None) -> str:
        if name in self._fifo_by_name:
            raise ValueError(f"duplicate fifo {name!r}")
        f = Fifo(name=name, index=len(self.fifos), width=width, group=group,
                 depth=depth)
        self.fifos.append(f)
        self._fifo_by_name[name] = f.index
        return name

    def fifo_array(self, name: str, n: int, width: int = 32,
                   depth: Optional[int] = None) -> List[str]:
        """Declare ``hls::stream<T> name[n]`` — one group of n streams."""
        return [self.fifo(f"{name}[{i}]", width=width, group=name, depth=depth)
                for i in range(n)]

    def fifo_index(self, name: str) -> int:
        return self._fifo_by_name[name]

    # ---------------------------------------------------------------- tasks
    def task(self, name: str, data_dependent: bool = False
             ) -> Callable[[TaskProgram], TaskProgram]:
        def deco(fn: TaskProgram) -> TaskProgram:
            self.tasks.append(Task(name=name, index=len(self.tasks),
                                   program=fn,
                                   data_dependent=data_dependent))
            return fn
        return deco

    def add_task(self, name: str, fn: TaskProgram,
                 data_dependent: bool = False) -> None:
        self.tasks.append(Task(name=name, index=len(self.tasks), program=fn,
                               data_dependent=data_dependent))

    # ------------------------------------------------------------- metadata
    @property
    def n_fifos(self) -> int:
        return len(self.fifos)

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def groups(self) -> Dict[str, List[int]]:
        """Map group name -> fifo indices.  Ungrouped fifos form singleton
        groups keyed by their own name (the paper's grouped optimizers then
        degrade gracefully on designs without stream arrays)."""
        out: Dict[str, List[int]] = {}
        for f in self.fifos:
            key = f.group if f.group is not None else f.name
            out.setdefault(key, []).append(f.index)
        return out

    def widths(self) -> List[int]:
        return [f.width for f in self.fifos]

    def declared_depths(self) -> List[Optional[int]]:
        return [f.depth for f in self.fifos]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Design({self.name!r}, fifos={self.n_fifos}, "
                f"tasks={self.n_tasks})")
