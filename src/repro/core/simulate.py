"""Trace-based incremental FIFO-latency evaluation (the LightningSim core).

Two exact evaluators over a :class:`repro.core.simgraph.SimGraph`:

``evaluate_np``
    Kahn-worklist longest-path solve, one config at a time.  Readable
    reference; also the arbiter for the (rare) configs the batched path
    cannot classify within its iteration cap.

``BatchedEvaluator``
    The TPU-native formulation.  Event times are the least fixpoint of a
    monotone max-plus map; we iterate Jacobi steps where each step is

        cross-edge gathers  (data edges + depth-dependent back-pressure)
        -> segmented max-plus *associative scan* along each task's ops

    vmapped over a batch of candidate depth vectors and jit-compiled.
    Intra-task chains (the long dependency chains) are resolved wholesale by
    the scan, so the iteration count equals the number of *cross* edges on
    the critical path — small in practice (<= a few dozen).  A true deadlock
    is a positive cycle: iterates grow strictly, provably never converging;
    we flag DEADLOCK as soon as any time exceeds the design's schedule upper
    bound, and classify anything still unresolved at the iteration cap with
    ``evaluate_np``.

Numeric domain: times are exact in float32 while below 2**24; we assert the
design's schedule upper bound stays below ~1.5e7 cycles at build time.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bram import (BRAM18K_CONFIGS, SRL_BITS, SRL_DEPTH,
                             design_bram_np, fifo_read_latency)
from repro.core.design import READ, WRITE
from repro.core.simgraph import SimGraph

BIG = np.float32(1e9)
F32_EXACT_LIMIT = 1.5e7

# status codes
CONVERGED = 0
DEADLOCK = 1
UNRESOLVED = 2


# --------------------------------------------------------------------------
# numpy exact reference (single config)
# --------------------------------------------------------------------------

def _worklist_tables(g: SimGraph):
    """Cached per-graph tables for the event-driven worklist."""
    cached = getattr(g, "_worklist_cache", None)
    if cached is not None:
        return cached
    E = g.n_events
    starts = np.flatnonzero(g.seg_start)
    bounds = np.concatenate([starts, [E]]).astype(np.int64)
    n_segs = len(starts)
    # segment of each event
    seg_of_evt = np.searchsorted(starts, np.arange(E), side="right") - 1
    F = g.n_fifos
    reader_seg = np.full(F, -1, dtype=np.int64)
    writer_seg = np.full(F, -1, dtype=np.int64)
    for e in range(E):
        f = int(g.fifo[e])
        if g.kind[e] == READ:
            reader_seg[f] = seg_of_evt[e]
        else:
            writer_seg[f] = seg_of_evt[e]
    kind = g.kind.astype(np.int64)
    fifo = g.fifo.astype(np.int64)
    delta = g.delta.astype(np.int64)
    rank = g.rank.astype(np.int64)
    cached = (bounds, n_segs, kind, fifo, delta, rank, reader_seg, writer_seg)
    g._worklist_cache = cached
    return cached


def evaluate_np(g: SimGraph, depths: np.ndarray) -> Tuple[int, bool]:
    """Exact (latency, deadlocked) for one depth vector.

    Event-driven Kahn worklist: O(E + wakeups).  This is the CPU fast path
    of the incremental simulator (the LightningSim analogue) and the
    arbiter for rows the batched backends cannot classify.
    """
    depths = np.asarray(depths, dtype=np.int64)
    E = g.n_events
    rd_lat = [fifo_read_latency(int(d), int(w))
              for d, w in zip(depths, g.widths)]
    (bounds, n_segs, kind, fifo, delta, rank,
     reader_seg, writer_seg) = _worklist_tables(g)

    cursor = [0] * n_segs
    prev_t = [0] * n_segs
    t = [0] * E
    wtimes: List[List[int]] = [[] for _ in range(g.n_fifos)]
    rtimes: List[List[int]] = [[] for _ in range(g.n_fifos)]
    dl = depths.tolist()

    from collections import deque
    queue = deque(range(n_segs))
    queued = [True] * n_segs
    kindl = kind.tolist()
    fifol = fifo.tolist()
    deltal = delta.tolist()
    rankl = rank.tolist()
    boundsl = bounds.tolist()

    while queue:
        s = queue.popleft()
        queued[s] = False
        i = boundsl[s] + cursor[s]
        hi = boundsl[s + 1]
        pt = prev_t[s]
        woke_read: set = set()
        woke_write: set = set()
        while i < hi:
            f = fifol[i]
            ready = pt + deltal[i]
            if kindl[i] == READ:
                wt = wtimes[f]
                if len(wt) <= rankl[i]:
                    break
                ti = wt[rankl[i]] + rd_lat[f]
                if ready > ti:
                    ti = ready
                rtimes[f].append(ti)
                woke_read.add(f)
            else:
                j = rankl[i]
                d = dl[f]
                ti = ready
                if j >= d:
                    rt = rtimes[f]
                    if len(rt) <= j - d:
                        break
                    slot = rt[j - d] + 1
                    if slot > ti:
                        ti = slot
                wtimes[f].append(ti)
                woke_write.add(f)
            t[i] = ti
            pt = ti
            cursor[s] += 1
            i += 1
        prev_t[s] = pt
        for f in woke_read:     # freed slots -> wake the writer
            ws = writer_seg[f]
            if ws >= 0 and not queued[ws]:
                queue.append(ws)
                queued[ws] = True
        for f in woke_write:    # new data -> wake the reader
            rs = reader_seg[f]
            if rs >= 0 and not queued[rs]:
                queue.append(rs)
                queued[rs] = True

    for s in range(n_segs):
        if boundsl[s] + cursor[s] < boundsl[s + 1]:
            return -1, True
    lat = 0
    for ti_ in range(g.n_tasks):
        le = int(g.last_evt[ti_])
        base = t[le] if le >= 0 else 0
        v = base + int(g.end_delay[ti_])
        if v > lat:
            lat = v
    return lat, False


# --------------------------------------------------------------------------
# jnp helpers
# --------------------------------------------------------------------------

def bram_count_jnp(depths: jnp.ndarray, widths: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1, jnp-vectorized (mirrors bram.bram_count_np)."""
    d = depths.astype(jnp.int32)
    w0 = jnp.broadcast_to(widths.astype(jnp.int32), d.shape)
    n = jnp.zeros_like(d)
    w = w0
    for d_i, w_i in BRAM18K_CONFIGS:
        n = n + (w // w_i) * (-(-d // d_i))
        w = w % w_i
        fits = (w > 0) & (d <= d_i)
        n = n + fits.astype(jnp.int32)
        w = jnp.where(fits, 0, w)
    srl = (d <= SRL_DEPTH) | (d * w0 <= SRL_BITS)
    return jnp.where(srl, 0, n)


def _combine(x, y):
    """Max-plus composition of f(x)=max(x+a, m) elements."""
    a1, m1 = x
    a2, m2 = y
    return a1 + a2, jnp.maximum(m1 + a2, m2)


# --------------------------------------------------------------------------
# Batched evaluator
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BatchStats:
    n_calls: int = 0
    n_configs: int = 0
    n_fallbacks: int = 0
    wall_s: float = 0.0


class BatchedEvaluator:
    """Incremental trace-based evaluation over candidate depth matrices.

    Backends:

    ``numpy``  (default here)  — the event-driven worklist, one config at a
        time.  This mirrors the paper's CPU tool and is the fastest option
        on this container (O(E) exact, ~10 ms at E=26k).
    ``jax``    — jit(vmap) Jacobi + segmented-scan fixpoint; the TPU-native
        formulation (DESIGN.md §6).  Tiered iteration escalation: rows not
        converged at ``max_iters`` fall back to the worklist (deadlocked
        rows never converge, by construction).
    ``pallas`` — the ``kernels/fifo_eval`` kernel (interpret mode on CPU).

    All three are exact and cross-validated in tests.
    """

    BUCKETS = (1, 8, 32, 128, 512, 2048)

    def __init__(self, g: SimGraph, max_iters: int = 64,
                 backend: str = "numpy", use_pallas: bool = False):
        if g.latency_upper_bound() > F32_EXACT_LIMIT:
            raise ValueError(
                "design schedule bound exceeds float32-exact domain; "
                "split the design or reduce trip counts")
        self.g = g
        self.max_iters = int(max_iters)
        self.stats = BatchStats()
        if use_pallas:
            backend = "pallas"
        if backend not in ("numpy", "jax", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.use_pallas = backend == "pallas"

        E = max(g.n_events, 1)
        R = max(int(g.n_reads.sum()), 1)
        self._E = E
        self._B = float(g.latency_upper_bound())

        pad_i32 = lambda a, n: np.asarray(
            np.concatenate([a, np.zeros(max(0, n - len(a)), a.dtype)]),
            dtype=np.int32)

        self.kind = jnp.asarray(pad_i32(g.kind.astype(np.int32), E))
        self.fifo = jnp.asarray(pad_i32(g.fifo, E))
        self.delta = jnp.asarray(pad_i32(g.delta.astype(np.int32), E),
                                 dtype=jnp.float32)
        self.seg_start = jnp.asarray(pad_i32(g.seg_start.astype(np.int32), E))
        self.rank = jnp.asarray(pad_i32(g.rank.astype(np.int32), E))
        self.data_src = jnp.asarray(pad_i32(g.data_src.astype(np.int32), E))
        self.read_evt_flat = jnp.asarray(
            pad_i32(g.read_evt_flat.astype(np.int32), R))
        self.read_base = jnp.asarray(g.read_base.astype(np.int32))
        self.n_reads = jnp.asarray(g.n_reads.astype(np.int32))
        self.n_writes = jnp.asarray(g.n_writes.astype(np.int32))
        self.widths = jnp.asarray(g.widths.astype(np.int32))
        self.last_evt = jnp.asarray(
            np.maximum(g.last_evt, 0).astype(np.int32))
        self.has_evt = jnp.asarray((g.last_evt >= 0))
        self.end_delay = jnp.asarray(g.end_delay.astype(np.int32),
                                     dtype=jnp.float32)
        # Real (unpadded) event mask.
        self.evt_mask = jnp.asarray(
            (np.arange(E) < g.n_events))

        if self.use_pallas:
            from repro.kernels.fifo_eval import ops as fifo_ops
            self._pallas_eval = fifo_ops.make_batched_eval(
                self, interpret=True)

        self._jit_cache: Dict[int, callable] = {}

    # ------------------------------------------------------------------
    def _eval_one(self, depths: jnp.ndarray):
        """(F,) int32 depths -> (latency f32, bram i32, status i8, iters)."""
        g = self
        depths = depths.astype(jnp.int32)
        widths = g.widths
        is_bram = ~((depths <= SRL_DEPTH) | (depths * widths <= SRL_BITS))
        rd_lat_f = 1.0 + is_bram.astype(jnp.float32)

        fifo = g.fifo
        is_read = (g.kind == READ) & g.evt_mask
        is_write = (g.kind == WRITE) & g.evt_mask

        # back-pressure gather indices (depth-dependent)
        bp_pos = g.rank - depths[fifo]
        overrun = is_write & (bp_pos >= g.n_reads[fifo])
        structural_deadlock = jnp.any(overrun)
        bp_valid = is_write & (bp_pos >= 0) & ~overrun
        flat_idx = jnp.clip(g.read_base[fifo] + bp_pos, 0,
                            g.read_evt_flat.shape[0] - 1)
        bp_idx = g.read_evt_flat[flat_idx]

        data_idx = jnp.clip(g.data_src, 0, g._E - 1)
        has_data = is_read & (g.data_src >= 0)
        rd_lat_e = rd_lat_f[fifo]

        neg = -BIG
        a_base = jnp.where(g.seg_start == 1, neg, g.delta)

        def step(t):
            b_read = jnp.where(has_data, t[data_idx] + rd_lat_e, neg)
            b_write = jnp.where(bp_valid, t[bp_idx] + 1.0, neg)
            b = jnp.where(is_read, b_read, b_write)
            m = jnp.where(g.seg_start == 1, jnp.maximum(b, g.delta), b)
            A, M = lax.associative_scan(_combine, (a_base, m))
            return jnp.maximum(A, M)

        def cond(state):
            t, prev, it, conv = state
            over = jnp.max(t) > g._B
            return (~conv) & (it < g.max_iters) & (~over)

        def body(state):
            t, prev, it, _ = state
            t2 = step(t)
            return t2, t, it + 1, jnp.all(t2 == t)

        t0 = jnp.zeros(g._E, dtype=jnp.float32)
        t, _, iters, conv = lax.while_loop(
            cond, body, (step(t0), t0, jnp.int32(1), jnp.bool_(False)))

        over = jnp.max(t) > g._B
        status = jnp.where(
            structural_deadlock | over, DEADLOCK,
            jnp.where(conv, CONVERGED, UNRESOLVED)).astype(jnp.int8)

        t_last = jnp.where(g.has_evt, t[g.last_evt], 0.0)
        latency = jnp.max(t_last + g.end_delay)
        bram = jnp.sum(bram_count_jnp(depths, widths)).astype(jnp.int32)
        return latency, bram, status, iters

    def _get_jit(self, c: int):
        fn = self._jit_cache.get(c)
        if fn is None:
            fn = jax.jit(jax.vmap(self._eval_one))
            self._jit_cache[c] = fn
        return fn

    # ------------------------------------------------------------------
    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) int depths -> (latency int64, bram int64, deadlock bool).

        Pads C up to a bucket size, runs the jitted batched evaluator, and
        resolves UNRESOLVED rows exactly with ``evaluate_np``.
        """
        depth_matrix = np.asarray(depth_matrix, dtype=np.int32)
        if depth_matrix.ndim == 1:
            depth_matrix = depth_matrix[None, :]
        C = depth_matrix.shape[0]
        t_start = time.perf_counter()

        if self.backend == "numpy":
            lat = np.zeros(C, dtype=np.int64)
            dead = np.zeros(C, dtype=bool)
            for i in range(C):
                lat[i], dead[i] = evaluate_np(self.g, depth_matrix[i])
            bram = design_bram_np(depth_matrix.astype(np.int64),
                                  np.asarray(self.g.widths))
        else:
            if self.backend == "pallas":
                lat, bram, status = self._pallas_eval(depth_matrix)
            else:
                bucket = next((b for b in self.BUCKETS if b >= C), None)
                padded = depth_matrix
                if bucket is not None and bucket != C:
                    pad = np.repeat(depth_matrix[-1:], bucket - C, axis=0)
                    padded = np.concatenate([depth_matrix, pad], axis=0)
                fn = self._get_jit(padded.shape[0])
                lat, bram, status, _ = jax.device_get(
                    fn(jnp.asarray(padded)))
                lat, bram, status = lat[:C], bram[:C], status[:C]

            lat = np.asarray(np.rint(lat), dtype=np.int64)
            bram = np.asarray(bram, dtype=np.int64)
            dead = np.asarray(status) == DEADLOCK
            # Tiered escalation: anything not classified at the iteration
            # cap (deadlocks never converge; rare slow-converging feasible
            # rows) is resolved exactly by the worklist.
            unresolved = np.flatnonzero(np.asarray(status) == UNRESOLVED)
            for i in unresolved:
                l, dd = evaluate_np(self.g, depth_matrix[i])
                lat[i] = l
                dead[i] = dd
                self.stats.n_fallbacks += 1

        self.stats.n_calls += 1
        self.stats.n_configs += C
        self.stats.wall_s += time.perf_counter() - t_start
        lat = np.where(dead, -1, lat)
        return lat, bram, dead

    # convenience -------------------------------------------------------
    def evaluate_one(self, depths: np.ndarray) -> Tuple[int, int, bool]:
        lat, bram, dead = self.evaluate(np.asarray(depths)[None, :])
        return int(lat[0]), int(bram[0]), bool(dead[0])

    def bram_only(self, depth_matrix: np.ndarray) -> np.ndarray:
        return design_bram_np(np.asarray(depth_matrix, dtype=np.int64),
                              np.asarray(self.g.widths))
