"""Trace-based incremental FIFO-latency evaluation (the LightningSim core).

This module is the stable public façade over the evaluation-backend
subsystem in :mod:`repro.core.backends`:

``evaluate_np``
    Kahn-worklist longest-path solve, one config at a time.  Readable
    reference; also the arbiter for the (rare) configs the batched path
    cannot classify within its iteration cap.

``BatchedEvaluator``
    Thin façade over the backend registry.  ``backend=`` selects

    ``"numpy"`` (alias ``"worklist"``, default) — the event-driven
        worklist; mirrors the paper's CPU tool and is the fastest option on
        this container (O(E) exact, ~10 ms at E=26k).  Also provides the
        *incremental* fast path: ``evaluate_incremental`` re-solves only
        the task segments coupled to the changed FIFOs.
    ``"jax"`` (alias ``"fixpoint"``) — jit(vmap) Jacobi + segmented-scan
        fixpoint; the TPU-native formulation (DESIGN.md §6).
    ``"pallas"`` — the ``kernels/fifo_eval`` kernel (interpret mode on CPU).

    Batch bucketing, jit-cache reuse, and tiered UNRESOLVED-row escalation
    to the worklist live in :class:`repro.core.backends.DispatchPolicy`.
    All backends are exact and cross-validated in ``tests/test_backends``.

Numeric domain: times are exact in float32 while below 2**24; we assert the
design's schedule upper bound stays below ~1.5e7 cycles at build time.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.core.backends import (BIG, BUCKETS, CONVERGED, DEADLOCK,
                                 F32_EXACT_LIMIT, UNRESOLVED, DispatchPolicy,
                                 RungCascade, WorklistBackend, evaluate_np,
                                 get_backend)
from repro.core.backends.worklist import WorklistState
from repro.core.bram import design_bram_np
from repro.core.config import EvalConfig, resolve_config
from repro.core.simgraph import SimGraph

__all__ = [
    "BIG", "CONVERGED", "DEADLOCK", "F32_EXACT_LIMIT", "UNRESOLVED",
    "BatchStats", "BatchedEvaluator", "bram_count_jnp", "evaluate_np",
]


def __getattr__(name):
    # re-exported lazily so the numpy worklist path never imports jax
    if name == "bram_count_jnp":
        from repro.core.backends.operands import bram_count_jnp
        return bram_count_jnp
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class BatchStats:
    n_calls: int = 0
    n_configs: int = 0
    n_fallbacks: int = 0
    n_incremental: int = 0
    n_dedup: int = 0          # duplicate in-batch rows solved once
    n_condensed: int = 0      # rows resolved on a condensed rung
    n_cond_fail: int = 0      # rung attempts whose certificate failed
    wall_s: float = 0.0


#: historical BatchedEvaluator default (the advisor default is 256)
_EVALUATOR_DEFAULT = EvalConfig(max_iters=64)


class BatchedEvaluator:
    """Incremental trace-based evaluation over candidate depth matrices.

    ``config`` is the shared :class:`~repro.core.config.EvalConfig`
    (backend, iteration cap, condensation, sharding).  Runtime objects
    stay explicit keywords: ``rungs`` is a prebuilt
    :class:`~repro.core.condense.CondensedGraph` (or list) to use
    verbatim on any backend — the snapshot-restore and test hook —
    and ``mesh`` an explicit :class:`jax.sharding.Mesh`.  The legacy
    keyword spellings (``backend=``, ``max_iters=``, ``condense=``,
    ``shards=``, ``use_pallas=``) are deprecated shims.
    """

    BUCKETS = BUCKETS

    #: how many solved worklist states to keep for incremental re-solves
    STATE_CACHE_CAP = 128

    def __init__(self, g: SimGraph, config: Optional[EvalConfig] = None,
                 *, rungs=None, mesh=None, **legacy):
        if config is not None and not isinstance(config, EvalConfig):
            # pre-EvalConfig signature: second positional was max_iters
            import warnings
            warnings.warn(
                "BatchedEvaluator(g, max_iters) positional form is "
                "deprecated; pass config=EvalConfig(max_iters=...)",
                DeprecationWarning, stacklevel=2)
            config, legacy = None, dict(legacy, max_iters=int(config))
        if "condense" in legacy and not isinstance(
                legacy["condense"], (str, type(None))):
            # prebuilt CondensedGraph rungs used to ride the condense=
            # kwarg; they are runtime objects, so they move to rungs=
            import warnings
            warnings.warn(
                "BatchedEvaluator(condense=<rungs>) is deprecated; pass "
                "prebuilt CondensedGraphs via rungs=", DeprecationWarning,
                stacklevel=2)
            rungs = legacy.pop("condense")
        config = resolve_config(config, legacy, "BatchedEvaluator",
                                default=_EVALUATOR_DEFAULT)
        if g.latency_upper_bound() > F32_EXACT_LIMIT:
            raise ValueError(
                "design schedule bound exceeds float32-exact domain; "
                "split the design or reduce trip counts")
        self.g = g
        self.max_iters = config.max_iters
        self.stats = BatchStats()
        backend, shards = config.backend, config.shards
        # an explicit mesh/shard count selects the sharded scan backend
        # (docs/mesh.md); "auto" calibration also races it when the
        # process sees more than one device
        if (mesh is not None or shards is not None) \
                and backend not in ("mesh", "sharded"):
            backend = "mesh"
        self._mesh, self._shards = mesh, shards
        self.calibration = None
        if backend == "auto":
            backend = self._calibrate()
        self.backend = backend
        self.config = config.replace(backend=backend)
        if backend in ("mesh", "sharded"):
            from repro.core.backends.mesh import MeshBackend
            self._impl = MeshBackend(max_iters=self.max_iters,
                                     mesh=mesh, shards=shards)
        else:
            self._impl = get_backend(backend)(max_iters=self.max_iters)
        self._impl.prepare(g)
        if isinstance(self._impl, WorklistBackend):
            self._worklist = self._impl
        else:
            self._worklist = WorklistBackend(max_iters=self.max_iters)
            self._worklist.prepare(g)
        self.use_pallas = self._impl.name == "pallas"
        self.dispatch = DispatchPolicy(
            self._worklist,
            shard_multiple=getattr(self._impl, "shard_multiple", 1))
        self._states: "OrderedDict[bytes, WorklistState]" = OrderedDict()
        self.condensation = self._build_cascade(
            config.condense if rungs is None else rungs)
        self._cascade = RungCascade(self.condensation, self.dispatch,
                                    self._impl) if self.condensation \
            else None

    # ------------------------------------------------------- condensation
    def _build_cascade(self, condense):
        """Condense once per evaluator: ``"auto"`` builds (and caches on
        the graph) the default rung cascade; an explicit CondensedGraph
        or list (the ``rungs=`` argument) uses those rungs verbatim;
        None disables condensation.

        The per-row worklist's cost is bound by wake-wave count rather
        than event count, so it skips ``aggressive`` rungs — they only
        pay on the batched scan backends whose per-iteration cost is
        proportional to E_pad.
        """
        if condense is None:
            return []
        scan = not isinstance(self._impl, WorklistBackend)
        if condense == "auto":
            # the per-row worklist's cost is bound by wake-wave count
            # (set by the back-pressure dynamics), not event count, so
            # auto-condensation is a wash there and stays scan-only;
            # pass explicit CondensedGraphs to force it anywhere
            if not scan:
                return []
            cgs = getattr(self.g, "_cascade_cache", None)
            if cgs is None:
                from repro.core.condense import condense_auto
                cgs = condense_auto(self.g)
                self.g._cascade_cache = cgs
            # aggressive first: per-iteration cost is proportional to
            # E_pad, and folding the back-pressure anchors away also
            # slashes the Jacobi iteration count
            by_tag = {cg.tag: cg for cg in cgs}
            cgs = [by_tag[t] for t in ("aggressive", "safe") if t in by_tag]
        else:
            cgs = list(condense) if isinstance(condense, (list, tuple)) \
                else [condense]
        rungs = []
        for cg in cgs:
            impl = self._impl.spawn()   # keeps mesh/config of the primary
            impl.prepare(cg)
            rungs.append((cg, impl))
        return rungs

    def _calibrate(self) -> str:
        """One-shot per-design backend calibration (``backend="auto"``).

        Times every calibration candidate (the numpy worklist, plus the
        jax fixpoint when importable, plus the fused Pallas kernel when
        the design condenses, plus the sharded mesh backend when the
        process sees more than one device) through the SAME evaluation
        path production uses — a full ``BatchedEvaluator`` including
        each backend's condensation cascade, on a DSE-representative
        16-row batch — and picks the fastest.  The probe timings are
        kept in ``self.calibration`` for the runtime report.
        """
        import importlib.util

        candidates = ["numpy"]
        if importlib.util.find_spec("jax") is not None:
            candidates.append("jax")
            import jax
            if jax.device_count() > 1:
                # sharding only *can* pay with a real multi-device mesh;
                # the probe decides whether it actually does here
                candidates.append("mesh")
            # the condensation-native kernel evaluates AND certifies the
            # hot rungs in one device launch — it only *can* win where a
            # cascade exists, so probe it exactly there (raw streams
            # would just time the interpret-mode kernel at full E_pad)
            cgs = getattr(self.g, "_cascade_cache", None)
            if cgs is None:
                from repro.core.condense import condense_auto
                cgs = condense_auto(self.g)
                self.g._cascade_cache = cgs
            if cgs:
                candidates.append("pallas")
        u = np.asarray(self.g.upper_bounds, dtype=np.int64)
        rng = np.random.default_rng(0)
        probe = np.stack([np.maximum(
            2, (u * rng.uniform(0.5, 1.0, u.size)).astype(np.int64))
            for _ in range(16)])
        timings = {}
        for name in candidates:
            ev = BatchedEvaluator(self.g, EvalConfig(
                backend=name, max_iters=self.max_iters))
            ev.evaluate(probe)                # warm (jit compile)
            t0 = time.perf_counter()
            ev.evaluate(probe)
            timings[name] = time.perf_counter() - t0
        chosen = min(timings, key=timings.get)
        self.calibration = {"chosen": chosen, "probe_s": timings}
        return chosen

    # ------------------------------------------------------------------
    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) int depths -> (latency int64, bram int64, deadlock bool).

        Routes through the dispatch policy: bucket-padded jit reuse for the
        batched backends, exact worklist escalation for UNRESOLVED rows,
        and -1 latency on deadlocked rows.  Duplicate rows within the
        batch are solved once and scattered back (exact, order-preserving;
        DSE batches repeat rows constantly — annealing chains initialize
        at the same corner, frontier refiners revisit the same configs).
        """
        depth_matrix = np.atleast_2d(np.asarray(depth_matrix))
        t_start = time.perf_counter()
        C = depth_matrix.shape[0]
        uniq, inverse = np.unique(depth_matrix, axis=0,
                                  return_inverse=True)
        if uniq.shape[0] < C:
            lat, bram, dead = self._eval_rows(uniq)
            lat, bram, dead = lat[inverse], bram[inverse], dead[inverse]
            self.stats.n_dedup += C - uniq.shape[0]
        else:
            lat, bram, dead = self._eval_rows(depth_matrix)
        self.stats.n_calls += 1
        self.stats.n_configs += C
        self.stats.wall_s += time.perf_counter() - t_start
        return lat, bram, dead

    def _eval_rows(self, m: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Unique rows -> exact results: condensation cascade first (each
        accepted row carries a passed exactness certificate or a sound
        deadlock verdict), raw dispatch as the unconditional backstop.
        The escalation logic lives in
        :class:`repro.core.backends.RungCascade`; kernel-backed rungs
        certify on-device, the rest through the host verifier."""
        if self._cascade is None:
            return self.dispatch.dispatch(self._impl, m, self.stats)
        m = np.asarray(m, dtype=np.int64)
        lat, dead = self._cascade.evaluate(m, self.stats)
        bram = design_bram_np(m, np.asarray(self.g.widths))
        return lat, bram, dead

    # ------------------------------------------------ incremental fast path
    @property
    def prefer_incremental(self) -> bool:
        """Whether single-FIFO-move searches should use the delta path.

        The incremental worklist always *works*, but only clearly wins when
        the primary backend is the worklist itself; batched backends may
        amortize better on real accelerators.
        """
        return self._impl is self._worklist

    def _state_for(self, depths: np.ndarray) -> WorklistState:
        key = depths.tobytes()
        st = self._states.get(key)
        if st is None:
            st = self._worklist.solve(depths)
            self._remember(key, st)
        else:
            self._states.move_to_end(key)
        return st

    def _remember(self, key: bytes, st: WorklistState):
        self._states[key] = st
        self._states.move_to_end(key)
        while len(self._states) > self.STATE_CACHE_CAP:
            self._states.popitem(last=False)

    def evaluate_incremental(self, base_depths: Optional[np.ndarray],
                             depth_matrix: np.ndarray
                             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Incremental (latency, bram, deadlock) against base config(s).

        ``base_depths`` is one (F,) base row, a (C, F) per-row base matrix,
        or None (full solves, states cached for future deltas).  Each row is
        re-solved only over the task segments transitively coupled to the
        FIFOs that differ from its base — the LightningSim primitive.
        """
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        C = m.shape[0]
        base = None
        if base_depths is not None:
            base = np.atleast_2d(np.asarray(base_depths, dtype=np.int64))
            if base.shape[0] == 1 and C > 1:
                base = np.broadcast_to(base, m.shape)
        t_start = time.perf_counter()
        lat = np.zeros(C, dtype=np.int64)
        dead = np.zeros(C, dtype=bool)
        for i in range(C):
            if base is None:
                st = self._state_for(m[i])
            else:
                base_st = self._state_for(base[i])
                st = self._worklist.solve_delta(base_st, m[i])
                self._remember(m[i].tobytes(), st)
            lat[i] = st.latency
            dead[i] = st.deadlocked
        bram = design_bram_np(m, np.asarray(self.g.widths))
        self.stats.n_calls += 1
        self.stats.n_configs += C
        self.stats.n_incremental += C
        self.stats.wall_s += time.perf_counter() - t_start
        return lat, bram, dead

    @property
    def incr_stats(self):
        return self._worklist.incr_stats

    def condensation_info(self) -> list:
        """Per-rung condensation summary for reports: tag, raw/condensed
        event counts, and the compression ratio."""
        return [{"tag": cg.tag,
                 "events_raw": cg.n_raw_events,
                 "events_condensed": cg.n_events,
                 "compression": round(cg.compression, 2)}
                for cg, _ in self.condensation]

    # convenience -------------------------------------------------------
    def evaluate_one(self, depths: np.ndarray) -> Tuple[int, int, bool]:
        lat, bram, dead = self.evaluate(np.asarray(depths)[None, :])
        return int(lat[0]), int(bram[0]), bool(dead[0])

    def bram_only(self, depth_matrix: np.ndarray) -> np.ndarray:
        return design_bram_np(np.asarray(depth_matrix, dtype=np.int64),
                              np.asarray(self.g.widths))
