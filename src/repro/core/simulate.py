"""Trace-based incremental FIFO-latency evaluation (the LightningSim core).

This module is the stable public façade over the evaluation-backend
subsystem in :mod:`repro.core.backends`:

``evaluate_np``
    Kahn-worklist longest-path solve, one config at a time.  Readable
    reference; also the arbiter for the (rare) configs the batched path
    cannot classify within its iteration cap.

``BatchedEvaluator``
    Thin façade over the backend registry.  ``backend=`` selects

    ``"numpy"`` (alias ``"worklist"``, default) — the event-driven
        worklist; mirrors the paper's CPU tool and is the fastest option on
        this container (O(E) exact, ~10 ms at E=26k).  Also provides the
        *incremental* fast path: ``evaluate_incremental`` re-solves only
        the task segments coupled to the changed FIFOs.
    ``"jax"`` (alias ``"fixpoint"``) — jit(vmap) Jacobi + segmented-scan
        fixpoint; the TPU-native formulation (DESIGN.md §6).
    ``"pallas"`` — the ``kernels/fifo_eval`` kernel (interpret mode on CPU).

    Batch bucketing, jit-cache reuse, and tiered UNRESOLVED-row escalation
    to the worklist live in :class:`repro.core.backends.DispatchPolicy`.
    All backends are exact and cross-validated in ``tests/test_backends``.

Numeric domain: times are exact in float32 while below 2**24; we assert the
design's schedule upper bound stays below ~1.5e7 cycles at build time.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.core.backends import (BIG, BUCKETS, CONVERGED, DEADLOCK,
                                 F32_EXACT_LIMIT, UNRESOLVED, DispatchPolicy,
                                 WorklistBackend, evaluate_np, get_backend)
from repro.core.backends.worklist import WorklistState
from repro.core.bram import design_bram_np
from repro.core.simgraph import SimGraph

__all__ = [
    "BIG", "CONVERGED", "DEADLOCK", "F32_EXACT_LIMIT", "UNRESOLVED",
    "BatchStats", "BatchedEvaluator", "bram_count_jnp", "evaluate_np",
]


def __getattr__(name):
    # re-exported lazily so the numpy worklist path never imports jax
    if name == "bram_count_jnp":
        from repro.core.backends.operands import bram_count_jnp
        return bram_count_jnp
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class BatchStats:
    n_calls: int = 0
    n_configs: int = 0
    n_fallbacks: int = 0
    n_incremental: int = 0
    n_dedup: int = 0          # duplicate in-batch rows solved once
    wall_s: float = 0.0


class BatchedEvaluator:
    """Incremental trace-based evaluation over candidate depth matrices."""

    BUCKETS = BUCKETS

    #: how many solved worklist states to keep for incremental re-solves
    STATE_CACHE_CAP = 128

    def __init__(self, g: SimGraph, max_iters: int = 64,
                 backend: str = "numpy", use_pallas: bool = False):
        if g.latency_upper_bound() > F32_EXACT_LIMIT:
            raise ValueError(
                "design schedule bound exceeds float32-exact domain; "
                "split the design or reduce trip counts")
        self.g = g
        self.max_iters = int(max_iters)
        self.stats = BatchStats()
        if use_pallas:
            backend = "pallas"
        self.backend = backend
        self._impl = get_backend(backend)(max_iters=self.max_iters)
        self._impl.prepare(g)
        if isinstance(self._impl, WorklistBackend):
            self._worklist = self._impl
        else:
            self._worklist = WorklistBackend(max_iters=self.max_iters)
            self._worklist.prepare(g)
        self.use_pallas = self._impl.name == "pallas"
        self.dispatch = DispatchPolicy(self._worklist)
        self._states: "OrderedDict[bytes, WorklistState]" = OrderedDict()

    # ------------------------------------------------------------------
    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C, F) int depths -> (latency int64, bram int64, deadlock bool).

        Routes through the dispatch policy: bucket-padded jit reuse for the
        batched backends, exact worklist escalation for UNRESOLVED rows,
        and -1 latency on deadlocked rows.  Duplicate rows within the
        batch are solved once and scattered back (exact, order-preserving;
        DSE batches repeat rows constantly — annealing chains initialize
        at the same corner, frontier refiners revisit the same configs).
        """
        depth_matrix = np.atleast_2d(np.asarray(depth_matrix))
        t_start = time.perf_counter()
        C = depth_matrix.shape[0]
        uniq, inverse = np.unique(depth_matrix, axis=0,
                                  return_inverse=True)
        if uniq.shape[0] < C:
            lat, bram, dead = self.dispatch.dispatch(
                self._impl, uniq, self.stats)
            lat, bram, dead = lat[inverse], bram[inverse], dead[inverse]
            self.stats.n_dedup += C - uniq.shape[0]
        else:
            lat, bram, dead = self.dispatch.dispatch(
                self._impl, depth_matrix, self.stats)
        self.stats.n_calls += 1
        self.stats.n_configs += C
        self.stats.wall_s += time.perf_counter() - t_start
        return lat, bram, dead

    # ------------------------------------------------ incremental fast path
    @property
    def prefer_incremental(self) -> bool:
        """Whether single-FIFO-move searches should use the delta path.

        The incremental worklist always *works*, but only clearly wins when
        the primary backend is the worklist itself; batched backends may
        amortize better on real accelerators.
        """
        return self._impl is self._worklist

    def _state_for(self, depths: np.ndarray) -> WorklistState:
        key = depths.tobytes()
        st = self._states.get(key)
        if st is None:
            st = self._worklist.solve(depths)
            self._remember(key, st)
        else:
            self._states.move_to_end(key)
        return st

    def _remember(self, key: bytes, st: WorklistState):
        self._states[key] = st
        self._states.move_to_end(key)
        while len(self._states) > self.STATE_CACHE_CAP:
            self._states.popitem(last=False)

    def evaluate_incremental(self, base_depths: Optional[np.ndarray],
                             depth_matrix: np.ndarray
                             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Incremental (latency, bram, deadlock) against base config(s).

        ``base_depths`` is one (F,) base row, a (C, F) per-row base matrix,
        or None (full solves, states cached for future deltas).  Each row is
        re-solved only over the task segments transitively coupled to the
        FIFOs that differ from its base — the LightningSim primitive.
        """
        m = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        C = m.shape[0]
        base = None
        if base_depths is not None:
            base = np.atleast_2d(np.asarray(base_depths, dtype=np.int64))
            if base.shape[0] == 1 and C > 1:
                base = np.broadcast_to(base, m.shape)
        t_start = time.perf_counter()
        lat = np.zeros(C, dtype=np.int64)
        dead = np.zeros(C, dtype=bool)
        for i in range(C):
            if base is None:
                st = self._state_for(m[i])
            else:
                base_st = self._state_for(base[i])
                st = self._worklist.solve_delta(base_st, m[i])
                self._remember(m[i].tobytes(), st)
            lat[i] = st.latency
            dead[i] = st.deadlocked
        bram = design_bram_np(m, np.asarray(self.g.widths))
        self.stats.n_calls += 1
        self.stats.n_configs += C
        self.stats.n_incremental += C
        self.stats.wall_s += time.perf_counter() - t_start
        return lat, bram, dead

    @property
    def incr_stats(self):
        return self._worklist.incr_stats

    # convenience -------------------------------------------------------
    def evaluate_one(self, depths: np.ndarray) -> Tuple[int, int, bool]:
        lat, bram, dead = self.evaluate(np.asarray(depths)[None, :])
        return int(lat[0]), int(bram[0]), bool(dead[0])

    def bram_only(self, depth_matrix: np.ndarray) -> np.ndarray:
        return design_bram_np(np.asarray(depth_matrix, dtype=np.int64),
                              np.asarray(self.g.widths))
