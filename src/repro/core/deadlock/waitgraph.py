"""Wait-for-graph extraction and per-FIFO blame assignment.

When the discrete-event oracle (:mod:`repro.core.oracle`) reports a
deadlock, every blocked task is stuck on exactly one FIFO op:

* blocked on a **READ** of fifo ``f``  -> it waits for ``f``'s *writer*
  task to produce the next element (``f`` is empty at its read rank);
* blocked on a **WRITE** to fifo ``f`` -> it waits for ``f``'s *reader*
  task to free a slot (``f`` is full at depth ``d_f``).

Each blocked task therefore has exactly one outgoing wait edge, so the
wait-for graph restricted to blocked tasks is a functional graph and
always contains at least one cycle — the deadlock cycle.  The FIFOs
labelling the edges of those cycles are the *blamed* channels: enlarging
(or, for empty-waits, filling) any one of them is what breaks the cycle.
This is the diagnosis FIFOAdvisor surfaces instead of a boolean flag.

FIFO endpoint tasks (single producer / single consumer, enforced by
:mod:`repro.core.simgraph`) are recovered from the software-execution
trace, which always completes — sequential executability does not depend
on depths.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.design import Design, READ
from repro.core.oracle import SimResult, simulate
from repro.core.tracer import Trace, collect_trace

__all__ = ["WaitEdge", "WaitForGraph", "deadlock_blame",
           "extract_wait_graph", "fifo_endpoints"]


def fifo_endpoints(trace: Trace) -> Tuple[np.ndarray, np.ndarray]:
    """Per-fifo (writer_task, reader_task) indices from the trace
    (-1 where a side never touches the fifo)."""
    F = trace.design.n_fifos
    writer = np.full(F, -1, dtype=np.int64)
    reader = np.full(F, -1, dtype=np.int64)
    for tt in trace.tasks:
        for i in range(tt.n_ops):
            f = int(tt.fifos[i])
            if tt.kinds[i] == READ:
                reader[f] = tt.task
            else:
                writer[f] = tt.task
    return writer, reader


@dataclasses.dataclass(frozen=True)
class WaitEdge:
    """``waiter`` cannot progress until ``holder`` acts on ``fifo``."""

    waiter: str          # blocked task name
    holder: str          # the task it waits for
    fifo: str            # the channel the wait goes through
    reason: str          # "empty" (blocked read) | "full" (blocked write)


@dataclasses.dataclass
class WaitForGraph:
    """The wait-for graph of one deadlocked oracle run."""

    edges: List[WaitEdge]

    def cycles(self) -> List[List[str]]:
        """Task-name cycles, each rotated to start at its lexicographically
        smallest member (deterministic across runs).

        Every blocked task has exactly one outgoing edge, so cycles are
        found by pointer chasing in O(tasks).
        """
        nxt: Dict[str, str] = {e.waiter: e.holder for e in self.edges}
        seen: Set[str] = set()
        out: List[List[str]] = []
        for start in sorted(nxt):
            if start in seen:
                continue
            path: List[str] = []
            pos: Dict[str, int] = {}
            node: Optional[str] = start
            while node is not None and node not in seen:
                if node in pos:             # closed a new cycle
                    cyc = path[pos[node]:]
                    k = cyc.index(min(cyc))
                    out.append(cyc[k:] + cyc[:k])
                    break
                pos[node] = len(path)
                path.append(node)
                node = nxt.get(node)
            seen.update(path)
        return out

    def blame(self) -> List[str]:
        """Sorted names of the FIFOs on the blocking cycle(s) — the
        channels whose sizing participates in the deadlock."""
        on_cycle: Set[str] = set()
        for cyc in self.cycles():
            members = set(cyc)
            for e in self.edges:
                if e.waiter in members and e.holder in members:
                    on_cycle.add(e.fifo)
        return sorted(on_cycle)

    def describe(self) -> str:
        """Human-readable one-line-per-edge diagnosis."""
        lines = []
        for cyc in self.cycles():
            lines.append("cycle: " + " -> ".join(cyc + [cyc[0]]))
        for e in self.edges:
            lines.append(f"  {e.waiter} waits for {e.holder} "
                         f"({e.fifo} {e.reason})")
        return "\n".join(lines)


def extract_wait_graph(design: Design, result: SimResult,
                       trace: Optional[Trace] = None) -> WaitForGraph:
    """Build the wait-for graph of a deadlocked :class:`SimResult`.

    ``result`` must come from :func:`repro.core.oracle.simulate` (it
    carries ``blocked_ops``); ``trace`` is collected on demand when not
    supplied by the caller.
    """
    if not result.deadlocked:
        return WaitForGraph(edges=[])
    if trace is None:
        trace = collect_trace(design)
    writer, reader = fifo_endpoints(trace)
    task_names = [t.name for t in design.tasks]
    edges: List[WaitEdge] = []
    for (name, kind, fifo) in result.blocked_ops:
        if kind == READ:
            holder, reason = writer[fifo], "empty"
        else:
            holder, reason = reader[fifo], "full"
        if holder < 0:       # no counterpart task ever touches this fifo
            continue
        edges.append(WaitEdge(waiter=name, holder=task_names[int(holder)],
                              fifo=design.fifos[fifo].name, reason=reason))
    return WaitForGraph(edges=edges)


def deadlock_blame(design: Design, depths: Sequence[int],
                   trace: Optional[Trace] = None) -> List[str]:
    """Run the oracle at ``depths`` and return the blamed FIFO names
    (empty list when the configuration is deadlock-free)."""
    result = simulate(design, depths)
    if not result.deadlocked:
        return []
    return extract_wait_graph(design, result, trace=trace).blame()
