"""Deadlock diagnosis and certification.

Two first-class capabilities on top of the boolean ``deadlocked`` flag:

* **blame** — wait-for-graph extraction from deadlocked oracle runs with
  per-FIFO blame assignment (which channels sit on the blocking cycle);
* **certification** — minimal deadlock-free depth vectors via monotone
  binary search, driven through the incremental ``solve_delta`` /
  :class:`~repro.core.backends.ConfigCache` fast path, with a naive
  oracle-bisection arbiter for cross-checking.

``FifoAdvisor.min_safe_depths()`` is the high-level entry point; see
``docs/fuzzing.md`` for semantics.
"""

from repro.core.deadlock.certify import (CertificationResult,
                                         certify_min_depths,
                                         certify_min_depths_oracle)
from repro.core.deadlock.waitgraph import (WaitEdge, WaitForGraph,
                                           deadlock_blame,
                                           extract_wait_graph,
                                           fifo_endpoints)

__all__ = [
    "CertificationResult", "WaitEdge", "WaitForGraph",
    "certify_min_depths", "certify_min_depths_oracle", "deadlock_blame",
    "extract_wait_graph", "fifo_endpoints",
]
