"""Minimal deadlock-free depth certification via monotone binary search.

Feasibility (absence of deadlock) is **monotone** in every FIFO depth:
enlarging a FIFO only removes back-pressure edges from the dependency
structure, so it can never *introduce* a deadlock.  That makes per-FIFO
minimal safe depths binary-searchable.

The certifier maintains one invariant — the current depth vector is
always verified deadlock-free — and lowers one coordinate at a time:

1. start from a provably feasible vector: the per-FIFO ``max_occupancy``
   of the no-back-pressure schedule (a depth at or above that occupancy
   is behaviourally unbounded, see :mod:`repro.core.simgraph` — and it
   is usually far below the declared/observed upper bounds, which keeps
   the binary searches short);
2. for each FIFO in index order, binary search the smallest depth that
   keeps the *whole current vector* feasible, then pin it there.

Because lowering later coordinates only ever tightens the design, the
final vector is **coordinate-wise minimal**: it is deadlock-free, and
decreasing any single FIFO below its certified depth deadlocks.  (It is
one minimal element of the feasible lattice, not a bound on every
feasible configuration — but any configuration **at or above it
everywhere** is guaranteed deadlock-free, which is what lets optimizers
clamp their search spaces with it.)

Every probe differs from the invariant vector in exactly one FIFO, so
probes ride the incremental ``solve_delta`` fast path of the worklist
backend and the advisor-wide :class:`~repro.core.backends.ConfigCache`
— certification costs a few re-run task segments per probe instead of a
full oracle simulation (``benchmarks/fuzz.py`` measures the speedup).
:func:`certify_min_depths_oracle` is the naive discrete-event-simulation
bisection, kept as the independent cross-check.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.backends import ConfigCache
from repro.core.design import Design
from repro.core.oracle import simulate
from repro.core.simgraph import SimGraph

__all__ = ["CertificationResult", "certify_min_depths",
           "certify_min_depths_oracle"]


@dataclasses.dataclass
class CertificationResult:
    """Outcome of one certification run.

    ``depths`` is deadlock-free and coordinate-wise minimal w.r.t. the
    ``start`` vector the search descended from.
    """

    depths: np.ndarray        # (F,) certified minimal safe depths
    start: np.ndarray         # (F,) the feasible vector the search started at
    latency: int              # design latency at the certified depths
    bram: int                 # BRAM cost at the certified depths
    n_probes: int             # feasibility probes that missed the cache
    wall_s: float
    n_cache_hits: int = 0     # feasibility probes answered by the cache


def _probe_factory(evaluator, cache: Optional[ConfigCache]):
    """Returns ``probe(row, base) -> (deadlocked, latency, bram, cached)``
    routed through the cache and, when the evaluator prefers it, the
    incremental re-simulation path (single-FIFO deltas of a solved
    base).  ``cached`` is True when the cache answered — the driver
    counts those separately so ``n_probes`` reports real evaluator work."""
    def probe(row: np.ndarray, base: Optional[np.ndarray]):
        m = row[None, :]
        if cache is not None:
            lat, bram, dead, miss = cache.lookup(m)
            if not miss.any():
                return bool(dead[0]), int(lat[0]), int(bram[0]), True
        if (base is not None
                and getattr(evaluator, "prefer_incremental", False)):
            lat, bram, dead = evaluator.evaluate_incremental(
                base[None, :], m)
        else:
            lat, bram, dead = evaluator.evaluate(m)
        if cache is not None:
            cache.insert(m, lat, bram, dead)
        return bool(dead[0]), int(lat[0]), int(bram[0]), False
    return probe


def _coordinate_descent(g: SimGraph, probe,
                        upper: Optional[np.ndarray],
                        lower: Optional[np.ndarray],
                        bounds=None) -> CertificationResult:
    """The shared certification driver.

    ``probe(row, base) -> (deadlocked, latency, bram, cached)`` is the
    only pluggable part — the fast path routes it through the
    incremental evaluator + cache, the oracle arbiter through full
    discrete-event simulations.  Keeping one driver means the two
    certifiers can only ever disagree through their *evaluators* (the
    property the differential tests pin), never through drifted search
    logic.

    ``bounds`` (a :class:`~repro.core.bounds.ChannelBounds`) seeds the
    search: its sound per-FIFO lower bounds raise the floors (pinned
    channels collapse their binary search to nothing), and one extra
    *shortcut probe* of the floor vector settles the whole descent when
    it is jointly feasible — by monotonicity, descending coordinate-wise
    from any feasible ``cur >= floor`` with per-coordinate minima at or
    above ``floor`` can only land exactly on ``floor``.
    """
    t0 = time.perf_counter()
    F = g.n_fifos
    start = (np.asarray(upper, dtype=np.int64) if upper is not None
             else g.max_occupancy)
    start = np.maximum(start, 1)
    floor = (np.asarray(lower, dtype=np.int64) if lower is not None
             else np.ones(F, dtype=np.int64))
    if bounds is not None:
        # Clip to the start: analytical floors are sound below it, but
        # must never raise the search above user-supplied `upper` caps
        # (only an explicit `lower` is allowed to do that).
        floor = np.maximum(floor, np.minimum(bounds.lower, start))
    floor = np.maximum(floor, 1)
    stats = {"miss": 0, "hit": 0}

    def run(row, base):
        dead, lat, bram, cached = probe(row, base)
        stats["hit" if cached else "miss"] += 1
        return dead, lat, bram

    # Floors above the start raise it: the result must respect `lower`
    # everywhere, so the invariant vector starts at max(start, floor).
    cur = np.maximum(start, floor)
    dead, lat, bram = run(cur, None)
    if dead:
        if (floor > start).any():
            raise ValueError(
                "floored certification start deadlocks: the requested "
                "`lower`/`bounds` floors raise depths above a start "
                "vector that is itself infeasible; pass a feasible "
                "`upper` (declared depths or observed write counts)")
        raise ValueError(
            "certification start vector deadlocks; pass a feasible "
            "`upper` (declared depths or observed write counts)")

    if bounds is not None and not np.array_equal(floor, cur):
        d, _, _ = run(floor, cur)
        if not d:
            cur = floor.copy()

    for f in range(F):
        lo, hi = int(floor[f]), int(cur[f])
        # invariant: cur with cur[f] = hi is verified deadlock-free
        while lo < hi:
            mid = (lo + hi) // 2
            row = cur.copy()
            row[f] = mid
            d, _, _ = run(row, cur)
            if d:
                lo = mid + 1
            else:
                hi = mid
        cur[f] = hi

    # final vector: re-resolve its objectives (cached when already probed)
    dead, lat, bram = run(cur, None)
    assert not dead, "certified vector must be feasible (invariant)"
    return CertificationResult(depths=cur, start=start, latency=lat,
                               bram=bram, n_probes=stats["miss"],
                               n_cache_hits=stats["hit"],
                               wall_s=time.perf_counter() - t0)


def certify_min_depths(g: SimGraph, evaluator,
                       cache: Optional[ConfigCache] = None,
                       upper: Optional[np.ndarray] = None,
                       lower: Optional[np.ndarray] = None,
                       bounds=None) -> CertificationResult:
    """Certify minimal deadlock-free depths for ``g`` using ``evaluator``.

    ``evaluator`` is any object with the :class:`BatchedEvaluator`
    surface (``evaluate`` and, optionally, ``evaluate_incremental`` +
    ``prefer_incremental``).  ``upper`` overrides the start vector;
    ``lower`` sets per-FIFO search floors (default 1); ``bounds``
    (:func:`repro.core.bounds.channel_bounds` output) seeds floors and
    enables the shortcut probe — the certified vector is identical to
    the unseeded one, typically at a fraction of the probes
    (``benchmarks/bounds.py`` gates the reduction).

    Raises ``ValueError`` when the start vector itself deadlocks (it
    cannot, unless ``upper`` is below the design's occupancy needs).
    """
    return _coordinate_descent(g, _probe_factory(evaluator, cache),
                               upper, lower, bounds=bounds)


def certify_min_depths_oracle(design: Design,
                              upper: Optional[np.ndarray] = None,
                              lower: Optional[np.ndarray] = None,
                              bounds=None) -> CertificationResult:
    """The same coordinate descent, but every probe is a full
    discrete-event simulation (:func:`repro.core.oracle.simulate`).

    This is the independent arbiter for the fast path — tests assert both
    return identical vectors — and the cost model the incremental path is
    benchmarked against ("co-simulation bisection").
    """
    from repro.core.bram import design_bram_np
    from repro.core.simgraph import build_simgraph
    g = build_simgraph(design)
    widths = np.asarray(g.widths)

    def probe(row: np.ndarray, base):
        r = simulate(design, row)
        bram = int(design_bram_np(row[None, :], widths)[0])
        return r.deadlocked, int(r.latency), bram, False

    return _coordinate_descent(g, probe, upper, lower, bounds=bounds)
