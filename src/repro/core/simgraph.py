"""Packed simulation graph: trace -> dense, fixed-shape arrays.

This is the LightningSim-style artifact that makes incremental re-simulation
cheap: the event structure below is computed ONCE per design; evaluating a
new depth vector touches only these arrays (no re-execution of the design).

Layout is *task-contiguous* (each task's ops form one contiguous segment in
program order) so that intra-task timing is a segmented max-plus scan — the
key to the TPU-native evaluator in :mod:`repro.core.simulate` and the
Pallas kernel in :mod:`repro.kernels.fifo_eval`.

Arrays (E = total FIFO-op events, F = fifos, T = tasks):

=================  ======  ====================================================
``kind``           (E,)    READ / WRITE
``fifo``           (E,)    fifo index of the op
``delta``          (E,)    cycles between previous same-task op and this op
``seg_start``      (E,)    1 at each task's first event
``rank``           (E,)    k for the k-th read / j for the j-th write of fifo
``data_src``       (E,)    for READ rank k: event index of write k (else -1)
``read_evt_flat``  (R,)    all read event indices, grouped by fifo, rank order
``read_base``      (F,)    offset of each fifo's reads in ``read_evt_flat``
``n_reads``        (F,)    reads per fifo
``n_writes``       (F,)    writes per fifo
``last_evt``       (T,)    index of each task's final event (-1 if none)
``end_delay``      (T,)    trailing compute cycles after the final event
``widths``         (F,)    fifo element bit-widths
=================  ======  ====================================================

Back-pressure edges are the only depth-dependent part: write j of fifo f
waits on read ``j - d_f``, i.e. event ``read_evt_flat[read_base[f] + j - d_f]``
— a gather the evaluator performs per candidate configuration.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.design import Design, READ, WRITE
from repro.core.tracer import Trace, collect_trace


@dataclasses.dataclass
class SimGraph:
    design: Design
    # per-event
    kind: np.ndarray
    fifo: np.ndarray
    delta: np.ndarray
    seg_start: np.ndarray
    rank: np.ndarray
    data_src: np.ndarray
    # per-fifo
    read_evt_flat: np.ndarray
    read_base: np.ndarray
    n_reads: np.ndarray
    n_writes: np.ndarray
    widths: np.ndarray
    # per-task
    last_evt: np.ndarray
    end_delay: np.ndarray
    # metadata
    upper_bounds: np.ndarray       # default per-fifo search upper bound u_i
    max_occupancy: np.ndarray      # per-fifo max in-flight under no back-pressure
    unbounded_latency: int         # latency with all back-pressure disabled

    @property
    def n_events(self) -> int:
        return int(self.kind.shape[0])

    @property
    def n_fifos(self) -> int:
        return int(self.widths.shape[0])

    @property
    def n_tasks(self) -> int:
        return int(self.last_evt.shape[0])

    def groups(self) -> Dict[str, List[int]]:
        return self.design.groups()

    def latency_upper_bound(self) -> int:
        """Any deadlock-free schedule finishes within sum(delta) + 2*E + sum(end_delay)
        cycles (every event waits at most once for one other event)."""
        return int(self.delta.sum() + 2 * self.n_events
                   + self.end_delay.sum() + 16)


class DesignRuleError(ValueError):
    pass


def build_simgraph(design: Design, trace: Optional[Trace] = None) -> SimGraph:
    trace = trace if trace is not None else collect_trace(design)
    F = design.n_fifos
    T = design.n_tasks

    kinds, fifos, deltas, seg_start = [], [], [], []
    last_evt = np.full(T, -1, dtype=np.int64)
    end_delay = np.zeros(T, dtype=np.int64)

    # Per-fifo single-producer / single-consumer validation.
    writer_task = np.full(F, -1, dtype=np.int64)
    reader_task = np.full(F, -1, dtype=np.int64)

    write_events: List[List[int]] = [[] for _ in range(F)]
    read_events: List[List[int]] = [[] for _ in range(F)]
    rank = []

    e = 0
    for tt in trace.tasks:
        n = tt.n_ops
        for i in range(n):
            k = int(tt.kinds[i]); f = int(tt.fifos[i])
            kinds.append(k); fifos.append(f); deltas.append(int(tt.deltas[i]))
            seg_start.append(1 if i == 0 else 0)
            if k == WRITE:
                if writer_task[f] not in (-1, tt.task):
                    raise DesignRuleError(
                        f"fifo {design.fifos[f].name!r} has multiple writers")
                writer_task[f] = tt.task
                rank.append(len(write_events[f]))
                write_events[f].append(e)
            else:
                if reader_task[f] not in (-1, tt.task):
                    raise DesignRuleError(
                        f"fifo {design.fifos[f].name!r} has multiple readers")
                reader_task[f] = tt.task
                rank.append(len(read_events[f]))
                read_events[f].append(e)
            e += 1
        if n > 0:
            last_evt[tt.task] = e - 1
        end_delay[tt.task] = tt.end_delay

    E = e
    kind = np.asarray(kinds, dtype=np.int8)
    fifo = np.asarray(fifos, dtype=np.int32)
    delta = np.asarray(deltas, dtype=np.int64)
    seg_start_a = np.asarray(seg_start, dtype=np.int8)
    rank_a = np.asarray(rank, dtype=np.int64)

    n_reads = np.asarray([len(r) for r in read_events], dtype=np.int64)
    n_writes = np.asarray([len(w) for w in write_events], dtype=np.int64)
    read_base = np.zeros(F, dtype=np.int64)
    if F:
        read_base[1:] = np.cumsum(n_reads)[:-1]
    read_evt_flat = (np.concatenate([np.asarray(r, dtype=np.int64)
                                     for r in read_events])
                     if n_reads.sum() else np.zeros(0, dtype=np.int64))

    data_src = np.full(E, -1, dtype=np.int64)
    for f in range(F):
        wr = write_events[f]
        for k, rev in enumerate(read_events[f]):
            # sequential executability guarantees k < len(wr)
            data_src[rev] = wr[k]

    widths = np.asarray(design.widths(), dtype=np.int64)

    g = SimGraph(
        design=design, kind=kind, fifo=fifo, delta=delta,
        seg_start=seg_start_a, rank=rank_a, data_src=data_src,
        read_evt_flat=read_evt_flat, read_base=read_base,
        n_reads=n_reads, n_writes=n_writes, widths=widths,
        last_evt=last_evt, end_delay=end_delay,
        upper_bounds=trace.default_upper_bounds(),
        max_occupancy=np.zeros(F, dtype=np.int64),
        unbounded_latency=0,
    )

    # Unbounded (no back-pressure) schedule: gives per-fifo max occupancy
    # (used by greedy ranking + pruning) and the latency floor.
    t_inf = _unbounded_times(g)
    g.unbounded_latency = int(_latency_from_times(g, t_inf))
    g.max_occupancy = _max_occupancy(g, t_inf)
    return g


def _unbounded_times(g: SimGraph) -> np.ndarray:
    """Exact event completion times with back-pressure disabled (numpy).

    Kahn worklist over data edges only; O(E) with a per-task cursor.
    Uses SRL read latency (1) — this schedule is used for *structure*
    (occupancy, ordering) rather than reported latency.
    """
    E = g.n_events
    t = np.zeros(E, dtype=np.int64)
    # Task segment boundaries (segments appear in task order).
    starts = np.flatnonzero(g.seg_start).tolist()
    bounds = starts + [E]
    n_segs = len(starts)
    cursor = [0] * n_segs
    # per-fifo write completion times in rank order
    wtimes: List[List[int]] = [[] for _ in range(g.n_fifos)]
    prev_t = [0] * n_segs
    done = [False] * n_segs
    progress = True
    while progress:
        progress = False
        for s in range(n_segs):
            if done[s]:
                continue
            i = bounds[s] + cursor[s]
            while i < bounds[s + 1]:
                ready = prev_t[s] + int(g.delta[i])
                if g.kind[i] == READ:
                    f = int(g.fifo[i]); k = int(g.rank[i])
                    if len(wtimes[f]) <= k:
                        break  # producer not there yet
                    ti_ = max(ready, wtimes[f][k] + 1)
                else:
                    f = int(g.fifo[i])
                    ti_ = ready
                    wtimes[f].append(ti_)
                t[i] = ti_
                prev_t[s] = ti_
                cursor[s] += 1
                i += 1
                progress = True
            if i >= bounds[s + 1]:
                done[s] = True
    if not all(done):  # pragma: no cover - sequential executability rules this out
        raise RuntimeError("unbounded schedule did not complete")
    return t


def _latency_from_times(g: SimGraph, t: np.ndarray) -> int:
    lat = 0
    for ti in range(g.n_tasks):
        le = g.last_evt[ti]
        base = int(t[le]) if le >= 0 else 0
        lat = max(lat, base + int(g.end_delay[ti]))
    return lat


def _max_occupancy(g: SimGraph, t: np.ndarray) -> np.ndarray:
    """Max in-flight element count per fifo under the unbounded schedule.

    Element k occupies its fifo during [t_write_k, t_read_k).  Any depth
    >= this occupancy is behaviourally unbounded (no stall can occur), which
    both ranks FIFOs for the greedy optimizer and caps useful search depths.
    Unread elements occupy forever -> occupancy counts them all.
    """
    F = g.n_fifos
    occ = np.zeros(F, dtype=np.int64)
    for f in range(F):
        mask_w = (g.fifo == f) & (g.kind == WRITE)
        mask_r = (g.fifo == f) & (g.kind == READ)
        tw = np.sort(t[mask_w])
        tr = np.sort(t[mask_r])
        if tw.size == 0:
            continue
        # Sweep: +1 at write, -1 at read.  At equal timestamps the write is
        # counted FIRST (a slot only frees one cycle after its read), so a
        # depth equal to this occupancy is provably stall-free.
        times = np.concatenate([tw, tr])
        deltas = np.concatenate([np.ones_like(tw), -np.ones_like(tr)])
        order = np.lexsort((-deltas, times))
        running = np.cumsum(deltas[order])
        occ[f] = max(1, int(running.max()))
    return occ
