"""Deterministic fault-injection plane for chaos testing.

A :class:`FaultPlan` is a *schedule* of faults — crash worker lane ``k``
at its ``j``-th job, hang an evaluation past its deadline, corrupt byte
``b`` of a snapshot member, drop a client connection after frame ``n``,
delay a dispatch, abort a snapshot save mid-write — installed through
:class:`~repro.core.config.EvalConfig` (``faults=`` holds the plan's
JSON) or the ``REPRO_FAULTS`` environment variable, and consulted at
fixed injection points inside the worker pool, the advisory service, the
snapshot writer, and the campaign scheduler.

Everything is deterministic: a plan is a finite, ordered tuple of
:class:`Fault` records with explicit trigger indices, each fault fires
at most once, and :meth:`FaultPlan.random` derives a schedule from a
seed so the chaos harness (``benchmarks/chaos.py``, ``fuzz --mode
chaos``) can replay any failing schedule exactly.  The recovery
machinery the plan exercises (lane respawn + requeue, E_TIMEOUT
deadlines, snapshot quarantine) is held to the repo-wide bar: the final
result under an injected fault schedule must be bit-identical to the
fault-free run.

See ``docs/robustness.md`` for the fault model and the recovery
guarantees table.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Fault", "FaultPlan", "InjectedFault", "FAULT_KINDS",
           "resolve_plan", "check_worker_faults"]

#: every injection point the runtime consults, and what ``at`` indexes:
#:
#: ``crash_worker``     worker lane exits hard (``os._exit``) right
#:                      before evaluating its ``at``-th job since (re)spawn.
#: ``hang_worker``      worker lane sleeps ``value`` seconds before its
#:                      ``at``-th job — past the pool's recv deadline it
#:                      is declared dead and replaced.
#: ``delay_dispatch``   parent sleeps ``value`` seconds before shipping
#:                      job ``at`` to lane ``lane`` (scheduling jitter).
#: ``hang_eval``        a service evaluation round stalls ``value``
#:                      seconds at session round ``at`` (per-request
#:                      deadline -> E_TIMEOUT).
#: ``corrupt_snapshot`` flip byte ``value`` of the ``at``-th snapshot
#:                      member written (torn write: the manifest keeps
#:                      the good hash, so load quarantines the member).
#: ``crash_save``       abort a snapshot save (InjectedFault) before
#:                      writing member ``at`` (``at == n_designs``
#:                      aborts just before the manifest replace).
#: ``drop_conn``        server closes a client connection after sending
#:                      ``at`` frames (client re-attaches + replays).
FAULT_KINDS = ("crash_worker", "hang_worker", "delay_dispatch",
               "hang_eval", "corrupt_snapshot", "crash_save",
               "drop_conn")

#: fault kinds executed *inside* worker processes (shipped to the lane
#: at spawn; everything else fires in the parent)
_WORKER_KINDS = ("crash_worker", "hang_worker")


class InjectedFault(RuntimeError):
    """Raised at an injection point that simulates a hard process death
    (e.g. ``crash_save``).  Never raised unless a plan schedules it."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    Args:
        kind: one of :data:`FAULT_KINDS`.
        at: trigger index — what it counts depends on ``kind`` (job #
            within a worker incarnation, session round #, snapshot
            member #, frames sent on a connection).
        lane: worker lane the fault targets; ``-1`` matches any lane.
        target: design / session the fault targets; ``""`` matches any.
        value: kind-specific magnitude — seconds to hang/delay, or the
            byte offset to corrupt.
    """

    kind: str
    at: int = 0
    lane: int = -1
    target: str = ""
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        object.__setattr__(self, "at", int(self.at))
        object.__setattr__(self, "lane", int(self.lane))
        object.__setattr__(self, "value", float(self.value))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Fault":
        return cls(**d)


class FaultPlan:
    """An ordered schedule of faults with fire-once consumption.

    The plan itself is immutable; the *fired* set is runtime state, so a
    plan instance belongs to one run (rebuild from JSON to rerun the
    same schedule).
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: Tuple[Fault, ...] = tuple(faults)
        self._fired = [False] * len(self.faults)

    # ------------------------------------------------------------ querying
    def take(self, kind: str, *, lane: Optional[int] = None,
             at: Optional[int] = None,
             targets: Sequence[str] = ()) -> Optional[Fault]:
        """Consume and return the first unfired fault matching the
        caller's injection point, or None.

        A fault field set to its wildcard (``lane=-1`` / ``target=""``)
        matches any caller value; ``at`` always matches exactly, so
        callers consult the plan at every step of their counter.
        """
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.kind != kind:
                continue
            if lane is not None and f.lane >= 0 and f.lane != lane:
                continue
            if at is not None and f.at != at:
                continue
            if targets and f.target and f.target not in targets:
                continue
            self._fired[i] = True
            return f
        return None

    def consume_worker_fault(self, lane: int) -> Optional[Fault]:
        """Mark the worker-side fault that just killed/hung ``lane`` as
        fired (the one with the smallest ``at`` among that lane's unfired
        worker faults — the first its incarnation would have hit), so the
        respawned lane is shipped only the remaining schedule."""
        best = None
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.kind not in _WORKER_KINDS:
                continue
            if f.lane >= 0 and f.lane != lane:
                continue
            if best is None or f.at < self.faults[best].at:
                best = i
        if best is None:
            return None
        self._fired[best] = True
        return self.faults[best]

    def worker_payload(self, lane: int) -> List[dict]:
        """The unfired worker-side faults for ``lane``, as plain dicts a
        spawned child can act on without importing this module's state."""
        return [f.to_dict() for i, f in enumerate(self.faults)
                if not self._fired[i] and f.kind in _WORKER_KINDS
                and (f.lane < 0 or f.lane == lane)]

    @property
    def n_fired(self) -> int:
        return sum(self._fired)

    @property
    def all_fired(self) -> bool:
        return all(self._fired)

    def __len__(self) -> int:
        return len(self.faults)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FaultPlan({len(self.faults)} faults, "
                f"{self.n_fired} fired)")

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {"faults": [f.to_dict() for f in self.faults]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls([Fault.from_dict(f) for f in d.get("faults", ())])

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls.from_dict(json.loads(s))

    # ----------------------------------------------------------- factories
    @classmethod
    def random(cls, seed: int, *, n_lanes: int = 2, n_jobs: int = 2,
               kinds: Sequence[str] = _WORKER_KINDS + ("delay_dispatch",),
               n_faults: Optional[int] = None, hang_s: float = 1.0,
               delay_s: float = 0.01) -> "FaultPlan":
        """A seeded schedule of pool faults, each guaranteed to be
        *reachable* (lane < n_lanes, at < n_jobs) so chaos runs can
        assert the whole schedule fired."""
        import numpy as np

        rng = np.random.default_rng(seed)
        n = int(n_faults if n_faults is not None
                else 1 + rng.integers(0, 2))
        faults = []
        for _ in range(n):
            kind = kinds[int(rng.integers(len(kinds)))]
            value = {"hang_worker": hang_s,
                     "delay_dispatch": delay_s}.get(kind, 0.0)
            faults.append(Fault(kind, at=int(rng.integers(n_jobs)),
                                lane=int(rng.integers(n_lanes)),
                                value=value))
        return cls(faults)


def check_worker_faults(faults: List[dict], job_index: int) -> None:
    """Worker-side injection point: called by ``_worker_main`` before
    evaluating its ``job_index``-th job.  ``crash_worker`` exits the
    process hard (no cleanup — exactly how a segfault or OOM-kill
    looks to the parent); ``hang_worker`` sleeps past the pool's recv
    deadline."""
    import time

    for f in faults:
        if f["at"] != job_index:
            continue
        if f["kind"] == "crash_worker":
            os._exit(23)
        if f["kind"] == "hang_worker":
            time.sleep(float(f["value"]))


def resolve_plan(config=None,
                 env: Optional[Dict[str, str]] = None
                 ) -> Optional[FaultPlan]:
    """The plan installed for this run, or None (the overwhelmingly
    common case — no plan means every injection point is a no-op).

    Precedence: ``config.faults`` (an :class:`EvalConfig` carrying the
    plan's JSON) beats the ``REPRO_FAULTS`` environment variable, which
    holds either inline JSON or ``@/path/to/plan.json``.
    """
    spec = getattr(config, "faults", None)
    if not spec:
        spec = (env if env is not None else os.environ).get(
            "REPRO_FAULTS", "")
    if not spec:
        return None
    if spec.startswith("@"):
        with open(spec[1:], "r", encoding="utf-8") as f:
            spec = f.read()
    return FaultPlan.from_json(spec)
