"""Analytical per-channel depth bounds from one pass over the trace.

The paper leans on runtime analysis because fully static FIFO sizing is
"restrictive" — but for the affine-stage majority of the Stream-HLS
suite, closed-form bounds in the style of Alias's polyhedral
process-network communication-patterns analysis are exact and free.
This module derives them from the packed :class:`~repro.core.simgraph.
SimGraph` (the artifact every other engine already shares), so the
analysis is *static over the trace*: for affine designs the trace IS
the program and the bounds are closed-form; for data-dependent (DDCF)
designs they remain sound for the traced argument values and are
labelled as instance-specific.

Derivation
----------

For each FIFO ``f``, let read ``k`` (rank order) *transitively require*
write rank ``J_f(k)``: the largest write rank of ``f`` that must
complete before read ``k`` can issue, following program-order edges
within tasks and data edges across them.  One forward DP over the
trace (which is a topological order of program-order + data edges,
because the tracer runs tasks to completion in declaration order)
computes ``J`` for every channel simultaneously in O(E·F)::

    need[e] = max(need[prev-op-in-task], need[data_src[e]] if READ)
    need[e][fifo[e]] = max(need[e][fifo[e]], rank[e])   # on WRITE

With only ``f`` bounded at depth ``d`` (every other channel
behaviourally unbounded), the system deadlocks **iff** some read ``k``
requires a write ``J_f(k) >= k + d`` that back-pressure parks behind
it.  Hence the isolated minimal depth is exact::

    lower[f] = 1 + max_k (J_f(k) - k)        # slack of channel f

and it is a *sound lower bound* on the coordinate-descent certificate:
during descent every other coordinate sits at or below its
behaviourally-unbounded occupancy, so by monotonicity of feasibility
any ``d < lower[f]`` deadlocks in the descent context too.  The sound
upper bound is ``max_occupancy`` — a depth at that occupancy is
provably stall-free (:mod:`repro.core.simgraph`), and it is exactly
the vector certification descends from.

Channels with ``lower == upper`` are **pinned**: their certified depth
is known without a single simulation probe.  Rate-matched map chains
pin at depth 1; reorder/burst channels (matmul column replay, conv
line buffers, fork/join skew) pin wherever the slack meets the
occupancy.  :func:`repro.core.deadlock.certify_min_depths` accepts
these bounds to seed its start vector and floors, and the optimizers
clamp their candidate grids with ``lower`` (every candidate below it
deadlocks in *every* configuration).  See ``docs/bounds.md``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.design import READ, WRITE
from repro.core.simgraph import SimGraph

__all__ = [
    "ChannelBounds", "channel_bounds",
    "INORDER_MATCHED", "INORDER_MISMATCHED", "REORDER", "DATA_DEPENDENT",
]

#: producer/consumer never skew: every read k waits only on write k, and
#: at most one element is ever in flight — pinned exactly at depth 1.
INORDER_MATCHED = "inorder_matched"
#: reads stay in write order (slack 0) but bursts leave >1 element in
#: flight — depth 1 is feasible, larger depths only buy performance.
INORDER_MISMATCHED = "inorder_mismatched"
#: some read transitively requires a *later* write of the same channel
#: (cross-lane reorder, fork/join skew) — depth must cover the skew.
REORDER = "reorder"
#: an endpoint task is data-dependent (DDCF): bounds hold for the traced
#: arguments but are not closed-form over all inputs.
DATA_DEPENDENT = "data_dependent"


@dataclasses.dataclass
class ChannelBounds:
    """Per-FIFO analytical depth bounds plus the channel taxonomy.

    ``lower[f] <= certified[f] <= upper[f]`` for the coordinate-descent
    certificate; ``slack[f] = max_k (J_f(k) - k)`` is the reorder skew
    the lower bound covers (0 for in-order channels).
    """

    lower: np.ndarray     # (F,) sound lower bounds on certified depths
    upper: np.ndarray     # (F,) sound upper bounds (= max_occupancy)
    slack: np.ndarray     # (F,) max transitive write-rank skew per read
    kinds: tuple          # (F,) channel classification strings

    @property
    def n_fifos(self) -> int:
        return int(self.lower.shape[0])

    @property
    def pinned(self) -> np.ndarray:
        """Mask of channels whose exact depth is provable without probing."""
        return self.lower == self.upper

    @property
    def n_pinned(self) -> int:
        return int(self.pinned.sum())

    def to_dict(self) -> dict:
        """JSON-ready summary (fuzz reports, benchmark artifacts)."""
        return {
            "lower": self.lower.tolist(),
            "upper": self.upper.tolist(),
            "slack": self.slack.tolist(),
            "kinds": list(self.kinds),
            "n_pinned": self.n_pinned,
        }

    def describe(self, names=None) -> str:
        """Human-readable per-channel table (used by docs snippets)."""
        lines = ["fifo                 kind                lower upper  pinned"]
        for f in range(self.n_fifos):
            name = (names[f] if names is not None else f"#{f}")
            lines.append(
                f"{name:<20} {self.kinds[f]:<18} {int(self.lower[f]):>5}"
                f" {int(self.upper[f]):>5}  {'yes' if self.pinned[f] else ''}")
        return "\n".join(lines)


def _event_tasks(g: SimGraph) -> np.ndarray:
    """Owning task index per event (events are task-contiguous)."""
    task_of = np.zeros(g.n_events, dtype=np.int64)
    prev = 0
    for t in range(g.n_tasks):
        le = int(g.last_evt[t])
        if le >= 0:
            task_of[prev:le + 1] = t
            prev = le + 1
    return task_of


def _required_write_ranks(g: SimGraph) -> np.ndarray:
    """The need-DP: ``need[e, f]`` = max write rank of fifo ``f`` that
    event ``e`` transitively requires (-1: none).  O(E·F)."""
    E, F = g.n_events, g.n_fifos
    need = np.full((E, F), -1, dtype=np.int64)
    row = np.full(F, -1, dtype=np.int64)
    for e in range(E):
        if g.seg_start[e]:
            row = np.full(F, -1, dtype=np.int64)
        else:
            row = row.copy()
        if g.kind[e] == READ:
            src = int(g.data_src[e])
            np.maximum(row, need[src], out=row)
        # the op itself touches write rank `rank[e]` of its fifo: a WRITE
        # emits it, a READ consumes it (its data_src already carries it,
        # but stating it keeps the invariant J(k) >= k explicit)
        f = int(g.fifo[e])
        if row[f] < g.rank[e]:
            row[f] = int(g.rank[e])
        need[e] = row
    return need


def channel_bounds(g: SimGraph) -> ChannelBounds:
    """Classify every channel and derive its ``(lower, upper)`` bounds."""
    F = g.n_fifos
    need = _required_write_ranks(g)
    task_of = _event_tasks(g)

    slack = np.zeros(F, dtype=np.int64)
    writer = np.full(F, -1, dtype=np.int64)
    reader = np.full(F, -1, dtype=np.int64)
    for e in range(g.n_events):
        f = int(g.fifo[e])
        if g.kind[e] == WRITE:
            writer[f] = task_of[e]
        else:
            reader[f] = task_of[e]
            k = int(g.rank[e])
            s = int(need[e, f]) - k
            if s > slack[f]:
                slack[f] = s

    upper = np.maximum(g.max_occupancy, 1).astype(np.int64)
    # slack exceeding occupancy-1 would contradict the occupancy proof
    # (depth == occupancy is stall-free); clip defensively so the bounds
    # stay sound even if a future scheduler tweak shifts occupancy.
    lower = np.minimum(1 + slack, upper)

    tasks = g.design.tasks if g.design is not None else []
    ddcf = np.zeros(F, dtype=bool)
    for f in range(F):
        for t in (writer[f], reader[f]):
            if t >= 0 and getattr(tasks[t], "data_dependent", False):
                ddcf[f] = True

    kinds = []
    for f in range(F):
        if ddcf[f]:
            kinds.append(DATA_DEPENDENT)
        elif slack[f] > 0:
            kinds.append(REORDER)
        elif upper[f] == 1:
            kinds.append(INORDER_MATCHED)
        else:
            kinds.append(INORDER_MISMATCHED)

    return ChannelBounds(lower=lower, upper=upper, slack=slack,
                         kinds=tuple(kinds))
