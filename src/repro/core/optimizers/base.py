"""Shared optimizer infrastructure: evaluation context, history, results.

All optimizers operate on *index vectors* into per-FIFO (or per-group)
pruned candidate grids (§III-C breakpoints), never on raw depths — this is
the paper's search-space pruning, applied uniformly.

Optimizers are *stepwise*: each subclass implements the ``_steps``
generator, which yields :class:`EvalRequest` batches and receives the
evaluated ``(latency, bram, deadlock)`` arrays back at the yield point.
Two drivers consume the generator:

* :meth:`Optimizer.run` — the legacy blocking API; fulfills every request
  against the optimizer's own :class:`EvalContext` and returns the final
  :class:`OptResult`.
* :meth:`Optimizer.propose` / :meth:`Optimizer.observe` — the stepwise
  API; a scheduler (``repro.core.campaign``) interleaves many optimizers
  and routes their requests into shared, cross-design dispatches.

Both drivers see identical request/result sequences, so they produce
identical histories and frontiers for the same seed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.backends import ConfigCache
from repro.core.bram import breakpoints
from repro.core.pareto import pareto_front
from repro.core.simgraph import SimGraph
from repro.core.simulate import BatchedEvaluator


@dataclasses.dataclass
class EvalRequest:
    """One batch of depth configurations an optimizer wants evaluated.

    ``base`` marks the rows as single-/few-FIFO deltas of already-solved
    configurations (one shared (F,) row or a per-row (C, F) matrix),
    making them eligible for the incremental re-simulation fast path.
    """

    depths: np.ndarray
    base: Optional[np.ndarray] = None

    def __post_init__(self):
        self.depths = np.atleast_2d(np.asarray(self.depths, dtype=np.int64))
        if self.base is not None:
            base = np.atleast_2d(np.asarray(self.base, dtype=np.int64))
            if base.shape[0] == 1 and self.depths.shape[0] > 1:
                base = np.broadcast_to(base, self.depths.shape)
            self.base = base

    @property
    def n_rows(self) -> int:
        return self.depths.shape[0]


@dataclasses.dataclass
class OptResult:
    name: str
    configs: np.ndarray        # (N, F) evaluated depth vectors
    latency: np.ndarray        # (N,)  -1 where deadlocked
    bram: np.ndarray           # (N,)
    deadlock: np.ndarray       # (N,) bool
    runtime_s: float
    n_evals: int

    def feasible_points(self) -> Tuple[np.ndarray, np.ndarray]:
        ok = ~self.deadlock
        pts = np.stack([self.latency[ok], self.bram[ok]], axis=1)
        return pts.astype(np.float64), np.flatnonzero(ok)

    def frontier(self) -> Tuple[np.ndarray, np.ndarray]:
        """(points (M,2), config rows (M,F)) of the Pareto-optimal set,
        deduplicated on (latency, bram)."""
        pts, idx = self.feasible_points()
        if pts.shape[0] == 0:
            return np.zeros((0, 2)), np.zeros((0, self.configs.shape[1]))
        sel = pareto_front(pts)
        _, first = np.unique(pts[sel], axis=0, return_index=True)
        sel = sel[np.sort(first)]
        return pts[sel], self.configs[idx[sel]]


class EvalContext:
    """Everything one optimizer run searches *with* and records *into*.

    Owns the pruned per-FIFO/per-group candidate grids (paper §III-C),
    the seeded RNG, the (possibly shared) :class:`ConfigCache`, the
    evaluation history, and the miss-counting budget.  Optimizers hold
    exactly one; `FifoAdvisor.make_context` builds them sharing the
    advisor's evaluator and cache (how campaign tasks and service
    sessions ride one trace).
    """

    def __init__(self, g: SimGraph, evaluator: Optional[BatchedEvaluator] = None,
                 upper_bounds: Optional[np.ndarray] = None,
                 occupancy_cap: bool = False, local_bounds: bool = False,
                 lower_bounds: Optional[np.ndarray] = None,
                 feasible_floor: Optional[np.ndarray] = None,
                 seed: int = 0, cache: Optional[ConfigCache] = None):
        self.g = g
        self.ev = evaluator or BatchedEvaluator(g)
        self.cache = cache if cache is not None else ConfigCache(g.n_fifos)
        self.rng = np.random.default_rng(seed)
        self.u = (np.asarray(upper_bounds, dtype=np.int64)
                  if upper_bounds is not None else g.upper_bounds.copy())
        self.u = np.maximum(self.u, 2)

        # Pruned per-FIFO candidate grids (paper §III-C).  With
        # ``occupancy_cap`` (beyond-paper), depths above the observed
        # no-back-pressure occupancy are collapsed to the first breakpoint
        # covering it — larger depths cannot change behaviour.
        self.candidates: List[np.ndarray] = []
        for f in range(g.n_fifos):
            cand = breakpoints(int(g.widths[f]), int(self.u[f]))
            if occupancy_cap:
                occ = int(g.max_occupancy[f])
                covering = cand[cand >= min(occ, int(self.u[f]))]
                cap = int(covering[0]) if covering.size else int(self.u[f])
                cand = cand[cand <= cap]
            self.candidates.append(cand)
        # Two kinds of per-FIFO floors prune the candidate grids:
        # ``lower_bounds`` — SOUND bounds from task-pair subgraph
        # feasibility (core/prune.py: below them every config
        # deadlocks); ``feasible_floor`` — a certified deadlock-free
        # vector (core/deadlock: above it everywhere, none does).  Only
        # the latter clamps the Baseline-Min probe: with a sound bound
        # alone, all-depth-2 remains the paper's deadlock probe.
        self.feasible_floor = (
            np.asarray(feasible_floor, dtype=np.int64)
            if feasible_floor is not None else None)
        if local_bounds or lower_bounds is not None \
                or feasible_floor is not None:
            if local_bounds and lower_bounds is None:
                from repro.core.prune import local_lower_bounds
                lower_bounds = local_lower_bounds(g, self.candidates)
            lb = np.zeros(g.n_fifos, dtype=np.int64)
            if lower_bounds is not None:
                lb = np.maximum(lb, np.asarray(lower_bounds,
                                               dtype=np.int64))
            if self.feasible_floor is not None:
                lb = np.maximum(lb, self.feasible_floor)
            self.candidates = [
                c[c >= lb[f]] if (c >= lb[f]).any() else c[-1:]
                for f, c in enumerate(self.candidates)]
        self.grid_sizes = np.asarray([len(c) for c in self.candidates])

        # Groups (stream arrays) for the grouped optimizers.  Grouped moves
        # pick ONE index applied to every member; member grids can differ in
        # length, so indices are clipped per member.
        self.groups: List[np.ndarray] = [
            np.asarray(v, dtype=np.int64) for v in g.groups().values()]
        self.group_grid_sizes = np.asarray(
            [max(self.grid_sizes[m].max(), 1) for m in self.groups])

        # Per-fifo depth used for columns a grouped move does not set.
        self._default_depths = np.asarray(
            [c[-1] for c in self.candidates], dtype=np.int64)

        # History.
        self._configs: List[np.ndarray] = []
        self._lat: List[np.ndarray] = []
        self._bram: List[np.ndarray] = []
        self._dead: List[np.ndarray] = []
        self.n_evals = 0

    # ------------------------------------------------------------- depths
    def depths_from_indices(self, idx: np.ndarray) -> np.ndarray:
        """(C, F) grid indices -> (C, F) depths (per-FIFO grids)."""
        idx = np.atleast_2d(idx)
        out = np.empty_like(idx, dtype=np.int64)
        for f in range(self.g.n_fifos):
            cand = self.candidates[f]
            out[:, f] = cand[np.clip(idx[:, f], 0, len(cand) - 1)]
        return out

    def depths_from_group_indices(self, gidx: np.ndarray) -> np.ndarray:
        """(C, n_groups) indices -> (C, F) depths (index shared per group).

        Columns for FIFOs not covered by any group fall back to their
        largest candidate depth (behaviourally unconstrained) instead of
        uninitialized memory.
        """
        gidx = np.atleast_2d(gidx)
        C = gidx.shape[0]
        out = np.tile(self._default_depths, (C, 1))
        for gi, members in enumerate(self.groups):
            for f in members:
                cand = self.candidates[f]
                out[:, f] = cand[np.clip(gidx[:, gi], 0, len(cand) - 1)]
        return out

    def baseline_max(self) -> np.ndarray:
        return self.u.copy()

    def baseline_min(self) -> np.ndarray:
        """The paper's deadlock probe: all-depth-2 — clamped to the
        certified ``feasible_floor`` when one is in force, so
        Baseline-Min stays the minimal configuration *of the searched
        space* (and is then feasible by depth monotonicity)."""
        floor = np.full(self.g.n_fifos, 2, dtype=np.int64)
        if self.feasible_floor is not None:
            floor = np.maximum(floor, self.feasible_floor)
        return floor

    # ---------------------------------------------------------- evaluation
    def record(self, depth_matrix: np.ndarray, lat: np.ndarray,
               bram: np.ndarray, dead: np.ndarray, n_new_evals: int):
        """Append one evaluated batch to the history and count budget.

        Used by :meth:`_finish` and by external schedulers
        (``repro.core.campaign``) that resolve cache misses themselves;
        ``n_new_evals`` is the number of rows that were actually simulated
        (cache misses) — only those count against the budget.

        The config matrix is COPIED into the history: optimizers may (and
        greedy does) keep mutating their working arrays after a request
        resolves, and ``np.asarray``/``atleast_2d`` alias rather than
        copy.
        """
        self.n_evals += int(n_new_evals)
        self._configs.append(np.array(depth_matrix, dtype=np.int64))
        self._lat.append(lat)
        self._bram.append(bram)
        self._dead.append(dead)
        return lat, bram, dead

    def _finish(self, depth_matrix, lat, bram, dead, miss, base=None):
        """Resolve cache misses, record history, count budget.

        Only cache *misses* count against the simulator budget; hits are
        recorded in the shared :class:`ConfigCache` stats.  When ``base``
        is given and the evaluator prefers it, misses go through the
        incremental re-simulation fast path (single-FIFO-move searches)."""
        rows = np.flatnonzero(miss)
        if rows.size:
            sub = depth_matrix[rows]
            if base is not None and self.ev.prefer_incremental:
                l, b, dd = self.ev.evaluate_incremental(base[rows], sub)
            else:
                l, b, dd = self.ev.evaluate(sub)
            lat[rows], bram[rows], dead[rows] = l, b, dd
            self.cache.insert(sub, l, b, dd)
        return self.record(depth_matrix, lat, bram, dead, rows.size)

    def evaluate(self, depth_matrix: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Evaluate configs (cached), record history, count budget."""
        depth_matrix = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        lat, bram, dead, miss = self.cache.lookup(depth_matrix)
        return self._finish(depth_matrix, lat, bram, dead, miss)

    def evaluate_delta(self, base: np.ndarray, depth_matrix: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Like :meth:`evaluate`, but rows are deltas of known base configs
        (one shared (F,) base or a per-row (C, F) matrix): misses use the
        evaluator's incremental re-simulation when it prefers it."""
        depth_matrix = np.atleast_2d(np.asarray(depth_matrix, dtype=np.int64))
        base = np.atleast_2d(np.asarray(base, dtype=np.int64))
        if base.shape[0] == 1 and depth_matrix.shape[0] > 1:
            base = np.broadcast_to(base, depth_matrix.shape)
        lat, bram, dead, miss = self.cache.lookup(depth_matrix)
        return self._finish(depth_matrix, lat, bram, dead, miss, base=base)

    def evaluate_one(self, depths: np.ndarray) -> Tuple[int, int, bool]:
        lat, bram, dead = self.evaluate(np.asarray(depths)[None, :])
        return int(lat[0]), int(bram[0]), bool(dead[0])

    def evaluate_one_delta(self, base: np.ndarray, depths: np.ndarray
                           ) -> Tuple[int, int, bool]:
        lat, bram, dead = self.evaluate_delta(
            np.asarray(base)[None, :], np.asarray(depths)[None, :])
        return int(lat[0]), int(bram[0]), bool(dead[0])

    def fulfill(self, req: EvalRequest
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Evaluate one :class:`EvalRequest` (cache + history + budget)."""
        if req.base is not None:
            return self.evaluate_delta(req.base, req.depths)
        return self.evaluate(req.depths)

    def history(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray, np.ndarray]:
        """Concatenated evaluation history and per-call batch lengths:
        ``(configs (N, F), lat (N,), bram (N,), dead (N,), steps (S,))``.
        The campaign checkpoint serializes exactly this."""
        steps = np.asarray([c.shape[0] for c in self._configs],
                           dtype=np.int64)
        if self._configs:
            cfgs = np.concatenate(self._configs, axis=0)
            lat = np.concatenate(self._lat)
            bram = np.concatenate(self._bram)
            dead = np.concatenate(self._dead)
        else:
            F = self.g.n_fifos
            cfgs = np.zeros((0, F), dtype=np.int64)
            lat = bram = np.zeros(0, dtype=np.int64)
            dead = np.zeros(0, dtype=bool)
        return cfgs, lat, bram, dead, steps

    def result(self, name: str, runtime_s: float) -> OptResult:
        cfgs, lat, bram, dead, _ = self.history()
        return OptResult(name=name, configs=cfgs, latency=lat, bram=bram,
                         deadlock=dead, runtime_s=runtime_s,
                         n_evals=self.n_evals)


class Optimizer:
    """Base class: subclasses implement the ``_steps`` generator.

    The generator yields :class:`EvalRequest` batches and receives the
    evaluated ``(latency, bram, deadlock)`` arrays at the yield point.
    """

    name = "base"

    def __init__(self, ctx: EvalContext, budget: int = 1000):
        self.ctx = ctx
        self.budget = int(budget)
        self._gen = None
        self._pending: Optional[EvalRequest] = None
        self._done = False
        #: wall time spent inside the generator (proposal/acceptance logic,
        #: excluding evaluation) — schedulers add their attributed eval time
        self.step_s = 0.0

    def _steps(self):  # pragma: no cover - interface
        """Yield :class:`EvalRequest`; receive ``(lat, bram, dead)``."""
        raise NotImplementedError
        yield

    # ------------------------------------------------------- stepwise API
    def start(self) -> None:
        """Prime the generator up to its first proposal (idempotent)."""
        if self._gen is None and not self._done:
            self._gen = self._steps()
            self._advance(None)

    def _advance(self, results) -> None:
        t0 = time.perf_counter()
        try:
            if results is None:
                self._pending = next(self._gen)
            else:
                self._pending = self._gen.send(results)
        except StopIteration:
            self._pending = None
            self._done = True
        finally:
            self.step_s += time.perf_counter() - t0

    def propose(self) -> Optional[EvalRequest]:
        """The outstanding batch to evaluate; None once the search ended."""
        self.start()
        return self._pending

    def observe(self, lat: np.ndarray, bram: np.ndarray,
                dead: np.ndarray) -> None:
        """Deliver results for the outstanding proposal and step once."""
        if self._pending is None:
            raise RuntimeError(
                f"{self.name}: observe() without a pending proposal")
        self._advance((np.asarray(lat), np.asarray(bram), np.asarray(dead)))

    @property
    def done(self) -> bool:
        return self._done

    def close(self) -> None:
        """Terminate the search now (generator cleanup runs); further
        :meth:`propose` calls return None.  The history evaluated so
        far remains valid — this is how the advisory service cancels a
        session mid-run."""
        if self._gen is not None:
            self._gen.close()
        self._pending = None
        self._done = True

    # ------------------------------------------------------- blocking API
    def run(self) -> OptResult:
        """Drive ``_steps`` to completion against this optimizer's ctx."""
        t0 = time.perf_counter()
        while True:
            req = self.propose()
            if req is None:
                break
            lat, bram, dead = self.ctx.fulfill(req)
            self.observe(lat, bram, dead)
        return self.ctx.result(self.name, time.perf_counter() - t0)
