"""FIFOAdvisor optimizer zoo (paper §III-D + beyond-paper additions)."""

from repro.core.optimizers.base import (EvalContext, EvalRequest, Optimizer,
                                        OptResult)
from repro.core.optimizers.random_search import (GroupedRandomSearch,
                                                 RandomSearch)
from repro.core.optimizers.annealing import (GroupedSimulatedAnnealing,
                                             SimulatedAnnealing)
from repro.core.optimizers.greedy import GreedySearch
from repro.core.optimizers.nsga2 import NSGA2
from repro.core.optimizers.vmap_search import VmapSearch

OPTIMIZERS = {
    "random": RandomSearch,
    "grouped_random": GroupedRandomSearch,
    "sa": SimulatedAnnealing,
    "grouped_sa": GroupedSimulatedAnnealing,
    "greedy": GreedySearch,
    "nsga2": NSGA2,
    "vmap_search": VmapSearch,
}

PAPER_OPTIMIZERS = ("greedy", "random", "grouped_random", "sa", "grouped_sa")

__all__ = [
    "EvalContext", "EvalRequest", "Optimizer", "OptResult", "OPTIMIZERS",
    "PAPER_OPTIMIZERS", "RandomSearch", "GroupedRandomSearch",
    "SimulatedAnnealing", "GroupedSimulatedAnnealing", "GreedySearch",
    "NSGA2", "VmapSearch",
]
