"""NSGA-II evolutionary multi-objective search (beyond-paper optimizer).

Non-dominated sorting + crowding-distance selection over grid-index
genomes.  Each generation evaluates the whole offspring population in one
batched simulator call, so this optimizer is nearly free on top of the
vectorized evaluator — the paper's single-config evaluation model would
make it budget-hungry.
"""

from __future__ import annotations

import numpy as np

from repro.core.optimizers.base import EvalContext, EvalRequest, Optimizer


def _non_dominated_sort(obj: np.ndarray) -> np.ndarray:
    """(N,2) objectives (minimize) -> integer front rank per row."""
    n = obj.shape[0]
    rank = np.full(n, -1, dtype=np.int64)
    remaining = np.arange(n)
    r = 0
    while remaining.size:
        pts = obj[remaining]
        # non-dominated within remaining
        order = np.lexsort((pts[:, 1], pts[:, 0]))
        best = np.inf
        keep = np.zeros(len(remaining), dtype=bool)
        for oi in order:
            if pts[oi, 1] < best:
                keep[oi] = True
                best = pts[oi, 1]
            elif pts[oi, 1] == best and not np.any(
                    (pts[:, 0] < pts[oi, 0]) & (pts[:, 1] <= pts[oi, 1])):
                keep[oi] = True
        rank[remaining[keep]] = r
        remaining = remaining[~keep]
        r += 1
    return rank


def _crowding(obj: np.ndarray) -> np.ndarray:
    n = obj.shape[0]
    dist = np.zeros(n)
    for k in range(2):
        order = np.argsort(obj[:, k], kind="stable")
        span = obj[order[-1], k] - obj[order[0], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span > 0 and n > 2:
            dist[order[1:-1]] += (obj[order[2:], k] -
                                  obj[order[:-2], k]) / span
    return dist


class NSGA2(Optimizer):
    name = "nsga2"

    def __init__(self, ctx: EvalContext, budget: int = 1000,
                 pop_size: int = 64, grouped: bool = True,
                 mut_rate: float = 0.15):
        super().__init__(ctx, budget)
        self.pop = int(pop_size)
        self.grouped = grouped
        self.mut_rate = float(mut_rate)

    def _dims(self) -> np.ndarray:
        return (self.ctx.group_grid_sizes if self.grouped
                else self.ctx.grid_sizes)

    def _depths(self, idx: np.ndarray) -> np.ndarray:
        return (self.ctx.depths_from_group_indices(idx) if self.grouped
                else self.ctx.depths_from_indices(idx))

    # Large finite penalty keeps crowding-distance arithmetic well-defined.
    _PENALTY = 1e12

    def _objectives(self, lat: np.ndarray, bram: np.ndarray,
                    dead: np.ndarray) -> np.ndarray:
        penal = np.where(dead, self._PENALTY, 0.0)
        return np.stack([lat + penal, bram + penal],
                        axis=1).astype(np.float64)

    def _steps(self):
        rng = self.ctx.rng
        dims = self._dims()
        D = len(dims)
        P = min(self.pop, max(8, self.budget // 4))

        # init: corners + random
        pop = np.stack(
            [rng.integers(0, dims[d], size=P) for d in range(D)], axis=1)
        pop[0] = dims - 1      # Baseline-Max corner
        pop[1] = 0             # Baseline-Min corner
        lat, bram, dead = yield EvalRequest(self._depths(pop))
        obj = self._objectives(lat, bram, dead)
        remaining = self.budget - P

        while remaining >= P:
            rank = _non_dominated_sort(obj)
            crowd = _crowding(obj)
            # binary tournament on (rank asc, crowding desc)
            a = rng.integers(0, P, size=P)
            b = rng.integers(0, P, size=P)
            better = (rank[a] < rank[b]) | (
                (rank[a] == rank[b]) & (crowd[a] >= crowd[b]))
            parents = np.where(better, a, b)
            # uniform crossover + per-gene mutation
            pa = pop[parents]
            pb = pop[parents[rng.permutation(P)]]
            xmask = rng.random((P, D)) < 0.5
            child = np.where(xmask, pa, pb)
            mmask = rng.random((P, D)) < self.mut_rate
            if mmask.any():
                noise = rng.integers(0, dims[None, :].repeat(P, 0))
                child = np.where(mmask, noise, child)
            lat, bram, dead = yield EvalRequest(self._depths(child))
            cobj = self._objectives(lat, bram, dead)
            remaining -= P
            # environmental selection from parents + children
            allpop = np.concatenate([pop, child], axis=0)
            allobj = np.concatenate([obj, cobj], axis=0)
            r = _non_dominated_sort(allobj)
            c = _crowding(allobj)
            order = np.lexsort((-c, r))
            keep = order[:P]
            pop, obj = allpop[keep], allobj[keep]
