"""Random sampling optimizers (paper §III-D, first two entries).

Both samplers draw depths ONLY from the pruned per-FIFO breakpoint grids —
"we use our BRAM usage model to suggest optimal sizes for each FIFO, from
which the sampler uniformly selects."  The grouped variant draws one index
per stream-array group (Stream-HLS arrays behave alike).
"""

from __future__ import annotations

import numpy as np

from repro.core.optimizers.base import EvalRequest, Optimizer


class RandomSearch(Optimizer):
    name = "random"
    batch = 128

    def _steps(self):
        ctx = self.ctx
        remaining = self.budget
        F = ctx.g.n_fifos
        while remaining > 0:
            C = min(self.batch, remaining)
            idx = np.stack(
                [ctx.rng.integers(0, ctx.grid_sizes[f], size=C)
                 for f in range(F)], axis=1)
            yield EvalRequest(ctx.depths_from_indices(idx))
            remaining -= C


class GroupedRandomSearch(Optimizer):
    name = "grouped_random"
    batch = 128

    def _steps(self):
        ctx = self.ctx
        remaining = self.budget
        G = len(ctx.groups)
        while remaining > 0:
            C = min(self.batch, remaining)
            gidx = np.stack(
                [ctx.rng.integers(0, ctx.group_grid_sizes[gi], size=C)
                 for gi in range(G)], axis=1)
            yield EvalRequest(ctx.depths_from_group_indices(gidx))
            remaining -= C
