"""Batched frontier descent (beyond-paper optimizer).

Exploits the vectorized evaluator's throughput directly: alternate

  1. a large grouped-random exploration batch, and
  2. a local-mutation batch around every current frontier point
     (one-coordinate moves toward smaller depths, plus pairwise blends),

each phase being ONE batched simulator call.  On hardware with wide vector
units (TPU; or this container's vmapped CPU path) this evaluates thousands
of configs per second and converges faster per wall-second than any of the
paper's sequential optimizers — measured in benchmarks/convergence.py.
"""

from __future__ import annotations

import numpy as np

from repro.core.optimizers.base import EvalContext, EvalRequest, Optimizer


class VmapSearch(Optimizer):
    name = "vmap_search"

    def __init__(self, ctx: EvalContext, budget: int = 1000,
                 explore_batch: int = 256, descend_batch: int = 256):
        super().__init__(ctx, budget)
        self.explore_batch = int(explore_batch)
        self.descend_batch = int(descend_batch)

    def _steps(self):
        ctx, rng = self.ctx, self.ctx.rng
        G = len(ctx.groups)
        remaining = self.budget

        # seed with the two baselines
        yield EvalRequest(
            np.stack([ctx.baseline_max(), ctx.baseline_min()]))
        remaining -= 2

        explore = True
        while remaining > 0:
            if explore:
                C = min(self.explore_batch, remaining)
                gidx = np.stack(
                    [rng.integers(0, ctx.group_grid_sizes[gi], size=C)
                     for gi in range(G)], axis=1)
                yield EvalRequest(ctx.depths_from_group_indices(gidx))
                remaining -= C
            else:
                res = ctx.result("tmp", 0.0)
                pts, front_cfg = res.frontier()
                if front_cfg.shape[0] == 0:
                    explore = True
                    continue
                C = min(self.descend_batch, remaining)
                base = front_cfg[rng.integers(0, front_cfg.shape[0], size=C)]
                trial = base.astype(np.int64).copy()
                F = ctx.g.n_fifos
                which = rng.integers(0, F, size=C)
                rows = np.arange(C)
                # move the chosen fifo down one breakpoint
                for i in range(C):
                    f = which[i]
                    cand = ctx.candidates[f]
                    pos = int(np.searchsorted(cand, trial[i, f]))
                    pos = max(0, min(pos, len(cand) - 1) - 1)
                    trial[i, f] = cand[pos]
                # blend a third of the batch with another frontier point
                nb = C // 3
                if nb and front_cfg.shape[0] > 1:
                    other = front_cfg[
                        rng.integers(0, front_cfg.shape[0], size=nb)]
                    mask = rng.random((nb, F)) < 0.5
                    trial[:nb] = np.where(mask, trial[:nb],
                                          other.astype(np.int64))
                yield EvalRequest(trial)
                remaining -= C
            explore = not explore
