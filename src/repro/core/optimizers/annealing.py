"""Simulated annealing over the β-swept weighted objective (paper §III-D).

The multi-objective problem is scalarized as

    f(x) = (1 - β) · f_lat(x)/L0  +  β · f_bram(x)/B0

for β in linspace(0, 1, N); one annealing chain per β.  All N chains step in
lockstep so each optimizer step evaluates N candidate configs in ONE batched
simulator call — the vectorized evaluator makes the β sweep essentially free.
The frontier is extracted from the union of all evaluated points.

Deadlocked candidates get infinite energy (always rejected) but still count
against the sample budget, mirroring the paper's accounting.
"""

from __future__ import annotations

import numpy as np

from repro.core.optimizers.base import EvalContext, EvalRequest, Optimizer


class SimulatedAnnealing(Optimizer):
    name = "sa"
    grouped = False

    def __init__(self, ctx: EvalContext, budget: int = 1000,
                 n_beta: int = 8, t0: float = 0.30, t_end: float = 0.002,
                 reset_prob: float = 0.10):
        super().__init__(ctx, budget)
        self.n_beta = int(n_beta)
        self.t0 = float(t0)
        self.t_end = float(t_end)
        self.reset_prob = float(reset_prob)

    # ------------------------------------------------------------------
    def _dims(self) -> np.ndarray:
        ctx = self.ctx
        return (ctx.group_grid_sizes if self.grouped else ctx.grid_sizes)

    def _depths(self, idx: np.ndarray) -> np.ndarray:
        ctx = self.ctx
        return (ctx.depths_from_group_indices(idx) if self.grouped
                else ctx.depths_from_indices(idx))

    def _steps(self):
        ctx = self.ctx
        rng = ctx.rng
        dims = self._dims()
        D = len(dims)
        N = self.n_beta
        betas = np.linspace(0.0, 1.0, N)

        # Normalizers from the two baselines (evaluated first, on budget).
        lat0, bram0, _ = yield EvalRequest(
            np.stack([ctx.baseline_max(), ctx.baseline_min()]))
        L0 = max(float(lat0[0]), 1.0)
        B0 = max(float(bram0[0]), 1.0)
        budget = self.budget - 2

        def energy(lat, bram, dead):
            e = ((1.0 - betas) * lat / L0 + betas * bram / B0)
            return np.where(dead, np.inf, e)

        # init chains at the max-index corner (Baseline-Max-like: feasible)
        state = np.tile((dims - 1)[None, :], (N, 1)).astype(np.int64)
        lat, bram, dead = yield EvalRequest(self._depths(state))
        budget -= N
        e_cur = energy(lat, bram, dead)

        steps = max(1, budget // N)
        cool = (self.t_end / self.t0) ** (1.0 / max(steps - 1, 1))
        temp = self.t0
        for _ in range(steps):
            # propose: single-coordinate move of +-1..2 (or random reset)
            prop = state.copy()
            pos = rng.integers(0, D, size=N)
            jump = rng.choice([-2, -1, 1, 2], size=N)
            rows = np.arange(N)
            prop[rows, pos] = np.clip(prop[rows, pos] + jump, 0,
                                      dims[pos] - 1)
            resets = rng.random(N) < self.reset_prob
            if resets.any():
                rand_pos = rng.integers(0, D, size=N)
                rand_val = rng.integers(0, dims[rand_pos])
                prop[resets, rand_pos[resets]] = rand_val[resets]

            # proposals differ from their chain's state by one coordinate:
            # eligible for the incremental re-simulation fast path
            lat, bram, dead = yield EvalRequest(
                self._depths(prop), base=self._depths(state))
            e_new = energy(lat, bram, dead)
            with np.errstate(invalid="ignore", over="ignore"):
                accept = (e_new <= e_cur) | (
                    rng.random(N) < np.exp(-(e_new - e_cur) /
                                           max(temp, 1e-9)))
            accept &= np.isfinite(e_new) | (e_new <= e_cur)
            state[accept] = prop[accept]
            e_cur = np.where(accept, e_new, e_cur)
            temp *= cool


class GroupedSimulatedAnnealing(SimulatedAnnealing):
    name = "grouped_sa"
    grouped = True
