"""Greedy heuristic (INR-Arch), paper §III-D.

Starting from Baseline-Max, visit FIFOs ranked by observed max occupancy
(largest first); set each to depth 2 and keep the reduction unless it
deadlocks or inflates latency beyond (1 + epsilon) x baseline.  An optional
refinement pass (on by default; it explains the paper's 10–2200 adaptive
sample counts) binary-searches the breakpoint grid of each *rejected* FIFO
for the smallest still-acceptable depth.
"""

from __future__ import annotations

import numpy as np

from repro.core.optimizers.base import EvalContext, EvalRequest, Optimizer


class GreedySearch(Optimizer):
    name = "greedy"

    def __init__(self, ctx: EvalContext, budget: int = 10**9,
                 epsilon: float = 0.01, refine: bool = True):
        super().__init__(ctx, budget)   # budget is a cap, not a target
        self.epsilon = float(epsilon)
        self.refine = refine

    def _steps(self):
        ctx = self.ctx
        cur = ctx.baseline_max()
        lat, _, dead = yield EvalRequest(cur)
        if dead[0]:  # pragma: no cover - Baseline-Max is deadlock-free
            raise RuntimeError("Baseline-Max deadlocked")
        limit = int(lat[0]) * (1.0 + self.epsilon)

        order = np.argsort(-ctx.g.max_occupancy, kind="stable")
        rejected = []
        for f in order:
            if ctx.n_evals >= self.budget:
                break
            # the paper's "set to 2" = the smallest candidate depth; the
            # grid floor is 2 unless the context clamps it higher (e.g. a
            # certified deadlock-free floor)
            floor = int(ctx.candidates[f][0])
            if cur[f] <= floor:
                continue
            trial = cur.copy()
            trial[f] = floor
            # single-FIFO move vs the accepted config: the incremental
            # re-simulation fast path re-solves only coupled segments
            lat, _, dead = yield EvalRequest(trial, base=cur)
            if not dead[0] and lat[0] <= limit:
                cur = trial
            else:
                rejected.append(int(f))

        if self.refine:
            for f in rejected:
                if ctx.n_evals >= self.budget:
                    break
                cand = ctx.candidates[f]
                lo, hi = 0, len(cand) - 1   # cand[hi] ~ current (accepted)
                # invariant: cand[hi] acceptable, cand[lo] == 2 rejected
                while hi - lo > 1 and ctx.n_evals < self.budget:
                    mid = (lo + hi) // 2
                    trial = cur.copy()
                    trial[f] = cand[mid]
                    lat, _, dead = yield EvalRequest(trial, base=cur)
                    if not dead[0] and lat[0] <= limit:
                        hi = mid
                    else:
                        lo = mid
                if cand[hi] < cur[f]:
                    cur[f] = cand[hi]
            # re-evaluate final config so it is part of the history
            yield EvalRequest(cur)
