"""Software-execution trace collection (the LightningSim front-end).

HLS ``#pragma HLS dataflow`` regions are required to be *sequentially
executable*: running the tasks to completion one after another in
declaration order, with unbounded FIFOs, is a valid execution that fixes
every data value — and therefore fixes all data-dependent control flow.
This is exactly how LightningSim collects its trace from native software
execution of the C source.  The collected trace pins down, per task, the
linear sequence of FIFO operations and the compute-cycle gaps between
them; FIFO depths only ever change *stall* timing, never the op sequence.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List

import numpy as np

from repro.core.design import DELAY, Design, READ, TaskCtx, WRITE


@dataclasses.dataclass
class TaskTrace:
    """Linear FIFO-op trace of one task.

    ``kinds[i]``/``fifos[i]`` identify the i-th FIFO op; ``deltas[i]`` is the
    number of compute cycles between the completion of op ``i-1`` (or task
    start) and the earliest issue of op ``i``.  ``end_delay`` is trailing
    compute after the last FIFO op.
    """

    task: int
    kinds: np.ndarray      # int8  (n_ops,)   READ/WRITE
    fifos: np.ndarray      # int32 (n_ops,)
    deltas: np.ndarray     # int64 (n_ops,)
    end_delay: int

    @property
    def n_ops(self) -> int:
        return int(self.kinds.shape[0])


@dataclasses.dataclass
class Trace:
    """Full design trace + functional results of the software execution."""

    design: Design
    tasks: List[TaskTrace]
    results: Dict[str, Any]
    write_counts: np.ndarray   # int64 (n_fifos,) total writes observed
    read_counts: np.ndarray    # int64 (n_fifos,)

    @property
    def n_events(self) -> int:
        return int(sum(t.n_ops for t in self.tasks))

    def default_upper_bounds(self) -> np.ndarray:
        """Per-FIFO search upper bound u_i.

        The paper: "the sizes defined in the design, the total number of
        writes observed during kernel execution, or user-specified".  We use
        the declared depth when present, else the observed write count
        (min depth that can buffer everything => Baseline-Max), floor 2.
        """
        u = np.empty(self.design.n_fifos, dtype=np.int64)
        for f in self.design.fifos:
            if f.depth is not None:
                u[f.index] = f.depth
            else:
                u[f.index] = self.write_counts[f.index]
        return np.maximum(u, 2)


class TraceDivergenceError(RuntimeError):
    """A task read from a FIFO that is empty under sequential semantics —
    the design is not sequentially executable (illegal HLS dataflow)."""


def collect_trace(design: Design) -> Trace:
    """Run the design under sequential semantics and collect its trace."""
    queues: List[deque] = [deque() for _ in range(design.n_fifos)]
    results: Dict[str, Any] = {}
    ctx = TaskCtx(design, design.args, results)

    task_traces: List[TaskTrace] = []
    write_counts = np.zeros(design.n_fifos, dtype=np.int64)
    read_counts = np.zeros(design.n_fifos, dtype=np.int64)

    for task in design.tasks:
        kinds: List[int] = []
        fifos: List[int] = []
        deltas: List[int] = []
        pending_delay = 0

        gen = task.program(ctx)
        send_value: Any = None
        while True:
            try:
                op = gen.send(send_value)
            except StopIteration:
                break
            send_value = None
            if op.kind == DELAY:
                pending_delay += op.cycles
            elif op.kind == WRITE:
                queues[op.fifo].append(op.value)
                write_counts[op.fifo] += 1
                kinds.append(WRITE)
                fifos.append(op.fifo)
                deltas.append(pending_delay)
                pending_delay = 0
            elif op.kind == READ:
                if not queues[op.fifo]:
                    raise TraceDivergenceError(
                        f"task {task.name!r} read empty fifo "
                        f"{design.fifos[op.fifo].name!r} under sequential "
                        f"semantics")
                send_value = queues[op.fifo].popleft()
                read_counts[op.fifo] += 1
                kinds.append(READ)
                fifos.append(op.fifo)
                deltas.append(pending_delay)
                pending_delay = 0
            else:  # pragma: no cover - defensive
                raise ValueError(f"unknown op kind {op.kind}")

        task_traces.append(TaskTrace(
            task=task.index,
            kinds=np.asarray(kinds, dtype=np.int8),
            fifos=np.asarray(fifos, dtype=np.int32),
            deltas=np.asarray(deltas, dtype=np.int64),
            end_delay=pending_delay,
        ))

    return Trace(design=design, tasks=task_traces, results=results,
                 write_counts=write_counts, read_counts=read_counts)
