"""Pallas TPU kernel: batched FIFO-configuration latency evaluation.

One grid program per candidate configuration; all per-event state lives in
VMEM as (1, E) float32/int32 vectors (E padded to a multiple of 128 lanes).
Each Jacobi iteration is

    cross-edge gathers (data + back-pressure)  ->  VPU max/where ops
    ->  segmented max-plus scan via STATIC Hillis-Steele doubling
        (ceil(log2 E) shift+combine vector steps, fully unrolled)

so the kernel is pure dense vector work — no pointer chasing.  The outer
``lax.while_loop`` stops on convergence, on exceeding the design's schedule
upper bound (deadlock), or at the iteration cap.

TPU adaptation notes (DESIGN.md §6): the CPU-oriented LightningSim
traversal is pointer-chasing over a worklist; here the same fixpoint is
computed as data-parallel sweeps whose only irregularity is two gathers of
``t`` by precomputed index vectors.  VMEM footprint is ~15 live (1, E)
f32 vectors (~2 MB at E=32768), well inside ~16 MB VMEM.  Validated in
``interpret=True`` mode on CPU (the container has no TPU); the gathers are
expressed with ``jnp.take`` which interpret mode executes exactly.

Layout of the per-config output row (float32, 128 lanes):
    [0] latency   [1] converged (0/1)   [2] over-bound (0/1)   [3] iters
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG = np.float32(-1e9)   # numpy scalar: must not become a captured tracer
OUT_LANES = 128


def _num_scan_steps(e_pad: int) -> int:
    steps = 0
    while (1 << steps) < e_pad:
        steps += 1
    return steps


def _fifo_eval_kernel(
    # shared (1, E) operands
    delta_ref, segst_ref, isread_ref, hasdata_ref, didx_ref, endb_ref,
    # per-config (1, E) operands
    rdlat_ref, bpidx_ref, bpval_ref, bpbase_ref,
    # outputs: result row, then (with_times) the final event times
    *refs,
    e_pad: int, max_iters: int, bound: float, with_times: bool,
):
    out_ref = refs[0]
    delta = delta_ref[...]            # (1, E) f32
    segst = segst_ref[...]            # (1, E) f32: 1.0 at segment starts
    is_read = isread_ref[...]         # (1, E) f32 mask
    has_data = hasdata_ref[...]       # (1, E) f32 mask
    data_idx = didx_ref[...]          # (1, E) i32
    end_bonus = endb_ref[...]         # (1, E) f32: end_delay at task-last, else NEG
    rd_lat = rdlat_ref[...]           # (1, E) f32
    bp_idx = bpidx_ref[...]           # (1, E) i32
    bp_valid = bpval_ref[...]         # (1, E) f32 mask
    bp_base = bpbase_ref[...]         # (1, E) f32: 1.0 + condensation offset

    a_base = jnp.where(segst > 0, NEG, delta)
    n_steps = _num_scan_steps(e_pad)

    def seg_scan(a, m):
        # inclusive max-plus scan, Hillis-Steele doubling (static shifts)
        for s in range(n_steps):
            sh = 1 << s
            a_prev = jnp.pad(a, ((0, 0), (sh, 0)),
                             constant_values=0.0)[:, :e_pad]
            m_prev = jnp.pad(m, ((0, 0), (sh, 0)),
                             constant_values=NEG)[:, :e_pad]
            m = jnp.maximum(m_prev + a, m)
            a = a_prev + a
        return a, m

    def step(t):
        td = jnp.take(t[0], data_idx[0], axis=0)[None, :]
        bd = jnp.where(has_data > 0, td + rd_lat, NEG)
        tb = jnp.take(t[0], bp_idx[0], axis=0)[None, :]
        bb = jnp.where(bp_valid > 0, tb + bp_base, NEG)
        b = jnp.where(is_read > 0, bd, bb)
        m = jnp.where(segst > 0, jnp.maximum(b, delta), b)
        A, M = seg_scan(a_base, m)
        return jnp.maximum(A, M)

    def cond(state):
        t, it, conv = state
        return (~conv) & (it < max_iters) & (jnp.max(t) <= bound)

    def body(state):
        t, it, _ = state
        t2 = step(t)
        return t2, it + 1, jnp.all(t2 == t)

    t0 = jnp.zeros((1, e_pad), dtype=jnp.float32)
    t, iters, conv = lax.while_loop(
        cond, body, (step(t0), jnp.int32(1), jnp.bool_(False)))

    latency = jnp.max(t + end_bonus)
    over = jnp.max(t) > bound
    row = jnp.zeros((1, OUT_LANES), dtype=jnp.float32)
    row = row.at[0, 0].set(latency)
    row = row.at[0, 1].set(conv.astype(jnp.float32))
    row = row.at[0, 2].set(over.astype(jnp.float32))
    row = row.at[0, 3].set(iters.astype(jnp.float32))
    out_ref[...] = row
    if with_times:
        refs[1][...] = t


def fifo_eval_pallas(
    delta: jnp.ndarray, segst: jnp.ndarray, is_read: jnp.ndarray,
    has_data: jnp.ndarray, data_idx: jnp.ndarray, end_bonus: jnp.ndarray,
    rd_lat: jnp.ndarray, bp_idx: jnp.ndarray, bp_valid: jnp.ndarray,
    bp_base: jnp.ndarray, *, max_iters: int, bound: float,
    interpret: bool = True, with_times: bool = False,
):
    """Launch the kernel.

    Shared operands are (1, E); per-config operands are (C, E); E must be
    a multiple of 128.  Returns (C, OUT_LANES) float32 result rows, plus
    the final (C, E) event times when ``with_times`` (the condensation
    certificate needs them; the extra output is skipped otherwise).
    """
    C, e_pad = rd_lat.shape
    assert e_pad % 128 == 0, "pad events to a lane multiple"
    kernel = functools.partial(_fifo_eval_kernel, e_pad=e_pad,
                               max_iters=max_iters, bound=bound,
                               with_times=with_times)
    shared = pl.BlockSpec((1, e_pad), lambda i: (0, 0))
    percfg = pl.BlockSpec((1, e_pad), lambda i: (i, 0))
    out_specs = [pl.BlockSpec((1, OUT_LANES), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((C, OUT_LANES), jnp.float32)]
    if with_times:
        out_specs.append(pl.BlockSpec((1, e_pad), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((C, e_pad), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=(C,),
        in_specs=[shared] * 6 + [percfg] * 4,
        out_specs=out_specs if with_times else out_specs[0],
        out_shape=out_shape if with_times else out_shape[0],
        interpret=interpret,
    )(delta, segst, is_read, has_data, data_idx, end_bonus,
      rd_lat, bp_idx, bp_valid, bp_base)
    if with_times:
        rows, times = out
        return rows, times
    return out, None
