"""jit'd wrapper around the fifo_eval Pallas kernel.

Builds the padded, lane-aligned event tensors from a
:class:`~repro.core.simgraph.SimGraph` once, then exposes a callable
``(C, F) int depths -> (latency, bram, status)`` that computes the
depth-dependent per-config operands (read latencies, back-pressure gather
indices) in stock jnp and launches the kernel for the heavy fixpoint.

The same factory can wrap either the kernel (``use_ref=False``) or the
pure-jnp oracle in :mod:`repro.kernels.fifo_eval.ref` — tests diff the two.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.bram import SRL_BITS, SRL_DEPTH
from repro.core.design import READ, WRITE
from repro.core.simulate import (CONVERGED, DEADLOCK, UNRESOLVED,
                                 bram_count_jnp)
from repro.kernels.fifo_eval.fifo_eval import NEG, fifo_eval_pallas
from repro.kernels.fifo_eval.ref import fifo_eval_ref


def make_batched_eval(ev, interpret: bool = True, use_ref: bool = False,
                      max_iters: int = None) -> Callable:
    """Build the batched evaluation closure for ``ev.g`` (a SimGraph)."""
    g = ev.g
    max_iters = int(max_iters if max_iters is not None else ev.max_iters)
    bound = float(g.latency_upper_bound())

    E = g.n_events
    E_pad = max(128, -(-max(E, 1) // 128) * 128)

    def padded(a, fill, dtype):
        out = np.full(E_pad, fill, dtype=dtype)
        out[:E] = a
        return out

    kind = padded(g.kind, READ, np.int32)          # pad kind irrelevant
    fifo_np = padded(g.fifo, 0, np.int64)
    delta = padded(g.delta, 0, np.float32)
    seg_start = padded(g.seg_start, 0, np.float32)
    if E < E_pad:
        seg_start[E] = 1.0                          # isolate the pad chain
    rank = padded(g.rank, 0, np.int64)
    data_src = padded(g.data_src, -1, np.int64)

    is_read = ((kind == READ) & (np.arange(E_pad) < E)).astype(np.float32)
    is_write = ((kind == WRITE) & (np.arange(E_pad) < E))
    has_data = ((data_src >= 0) & (is_read > 0)).astype(np.float32)
    data_idx = np.clip(data_src, 0, E_pad - 1).astype(np.int32)

    end_bonus = np.full(E_pad, float(NEG), dtype=np.float32)
    taskless_lat = 0.0
    for t in range(g.n_tasks):
        le = int(g.last_evt[t])
        if le >= 0:
            end_bonus[le] = float(g.end_delay[t])
        else:
            taskless_lat = max(taskless_lat, float(g.end_delay[t]))

    R = max(int(g.n_reads.sum()), 1)
    read_evt_flat = np.zeros(R, dtype=np.int64)
    read_evt_flat[:len(g.read_evt_flat)] = g.read_evt_flat

    consts = dict(
        delta=jnp.asarray(delta)[None, :],
        segst=jnp.asarray(seg_start)[None, :],
        is_read=jnp.asarray(is_read)[None, :],
        has_data=jnp.asarray(has_data)[None, :],
        data_idx=jnp.asarray(data_idx)[None, :],
        end_bonus=jnp.asarray(end_bonus)[None, :],
    )
    fifo_j = jnp.asarray(fifo_np, dtype=jnp.int32)
    rank_j = jnp.asarray(rank, dtype=jnp.int32)
    widths_j = jnp.asarray(g.widths, dtype=jnp.int32)
    n_reads_j = jnp.asarray(g.n_reads, dtype=jnp.int32)
    read_base_j = jnp.asarray(g.read_base, dtype=jnp.int32)
    read_flat_j = jnp.asarray(read_evt_flat, dtype=jnp.int32)
    is_write_j = jnp.asarray(is_write)

    inner = fifo_eval_ref if use_ref else functools.partial(
        fifo_eval_pallas, interpret=interpret)

    @jax.jit
    def run(depths):                     # (C, F) int32
        depths = depths.astype(jnp.int32)
        is_bram = ~((depths <= SRL_DEPTH) | (depths * widths_j <= SRL_BITS))
        rd_lat_f = 1.0 + is_bram.astype(jnp.float32)      # (C, F)
        rd_lat_e = rd_lat_f[:, fifo_j]                    # (C, E_pad)

        bp_pos = rank_j[None, :] - depths[:, fifo_j]      # (C, E_pad)
        overrun = is_write_j[None, :] & (bp_pos >= n_reads_j[fifo_j][None, :])
        structural = jnp.any(overrun, axis=1)             # (C,)
        bp_valid = (is_write_j[None, :] & (bp_pos >= 0) & ~overrun
                    ).astype(jnp.float32)
        flat = jnp.clip(read_base_j[fifo_j][None, :] + bp_pos, 0, R - 1)
        bp_idx = read_flat_j[flat]                        # (C, E_pad)

        out = inner(consts["delta"], consts["segst"], consts["is_read"],
                    consts["has_data"], consts["data_idx"],
                    consts["end_bonus"],
                    rd_lat_e, bp_idx, bp_valid,
                    max_iters=max_iters, bound=bound)
        lat = jnp.maximum(out[:, 0], taskless_lat)
        conv = out[:, 1] > 0
        over = out[:, 2] > 0
        status = jnp.where(
            structural | over, DEADLOCK,
            jnp.where(conv, CONVERGED, UNRESOLVED)).astype(jnp.int8)
        bram = jnp.sum(bram_count_jnp(depths, widths_j[None, :]),
                       axis=1).astype(jnp.int32)
        return lat, bram, status

    def call(depth_matrix: np.ndarray
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lat, bram, status = jax.device_get(run(jnp.asarray(depth_matrix)))
        return lat, bram, status

    return call
