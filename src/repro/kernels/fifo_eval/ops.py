"""jit'd wrapper around the fifo_eval fixpoint implementations.

Consumes the shared lane-aligned event tensors from
:mod:`repro.core.backends.operands` (built once per graph) and exposes a
callable ``(C, F) int depths -> (latency, bram, status)``.  The
depth-dependent per-config operands (read latencies, back-pressure gather
indices) come from the shared :func:`~repro.core.backends.operands
.depth_operands`; only the heavy fixpoint differs between inners:

``use_ref=False``  the Pallas kernel (:mod:`repro.kernels.fifo_eval
                   .fifo_eval`), interpret mode on CPU
``use_ref=True``   the pure-jnp oracle (:mod:`repro.kernels.fifo_eval.ref`),
                   which is also the ``fixpoint`` backend's implementation

Tests diff the two against each other and against the numpy worklist.
"""

from __future__ import annotations

import functools
from collections import Counter
from typing import Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backends.base import CONVERGED, DEADLOCK, UNRESOLVED
from repro.core.backends.operands import (bram_count_jnp, cert_row_operands,
                                          depth_operands, get_cert_tables,
                                          get_operands)
from repro.core.bram import (BRAM_READ_LATENCY, SRL_BITS, SRL_DEPTH,
                             SRL_READ_LATENCY)
from repro.core.simgraph import SimGraph
from repro.kernels.fifo_eval.fifo_eval import fifo_eval_pallas
from repro.kernels.fifo_eval.ref import fifo_eval_ref, fifo_eval_ref_hetero

#: device dispatches per wrapper kind ("batched" / "hetero" /
#: "condensed").  The cascade device-residency regression tests assert
#: that a fully-certifying batch costs exactly ONE "condensed" dispatch
#: and never touches the host verifier.
DISPATCH_COUNTS: Counter = Counter()


def _shard_over_rows(run: Callable, mesh) -> Callable:
    """Wrap an un-jitted row-batch fixpoint in ``shard_map`` over ``mesh``.

    Every input and output is partitioned along its leading (config-row)
    axis across ALL mesh axes jointly, so a 1-D ``("eval",)`` mesh splits
    a batch into per-device row shards and a 2-D ``("design", "eval")``
    campaign mesh splits design-major row blocks onto contiguous device
    groups.  Rows are independent (one fixpoint per candidate config), so
    sharding is pure row partitioning — bit-identical to the solo path.
    ``check_rep=False`` because ``lax.while_loop`` has no replication
    rule; nothing here relies on replication (no collectives at all).
    The caller must pad the row count to a multiple of the mesh size.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec
    spec = PartitionSpec(tuple(mesh.axis_names))
    return shard_map(run, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)


def _make_run(ops, inner, max_iters: int, with_times: bool) -> Callable:
    """The un-jitted batched fixpoint body shared by the solo jit path
    and the shard_map-wrapped mesh path."""

    def run(depths):                     # (C, F) int32
        rd_lat_e, bp_idx, bp_valid, bp_base, structural = depth_operands(
            ops, depths)
        out, times = inner(ops.delta, ops.seg_start, ops.is_read,
                           ops.has_data, ops.data_idx, ops.end_bonus,
                           rd_lat_e, bp_idx, bp_valid, bp_base,
                           max_iters=max_iters, bound=ops.bound)
        lat = jnp.maximum(out[:, 0], ops.taskless_lat)
        conv = out[:, 1] > 0
        over = out[:, 2] > 0
        status = jnp.where(
            structural | over, DEADLOCK,
            jnp.where(conv, CONVERGED, UNRESOLVED)).astype(jnp.int8)
        bram = jnp.sum(bram_count_jnp(depths.astype(jnp.int32),
                                      ops.widths[None, :]),
                       axis=1).astype(jnp.int32)
        if with_times:
            return lat, bram, status, times
        return lat, bram, status

    return run


def make_batched_eval(ev_or_graph, interpret: bool = True,
                      use_ref: bool = False,
                      max_iters: int = None,
                      with_times: bool = False,
                      mesh=None) -> Callable:
    """Build the batched evaluation closure for a SimGraph.

    Accepts either a :class:`~repro.core.simgraph.SimGraph` (raw or
    condensed — the condensation offsets ride the shared operands) or
    any object with ``.g`` / ``.max_iters`` (e.g. a ``BatchedEvaluator``).
    With ``with_times`` the closure returns ``(lat, bram, status, t)``
    where ``t`` is the (C, E_pad) final event-time matrix the
    condensation certificate checks; otherwise ``(lat, bram, status)``
    and the times are dead-code-eliminated inside the jit.

    ``mesh`` (a :class:`jax.sharding.Mesh`) shards the config-row axis
    across its devices via ``shard_map`` — see
    :mod:`repro.core.backends.mesh`; the row count must then be a
    multiple of the mesh size (``MeshBackend`` pads).
    """
    g: SimGraph = getattr(ev_or_graph, "g", ev_or_graph)
    if max_iters is None:
        max_iters = getattr(ev_or_graph, "max_iters", 64)
    max_iters = int(max_iters)
    ops = get_operands(g)

    inner = fifo_eval_ref if use_ref else functools.partial(
        fifo_eval_pallas, interpret=interpret, with_times=with_times)

    run = _make_run(ops, inner, max_iters, with_times)
    if mesh is not None:
        run = _shard_over_rows(run, mesh)
    run = jax.jit(run)

    def call(depth_matrix: np.ndarray
             ) -> Tuple[np.ndarray, ...]:
        DISPATCH_COUNTS["batched"] += 1
        return jax.device_get(
            run(jnp.asarray(depth_matrix, dtype=jnp.int32)))

    return call


def make_condensed_eval(cg, interpret: bool = True,
                        max_iters: int = 64,
                        with_times: bool = False,
                        mesh=None, block: int = None
                        ) -> Optional[Callable]:
    """Build the FUSED condensed evaluation closure for a CondensedGraph.

    One kernel launch per batch evaluates the condensed fixpoint AND the
    exactness certificate (:mod:`repro.kernels.fifo_eval.condensed`),
    returning ``call(depths) -> (lat, bram, status, cert)`` — ``cert``
    is the per-row pass/fail mask with ``verify_rows`` semantics, True
    only on CONVERGED rows, so the rung cascade accepts/escalates rows
    without the event-time matrix ever leaving the device.  Returns None
    when the graph has no expressible certificate tables (the caller
    falls back to the host verifier).

    ``mesh`` shards the config-row axis like :func:`make_batched_eval`;
    the batch is padded to the kernel's row-block size internally (per
    shard under a mesh), so callers only pad to the shard multiple.
    """
    from repro.kernels.fifo_eval.condensed import (fifo_eval_condensed,
                                                   pick_block)
    ops = get_operands(cg)
    ct = get_cert_tables(cg)
    if ct is None:
        return None
    if block is None:
        block = pick_block(ops.e_pad, ct.v_pad)
    max_iters = int(max_iters)

    def run(depths):                     # (C, F) int32, C % shards == 0
        c = depths.shape[0]
        # shrink the row block to the (static) batch size: escalation
        # rungs see small bucketed batches (8 rows), and padding those up
        # to the full-batch block would re-evaluate the rung 4x over
        b = min(block, max(8, 1 << (c - 1).bit_length()))
        pad = -c % b
        if pad:
            depths = jnp.concatenate(
                [depths,
                 jnp.broadcast_to(depths[-1:], (pad, depths.shape[1]))])
        rd_lat_e, bp_idx, bp_valid, bp_base, structural = depth_operands(
            ops, depths)
        csrc, cdst, cthr, cval = cert_row_operands(ops, ct, depths)
        out, times = fifo_eval_condensed(
            ops.delta, ops.seg_start, ops.is_read, ops.has_data,
            ops.data_idx, ops.end_bonus, rd_lat_e, bp_idx, bp_valid,
            bp_base, csrc, cdst, cthr, cval, max_iters=max_iters,
            bound=ops.bound, block=b, interpret=interpret,
            with_times=with_times)
        lat = jnp.maximum(out[:, 0], ops.taskless_lat)
        conv = out[:, 1] > 0
        over = out[:, 2] > 0
        status = jnp.where(
            structural | over, DEADLOCK,
            jnp.where(conv, CONVERGED, UNRESOLVED)).astype(jnp.int8)
        # kernel cert = conv & ~over & no violated slot; a structurally
        # deadlocked row must additionally never certify
        cert = (out[:, 4] > 0) & (status == CONVERGED)
        bram = jnp.sum(bram_count_jnp(depths.astype(jnp.int32),
                                      ops.widths[None, :]),
                       axis=1).astype(jnp.int32)
        res = (lat[:c], bram[:c], status[:c], cert[:c])
        if with_times:
            res = res + (times[:c],)
        return res

    if mesh is not None:
        run = _shard_over_rows(run, mesh)
    run = jax.jit(run)

    def call(depth_matrix: np.ndarray) -> Tuple[np.ndarray, ...]:
        DISPATCH_COUNTS["condensed"] += 1
        return jax.device_get(
            run(jnp.asarray(depth_matrix, dtype=jnp.int32)))

    return call


def make_hetero_batched_eval(max_iters: int = 64, mesh=None) -> Callable:
    """Build the CROSS-DESIGN batched evaluation closure.

    Consumes the stacked per-row batch dict produced by
    :func:`repro.core.backends.operands.stack_hetero` — every row carries
    its own (padded) event tables, so one vmapped dispatch can mix rows
    from many SimGraphs.  The depth-dependent operand computation mirrors
    :func:`~repro.core.backends.operands.depth_operands` with per-row
    gathers (``take_along_axis`` instead of closed-over tables); the two
    are cross-validated in ``tests/test_campaign.py``.

    Returns ``call(batch) -> (latency i64, bram i64, status i8)``; the
    jit cache is keyed on the batch shape, so callers should bucket the
    total row count (see ``HeteroDispatcher``).

    ``mesh`` shards the packed row batch over the mesh's devices — since
    every row carries its own event tables, the stacked batch is sharded
    leaf-by-leaf along rows with zero replication or collectives.  Rows
    are stacked design-major, so on a 2-D ``("design", "eval")`` campaign
    mesh contiguous design blocks land on contiguous device groups.  The
    (bucketed) row count must be a multiple of the mesh size.
    """

    def run(b):
        d = b["depths"].astype(jnp.int32)              # (C, F*)
        w = b["widths"].astype(jnp.int32)              # (C, F*)
        is_bram = ~((d <= SRL_DEPTH) | (d * w <= SRL_BITS))
        rd_lat_f = jnp.where(is_bram, float(BRAM_READ_LATENCY),
                             float(SRL_READ_LATENCY))
        fifo = b["fifo"].astype(jnp.int32)             # (C, E*)
        rd_lat_e = jnp.take_along_axis(rd_lat_f, fifo, axis=1)
        d_e = jnp.take_along_axis(d, fifo, axis=1)
        bp_pos = b["rank"].astype(jnp.int32) - d_e
        is_write = b["is_write"]
        overrun = is_write & (bp_pos >= b["evt_n_reads"])
        structural = jnp.any(overrun, axis=1)          # (C,)
        bp_valid = (is_write & (bp_pos >= 0) & ~overrun
                    ).astype(jnp.float32)
        flat = jnp.clip(b["evt_read_base"] + bp_pos, 0,
                        b["n_flat_reads"][:, None] - 1)
        bp_idx = jnp.take_along_axis(
            b["read_evt_flat"].astype(jnp.int32), flat, axis=1)
        out = fifo_eval_ref_hetero(
            b["delta"], b["seg_start"], b["is_read"], b["has_data"],
            b["data_idx"].astype(jnp.int32), b["end_bonus"],
            rd_lat_e, bp_idx, bp_valid, b["bound"], max_iters=max_iters)
        lat = jnp.maximum(out[:, 0], b["taskless"])
        conv = out[:, 1] > 0
        over = out[:, 2] > 0
        status = jnp.where(
            structural | over, DEADLOCK,
            jnp.where(conv, CONVERGED, UNRESOLVED)).astype(jnp.int8)
        bram = jnp.sum(bram_count_jnp(d, w), axis=1).astype(jnp.int32)
        return lat, bram, status

    if mesh is not None:
        run = _shard_over_rows(run, mesh)
    run = jax.jit(run)

    def call(batch: dict) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        DISPATCH_COUNTS["hetero"] += 1
        lat, bram, status = jax.device_get(
            run({k: jnp.asarray(v) for k, v in batch.items()}))
        lat = np.asarray(np.rint(lat), dtype=np.int64)
        return lat, np.asarray(bram, dtype=np.int64), np.asarray(status)

    return call
