"""jit'd wrapper around the fifo_eval fixpoint implementations.

Consumes the shared lane-aligned event tensors from
:mod:`repro.core.backends.operands` (built once per graph) and exposes a
callable ``(C, F) int depths -> (latency, bram, status)``.  The
depth-dependent per-config operands (read latencies, back-pressure gather
indices) come from the shared :func:`~repro.core.backends.operands
.depth_operands`; only the heavy fixpoint differs between inners:

``use_ref=False``  the Pallas kernel (:mod:`repro.kernels.fifo_eval
                   .fifo_eval`), interpret mode on CPU
``use_ref=True``   the pure-jnp oracle (:mod:`repro.kernels.fifo_eval.ref`),
                   which is also the ``fixpoint`` backend's implementation

Tests diff the two against each other and against the numpy worklist.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backends.base import CONVERGED, DEADLOCK, UNRESOLVED
from repro.core.backends.operands import (bram_count_jnp, depth_operands,
                                          get_operands)
from repro.core.simgraph import SimGraph
from repro.kernels.fifo_eval.fifo_eval import fifo_eval_pallas
from repro.kernels.fifo_eval.ref import fifo_eval_ref


def make_batched_eval(ev_or_graph, interpret: bool = True,
                      use_ref: bool = False,
                      max_iters: int = None) -> Callable:
    """Build the batched evaluation closure for a SimGraph.

    Accepts either a :class:`~repro.core.simgraph.SimGraph` or any object
    with ``.g`` / ``.max_iters`` (e.g. a ``BatchedEvaluator``).
    """
    g: SimGraph = getattr(ev_or_graph, "g", ev_or_graph)
    if max_iters is None:
        max_iters = getattr(ev_or_graph, "max_iters", 64)
    max_iters = int(max_iters)
    ops = get_operands(g)

    inner = fifo_eval_ref if use_ref else functools.partial(
        fifo_eval_pallas, interpret=interpret)

    @jax.jit
    def run(depths):                     # (C, F) int32
        rd_lat_e, bp_idx, bp_valid, structural = depth_operands(ops, depths)
        out = inner(ops.delta, ops.seg_start, ops.is_read,
                    ops.has_data, ops.data_idx, ops.end_bonus,
                    rd_lat_e, bp_idx, bp_valid,
                    max_iters=max_iters, bound=ops.bound)
        lat = jnp.maximum(out[:, 0], ops.taskless_lat)
        conv = out[:, 1] > 0
        over = out[:, 2] > 0
        status = jnp.where(
            structural | over, DEADLOCK,
            jnp.where(conv, CONVERGED, UNRESOLVED)).astype(jnp.int8)
        bram = jnp.sum(bram_count_jnp(depths.astype(jnp.int32),
                                      ops.widths[None, :]),
                       axis=1).astype(jnp.int32)
        return lat, bram, status

    def call(depth_matrix: np.ndarray
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lat, bram, status = jax.device_get(
            run(jnp.asarray(depth_matrix, dtype=jnp.int32)))
        return lat, bram, status

    return call
