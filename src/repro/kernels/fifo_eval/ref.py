"""Pure-jnp oracle for the fifo_eval Pallas kernel.

Implements the identical fixpoint (Jacobi over cross edges, segmented
max-plus inclusive scan for intra-task chains) with stock jnp ops —
``lax.associative_scan`` instead of the kernel's hand-rolled Hillis-Steele
doubling, and a plain ``lax.while_loop``.  Any disagreement between this
and the kernel (beyond float-identical results — both are exact integer
arithmetic in f32) is a kernel bug; tests sweep shapes and designs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG = jnp.float32(-1e9)


def _combine(x, y):
    a1, m1 = x
    a2, m2 = y
    return a1 + a2, jnp.maximum(m1 + a2, m2)


def fifo_eval_ref_hetero(
    delta: jnp.ndarray, segst: jnp.ndarray, is_read: jnp.ndarray,
    has_data: jnp.ndarray, data_idx: jnp.ndarray, end_bonus: jnp.ndarray,
    rd_lat: jnp.ndarray, bp_idx: jnp.ndarray, bp_valid: jnp.ndarray,
    bound: jnp.ndarray, *, max_iters: int,
) -> jnp.ndarray:
    """Cross-design variant of :func:`fifo_eval_ref`: every operand is
    per-row (each row may come from a *different* SimGraph padded to a
    shared ``E*`` envelope), and the deadlock bound is a (C,) vector.
    Returns (C, 4): [latency, converged, over_bound, iters] per row."""

    def one(delta_r, segst_r, is_read_r, has_data_r, data_idx_r,
            end_bonus_r, rd_lat_r, bp_idx_r, bp_valid_r, bound_r):
        a_base = jnp.where(segst_r > 0, NEG, delta_r)

        def step(t):
            bd = jnp.where(has_data_r > 0, t[data_idx_r] + rd_lat_r, NEG)
            bb = jnp.where(bp_valid_r > 0, t[bp_idx_r] + 1.0, NEG)
            b = jnp.where(is_read_r > 0, bd, bb)
            m = jnp.where(segst_r > 0, jnp.maximum(b, delta_r), b)
            A, M = lax.associative_scan(_combine, (a_base, m))
            return jnp.maximum(A, M)

        def cond(state):
            t, it, conv = state
            return (~conv) & (it < max_iters) & (jnp.max(t) <= bound_r)

        def body(state):
            t, it, _ = state
            t2 = step(t)
            return t2, it + 1, jnp.all(t2 == t)

        t0 = jnp.zeros(delta_r.shape[0], dtype=jnp.float32)
        t, iters, conv = lax.while_loop(
            cond, body, (step(t0), jnp.int32(1), jnp.bool_(False)))
        latency = jnp.max(t + end_bonus_r)
        over = jnp.max(t) > bound_r
        return jnp.stack([latency, conv.astype(jnp.float32),
                          over.astype(jnp.float32),
                          iters.astype(jnp.float32)])

    return jax.vmap(one)(delta, segst, is_read, has_data, data_idx,
                         end_bonus, rd_lat, bp_idx, bp_valid, bound)


def fifo_eval_ref(
    delta: jnp.ndarray, segst: jnp.ndarray, is_read: jnp.ndarray,
    has_data: jnp.ndarray, data_idx: jnp.ndarray, end_bonus: jnp.ndarray,
    rd_lat: jnp.ndarray, bp_idx: jnp.ndarray, bp_valid: jnp.ndarray,
    bp_base: jnp.ndarray, *, max_iters: int, bound: float,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same signature/semantics as fifo_eval_pallas; returns a (C, 4)
    [latency, converged, over_bound, iters] row per config plus the
    final (C, E) event times (condensed-graph callers certify the
    solution against them; jit dead-code-eliminates the times when the
    caller discards them).  ``bp_base`` is the additive back-pressure
    term (1.0 on raw graphs, 1.0 + anchor offset on condensed ones)."""

    def one(rd_lat_c, bp_idx_c, bp_valid_c, bp_base_c):
        a_base = jnp.where(segst[0] > 0, NEG, delta[0])

        def step(t):
            bd = jnp.where(has_data[0] > 0,
                           t[data_idx[0]] + rd_lat_c, NEG)
            bb = jnp.where(bp_valid_c > 0, t[bp_idx_c] + bp_base_c, NEG)
            b = jnp.where(is_read[0] > 0, bd, bb)
            m = jnp.where(segst[0] > 0, jnp.maximum(b, delta[0]), b)
            A, M = lax.associative_scan(_combine, (a_base, m))
            return jnp.maximum(A, M)

        def cond(state):
            t, it, conv = state
            return (~conv) & (it < max_iters) & (jnp.max(t) <= bound)

        def body(state):
            t, it, _ = state
            t2 = step(t)
            return t2, it + 1, jnp.all(t2 == t)

        t0 = jnp.zeros(delta.shape[1], dtype=jnp.float32)
        t, iters, conv = lax.while_loop(
            cond, body, (step(t0), jnp.int32(1), jnp.bool_(False)))
        latency = jnp.max(t + end_bonus[0])
        over = jnp.max(t) > bound
        return jnp.stack([latency, conv.astype(jnp.float32),
                          over.astype(jnp.float32),
                          iters.astype(jnp.float32)]), t

    return jax.vmap(one)(rd_lat, bp_idx, bp_valid, bp_base)
