"""Batched FIFO-configuration latency evaluation (Pallas TPU kernel).

``fifo_eval.py``  pl.pallas_call kernel (BlockSpec VMEM tiling, one grid
                  program per candidate configuration).
``ops.py``        jit'd wrapper: SimGraph -> padded event tensors -> kernel.
``ref.py``        pure-jnp oracle with identical semantics.
"""

from repro.kernels.fifo_eval.fifo_eval import fifo_eval_pallas
from repro.kernels.fifo_eval.ops import make_batched_eval
from repro.kernels.fifo_eval.ref import fifo_eval_ref

__all__ = ["fifo_eval_pallas", "fifo_eval_ref", "make_batched_eval"]
