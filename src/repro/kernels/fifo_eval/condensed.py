"""Pallas TPU kernel: condensation-native fused evaluation + certificate.

The raw kernel (:mod:`repro.kernels.fifo_eval.fifo_eval`) launches one
grid program per configuration over (1, E) vectors — the right shape at
E = 8k-13k raw events.  Post-condensation the hot rungs run at
Ec = 64-512 anchors (25-150x compression), where a one-row program
wastes the vector unit and, worse, the exactness certificate
(``condense.verify_rows``) used to run on the HOST: every batch paid a
device->host transfer of the (C, E_pad) event-time matrix plus an
O(C x E_raw) int64 expansion just to decide which rows to accept.

This kernel owns the whole rung on-device:

* **condensed tiles** — each grid program evaluates a BLOCK of
  configurations over the rank-dense condensed stream: per-config
  operands arrive as (BLOCK, Ec_pad) tiles and certificate slots as
  (BLOCK, V_pad) tiles; Pallas's BlockSpec pipeline streams consecutive
  tiles through VMEM, double-buffering the HBM copies against compute.
* **per-row fixpoint** — the same Jacobi + segmented Hillis-Steele scan
  as the raw kernel, but batched over the block with per-row freezing:
  converged / over-bound rows stop updating (and stop counting
  iterations) while the rest of the block keeps stepping, so easy rows
  do not ride along for the block's worst case.
* **fused certificate** — after the fixpoint, the dropped cross
  constraints of every folded event are checked as flat gather slots
  (``t[src] - t[dst] > thr``, see
  :func:`repro.core.backends.operands.cert_row_operands`) and the
  pass/fail verdict is emitted as output lane [4].  Times never leave
  the device; a fully-certifying batch costs exactly one dispatch.

Integer times are exact in float32 below 2**24 (asserted at evaluator
construction), so the in-kernel f32 certificate is bit-for-bit equal to
the int64 host verifier — property-tested in
``tests/test_condensed_kernel.py``.

Validated in ``interpret=True`` mode on CPU (the container has no TPU);
pass ``interpret=False`` on real hardware.

Layout of the per-config output row (float32, 128 lanes):
    [0] latency  [1] converged  [2] over-bound  [3] iters  [4] certified
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.fifo_eval.fifo_eval import (NEG, OUT_LANES,
                                               _num_scan_steps)

#: default configurations per grid program.  Condensed tiles are narrow
#: (Ec_pad is 128-512 where raw graphs run 8k-13k events), so a block of
#: rows keeps the 8x128 vector registers busy and amortizes the per-grid
#: step overhead; 32 is the measured sweet spot on the benchmark rungs.
BLOCK = 32

#: VMEM working-set budget for picking a block size: ~12 live
#: (block, Ec_pad) f32 tiles (operands + fixpoint temps) plus 4
#: (block, V_pad) certificate tiles, kept well under the ~16 MB VMEM.
_VMEM_BUDGET = 12 * 2**20


def pick_block(e_pad: int, v_pad: int, block: int = BLOCK) -> int:
    """Largest power-of-two block <= ``block`` whose working set fits
    the VMEM budget (never below the 8-sublane f32 min tile)."""
    while block > 8 and (12 * e_pad + 4 * v_pad) * block * 4 > _VMEM_BUDGET:
        block //= 2
    return block


def _condensed_kernel(
    # shared (1, E) operands
    delta_ref, segst_ref, isread_ref, hasdata_ref, didx_ref, endb_ref,
    # per-config (BLOCK, E) operands
    rdlat_ref, bpidx_ref, bpval_ref, bpbase_ref,
    # per-config (BLOCK, V) certificate slots
    csrc_ref, cdst_ref, cthr_ref, cval_ref,
    # outputs: result rows, then (with_times) the final event times
    *refs,
    e_pad: int, block: int, max_iters: int, bound: float,
    with_times: bool,
):
    out_ref = refs[0]
    delta = delta_ref[...]            # (1, E) f32
    segst = segst_ref[...]            # (1, E) f32: 1.0 at segment starts
    is_read = isread_ref[...]         # (1, E) f32 mask
    has_data = hasdata_ref[...]       # (1, E) f32 mask
    data_idx = didx_ref[...]          # (1, E) i32
    end_bonus = endb_ref[...]         # (1, E) f32
    rd_lat = rdlat_ref[...]           # (B, E) f32
    bp_idx = bpidx_ref[...]           # (B, E) i32
    bp_valid = bpval_ref[...]         # (B, E) f32 mask
    bp_base = bpbase_ref[...]         # (B, E) f32

    a_base = jnp.broadcast_to(jnp.where(segst > 0, NEG, delta),
                              (block, e_pad))
    n_steps = _num_scan_steps(e_pad)

    def seg_scan(a, m):
        # inclusive max-plus scan, Hillis-Steele doubling (static shifts)
        for s in range(n_steps):
            sh = 1 << s
            a_prev = jnp.pad(a, ((0, 0), (sh, 0)),
                             constant_values=0.0)[:, :e_pad]
            m_prev = jnp.pad(m, ((0, 0), (sh, 0)),
                             constant_values=NEG)[:, :e_pad]
            m = jnp.maximum(m_prev + a, m)
            a = a_prev + a
        return a, m

    def step(t):                      # (B, E) -> (B, E)
        td = jnp.take(t, data_idx[0], axis=1)         # shared data edges
        bd = jnp.where(has_data > 0, td + rd_lat, NEG)
        tb = jnp.take_along_axis(t, bp_idx, axis=1)   # per-row bp edges
        bb = jnp.where(bp_valid > 0, tb + bp_base, NEG)
        b = jnp.where(is_read > 0, bd, bb)
        m = jnp.where(segst > 0, jnp.maximum(b, delta), b)
        A, M = seg_scan(a_base, m)
        return jnp.maximum(A, M)

    def cond(state):
        t, it, conv, over = state
        return jnp.any(~conv & ~over) & (it < max_iters)

    def body(state):
        # per-row freezing: finished rows (converged or past the bound)
        # keep their times and flags while active rows step
        t, it, conv, over = state
        active = ~conv & ~over                        # (B,)
        t2 = jnp.where(active[:, None], step(t), t)
        conv = conv | (active & jnp.all(t2 == t, axis=1))
        over = over | (active & (jnp.max(t2, axis=1) > bound))
        return t2, it + 1, conv, over

    t0 = jnp.zeros((block, e_pad), dtype=jnp.float32)
    flags0 = jnp.zeros((block,), dtype=jnp.bool_)
    t, iters, conv, over = lax.while_loop(
        cond, body, body((t0, jnp.int32(0), flags0, flags0)))

    # fused exactness certificate: slot v of row c is violated iff
    # valid and t[src] - t[dst] > thr (all-integer f32, exact < 2**24)
    csrc = csrc_ref[...]              # (B, V) i32
    cdst = cdst_ref[...]              # (B, V) i32
    cthr = cthr_ref[...]              # (B, V) f32
    cval = cval_ref[...]              # (B, V) f32 mask
    ts = jnp.take_along_axis(t, csrc, axis=1)
    td = jnp.take_along_axis(t, cdst, axis=1)
    viol = (cval > 0) & (ts - td > cthr)
    cert = conv & ~over & ~jnp.any(viol, axis=1)      # (B,)

    latency = jnp.max(t + end_bonus, axis=1)
    row = jnp.stack(
        [latency,
         conv.astype(jnp.float32),
         over.astype(jnp.float32),
         jnp.full((block,), iters, dtype=jnp.float32),
         cert.astype(jnp.float32)], axis=1)           # (B, 5)
    out_ref[...] = jnp.pad(row, ((0, 0), (0, OUT_LANES - 5)))
    if with_times:
        refs[1][...] = t


def fifo_eval_condensed(
    delta: jnp.ndarray, segst: jnp.ndarray, is_read: jnp.ndarray,
    has_data: jnp.ndarray, data_idx: jnp.ndarray, end_bonus: jnp.ndarray,
    rd_lat: jnp.ndarray, bp_idx: jnp.ndarray, bp_valid: jnp.ndarray,
    bp_base: jnp.ndarray, cert_src: jnp.ndarray, cert_dst: jnp.ndarray,
    cert_thr: jnp.ndarray, cert_valid: jnp.ndarray, *,
    max_iters: int, bound: float, block: int = BLOCK,
    interpret: bool = True, with_times: bool = False,
):
    """Launch the fused kernel.

    Shared operands are (1, E); per-config operands (C, E); certificate
    slots (C, V).  E and V must be multiples of 128 and C a multiple of
    ``block`` (the wrapper in ``kernels/fifo_eval/ops.py`` pads).
    Returns (C, OUT_LANES) f32 result rows ([4] = certificate verdict),
    plus the final (C, E) event times when ``with_times``.
    """
    C, e_pad = rd_lat.shape
    v_pad = cert_src.shape[1]
    assert e_pad % 128 == 0 and v_pad % 128 == 0, \
        "pad events and certificate slots to a lane multiple"
    assert C % block == 0, "pad the config batch to a block multiple"
    kernel = functools.partial(
        _condensed_kernel, e_pad=e_pad, block=block, max_iters=max_iters,
        bound=bound, with_times=with_times)
    shared = pl.BlockSpec((1, e_pad), lambda i: (0, 0))
    percfg = pl.BlockSpec((block, e_pad), lambda i: (i, 0))
    certsp = pl.BlockSpec((block, v_pad), lambda i: (i, 0))
    out_specs = [pl.BlockSpec((block, OUT_LANES), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((C, OUT_LANES), jnp.float32)]
    if with_times:
        out_specs.append(pl.BlockSpec((block, e_pad), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((C, e_pad), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=(C // block,),
        in_specs=[shared] * 6 + [percfg] * 4 + [certsp] * 4,
        out_specs=out_specs if with_times else out_specs[0],
        out_shape=out_shape if with_times else out_shape[0],
        interpret=interpret,
    )(delta, segst, is_read, has_data, data_idx, end_bonus,
      rd_lat, bp_idx, bp_valid, bp_base,
      cert_src, cert_dst, cert_thr, cert_valid)
    if with_times:
        rows, times = out
        return rows, times
    return out, None
