"""The Stream-HLS benchmark suite (paper Tables II/III), re-derived.

24 designs: the 21 of Table II plus ``gesummv``, ``k7mmtree_balanced`` and
``ResMLP`` from Table III.  Task-graph *structures* follow the published
kernels (PolyBench linear algebra + small DNN blocks lowered to dataflow);
trip counts are scaled down so every design traces in milliseconds and
keeps its schedule inside the evaluator's float32-exact domain (DESIGN.md
§8 records this deviation — all relative paper claims are preserved).

Each factory returns a fresh :class:`~repro.core.design.Design`; the
registry ``STREAMHLS_DESIGNS`` maps name -> factory.
"""

from __future__ import annotations

from typing import Callable, Dict, List

from repro.core.design import Design
from repro.designs.builder import (buffered_matmul_stage, conv_stage,
                                   fork_stage, join_stage, map_stage,
                                   matmul_stage, matvec_stage, producer,
                                   sink, streams)


def _vals(n: int, seed: int = 1) -> List[float]:
    """Deterministic pseudo-random input values (affect only functional
    checks for these static-control designs)."""
    out = []
    x = seed * 2654435761 % 2**32
    for _ in range(n):
        x = (1103515245 * x + 12345) % 2**31
        out.append((x % 1000) / 500.0 - 1.0)
    return out


_relu = lambda v: v if v > 0 else 0.0


# ---------------------------------------------------------------------------
# PolyBench linear algebra
# ---------------------------------------------------------------------------

def gemm(m: int = 32, k: int = 32, n: int = 32, lanes: int = 8) -> Design:
    """C = alpha*A@B + beta*C."""
    d = Design("gemm")
    a = streams(d, "a", lanes)
    c_in = streams(d, "c_in", lanes)
    ab = streams(d, "ab", lanes)
    c_out = streams(d, "c_out", lanes)
    producer(d, "load_a", a, _vals(m * k))
    producer(d, "load_c", c_in, _vals(m * n, seed=2))
    matmul_stage(d, "mm", a, ab, m, k, n)
    join_stage(d, "scale_add", ab, c_in, c_out, m * n,
               fn=lambda x, y: 1.5 * x + 1.2 * y)
    sink(d, "store_c", c_out, m * n, result_key="C")
    return d


def atax(m: int = 96, n: int = 96, lanes: int = 2) -> Design:
    """y = A^T (A x)."""
    d = Design("atax")
    x = streams(d, "x", lanes)
    tmp = streams(d, "tmp", lanes)
    y = streams(d, "y", lanes)
    producer(d, "load_x", x, _vals(n))
    matvec_stage(d, "ax", x, tmp, rows=m, cols=n, reuse_input=True)
    matvec_stage(d, "aty", tmp, y, rows=n, cols=m, reuse_input=True)
    sink(d, "store_y", y, n, result_key="y")
    return d


def bicg(m: int = 96, n: int = 96, lanes: int = 2) -> Design:
    """s = A^T r ; q = A p (two independent streaming matvecs)."""
    d = Design("bicg")
    r = streams(d, "r", lanes)
    p = streams(d, "p", lanes)
    s = streams(d, "s", lanes)
    q = streams(d, "q", lanes)
    producer(d, "load_r", r, _vals(m))
    producer(d, "load_p", p, _vals(n, seed=2))
    matvec_stage(d, "at_r", r, s, rows=n, cols=m, reuse_input=True)
    matvec_stage(d, "a_p", p, q, rows=m, cols=n, reuse_input=True)
    sink(d, "store_s", s, n, result_key="s")
    sink(d, "store_q", q, m, result_key="q")
    return d


def mvt(n: int = 96, lanes: int = 2) -> Design:
    """x1 += A y1 ; x2 += A^T y2."""
    d = Design("mvt")
    y1 = streams(d, "y1", lanes)
    y2 = streams(d, "y2", lanes)
    t1 = streams(d, "t1", lanes)
    t2 = streams(d, "t2", lanes)
    x1i = streams(d, "x1_in", lanes)
    x2i = streams(d, "x2_in", lanes)
    x1o = streams(d, "x1_out", lanes)
    x2o = streams(d, "x2_out", lanes)
    producer(d, "load_y1", y1, _vals(n))
    producer(d, "load_y2", y2, _vals(n, seed=2))
    producer(d, "load_x1", x1i, _vals(n, seed=3))
    producer(d, "load_x2", x2i, _vals(n, seed=4))
    matvec_stage(d, "a_y1", y1, t1, rows=n, cols=n, reuse_input=True)
    matvec_stage(d, "at_y2", y2, t2, rows=n, cols=n, reuse_input=True)
    join_stage(d, "add_x1", x1i, t1, x1o, n)
    join_stage(d, "add_x2", x2i, t2, x2o, n)
    sink(d, "store_x1", x1o, n, result_key="x1")
    sink(d, "store_x2", x2o, n, result_key="x2")
    return d


def gesummv(n: int = 96, lanes: int = 2) -> Design:
    """y = alpha*A@x + beta*B@x."""
    d = Design("gesummv")
    x = streams(d, "x", lanes)
    xa = streams(d, "xa", lanes)
    xb = streams(d, "xb", lanes)
    ta = streams(d, "ta", lanes)
    tb = streams(d, "tb", lanes)
    y = streams(d, "y", lanes)
    producer(d, "load_x", x, _vals(n))
    fork_stage(d, "dup_x", x, xa, xb, n)
    matvec_stage(d, "a_x", xa, ta, rows=n, cols=n, reuse_input=True)
    matvec_stage(d, "b_x", xb, tb, rows=n, cols=n, reuse_input=True)
    join_stage(d, "sum", ta, tb, y, n,
               fn=lambda a, b: 1.5 * a + 1.2 * b)
    sink(d, "store_y", y, n, result_key="y")
    return d


# ---------------------------------------------------------------------------
# matmul chains / trees (k2mm .. k15mm*)
# ---------------------------------------------------------------------------

def _kmm_seq(name: str, dims: List[int], lanes: int = 4,
             relu: bool = False) -> Design:
    """Chain of len(dims)-1 matmuls: X(m0 x m1) @ W1(m1 x m2) @ ..."""
    d = Design(name)
    m0 = dims[0]
    cur = streams(d, "x0", lanes)
    producer(d, "load_x0", cur, _vals(m0 * dims[1]))
    for s in range(1, len(dims) - 1):
        k, n = dims[s], dims[s + 1]
        out = streams(d, f"x{s}", lanes)
        matmul_stage(d, f"mm{s}", cur, out, m=m0, k=k, n=n)
        if relu and s < len(dims) - 2:
            ract = streams(d, f"r{s}", lanes)
            map_stage(d, f"relu{s}", out, ract, m0 * n, fn=_relu)
            out = ract
        cur = out
    sink(d, "store", cur, m0 * dims[-1], result_key="out")
    return d


def _kmm_tree(name: str, n_leaves: int, chain: List[int],
              inner: List[int], lanes: int = 4,
              relu: bool = False, b_col_order: bool = True) -> Design:
    """Balanced reduction tree over a matrix chain product: leaf t computes
    X_t @ W_t with X_t of shape (chain[t] x inner[t]) and W_t local of
    shape (inner[t] x chain[t+1]); pairs are combined bottom-up (left
    operand streamed, right operand buffered).  n_leaves*2-1 matmuls total
    (8 leaves -> k15mm, 4 leaves -> k7mm).  ``chain`` adjacency guarantees
    every tree node's operand shapes are compatible."""
    assert len(chain) == n_leaves + 1 and len(inner) >= n_leaves
    d = Design(name)
    level: List = []
    for i in range(n_leaves):
        m, k, n = chain[i], inner[i], chain[i + 1]
        src = streams(d, f"in{i}", lanes)
        out = streams(d, f"l0_{i}", lanes)
        producer(d, f"load{i}", src, _vals(m * k, seed=i + 1))
        matmul_stage(d, f"leaf{i}", src, out, m=m, k=k, n=n)
        level.append((out, m, n))
    lvl = 1
    while len(level) > 1:
        nxt = []
        for j in range(0, len(level), 2):
            (a, ma, na), (b, mb, nb) = level[j], level[j + 1]
            out = streams(d, f"l{lvl}_{j // 2}", lanes)
            # combine: A (ma x na) streamed, B (mb x nb) buffered
            buffered_matmul_stage(d, f"node{lvl}_{j // 2}", a, b, out,
                                  m=ma, k=na, n=nb, b_col_order=b_col_order)
            cur = (out, ma, nb)
            if relu and len(level) > 2:
                ract = streams(d, f"lr{lvl}_{j // 2}", lanes)
                map_stage(d, f"relu{lvl}_{j // 2}", out, ract, ma * nb,
                          fn=_relu)
                cur = (ract, ma, nb)
            nxt.append(cur)
        level = nxt
        lvl += 1
    out, m, n = level[0]
    sink(d, "store", out, m * n, result_key="out")
    return d


# Balanced: every chain/inner dim equal -> all stream rates match.
_CH8_BAL, _IN8_BAL = [24] * 9, [24] * 8
_CH4_BAL, _IN4_BAL = [24] * 5, [24] * 4
# Imbalanced: uneven chain dims -> producer/consumer rate mismatches.
_CH8_IMB = [28, 12, 32, 16, 24, 18, 22, 12, 28]
_IN8_IMB = [16, 30, 12, 24, 18, 28, 16, 22]
_CH4_IMB = [28, 12, 32, 16, 24]
_IN4_IMB = [16, 30, 12, 24]


def k2mm() -> Design:
    return _kmm_seq("k2mm", [24, 24, 24, 24], lanes=4)


def k3mm() -> Design:
    return _kmm_seq("k3mm", [24, 24, 24, 24, 24], lanes=4)


def k7mmseq_balanced() -> Design:
    return _kmm_seq("k7mmseq_balanced", [20] * 8)


def k7mmseq_unbalanced() -> Design:
    return _kmm_seq("k7mmseq_unbalanced", [20, 28, 10, 32, 14, 24, 16, 20])


def k7mmtree_balanced() -> Design:
    return _kmm_tree("k7mmtree_balanced", 4, _CH4_BAL, _IN4_BAL,
                     b_col_order=False)


def k7mmtree_unbalanced() -> Design:
    return _kmm_tree("k7mmtree_unbalanced", 4, _CH4_IMB, _IN4_IMB,
                     b_col_order=False)


def k15mmseq() -> Design:
    return _kmm_seq("k15mmseq", [16] * 16)


def k15mmseq_imbalanced() -> Design:
    return _kmm_seq("k15mmseq_imbalanced",
                    [16, 22, 10, 26, 12, 20, 10, 28, 16, 12, 22, 10, 20, 16, 12, 16])


def k15mmseq_relu() -> Design:
    return _kmm_seq("k15mmseq_relu", [16] * 16, relu=True)


def k15mmseq_relu_imbalanced() -> Design:
    return _kmm_seq("k15mmseq_relu_imbalanced",
                    [16, 22, 10, 26, 12, 20, 10, 28, 16, 12, 22, 10, 20, 16, 12, 16],
                    relu=True)


def k15mmtree() -> Design:
    return _kmm_tree("k15mmtree", 8, _CH8_BAL, _IN8_BAL)


def k15mmtree_imbalanced() -> Design:
    return _kmm_tree("k15mmtree_imbalanced", 8, _CH8_IMB, _IN8_IMB)


def k15mmtree_relu() -> Design:
    return _kmm_tree("k15mmtree_relu", 8, _CH8_BAL, _IN8_BAL, relu=True)


def k15mmtree_relu_imbalanced() -> Design:
    return _kmm_tree("k15mmtree_relu_imbalanced", 8, _CH8_IMB, _IN8_IMB,
                     relu=True)


# ---------------------------------------------------------------------------
# DNN blocks
# ---------------------------------------------------------------------------

def feedforward(seq: int = 32, dim: int = 16, hidden: int = 64,
                lanes: int = 8) -> Design:
    """Transformer FFN with residual: y = x + W2 relu(W1 x)."""
    d = Design("FeedForward")
    x = streams(d, "x", lanes)
    skip = streams(d, "skip", lanes)
    main = streams(d, "main", lanes)
    h = streams(d, "h", lanes)
    hr = streams(d, "hr", lanes)
    o = streams(d, "o", lanes)
    y = streams(d, "y", lanes)
    producer(d, "load_x", x, _vals(seq * dim))
    fork_stage(d, "fork", x, skip, main, seq * dim)
    matmul_stage(d, "w1", main, h, m=seq, k=dim, n=hidden)
    map_stage(d, "relu", h, hr, seq * hidden, fn=_relu)
    matmul_stage(d, "w2", hr, o, m=seq, k=hidden, n=dim)
    join_stage(d, "residual", skip, o, y, seq * dim)
    sink(d, "store", y, seq * dim, result_key="y")
    return d


def autoencoder(seq: int = 24, dims=(32, 16, 8, 16, 32), lanes: int = 4
                ) -> Design:
    """Encoder-decoder MLP stack with ReLUs between layers."""
    d = Design("Autoencoder")
    cur = streams(d, "x", lanes)
    producer(d, "load", cur, _vals(seq * dims[0]))
    for i in range(len(dims) - 1):
        out = streams(d, f"z{i}", lanes)
        matmul_stage(d, f"fc{i}", cur, out, m=seq, k=dims[i], n=dims[i + 1])
        if i < len(dims) - 2:
            act = streams(d, f"a{i}", lanes)
            map_stage(d, f"relu{i}", out, act, seq * dims[i + 1], fn=_relu)
            cur = act
        else:
            cur = out
    sink(d, "store", cur, seq * dims[-1], result_key="y")
    return d


def residual_block(length: int = 768, taps: int = 9, lanes: int = 4
                   ) -> Design:
    """conv->relu->conv with a skip path: the skip FIFO must buffer the
    main path's latency — the canonical FIFO-sizing trap."""
    d = Design("ResidualBlock")
    x = streams(d, "x", lanes)
    skip = streams(d, "skip", lanes)
    main = streams(d, "main", lanes)
    c1 = streams(d, "c1", lanes)
    r1 = streams(d, "r1", lanes)
    c2 = streams(d, "c2", lanes)
    y = streams(d, "y", lanes)
    yr = streams(d, "yr", lanes)
    producer(d, "load", x, _vals(length))
    fork_stage(d, "fork", x, skip, main, length)
    conv_stage(d, "conv1", main, c1, length, taps)
    map_stage(d, "relu1", c1, r1, length, fn=_relu, extra_delay=1)
    conv_stage(d, "conv2", r1, c2, length, taps)
    join_stage(d, "residual", skip, c2, y, length)
    map_stage(d, "relu2", y, yr, length, fn=_relu)
    sink(d, "store", yr, length, result_key="y")
    return d


def depth_sep_conv_block(length: int = 160, channels: int = 8,
                         taps: int = 5) -> Design:
    """Depthwise (per-channel) convs feeding a pointwise 1x1 combine."""
    d = Design("DepthSepConvBlock")
    xin = streams(d, "xin", channels)
    dw = streams(d, "dw", channels)
    pw = streams(d, "pw", channels)
    y = streams(d, "y", channels)
    producer(d, "load", xin, _vals(length * channels))
    for c in range(channels):
        conv_stage(d, f"dwconv{c}", [xin[c]], [dw[c]], length, taps)

    def pointwise(ctx, dw=tuple(dw), pw=tuple(pw), n=length, C=channels):
        for i in range(n):
            acc = 0.0
            for c in range(C):
                yield ctx.delay(1)
                v = yield ctx.read(dw[c])
                acc += 0.1 * v
            for c in range(C):
                yield ctx.write(pw[c], acc)
    d.add_task("pointwise", pointwise)
    map_stage(d, "relu", pw, y, length * channels, fn=_relu)
    sink(d, "store", y, length * channels, result_key="y")
    return d


def resmlp(seq: int = 16, dim: int = 16, blocks: int = 2, lanes: int = 8
           ) -> Design:
    """Stacked MLP blocks, each with a residual skip (ResMLP-style)."""
    d = Design("ResMLP")
    cur = streams(d, "x", lanes)
    producer(d, "load", cur, _vals(seq * dim))
    for b in range(blocks):
        skip = streams(d, f"skip{b}", lanes)
        main = streams(d, f"main{b}", lanes)
        h = streams(d, f"h{b}", lanes)
        hr = streams(d, f"hr{b}", lanes)
        o = streams(d, f"o{b}", lanes)
        y = streams(d, f"y{b}", lanes)
        fork_stage(d, f"fork{b}", cur, skip, main, seq * dim)
        matmul_stage(d, f"fc{b}a", main, h, m=seq, k=dim, n=dim * 4)
        map_stage(d, f"relu{b}", h, hr, seq * dim * 4, fn=_relu)
        matmul_stage(d, f"fc{b}b", hr, o, m=seq, k=dim * 4, n=dim)
        join_stage(d, f"residual{b}", skip, o, y, seq * dim)
        cur = y
    sink(d, "store", cur, seq * dim, result_key="y")
    return d


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

STREAMHLS_DESIGNS: Dict[str, Callable[[], Design]] = {
    "atax": atax,
    "Autoencoder": autoencoder,
    "bicg": bicg,
    "DepthSepConvBlock": depth_sep_conv_block,
    "FeedForward": feedforward,
    "gemm": gemm,
    "gesummv": gesummv,
    "k2mm": k2mm,
    "k3mm": k3mm,
    "k7mmseq_balanced": k7mmseq_balanced,
    "k7mmseq_unbalanced": k7mmseq_unbalanced,
    "k7mmtree_balanced": k7mmtree_balanced,
    "k7mmtree_unbalanced": k7mmtree_unbalanced,
    "k15mmseq": k15mmseq,
    "k15mmseq_imbalanced": k15mmseq_imbalanced,
    "k15mmseq_relu": k15mmseq_relu,
    "k15mmseq_relu_imbalanced": k15mmseq_relu_imbalanced,
    "k15mmtree": k15mmtree,
    "k15mmtree_imbalanced": k15mmtree_imbalanced,
    "k15mmtree_relu": k15mmtree_relu,
    "k15mmtree_relu_imbalanced": k15mmtree_relu_imbalanced,
    "mvt": mvt,
    "ResidualBlock": residual_block,
    "ResMLP": resmlp,
}

TABLE_II_DESIGNS = [n for n in STREAMHLS_DESIGNS
                    if n not in ("gesummv", "k7mmtree_balanced", "ResMLP")]

#: representative fast subset shared by the benchmarks (FULL=1 runs
#: everything) and the campaign CLI's ``--designs fast``
FAST_DESIGNS = ("atax", "gemm", "gesummv", "FeedForward", "Autoencoder",
                "k7mmtree_balanced", "k15mmseq", "k15mmtree",
                "ResidualBlock", "mvt")

#: CI smoke pair (QUICK=1 / the campaign CLI's ``--designs quick``)
QUICK_DESIGNS = ("gemm", "FeedForward")


def make_design(name: str) -> Design:
    return STREAMHLS_DESIGNS[name]()
