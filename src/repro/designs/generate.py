"""Seeded random design generator for design-space fuzzing.

Generates Stream-HLS-like affine loader/compute/store pipelines (via the
stage builders in :mod:`repro.designs.builder`) interleaved with
data-dependent control-flow motifs in the style of
:mod:`repro.designs.ddcf` — value-dependent branches, phase-alternating
producers, run-length expanders — so the evaluator stack can be stressed
far beyond the hand-written benchmark suite.

Every generated design is described by a fully *serializable*
:class:`DesignSpec` (plain ints/floats/strings), which buys three things
at once:

* **determinism** — ``build_design(spec)`` always reconstructs the same
  :class:`~repro.core.design.Design`;
* **shrinking** — a mismatch found by the fuzzer is minimized by
  structural reductions over the spec (:func:`shrink_spec`), not over
  opaque Python closures;
* **corpus files** — minimal reproducing specs serialize to JSON and are
  replayed by CI as regression tests (``docs/fuzzing.md``).

Every design also carries a **numpy functional reference**: the expected
value stream is computed stage by stage with plain numpy while the design
is being built, so the functional outputs recorded by the tracer and the
oracle (``ctx.result``) can be checked against an independent model.

Grammar (see ``docs/fuzzing.md`` for the full write-up)::

    design  := source stage* sink
    source  := plain(n, lanes, ii, start_delay)      # memory loader
             | phase(n, lanes)                       # mult_by_2-style DDCF
    stage   := map(fn, ii, extra_delay)              # elementwise
             | conv(taps, ii)                        # sliding window
             | residual(fn, ii)                      # fork + map + join
             | matvec(rows, ii, row_overhead)        # count-changing
             | expand(ii)                            # DDCF run-length
             | router(ii)                            # DDCF value branch
"""

from __future__ import annotations

import dataclasses
import json
import random
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.design import Design
from repro.designs.builder import (conv_stage, fork_stage, join_stage,
                                   map_stage, matvec_stage, producer, sink,
                                   streams)

__all__ = [
    "DesignSpec", "GeneratedDesign", "StageSpec", "build_design",
    "generate_design", "shrink_spec", "spec_from_seed",
]

#: elementwise functions usable by map/residual stages, by name (specs
#: store the name so they stay JSON-serializable)
MAP_FNS: Dict[str, Callable[[float], float]] = {
    "relu": lambda v: v if v > 0 else 0.0,
    "halve": lambda v: 0.5 * v,
    "offset": lambda v: v + 0.25,
    "negate": lambda v: -v,
}

_MAP_FNS_NP: Dict[str, Callable[[np.ndarray], np.ndarray]] = {
    "relu": lambda v: np.maximum(v, 0.0),
    "halve": lambda v: 0.5 * v,
    "offset": lambda v: v + 0.25,
    "negate": lambda v: -v,
}

STAGE_KINDS = ("map", "conv", "residual", "matvec", "expand", "router")


@dataclasses.dataclass
class StageSpec:
    """One pipeline stage: a kind from the grammar plus its parameters."""

    kind: str
    params: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, object]:
        return {"kind": self.kind, "params": dict(self.params)}

    @staticmethod
    def from_json(obj: Dict[str, object]) -> "StageSpec":
        return StageSpec(kind=str(obj["kind"]),
                         params=dict(obj.get("params", {})))


@dataclasses.dataclass
class DesignSpec:
    """Serializable description of one generated design.

    ``seed`` drives only the input *values*; the structure is entirely
    explicit, so shrinking can edit it field by field.
    """

    seed: int
    n: int                      # source token count
    lanes: int                  # stream-array width for affine stages
    ii: int                     # source initiation interval
    start_delay: int            # source start offset (cycles)
    source: str                 # "plain" | "phase"
    stages: List[StageSpec] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict[str, object]:
        return {
            "seed": self.seed, "n": self.n, "lanes": self.lanes,
            "ii": self.ii, "start_delay": self.start_delay,
            "source": self.source,
            "stages": [s.to_json() for s in self.stages],
        }

    @staticmethod
    def from_json(obj: Dict[str, object]) -> "DesignSpec":
        return DesignSpec(
            seed=int(obj["seed"]), n=int(obj["n"]), lanes=int(obj["lanes"]),
            ii=int(obj["ii"]), start_delay=int(obj["start_delay"]),
            source=str(obj["source"]),
            stages=[StageSpec.from_json(s) for s in obj.get("stages", [])])

    @property
    def affine_only(self) -> bool:
        """True when every stage is affine (static trip counts and FIFO
        access order fixed at build time): a plain source and no
        ``expand``/``router`` stages.  On these designs the analytical
        channel bounds (:mod:`repro.core.bounds`) are closed-form and
        exact — the fuzz ``bounds`` mode asserts it."""
        return (self.source == "plain"
                and all(s.kind not in ("expand", "router")
                        for s in self.stages))

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=1, sort_keys=True)

    @staticmethod
    def loads(text: str) -> "DesignSpec":
        return DesignSpec.from_json(json.loads(text))


@dataclasses.dataclass
class GeneratedDesign:
    """A built design plus its independently computed expected outputs."""

    spec: DesignSpec
    design: Design
    expected: Dict[str, float]   # result key -> numpy-reference value

    def check_results(self, results: Dict[str, float],
                      rtol: float = 1e-8, atol: float = 1e-9) -> bool:
        """True when ``results`` (from the tracer or the oracle) matches
        the numpy reference on every expected key."""
        for key, want in self.expected.items():
            got = results.get(key)
            if got is None:
                return False
            if not np.isclose(float(got), want, rtol=rtol, atol=atol):
                return False
        return True


# ---------------------------------------------------------------------------
# seed -> spec
# ---------------------------------------------------------------------------

def spec_from_seed(seed: int, quick: bool = False) -> DesignSpec:
    """Derive a :class:`DesignSpec` deterministically from ``seed``.

    ``quick`` shrinks token counts and stage counts so CI-bounded fuzz
    campaigns stay within their time budget.
    """
    rng = random.Random(seed * 2654435761 + 17)
    n = rng.randrange(6, 25) if quick else rng.randrange(8, 65)
    lanes = rng.choice((1, 2, 4))
    ii = rng.choice((1, 1, 2))
    start_delay = rng.choice((0, 0, 1, 4, 8))
    source = "phase" if rng.random() < 0.3 else "plain"
    n_stages = rng.randrange(1, 4) if quick else rng.randrange(1, 5)
    stages: List[StageSpec] = []
    for _ in range(n_stages):
        kind = rng.choices(
            STAGE_KINDS, weights=(25, 20, 15, 10, 15, 15))[0]
        if kind == "map":
            stages.append(StageSpec("map", {
                "fn": rng.choice(sorted(MAP_FNS)),
                "ii": rng.choice((1, 2)),
                "extra_delay": rng.choice((0, 0, 1, 3)),
            }))
        elif kind == "conv":
            stages.append(StageSpec("conv", {
                "taps": rng.choice((3, 5)),
                "ii": rng.choice((1, 2)),
            }))
        elif kind == "residual":
            stages.append(StageSpec("residual", {
                "fn": rng.choice(sorted(MAP_FNS)),
                "ii": rng.choice((1, 2)),
            }))
        elif kind == "matvec":
            stages.append(StageSpec("matvec", {
                "rows": rng.randrange(4, 13) if quick
                else rng.randrange(4, 33),
                "ii": 1,
                "row_overhead": rng.choice((0, 2, 4)),
            }))
        elif kind == "expand":
            stages.append(StageSpec("expand", {"ii": rng.choice((1, 2))}))
        else:  # router
            stages.append(StageSpec("router", {"ii": rng.choice((1, 2))}))
    return DesignSpec(seed=seed, n=n, lanes=lanes, ii=ii,
                      start_delay=start_delay, source=source, stages=stages)


def _source_values(spec: DesignSpec) -> np.ndarray:
    """Deterministic input values in [-1, 1) (exact dyadic floats, so the
    python-loop design arithmetic and the numpy reference agree bit for
    bit)."""
    rng = random.Random(spec.seed ^ 0x5EED)
    return np.asarray([rng.randrange(-512, 512) / 512.0
                       for _ in range(max(spec.n, 1))], dtype=np.float64)


# ---------------------------------------------------------------------------
# DDCF stage task programs (lane-1 streams; affine stages carry the lanes)
# ---------------------------------------------------------------------------

def _phase_source(d: Design, out, a_vals: Sequence[float],
                  b_vals: Sequence[float]) -> None:
    """mult_by_2-style two-phase producer + alternating consumer: stream A
    is filled completely before stream B, the consumer interleaves reads —
    deadlock-free sizing of A requires knowing ``n`` at runtime."""
    pa = d.fifo("phase_a", width=32)
    pb = d.fifo("phase_b", width=32)

    def prod(ctx, a=tuple(a_vals), b=tuple(b_vals)):
        for v in a:
            yield ctx.delay(1)
            yield ctx.write(pa, v)
        for v in b:
            yield ctx.delay(1)
            yield ctx.write(pb, v)

    def cons(ctx, out=tuple(out), n=len(a_vals)):
        for i in range(n):
            yield ctx.delay(1)
            x = yield ctx.read(pa)
            y = yield ctx.read(pb)
            yield ctx.write(out[i % len(out)], x + y)

    d.add_task("phase_src", prod, data_dependent=True)
    d.add_task("phase_mix", cons, data_dependent=True)


def _expand_stage(d: Design, k: int, inp, out, count: int, ii: int) -> None:
    """DDCF run-length expander/contractor pair.

    The expander derives a per-element repeat count from the *value* it
    reads (``1 + floor(|v| * 8) % 3``), announces it on a count stream,
    and emits that many copies; the contractor's inner trip count is
    therefore known only at kernel runtime (the paper's §IV-D argument).
    """
    cnt = d.fifo(f"exp{k}_cnt", width=8)
    data = d.fifo(f"exp{k}_data", width=32)

    def expander(ctx, inp=tuple(inp), n=count, ii=ii):
        for i in range(n):
            yield ctx.delay(ii)
            v = yield ctx.read(inp[i % len(inp)])
            r = 1 + int(abs(v) * 8.0) % 3
            yield ctx.write(cnt, r)
            for _ in range(r):
                yield ctx.delay(1)
                yield ctx.write(data, v)

    def contractor(ctx, out=tuple(out), n=count):
        for i in range(n):
            yield ctx.delay(1)
            r = yield ctx.read(cnt)
            acc = 0.0
            for _ in range(r):
                v = yield ctx.read(data)
                acc += v
            yield ctx.write(out[i % len(out)], acc)

    d.add_task(f"expand{k}", expander, data_dependent=True)
    d.add_task(f"contract{k}", contractor, data_dependent=True)


def _expand_ref(vals: np.ndarray) -> np.ndarray:
    r = 1 + np.floor(np.abs(vals) * 8.0).astype(np.int64) % 3
    return vals * r


def _router_stage(d: Design, k: int, inp, out, count: int, ii: int) -> None:
    """DDCF value-dependent branch: route positives/non-positives onto two
    streams, then publish the positive count; the merger reads the count
    FIRST, so both branch FIFOs must buffer their whole partition before
    any draining starts — the branch split (and thus the minimal safe
    depths) is a property of the runtime values.
    """
    pos = d.fifo(f"rt{k}_pos", width=32)
    neg = d.fifo(f"rt{k}_neg", width=32)
    cnt = d.fifo(f"rt{k}_cnt", width=16)

    def route(ctx, inp=tuple(inp), n=count, ii=ii):
        n_pos = 0
        for i in range(n):
            yield ctx.delay(ii)
            v = yield ctx.read(inp[i % len(inp)])
            if v > 0:
                yield ctx.write(pos, v)
                n_pos += 1
            else:
                yield ctx.write(neg, v)
        yield ctx.write(cnt, n_pos)

    def merge(ctx, out=tuple(out), n=count):
        c = yield ctx.read(cnt)
        for i in range(c):
            yield ctx.delay(1)
            v = yield ctx.read(pos)
            yield ctx.write(out[i % len(out)], v)
        for i in range(n - c):
            yield ctx.delay(1)
            v = yield ctx.read(neg)
            yield ctx.write(out[(c + i) % len(out)], v)

    d.add_task(f"route{k}", route, data_dependent=True)
    d.add_task(f"merge{k}", merge, data_dependent=True)


def _router_ref(vals: np.ndarray) -> np.ndarray:
    return np.concatenate([vals[vals > 0], vals[vals <= 0]])


def _conv_ref(vals: np.ndarray, taps: int, weight: float) -> np.ndarray:
    out = np.empty_like(vals)
    for i in range(vals.shape[0]):
        out[i] = weight * float(vals[max(0, i - taps + 1): i + 1].sum())
    return out


# ---------------------------------------------------------------------------
# spec -> design + reference
# ---------------------------------------------------------------------------

def build_design(spec: DesignSpec) -> GeneratedDesign:
    """Construct the :class:`~repro.core.design.Design` for ``spec`` and,
    in lockstep, its numpy functional reference.

    The returned :class:`GeneratedDesign` carries the expected value of
    every ``ctx.result`` key the design records, computed purely with
    numpy over the known source values — never by running either
    simulation engine.
    """
    d = Design(f"fuzz_{spec.seed}")
    vals = _source_values(spec)
    lanes = max(1, spec.lanes)

    cur = streams(d, "src", lanes)
    if spec.source == "phase":
        b_vals = -0.5 * vals
        _phase_source(d, cur, vals.tolist(), b_vals.tolist())
        vals = vals + b_vals
    else:
        producer(d, "load", cur, vals.tolist(), ii=spec.ii,
                 start_delay=spec.start_delay)

    for k, st in enumerate(spec.stages):
        p = st.params
        count = vals.shape[0]
        if st.kind == "map":
            out = streams(d, f"s{k}", lanes)
            map_stage(d, f"map{k}", cur, out, count,
                      fn=MAP_FNS[str(p["fn"])], ii=int(p.get("ii", 1)),
                      extra_delay=int(p.get("extra_delay", 0)))
            vals = _MAP_FNS_NP[str(p["fn"])](vals)
        elif st.kind == "conv":
            out = streams(d, f"s{k}", lanes)
            conv_stage(d, f"conv{k}", cur, out, count,
                       taps=int(p["taps"]), weight=0.125,
                       ii=int(p.get("ii", 1)))
            vals = _conv_ref(vals, int(p["taps"]), 0.125)
        elif st.kind == "residual":
            skip = streams(d, f"s{k}_skip", lanes)
            main = streams(d, f"s{k}_main", lanes)
            mapped = streams(d, f"s{k}_map", lanes)
            out = streams(d, f"s{k}", lanes)
            fork_stage(d, f"fork{k}", cur, skip, main, count,
                       ii=int(p.get("ii", 1)))
            map_stage(d, f"rmap{k}", main, mapped, count,
                      fn=MAP_FNS[str(p["fn"])])
            join_stage(d, f"join{k}", skip, mapped, out, count)
            vals = vals + _MAP_FNS_NP[str(p["fn"])](vals)
        elif st.kind == "matvec":
            rows = int(p["rows"])
            out = streams(d, f"s{k}", lanes)
            matvec_stage(d, f"mv{k}", cur, out, rows=rows, cols=count,
                         weight=0.0625, ii=int(p.get("ii", 1)),
                         row_overhead=int(p.get("row_overhead", 2)),
                         reuse_input=True)
            vals = np.full(rows, 0.0625 * float(vals.sum()))
        elif st.kind == "expand":
            out = streams(d, f"s{k}", lanes)
            _expand_stage(d, k, cur, out, count, ii=int(p.get("ii", 1)))
            vals = _expand_ref(vals)
        elif st.kind == "router":
            out = streams(d, f"s{k}", lanes)
            _router_stage(d, k, cur, out, count, ii=int(p.get("ii", 1)))
            vals = _router_ref(vals)
        else:
            raise ValueError(f"unknown stage kind {st.kind!r}")
        cur = out

    sink(d, "store", cur, vals.shape[0], result_key="out")
    expected = {"out": float(vals.sum())}
    return GeneratedDesign(spec=spec, design=d, expected=expected)


def generate_design(seed: int, quick: bool = False) -> GeneratedDesign:
    """One-call front door: seed -> spec -> built design + reference."""
    return build_design(spec_from_seed(seed, quick=quick))


# ---------------------------------------------------------------------------
# shrinking
# ---------------------------------------------------------------------------

def _reductions(spec: DesignSpec) -> List[DesignSpec]:
    """Candidate one-step structural reductions of ``spec``, most
    aggressive first (drop whole stages before shrinking scalars)."""
    out: List[DesignSpec] = []
    for i in range(len(spec.stages)):
        r = DesignSpec.from_json(spec.to_json())
        del r.stages[i]
        out.append(r)
    if spec.n > 2:
        r = DesignSpec.from_json(spec.to_json())
        r.n = max(2, spec.n // 2)
        out.append(r)
    if spec.lanes > 1:
        r = DesignSpec.from_json(spec.to_json())
        r.lanes = 1
        out.append(r)
    if spec.source == "phase":
        r = DesignSpec.from_json(spec.to_json())
        r.source = "plain"
        out.append(r)
    if spec.start_delay or spec.ii > 1:
        r = DesignSpec.from_json(spec.to_json())
        r.start_delay, r.ii = 0, 1
        out.append(r)
    for i, st in enumerate(spec.stages):
        if st.kind == "matvec" and int(st.params["rows"]) > 2:
            r = DesignSpec.from_json(spec.to_json())
            r.stages[i].params["rows"] = max(2, int(st.params["rows"]) // 2)
            out.append(r)
    return out


def shrink_spec(spec: DesignSpec,
                still_fails: Callable[[DesignSpec], bool],
                max_steps: int = 200) -> DesignSpec:
    """Greedy structural shrink: repeatedly apply the first reduction that
    still reproduces the failure (``still_fails``) until none does.

    ``still_fails`` must treat a design that errors during build/trace as
    NOT reproducing (the shrink must preserve the original failure mode,
    not trade it for a different crash).
    """
    cur = spec
    for _ in range(max_steps):
        for cand in _reductions(cur):
            try:
                reproduced = still_fails(cand)
            except Exception:
                reproduced = False
            if reproduced:
                cur = cand
                break
        else:
            return cur
    return cur


def load_corpus_specs(paths: Sequence[str]) -> List[DesignSpec]:
    """Parse corpus JSON files (written by the fuzzer's shrink stage).

    Accepts full corpus entries (``{"spec": ...}``) and bare spec
    objects; anything else in the corpus directory is a hard error with
    the offending filename (a campaign report dropped there by mistake
    must not be silently skipped OR cryptically crash the replay).
    """
    specs = []
    for path in paths:
        with open(path) as f:
            obj = json.load(f)
        try:
            if not isinstance(obj, dict):
                raise TypeError(f"expected a JSON object, got "
                                f"{type(obj).__name__}")
            specs.append(DesignSpec.from_json(obj.get("spec", obj)))
        except (KeyError, TypeError, ValueError) as exc:
            raise ValueError(
                f"corpus file {path!r} is not a DesignSpec corpus entry "
                f"({type(exc).__name__}: {exc})") from exc
    return specs


def corpus_entry(spec: DesignSpec, note: str,
                 mismatch: Optional[Dict[str, object]] = None
                 ) -> Dict[str, object]:
    """JSON payload for one corpus file: the minimal spec + provenance."""
    out: Dict[str, object] = {"spec": spec.to_json(), "note": note}
    if mismatch is not None:
        out["mismatch"] = mismatch
    return out
