"""Designs with data-dependent control flow (paper Fig. 2 + §IV-D).

These are the designs for which the paper argues *only* runtime analysis
can size FIFOs deadlock-free: FIFO op counts and interleavings depend on
values known only at kernel runtime (the argument ``n``; the graph fed to
the GNN accelerator).
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.design import Design


def mult_by_2(n: int = 64) -> Design:
    """Paper Fig. 2, verbatim: producer fills stream x with n items, then
    stream y; consumer alternates x/y reads.  Deadlock-free sizing REQUIRES
    knowing n — static analysis cannot."""
    d = Design("mult_by_2", args={"n": n})
    d.fifo("x", width=32)
    d.fifo("y", width=32)

    @d.task("producer", data_dependent=True)
    def producer(ctx):
        n = ctx.arg("n")
        for _ in range(n):
            yield ctx.delay(1)
            yield ctx.write("x", 1)
        for _ in range(n):
            yield ctx.delay(1)
            yield ctx.write("y", 1)

    @d.task("consumer", data_dependent=True)
    def consumer(ctx):
        n = ctx.arg("n")
        s = 0
        for _ in range(n):
            yield ctx.delay(1)
            a = yield ctx.read("x")
            b = yield ctx.read("y")
            s += a + b
        ctx.result("sum", s)

    return d


# ---------------------------------------------------------------------------
# FlowGNN PNA-like accelerator
# ---------------------------------------------------------------------------

def _random_graph(n_nodes: int, n_edges: int, seed: int
                  ) -> Tuple[List[Tuple[int, int]], List[int]]:
    """Deterministic random multigraph with skewed in-degrees (hub nodes);
    edges sorted by destination (the FlowGNN gather contract).  Source
    indices remain arbitrary — that is the deadlock mechanism below.
    Returns (edges, in_degrees)."""
    x = (seed * 2654435761 + 12345) % 2**31
    edges = []
    for _ in range(n_edges):
        x = (1103515245 * x + 12345) % 2**31
        u = x % n_nodes
        x = (1103515245 * x + 12345) % 2**31
        if x % 4 == 0:   # ~25% of edges land on a small hub set
            v = (x // 7) % max(n_nodes // 16, 1)
        else:
            v = (x // 7) % n_nodes
        edges.append((u, v))
    edges.sort(key=lambda e: e[1])
    deg = [0] * n_nodes
    for _, v in edges:
        deg[v] += 1
    return edges, deg


# The three PNA aggregator kinds our model instantiates; std keeps running
# moments and is costlier per message.
_AGGS = ("mean", "max", "std")
_AGG_COST = {"mean": (1, 1), "max": (1, 1), "std": (3, 4)}


def flowgnn_pna(n_nodes: int = 64, n_edges: int = 256, lanes: int = 4,
                seed: int = 7) -> Design:
    """PNA message-passing layer in the FlowGNN dataflow style.

    node_loader streams per-node data in node order: the self-feature
    (skip path to the combine stage), the in-degree (to each aggregator —
    data-dependent trip counts), and the node's FEATURE into ``feat_q``.
    scatter walks the dest-sorted edge stream; edge (u, v) needs feature u,
    so scatter pulls ``feat_q`` forward to u — how far ahead of the
    aggregation frontier the loader must run is a property of the RUNTIME
    GRAPH (an early edge with a late source forces deep buffering).
    Undersized deg/skip queues then deadlock the engine through the cycle
    scatter -> feat_q -> node_loader -> deg_q -> aggregator -> msg ->
    scatter.  Static analysis cannot bound any of this; the paper's §IV-D
    argument.

    Declared depths model the hand-sized original accelerator (the case
    study's "user-defined Baseline-Max": generous node-count-sized control
    queues, 64-deep message lanes).
    """
    edges, deg = _random_graph(n_nodes, n_edges, seed)
    d = Design("flowgnn_pna", args={"edges": edges, "deg": deg})

    d.fifo("edges_q", width=64, depth=32)
    d.fifo("feat_q", width=256, depth=n_nodes)
    d.fifo("skip_q", width=256, depth=n_nodes)
    deg_qs = [d.fifo(f"deg_{a}", width=16, depth=n_nodes) for a in _AGGS]
    msg = {a: d.fifo_array(f"msg_{a}", lanes, width=32, depth=64)
           for a in _AGGS}
    agg = {a: d.fifo(f"agg_{a}", width=32, depth=16) for a in _AGGS}
    d.fifo("out_q", width=32, depth=16)

    @d.task("edge_loader", data_dependent=True)
    def edge_loader(ctx):
        for (u, v) in ctx.arg("edges"):
            yield ctx.delay(1)
            yield ctx.write("edges_q", (u, v))

    @d.task("node_loader", data_dependent=True)
    def node_loader(ctx):
        for v, dv in enumerate(ctx.arg("deg")):
            yield ctx.delay(1)
            yield ctx.write("skip_q", 0.001 * v)
            yield ctx.write("feat_q", 0.01 * v)
            for q in deg_qs:
                yield ctx.write(q, dv)

    @d.task("scatter", data_dependent=True)
    def scatter(ctx):
        n_e = len(ctx.arg("edges"))
        feats: List[float] = []
        for _ in range(n_e):
            yield ctx.delay(1)
            (u, v) = yield ctx.read("edges_q")
            while len(feats) <= u:           # pull features forward to u
                f = yield ctx.read("feat_q")
                feats.append(f)
            yield ctx.delay(1)
            for a in _AGGS:
                yield ctx.write(msg[a][v % lanes], feats[u] + 1.0)

    def make_aggregator(a: str, q: str):
        per_msg, epilogue = _AGG_COST[a]

        def prog(ctx, a=a, q=q, per_msg=per_msg, epilogue=epilogue):
            n_v = len(ctx.arg("deg"))
            for v in range(n_v):
                yield ctx.delay(1)
                dv = yield ctx.read(q)
                acc = 0.0
                for _ in range(dv):          # data-dependent trip count
                    m = yield ctx.read(msg[a][v % lanes])
                    yield ctx.delay(per_msg)
                    acc += m
                yield ctx.delay(epilogue)
                yield ctx.write(agg[a], acc)
        return prog

    for a in _AGGS:
        d.add_task(f"agg_{a}", make_aggregator(a, f"deg_{a}"),
                   data_dependent=True)

    @d.task("combine", data_dependent=True)
    def combine(ctx):
        n_v = len(ctx.arg("deg"))
        total = 0.0
        for _ in range(n_v):
            self_feat = yield ctx.read("skip_q")
            vals = [self_feat]
            for a in _AGGS:
                x = yield ctx.read(agg[a])
                vals.append(x)
            yield ctx.delay(6)               # per-node update MLP
            y = sum(vals) / 4.0
            total += y
            yield ctx.write("out_q", y)
        ctx.result("checksum", total)

    @d.task("store", data_dependent=True)
    def store(ctx):
        n_v = len(ctx.arg("deg"))
        for _ in range(n_v):
            yield ctx.delay(1)
            yield ctx.read("out_q")

    return d
