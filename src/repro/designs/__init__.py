"""Benchmark dataflow designs (Stream-HLS-style kernels + DDCF designs)."""

from repro.designs.streamhls import STREAMHLS_DESIGNS, make_design
from repro.designs.ddcf import flowgnn_pna, mult_by_2

__all__ = ["STREAMHLS_DESIGNS", "make_design", "flowgnn_pna", "mult_by_2"]
