"""Benchmark dataflow designs (Stream-HLS-style kernels + DDCF designs)."""

from repro.designs.streamhls import (FAST_DESIGNS, QUICK_DESIGNS,
                                     STREAMHLS_DESIGNS, make_design)
from repro.designs.ddcf import flowgnn_pna, mult_by_2

__all__ = ["FAST_DESIGNS", "QUICK_DESIGNS", "STREAMHLS_DESIGNS",
           "make_design", "flowgnn_pna", "mult_by_2"]
