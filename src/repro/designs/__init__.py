"""Benchmark dataflow designs (Stream-HLS-style kernels + DDCF designs)
plus the seeded random design generator used by the fuzzer."""

from repro.designs.streamhls import (FAST_DESIGNS, QUICK_DESIGNS,
                                     STREAMHLS_DESIGNS, make_design)
from repro.designs.ddcf import flowgnn_pna, mult_by_2
from repro.designs.generate import (DesignSpec, GeneratedDesign, StageSpec,
                                    build_design, generate_design,
                                    shrink_spec, spec_from_seed)

__all__ = ["DesignSpec", "FAST_DESIGNS", "GeneratedDesign", "QUICK_DESIGNS",
           "STREAMHLS_DESIGNS", "StageSpec", "build_design", "flowgnn_pna",
           "generate_design", "make_design", "mult_by_2", "shrink_spec",
           "spec_from_seed"]
