"""Reusable dataflow-stage builders mirroring Stream-HLS output structure.

Stream-HLS lowers affine kernels (PolyBench linear algebra, small DNN
blocks) to dataflow graphs in a recognizable shape: *loader* tasks stream
array elements from memory, *compute* tasks are pipelined loop nests
(II=1 unless noted) reading/writing stream arrays round-robin, *store*
tasks drain results.  Stream arrays (``hls::stream<T> v[L]``) carry the
``group`` tag the grouped optimizers exploit.

All builders take and return *stream array* handles (lists of FIFO names)
and register tasks on the shared :class:`repro.core.design.Design`.
Values flowing through the FIFOs are real numbers, so every design's
functional output can be checked against a numpy reference in tests.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

from repro.core.design import Design

Streams = List[str]


def streams(d: Design, name: str, lanes: int, width: int = 32,
            depth: Optional[int] = None) -> Streams:
    if lanes == 1:
        return [d.fifo(name, width=width, group=name, depth=depth)]
    return d.fifo_array(name, lanes, width=width, depth=depth)


# ---------------------------------------------------------------------------
# stage builders
# ---------------------------------------------------------------------------

def producer(d: Design, name: str, out: Streams, values: Sequence[float],
             ii: int = 1, start_delay: int = 0) -> None:
    """Memory loader: streams ``values`` round-robin over ``out``."""
    def prog(ctx, out=tuple(out), values=tuple(values), ii=ii,
             start_delay=start_delay):
        if start_delay:
            yield ctx.delay(start_delay)
        for i, v in enumerate(values):
            yield ctx.delay(ii)
            yield ctx.write(out[i % len(out)], v)
    d.add_task(name, prog)


def sink(d: Design, name: str, inp: Streams, count: int, ii: int = 1,
         result_key: Optional[str] = None) -> None:
    """Memory store: drains ``count`` elements round-robin; checksums."""
    def prog(ctx, inp=tuple(inp), count=count, ii=ii, key=result_key):
        acc = 0.0
        for i in range(count):
            yield ctx.delay(ii)
            v = yield ctx.read(inp[i % len(inp)])
            acc += v
        if key is not None:
            ctx.result(key, acc)
    d.add_task(name, prog)


def map_stage(d: Design, name: str, inp: Streams, out: Streams, count: int,
              fn: Callable[[float], float] = lambda v: v, ii: int = 1,
              extra_delay: int = 0) -> None:
    """Elementwise stage (ReLU, copy, cast): read 1 -> write 1, II cycles."""
    def prog(ctx, inp=tuple(inp), out=tuple(out), count=count, fn=fn,
             ii=ii, extra_delay=extra_delay):
        for i in range(count):
            yield ctx.delay(ii)
            v = yield ctx.read(inp[i % len(inp)])
            if extra_delay:
                yield ctx.delay(extra_delay)
            yield ctx.write(out[i % len(out)], fn(v))
    d.add_task(name, prog)


def fork_stage(d: Design, name: str, inp: Streams, out_a: Streams,
               out_b: Streams, count: int, ii: int = 1) -> None:
    """Duplicate a stream (residual skip paths): read 1 -> write to both."""
    def prog(ctx, inp=tuple(inp), a=tuple(out_a), b=tuple(out_b),
             count=count, ii=ii):
        for i in range(count):
            yield ctx.delay(ii)
            v = yield ctx.read(inp[i % len(inp)])
            yield ctx.write(a[i % len(a)], v)
            yield ctx.write(b[i % len(b)], v)
    d.add_task(name, prog)


def join_stage(d: Design, name: str, in_a: Streams, in_b: Streams,
               out: Streams, count: int,
               fn: Callable[[float, float], float] = lambda a, b: a + b,
               ii: int = 1) -> None:
    """Binary elementwise combine (residual add)."""
    def prog(ctx, a=tuple(in_a), b=tuple(in_b), out=tuple(out), count=count,
             fn=fn, ii=ii):
        for i in range(count):
            yield ctx.delay(ii)
            x = yield ctx.read(a[i % len(a)])
            y = yield ctx.read(b[i % len(b)])
            yield ctx.write(out[i % len(out)], fn(x, y))
    d.add_task(name, prog)


def matvec_stage(d: Design, name: str, inp: Streams, out: Streams,
                 rows: int, cols: int, weight: float = 0.01,
                 ii: int = 1, row_overhead: int = 2,
                 reuse_input: bool = False) -> None:
    """Dense matrix-vector row loop: per row read ``cols`` (unless the
    input vector is buffered locally after the first row — ``reuse_input``),
    accumulate at II, write 1 output."""
    def prog(ctx, inp=tuple(inp), out=tuple(out), rows=rows, cols=cols,
             w=weight, ii=ii, oh=row_overhead, reuse=reuse_input):
        xbuf: List[float] = []
        for r in range(rows):
            acc = 0.0
            if r == 0 or not reuse:
                for c in range(cols):
                    yield ctx.delay(ii)
                    v = yield ctx.read(inp[c % len(inp)])
                    if reuse:
                        xbuf.append(v)
                    acc += w * v
            else:
                yield ctx.delay(max(1, cols // 4))  # local-buffer MACs
                acc = sum(w * v for v in xbuf)
            if oh:
                yield ctx.delay(oh)
            yield ctx.write(out[r % len(out)], acc)
    d.add_task(name, prog)


def matmul_stage(d: Design, name: str, inp: Streams, out: Streams,
                 m: int, k: int, n: int, weight: float = 0.01,
                 ii: int = 1, row_overhead: int = 2) -> None:
    """Streaming matmul: A arrives row-major (m*k reads); B is a local
    buffer; each of the m rows emits n outputs.  Read-burst then
    write-burst per row — the bursty pattern that makes FIFO sizing
    non-trivial downstream."""
    def prog(ctx, inp=tuple(inp), out=tuple(out), m=m, k=k, n=n, w=weight,
             ii=ii, oh=row_overhead):
        for r in range(m):
            acc = 0.0
            for c in range(k):
                yield ctx.delay(ii)
                v = yield ctx.read(inp[(r * k + c) % len(inp)])
                acc += w * v
            if oh:
                yield ctx.delay(oh)
            for j in range(n):
                yield ctx.delay(ii)
                yield ctx.write(out[(r * n + j) % len(out)], acc)
    d.add_task(name, prog)


def conv_stage(d: Design, name: str, inp: Streams, out: Streams,
               length: int, taps: int, weight: float = 0.1,
               ii: int = 1) -> None:
    """1-D sliding-window "same" conv (line-buffer style): reads 1/cycle,
    emits 1/cycle (partial windows at the boundary), so in/out counts match
    — which keeps residual skip paths length-compatible."""
    def prog(ctx, inp=tuple(inp), out=tuple(out), n=length, taps=taps,
             w=weight, ii=ii):
        win: List[float] = []
        for i in range(n):
            yield ctx.delay(ii)
            v = yield ctx.read(inp[i % len(inp)])
            win.append(v)
            if len(win) > taps:
                win.pop(0)
            yield ctx.write(out[i % len(out)], w * sum(win))
    d.add_task(name, prog)


def buffered_matmul_stage(d: Design, name: str, a_in: Streams, b_in: Streams,
                          out: Streams, m: int, k: int, n: int,
                          weight: float = 0.01, ii: int = 1,
                          row_overhead: int = 2,
                          b_col_order: bool = False) -> None:
    """Two-streamed-input matmul: B (k*n elements) is buffered first, then
    A streams row-major.  This is the Stream-HLS reduction-tree node.

    With ``b_col_order`` the node consumes B column-major while the
    producer emits row-major — the transpose-between-stages pattern.  The
    B-side FIFOs then act as a reorder buffer and must hold nearly the
    whole operand, or the design deadlocks: the paper's Baseline-Min
    deadlock case (k15mmtree).  The reduction below is order-insensitive,
    so only *timing* (which lane is popped when) depends on the order.
    """
    def prog(ctx, a_in=tuple(a_in), b_in=tuple(b_in), out=tuple(out),
             m=m, k=k, n=n, w=weight, ii=ii, oh=row_overhead,
             col=b_col_order):
        bsum = 0.0
        L = len(b_in)
        if col:
            order = [i2 * n + j2 for j2 in range(n) for i2 in range(k)]
        else:
            order = range(k * n)
        for flat in order:
            yield ctx.delay(ii)
            v = yield ctx.read(b_in[flat % L])
            bsum += v
        for r in range(m):
            acc = 0.0
            for c in range(k):
                yield ctx.delay(ii)
                v = yield ctx.read(a_in[(r * k + c) % len(a_in)])
                acc += w * v
            acc += w * bsum / max(k * n, 1)
            if oh:
                yield ctx.delay(oh)
            for j in range(n):
                yield ctx.delay(ii)
                yield ctx.write(out[(r * n + j) % len(out)], acc)
    d.add_task(name, prog)
