"""CLI for cross-design DSE campaigns.

Runs designs x optimizers as one scheduled workload with checkpointing:

  python -m repro.launch.campaign --designs gemm,FeedForward \\
      --optimizers grouped_sa,grouped_random --budget 300 \\
      --checkpoint camp.npz --out campaign_results.json

  # after a kill, continue exactly where it stopped (byte-identical
  # frontiers to an uninterrupted run):
  python -m repro.launch.campaign --resume camp.npz

Design sets: ``quick`` (CI smoke pair), ``fast`` (the benchmark subset),
``all`` (every Stream-HLS design), or a comma-separated list of names.
"""

from __future__ import annotations

import argparse
import sys
import time



def parse_args(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.campaign",
        description="Run a cross-design FIFO-sizing DSE campaign.")
    p.add_argument("--designs", default="quick",
                   help="design set (quick/fast/all) or comma-list "
                        "of Stream-HLS design names")
    p.add_argument("--optimizers", default="grouped_sa,grouped_random",
                   help="comma-list of optimizer names")
    p.add_argument("--budget", type=int, default=300,
                   help="evaluation budget per task")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--backend", default="numpy",
                   help="per-design evaluator backend "
                        "(numpy/worklist, jax/fixpoint, pallas)")
    p.add_argument("--workers", default=None,
                   help="worklist worker processes: an int, 'auto', or 0 "
                        "to evaluate inline (default: auto for new "
                        "campaigns, the checkpointed value on --resume)")
    p.add_argument("--hetero", action="store_true",
                   help="pack cross-design batches into one fixpoint "
                        "dispatch (TPU-native path)")
    p.add_argument("--shards", type=int, default=None, metavar="N",
                   help="shard batched evaluation over N jax devices "
                        "(with --hetero: shards the packed cross-design "
                        "batch; otherwise forces the mesh backend). "
                        "See docs/mesh.md for CPU host-platform meshes")
    p.add_argument("--checkpoint", default=None, metavar="PATH",
                   help="write campaign state to this .npz periodically")
    p.add_argument("--checkpoint-every", type=int, default=8,
                   metavar="ROUNDS")
    p.add_argument("--resume", default=None, metavar="PATH",
                   help="resume from a checkpoint (other spec flags are "
                        "taken from the checkpoint)")
    p.add_argument("--max-rounds", type=int, default=None,
                   help="stop (and checkpoint) after this many rounds")
    p.add_argument("--out", default=None, metavar="JSON",
                   help="write per-task results to this JSON file")
    p.add_argument("--track-hypervolume", action="store_true",
                   help="record per-round hypervolume trajectories "
                        "(slower; for convergence studies)")
    p.add_argument("--alpha", type=float, default=0.7,
                   help="alpha for the selected-point summaries")
    return p.parse_args(argv)


def resolve_designs(arg: str):
    from repro.designs import (FAST_DESIGNS, QUICK_DESIGNS,
                               STREAMHLS_DESIGNS)
    sets = {"quick": list(QUICK_DESIGNS), "fast": list(FAST_DESIGNS),
            "all": sorted(STREAMHLS_DESIGNS)}
    if arg in sets:
        return sets[arg]
    return [d.strip() for d in arg.split(",") if d.strip()]


def resolve_workers(arg) -> int:
    from repro.core.campaign import default_workers
    if arg == "auto":
        return default_workers()
    return int(arg)


def main(argv=None) -> int:
    args = parse_args(argv)
    from repro.core.campaign import Campaign, CampaignSpec

    t0 = time.perf_counter()
    if args.resume:
        # only override the checkpointed worker count when the user
        # explicitly passed --workers
        override = (resolve_workers(args.workers)
                    if args.workers is not None else None)
        campaign = Campaign.resume(
            args.resume, workers=override,
            checkpoint_path=args.checkpoint or args.resume)
        print(f"resumed {len(campaign.tasks)} tasks at round "
              f"{campaign.round} "
              f"({sum(t.done for t in campaign.tasks)} already done)")
    else:
        from repro.core.config import EvalConfig
        spec = CampaignSpec(
            designs=tuple(resolve_designs(args.designs)),
            optimizers=tuple(
                o.strip() for o in args.optimizers.split(",") if o.strip()),
            budget=args.budget, seed=args.seed,
            eval=EvalConfig(backend=args.backend, shards=args.shards),
            workers=resolve_workers(args.workers
                                    if args.workers is not None
                                    else "auto"),
            hetero=args.hetero,
            checkpoint_every=args.checkpoint_every,
            track_hypervolume=args.track_hypervolume)
        campaign = Campaign(spec, checkpoint_path=args.checkpoint)
        print(f"campaign: {len(campaign.tasks)} tasks "
              f"({len(campaign.designs)} designs x "
              f"{len(spec.optimizers)} optimizers), backend="
              f"{spec.backend}, workers={spec.workers}"
              f"{', hetero' if spec.hetero else ''}"
              f"{f', shards={spec.shards}' if spec.shards else ''}")

    store = campaign.run(max_rounds=args.max_rounds)
    wall = time.perf_counter() - t0

    if not campaign.finished:
        print(f"stopped after --max-rounds at round {campaign.round} "
              f"({sum(t.done for t in campaign.tasks)}/"
              f"{len(campaign.tasks)} tasks done)"
              + (f"; resume with --resume {campaign.checkpoint_path}"
                 if campaign.checkpoint_path else ""))

    print(f"\n{'task':38s} {'evals':>6} {'frontier':>8} "
          f"{'hypervolume':>12} {'selected':>16}")
    for key in store.keys():
        dse = store[key]
        sel = dse.selected(args.alpha)
        sel_s = (f"({int(sel[0][0])},{int(sel[0][1])})"
                 if sel is not None else "-")
        print(f"{key:38s} {dse.result.n_evals:6d} "
              f"{dse.frontier_points.shape[0]:8d} "
              f"{dse.hypervolume():12.1f} {sel_s:>16}")
    print(f"\n{len(store)} tasks, {store.total_evals()} simulated "
          f"configs, {wall:.2f}s wall")
    if args.out:
        store.save_json(args.out, alpha=args.alpha,
                        extra={"wall_s": round(wall, 3)})
        print(f"results written to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
