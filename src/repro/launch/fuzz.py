"""Differential design-space fuzzing CLI.

Runs continuous differential campaigns over randomly generated designs
(:mod:`repro.designs.generate`): for every seed, the design is evaluated
at a spread of depth configurations by the discrete-event **oracle** and
by every requested trace-based :class:`EvalBackend`, and the results must
agree on

* **latency** (exact, cycle for cycle, on deadlock-free rows),
* **deadlock verdicts** (including per-FIFO blame being well-formed), and
* **functional outputs** vs the design's numpy reference (tracer and
  oracle both execute the real values).

On a disagreement the failing spec is *shrunk* to a minimal reproducing
design (structural reductions, see :func:`repro.designs.generate.shrink_spec`)
and serialized into the seed corpus, which CI replays first as
regression tests on every subsequent run.

``--mode bounds`` swaps the differential property: instead of backend
agreement, every design must satisfy the analytical channel-bounds
contract (:mod:`repro.core.bounds`) —

* ``analytical lower <= certified <= analytical upper`` on every FIFO,
* bounds-seeded certification returns the identical vector, and
* on affine-only specs the bounds are *exact* (``analytical ==
  certified``) and seeded certification is probe-free (the shortcut
  probe plus the start check, nothing else).

``--mode chaos`` swaps it again: every design is evaluated through a
2-lane :class:`~repro.core.campaign.pool.WorkerPool` running a seeded
:class:`~repro.core.faults.FaultPlan` that kills every lane mid-round
(crash or hang, seed-chosen), and the pooled results must be
bit-identical to the fault-free inline reference, with every scheduled
fault fired, exactly one respawn per lane death, and no worker process
outliving the pool.  Needs the ``fork`` start method (generated designs
ride to workers via copy-on-write); exits 2 otherwise so CI cannot
green-light a no-op chaos run.

  PYTHONPATH=src python -m repro.launch.fuzz --seeds 0:200 --quick
  PYTHONPATH=src python -m repro.launch.fuzz --seeds 0:200 --quick \\
      --mode bounds --corpus tests/fuzz_corpus
  PYTHONPATH=src python -m repro.launch.fuzz --seeds 0:50 \\
      --backends worklist,fixpoint --configs 6 --corpus tests/fuzz_corpus

Exit code 0 = zero disagreements (corpus replays included); an empty or
malformed ``--seeds`` range exits 2 so CI cannot green-light a no-op run.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.config import EvalConfig
from repro.core.oracle import simulate
from repro.core.simgraph import build_simgraph
from repro.core.simulate import BatchedEvaluator
from repro.core.tracer import collect_trace
from repro.designs.generate import (DesignSpec, GeneratedDesign,
                                    build_design, corpus_entry,
                                    load_corpus_specs, shrink_spec,
                                    spec_from_seed)

__all__ = ["Mismatch", "bounds_check", "bounds_one", "chaos_check",
           "chaos_one", "depth_configs", "differential_check", "fuzz_one",
           "main", "parse_args", "parse_seed_range", "resolve_backends"]


@dataclasses.dataclass
class Mismatch:
    """One observed disagreement, with everything needed to reproduce."""

    spec: DesignSpec
    kind: str            # "latency" | "deadlock" | "functional" | "blame"
    backend: str         # backend name ("oracle"/"trace" for functional)
    depths: Optional[List[int]]
    detail: str

    def to_json(self) -> Dict[str, object]:
        return {"kind": self.kind, "backend": self.backend,
                "depths": self.depths, "detail": self.detail}


def depth_configs(g, rng: np.random.Generator, n_random: int = 4
                  ) -> np.ndarray:
    """The depth matrix a design is differentially tested at: the two
    corner cases (all-1 — maximal back-pressure, most deadlocks — and the
    upper-bound vector) plus ``n_random`` uniform draws in between."""
    u = np.maximum(g.upper_bounds, 1)
    rows = [np.ones_like(u), np.minimum(u, 2), u]
    for _ in range(n_random):
        rows.append(rng.integers(1, u + 1))
    return np.unique(np.stack(rows), axis=0)


def differential_check(gen: GeneratedDesign,
                       backends: Sequence[str] = ("worklist",),
                       n_random: int = 4,
                       rng: Optional[np.random.Generator] = None
                       ) -> Tuple[List[Mismatch], int]:
    """Differentially test one generated design.

    Returns ``(mismatches, n_rows_checked)``.  The oracle is ground
    truth; every backend's (latency, deadlock) must match it row for
    row, the tracer's and oracle's functional outputs must match the
    numpy reference, and deadlocked rows must yield a non-empty,
    well-formed blame set.
    """
    from repro.core.deadlock import extract_wait_graph

    design = gen.design
    mism: List[Mismatch] = []
    spec = gen.spec
    rng = rng or np.random.default_rng(spec.seed)

    trace = collect_trace(design)
    if not gen.check_results(trace.results):
        mism.append(Mismatch(spec, "functional", "trace", None,
                             f"trace results {trace.results} != "
                             f"reference {gen.expected}"))
    g = build_simgraph(design, trace)
    matrix = depth_configs(g, rng, n_random=n_random)

    oracle_lat = np.zeros(matrix.shape[0], dtype=np.int64)
    oracle_dead = np.zeros(matrix.shape[0], dtype=bool)
    fifo_names = {f.name for f in design.fifos}
    for i in range(matrix.shape[0]):
        r = simulate(design, matrix[i])
        oracle_lat[i] = r.latency
        oracle_dead[i] = r.deadlocked
        if r.deadlocked:
            blame = extract_wait_graph(design, r, trace=trace).blame()
            if not blame or not set(blame) <= fifo_names:
                mism.append(Mismatch(
                    spec, "blame", "oracle", matrix[i].tolist(),
                    f"deadlocked row produced ill-formed blame {blame}"))
        elif not gen.check_results(r.results):
            mism.append(Mismatch(
                spec, "functional", "oracle", matrix[i].tolist(),
                f"oracle results {r.results} != reference {gen.expected}"))

    for name in backends:
        if name == "condensed":
            # the numpy worklist forced through the condensation cascade:
            # every accepted row carries a per-row exactness certificate,
            # so this differentially pins condensed-vs-oracle identity
            # without needing jax (docs/performance.md)
            from repro.core.condense import condense_auto
            rungs = condense_auto(g)
            if not rungs:
                # nothing compressed -> the cascade would be an exact
                # duplicate of the plain worklist run; skip rather than
                # double-count the seed as condensation coverage
                continue
            ev = BatchedEvaluator(
                g, EvalConfig(backend="worklist", max_iters=64),
                rungs=rungs)
        elif name == "pallas-condensed":
            # the fused Pallas mega-kernel driven through the rung
            # cascade: the kernel's on-device certificate decides row
            # acceptance (tests/test_condensed_kernel.py pins it
            # bit-for-bit to verify_rows; this pins the whole cascade
            # to the oracle)
            from repro.core.condense import condense_auto
            rungs = condense_auto(g)
            if not rungs:
                continue
            ev = BatchedEvaluator(
                g, EvalConfig(backend="pallas", max_iters=64),
                rungs=rungs)
        else:
            ev = BatchedEvaluator(
                g, EvalConfig(backend=name, max_iters=64))
        lat, _, dead = ev.evaluate(matrix)
        for i in range(matrix.shape[0]):
            if bool(dead[i]) != bool(oracle_dead[i]):
                mism.append(Mismatch(
                    spec, "deadlock", name, matrix[i].tolist(),
                    f"backend says deadlock={bool(dead[i])}, oracle says "
                    f"{bool(oracle_dead[i])}"))
            elif not dead[i] and int(lat[i]) != int(oracle_lat[i]):
                mism.append(Mismatch(
                    spec, "latency", name, matrix[i].tolist(),
                    f"backend latency {int(lat[i])} != oracle "
                    f"{int(oracle_lat[i])}"))
    return mism, int(matrix.shape[0])


def fuzz_one(spec: DesignSpec, backends: Sequence[str],
             n_random: int = 4) -> Tuple[List[Mismatch], int]:
    """Build + differentially check one spec (corpus replay entry point)."""
    gen = build_design(spec)
    return differential_check(gen, backends=backends, n_random=n_random)


def bounds_check(gen: GeneratedDesign) -> Tuple[List[Mismatch], int]:
    """The ``bounds`` differential property for one generated design.

    Certifies minimal safe depths twice — unseeded and seeded with the
    analytical :func:`~repro.core.bounds.channel_bounds` — and checks:
    bracket (``lower <= certified <= upper`` per FIFO), seeded/unseeded
    vector identity, and on affine-only specs exactness (``certified ==
    lower``) plus probe-freedom (seeded certification issues at most 2
    evaluator probes: the start check and the shortcut).

    Returns ``(mismatches, n_channels_checked)``.
    """
    from repro.core.backends import ConfigCache
    from repro.core.bounds import channel_bounds
    from repro.core.deadlock import certify_min_depths

    spec = gen.spec
    mism: List[Mismatch] = []
    g = build_simgraph(gen.design)
    b = channel_bounds(g)
    ev = BatchedEvaluator(g, EvalConfig(backend="worklist", max_iters=64))
    cert = certify_min_depths(g, ev, cache=ConfigCache(g.n_fifos))
    seeded = certify_min_depths(g, ev, cache=ConfigCache(g.n_fifos),
                                bounds=b)

    names = [f.name for f in gen.design.fifos]
    if not np.array_equal(cert.depths, seeded.depths):
        mism.append(Mismatch(
            spec, "bounds-identity", "bounds", seeded.depths.tolist(),
            f"seeded certification {seeded.depths.tolist()} != unseeded "
            f"{cert.depths.tolist()}"))
    viol = (b.lower > cert.depths) | (cert.depths > b.upper)
    if viol.any():
        f = int(np.flatnonzero(viol)[0])
        mism.append(Mismatch(
            spec, "bounds-bracket", "bounds", cert.depths.tolist(),
            f"fifo {names[f]!r} ({b.kinds[f]}): certified "
            f"{int(cert.depths[f])} outside analytical "
            f"[{int(b.lower[f])}, {int(b.upper[f])}]"))
    if spec.affine_only:
        if not np.array_equal(cert.depths, b.lower):
            f = int(np.flatnonzero(cert.depths != b.lower)[0])
            mism.append(Mismatch(
                spec, "bounds-exact", "bounds", cert.depths.tolist(),
                f"affine-only spec but fifo {names[f]!r} ({b.kinds[f]}) "
                f"certified {int(cert.depths[f])} != analytical lower "
                f"{int(b.lower[f])}"))
        if seeded.n_probes > 2:
            mism.append(Mismatch(
                spec, "bounds-probes", "bounds", seeded.depths.tolist(),
                f"affine-only spec needed {seeded.n_probes} evaluator "
                f"probes (expected <= 2: start check + shortcut)"))
    return mism, g.n_fifos


def bounds_one(spec: DesignSpec, backends: Sequence[str] = (),
               n_random: int = 0) -> Tuple[List[Mismatch], int]:
    """``fuzz_one``-shaped wrapper so ``--mode bounds`` reuses the
    corpus-replay / shrink plumbing (``backends``/``n_random`` unused)."""
    return bounds_check(build_design(spec))


def chaos_check(gen: GeneratedDesign, n_random: int = 2,
                rng: Optional[np.random.Generator] = None
                ) -> Tuple[List[Mismatch], int]:
    """The ``chaos`` differential property for one generated design.

    Evaluates the design's depth matrix twice — inline (the fault-free
    reference) and through a :class:`~repro.core.campaign.pool.WorkerPool`
    running a seeded :class:`~repro.core.faults.FaultPlan` with an
    aggressive recv deadline — and checks three things:

    * **identity**: pooled ``(latency, bram, deadlock)`` bit-identical
      to the inline reference despite every lane dying mid-round,
    * **coverage**: every scheduled fault fired (worker faults are
      pinned to each lane's *first* job so the schedule is reachable by
      construction — an unfired fault means the injection plumbing
      broke, not that the dice fell badly),
    * **recovery**: exactly one respawn per lane death, and no worker
      process outlives ``pool.close()``.

    Returns ``(mismatches, n_rows_checked)``.  Requires the ``fork``
    start method (the caller gates on it): generated designs have no
    ``make_design`` name, so they can only reach workers through fork's
    copy-on-write pages.
    """
    import multiprocessing as mp

    from repro.core.campaign.pool import WorkerPool
    from repro.core.faults import Fault, FaultPlan

    spec = gen.spec
    mism: List[Mismatch] = []
    design = gen.design
    trace = collect_trace(design)
    g = build_simgraph(design, trace)
    rng = rng or np.random.default_rng(spec.seed)
    matrix = depth_configs(g, rng, n_random=n_random)

    ref = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=64))
    want_lat, want_bram, want_dead = ref.evaluate(matrix)

    # round-robin the rows over up to 4 jobs / 2 lanes; degenerate
    # designs whose depth matrix collapses to one row get one lane
    n_jobs = min(4, matrix.shape[0])
    n_lanes = min(2, n_jobs)
    name = f"chaos_seed{spec.seed}"
    chunks = [c for c in np.array_split(matrix, n_jobs, axis=0)
              if c.shape[0]]
    jobs = [(j % n_lanes, name, chunk, None)
            for j, chunk in enumerate(chunks)]

    # one lethal fault per lane at that lane's first job (guaranteed to
    # fire: every lane receives at least one job), plus a dispatch delay
    # on a seed-chosen job index (wildcard lane, so always reachable)
    lethal = ("crash_worker", "hang_worker")
    faults = [Fault(lethal[int(rng.integers(2))], at=0, lane=w, value=1.0)
              for w in range(n_lanes)]
    faults.append(Fault("delay_dispatch",
                        at=int(rng.integers(len(jobs))), value=0.005))
    plan = FaultPlan(faults)

    pool = WorkerPool(n_lanes, max_iters=64, graphs={name: g},
                      faults=plan, recv_timeout_s=0.3)
    try:
        results = pool.run_jobs(jobs)
    finally:
        pool.close()

    got_lat = np.concatenate([r[0] for r in results])
    got_bram = np.concatenate([r[1] for r in results])
    got_dead = np.concatenate([r[2] for r in results])
    if not (np.array_equal(got_lat, want_lat)
            and np.array_equal(got_bram, want_bram)
            and np.array_equal(got_dead, want_dead)):
        bad = np.flatnonzero((got_lat != want_lat)
                             | (got_dead != want_dead))
        i = int(bad[0]) if bad.size else 0
        mism.append(Mismatch(
            spec, "chaos-identity", "pool", matrix[i].tolist(),
            f"pooled row {i} (lat={int(got_lat[i])}, "
            f"dead={bool(got_dead[i])}) != inline reference "
            f"(lat={int(want_lat[i])}, dead={bool(want_dead[i])}) "
            f"under plan {plan.to_json()}"))
    if not plan.all_fired:
        unfired = [f.to_dict() for i, f in enumerate(plan.faults)
                   if not plan._fired[i]]
        mism.append(Mismatch(
            spec, "chaos-coverage", "pool", None,
            f"{len(unfired)} scheduled fault(s) never fired: {unfired}"))
    if pool.stats["respawns"] != n_lanes:
        mism.append(Mismatch(
            spec, "chaos-recovery", "pool", None,
            f"expected {n_lanes} respawns (one per lane death), pool "
            f"reports {pool.stats}"))
    strays = mp.active_children()
    if strays:  # pragma: no cover - the defect this mode exists to catch
        for p in strays:
            p.kill()
        mism.append(Mismatch(
            spec, "chaos-zombies", "pool", None,
            f"{len(strays)} worker process(es) outlived pool.close()"))
    return mism, int(matrix.shape[0])


def chaos_one(spec: DesignSpec, backends: Sequence[str] = (),
              n_random: int = 2) -> Tuple[List[Mismatch], int]:
    """``fuzz_one``-shaped wrapper so ``--mode chaos`` reuses the
    corpus-replay / shrink plumbing (``backends`` unused)."""
    return chaos_check(build_design(spec), n_random=n_random)


def _shrunk(spec: DesignSpec, backends: Sequence[str], n_random: int,
            kind: str, backend: str, check=None) -> DesignSpec:
    """Shrink ``spec`` while the ORIGINAL failure mode still reproduces.

    A reduction that merely fails differently (another kind, another
    backend) is rejected — the corpus entry must guard the disagreement
    that was actually observed, not whatever the smaller design happens
    to trip over.  ``check`` defaults to the module-level ``fuzz_one``,
    resolved at call time so tests can monkeypatch it.
    """
    def still_fails(cand: DesignSpec) -> bool:
        found, _ = (check or fuzz_one)(cand, backends, n_random=n_random)
        return any(m.kind == kind and m.backend == backend for m in found)
    return shrink_spec(spec, still_fails)


def resolve_backends(arg: str) -> List[str]:
    """``auto`` -> every backend usable here, plus the two cascade
    pseudo-backends (``condensed`` = numpy worklist through the rung
    cascade; ``pallas-condensed`` = the fused Pallas kernel's on-device
    certificate through the same cascade, jax only); else a comma-list."""
    if arg == "auto":
        from repro.core.backends import available_backends
        names = list(available_backends()) + ["condensed"]
        if "pallas" in names:
            names.append("pallas-condensed")
        return names
    return [b.strip() for b in arg.split(",") if b.strip()]


def parse_args(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.fuzz",
        description="Differential fuzzing: generated designs, oracle vs "
                    "every evaluation backend.")
    p.add_argument("--seeds", default="0:50", metavar="LO:HI",
                   help="seed range (half-open, non-empty), e.g. 0:200")
    p.add_argument("--mode", choices=("diff", "bounds", "chaos"),
                   default="diff",
                   help="diff: oracle vs backends (default); bounds: "
                        "analytical channel-bounds contract (bracket, "
                        "seeded-certification identity, affine exactness); "
                        "chaos: worker-pool evaluation under injected "
                        "lane crashes/hangs must stay bit-identical to "
                        "the fault-free inline reference")
    p.add_argument("--quick", action="store_true",
                   help="small designs + the CI-bounded default backend "
                        "set (worklist, condensed, and pallas-condensed "
                        "when jax is importable)")
    p.add_argument("--backends", default=None,
                   help="comma-list of backend names (pseudo-backends "
                        "'condensed' and 'pallas-condensed' run the rung "
                        "cascade), or 'auto' for everything available")
    p.add_argument("--configs", type=int, default=4, metavar="N",
                   help="random depth configs per design (plus the three "
                        "corner configs)")
    p.add_argument("--corpus", default=None, metavar="DIR",
                   help="seed-corpus directory: replayed first, and "
                        "minimal shrunk specs for new mismatches are "
                        "written here")
    p.add_argument("--out", default=None, metavar="JSON",
                   help="write a machine-readable campaign summary")
    return p.parse_args(argv)


def parse_seed_range(text: str) -> range:
    """``LO:HI`` (half-open) or a single seed ``N`` -> a non-empty range.

    Raises ``ValueError`` on malformed input and on empty or inverted
    ranges (``5:5``, ``10:2``): those used to silently fuzz *zero*
    designs and report "0 disagreements", which let CI green-light a
    no-op campaign.
    """
    lo_s, _, hi_s = text.partition(":")
    try:
        lo = int(lo_s)
        hi = int(hi_s) if hi_s else lo + 1
    except ValueError:
        raise ValueError(
            f"--seeds {text!r} is not LO:HI (half-open ints) or a single "
            f"seed N") from None
    if hi <= lo:
        raise ValueError(
            f"--seeds {text!r} is an empty range (need LO < HI): a "
            f"campaign over zero designs proves nothing")
    return range(lo, hi)


def main(argv=None) -> int:
    args = parse_args(argv)
    try:
        seeds = parse_seed_range(args.seeds)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        print("usage: python -m repro.launch.fuzz --seeds LO:HI  "
              "(half-open, LO < HI; e.g. --seeds 0:200)", file=sys.stderr)
        return 2
    if args.backends:
        backends = resolve_backends(args.backends)
    elif args.quick:
        # the CI-bounded set: numpy worklist + cascade, and (when jax is
        # present) the fused kernel cascade — the numpy-only fuzz job
        # drops it automatically
        backends = ["worklist", "condensed"]
        import importlib.util
        if importlib.util.find_spec("jax") is not None:
            backends.append("pallas-condensed")
    else:
        backends = resolve_backends("auto")
    check = {"bounds": bounds_one, "chaos": chaos_one}.get(
        args.mode, fuzz_one)
    if args.mode == "chaos":
        from repro.core.campaign.pool import pick_start_method
        if pick_start_method() != "fork":
            print("error: --mode chaos needs the fork start method "
                  "(generated designs reach workers via copy-on-write; "
                  "jax is already imported or the platform lacks fork)",
                  file=sys.stderr)
            return 2

    t0 = time.perf_counter()
    all_mism: List[Mismatch] = []
    n_rows = n_designs = 0

    # 1. corpus replay: prior shrunk reproducers act as regression tests
    corpus_files = (sorted(glob.glob(os.path.join(args.corpus, "*.json")))
                    if args.corpus else [])
    for path, spec in zip(corpus_files, load_corpus_specs(corpus_files)):
        mism, rows = check(spec, backends, n_random=args.configs)
        n_designs += 1
        n_rows += rows
        if mism:
            print(f"CORPUS REGRESSION {os.path.basename(path)}: "
                  f"{mism[0].kind} ({mism[0].detail})")
            all_mism.extend(mism)
    if corpus_files:
        print(f"corpus: {len(corpus_files)} specs replayed, "
              f"{len(all_mism)} regressions")

    # 2. the fresh seed campaign
    for seed in seeds:
        spec = spec_from_seed(seed, quick=args.quick)
        mism, rows = check(spec, backends, n_random=args.configs)
        n_designs += 1
        n_rows += rows
        if not mism:
            continue
        print(f"seed {seed}: {len(mism)} disagreement(s); shrinking...")
        kind, backend = mism[0].kind, mism[0].backend
        small = _shrunk(spec, backends, args.configs,
                        kind=kind, backend=backend, check=check)
        small_mism, _ = check(small, backends, n_random=args.configs)
        same = [m for m in small_mism
                if m.kind == kind and m.backend == backend]
        repro = same[0] if same else mism[0]
        print(f"  minimal repro ({len(small.stages)} stages, n={small.n}): "
              f"{repro.kind} on {repro.backend}: {repro.detail}")
        if args.corpus:
            os.makedirs(args.corpus, exist_ok=True)
            path = os.path.join(args.corpus, f"shrunk_seed{seed}.json")
            with open(path, "w") as f:
                json.dump(corpus_entry(
                    small, note=f"shrunk from seed {seed}",
                    mismatch=repro.to_json()), f, indent=1)
            print(f"  corpus entry written: {path}")
        all_mism.extend(mism)

    wall = time.perf_counter() - t0
    if args.mode == "bounds":
        print(f"\n{n_designs} designs, {n_rows} channels checked against "
              f"the analytical bounds contract (bracket + seeded identity "
              f"+ affine exactness), {wall:.1f}s wall")
    elif args.mode == "chaos":
        print(f"\n{n_designs} designs, {n_rows} rows pooled under "
              f"injected lane deaths (crash/hang per lane + dispatch "
              f"delay), all bit-identical to the fault-free inline "
              f"reference, {wall:.1f}s wall")
    else:
        rate = n_rows * (1 + len(backends)) / max(wall, 1e-9)
        print(f"\n{n_designs} designs, {n_rows} configs x "
              f"{1 + len(backends)} evaluators ({', '.join(backends)} + "
              f"oracle), {wall:.1f}s wall ({rate:.0f} differential evals/s)")
    print(f"disagreements: {len(all_mism)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "mode": args.mode,
                "n_designs": n_designs, "n_rows": n_rows,
                "backends": list(backends), "wall_s": round(wall, 3),
                "mismatches": [m.to_json() for m in all_mism],
            }, f, indent=1)
    return 1 if all_mism else 0


if __name__ == "__main__":
    sys.exit(main())
