"""LLM decode demo: batched prefill + token-by-token decode (CPU, reduced).

Moved from ``repro.launch.serve`` when that entrypoint became the
FIFO-sizing advisory service; the flow is unchanged.

  PYTHONPATH=src python -m repro.launch.decode_demo --arch mamba2-1.3b \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import params as pm
from repro.models.transformer import model_specs
from repro.train.steps import make_decode_step, make_prefill_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(args.seed)
    params = pm.materialize(model_specs(cfg), key)

    B = args.batch
    F = cfg.frontend_tokens
    max_len = args.prompt_len + args.gen
    toks = jax.random.randint(key, (B, args.prompt_len - F), 0, cfg.vocab)
    embeds = (jax.random.normal(key, (B, F, cfg.d_model), jnp.float32)
              if F else None)

    prefill = jax.jit(make_prefill_step(cfg, max_len, cdt=jnp.float32))
    decode = jax.jit(make_decode_step(cfg, cdt=jnp.float32),
                     donate_argnums=(1,))

    t0 = time.perf_counter()
    last_logits, cache = prefill(params, toks, embeds)
    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0

    out_tokens = [np.asarray(tok[:, 0])]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        tok, cache = decode(params, cache, tok,
                            jnp.int32(args.prompt_len + i))
        tok = tok[:, None]
        out_tokens.append(np.asarray(tok[:, 0]))
    t_decode = time.perf_counter() - t0
    toks_s = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.prompt_len} toks x{B}: {t_prefill:.2f}s | "
          f"decode {args.gen - 1} steps: {t_decode:.2f}s "
          f"({toks_s:.1f} tok/s)")
    gen = np.stack(out_tokens, axis=1)
    print("generated:", gen[0][:12], "...")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": toks_s, "tokens": gen}


if __name__ == "__main__":
    main()
