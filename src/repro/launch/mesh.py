"""Device-mesh topology for sharded evaluation and campaign dispatch.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets ``xla_force_host_platform_device_count`` before
any jax initialization; everything else must see whatever the launch
environment configured).  jax itself is imported lazily inside each
constructor, so :func:`ensure_host_platform_devices` can be called from
a jax-free process to request a many-device CPU mesh *before* the
backend initializes.

Two named axes cover every consumer:

``eval``
    The config-batch axis: candidate depth rows are embarrassingly
    parallel, so the sharded evaluators (:mod:`repro.core.backends.mesh`)
    split rows across it and evaluate each shard with the unchanged
    jitted kernels — bit-identical to the solo path by construction.
``design``
    The campaign axis: the hetero dispatcher packs rows from many
    designs design-major, so partitioning over ``("design", "eval")``
    jointly lands contiguous design blocks on contiguous device groups.

On CPU hosts (CI, laptops) a multi-device mesh comes from XLA's
host-platform device emulation::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

or programmatically via :func:`ensure_host_platform_devices` before jax
initializes.
"""

from __future__ import annotations

import os
import sys
from typing import Optional, Sequence, Tuple

__all__ = [
    "device_grid", "ensure_host_platform_devices", "make_campaign_mesh",
    "make_eval_mesh", "make_local_mesh", "make_production_mesh",
]


def ensure_host_platform_devices(n: int) -> bool:
    """Request an ``n``-device CPU host-platform mesh for this process.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    when (a) no such flag is present already and (b) jax's backends have
    not initialized yet (the flag is read exactly once, at backend init).
    Returns True when a forced device count is in effect after the call
    — either ours or one the environment set — and False when it is too
    late to apply (jax already initialized), so callers can fall back to
    fewer shards instead of crashing.

    Never imports jax itself: safe from numpy-only processes.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return True
    if "jax" in sys.modules:
        try:
            from jax._src import xla_bridge
            if xla_bridge.backends_are_initialized():
                return False
        except Exception:          # private API moved: assume too late
            return False
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={int(n)}".strip())
    return True


def device_grid(n: int) -> Tuple[int, int]:
    """Near-square 2-D factorization of ``n`` devices, ``a <= b``."""
    n = int(n)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    a = int(n ** 0.5)
    while n % a:
        a -= 1
    return (a, n // a)


def _require(n_devices: int, shape: Sequence[int], what: str):
    import math
    need = math.prod(shape)
    if need > n_devices:
        raise ValueError(
            f"{what}: requested mesh shape {tuple(shape)} needs {need} "
            f"devices but only {n_devices} are available "
            f"(jax.device_count()). On CPU hosts, launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"or call ensure_host_platform_devices({need}) before jax "
            f"initializes.")


def make_eval_mesh(shards: Optional[int] = None):
    """1-D ``("eval",)`` mesh over ``shards`` devices (default: all).

    The config-batch sharding axis used by
    :class:`repro.core.backends.mesh.MeshBackend`.  Fails with a clear
    error when ``shards`` exceeds ``jax.device_count()``.
    """
    import jax
    n = jax.device_count()
    shards = n if shards is None else int(shards)
    _require(n, (shards,), "make_eval_mesh")
    return jax.make_mesh((shards,), ("eval",),
                         devices=jax.devices()[:shards])


def make_campaign_mesh(design_shards: Optional[int] = None,
                       eval_shards: Optional[int] = None):
    """2-D ``("design", "eval")`` mesh for cross-design campaign dispatch.

    Defaults to a near-square grid over every available device; either
    axis can be pinned.  The hetero dispatcher partitions its packed
    row batch over BOTH axes jointly (rows are stacked design-major, so
    design blocks land on contiguous device groups).
    """
    import jax
    n = jax.device_count()
    if design_shards is None and eval_shards is None:
        shape = device_grid(n)
    elif design_shards is None:
        _require(n, (eval_shards,), "make_campaign_mesh")
        shape = (n // int(eval_shards), int(eval_shards))
    elif eval_shards is None:
        _require(n, (design_shards,), "make_campaign_mesh")
        shape = (int(design_shards), n // int(design_shards))
    else:
        shape = (int(design_shards), int(eval_shards))
    _require(n, shape, "make_campaign_mesh")
    import math
    used = math.prod(shape)
    return jax.make_mesh(shape, ("design", "eval"),
                         devices=jax.devices()[:used])


def make_production_mesh(*, multi_pod: bool = False,
                         shape: Optional[Sequence[int]] = None):
    """Accelerator-pod mesh, shape derived from ``jax.device_count()``.

    Single pod: a near-square ``("data", "model")`` grid over every
    device (256 chips -> 16x16).  ``multi_pod`` splits the fleet into 2
    pods first: ``("pod", "data", "model")`` with a near-square grid per
    pod (512 chips -> 2x16x16).  Pass ``shape`` to pin an explicit
    topology; it is validated against the available device count and
    fails with a clear error instead of letting jax crash deep in
    ``make_mesh``.
    """
    import jax
    n = jax.device_count()
    if shape is not None:
        axes = ("pod", "data", "model") if len(shape) == 3 \
            else ("data", "model")
        if len(shape) != len(axes):
            raise ValueError(
                f"make_production_mesh: shape must be 2-D (data, model) "
                f"or 3-D (pod, data, model), got {tuple(shape)}")
        _require(n, shape, "make_production_mesh")
    elif multi_pod:
        if n < 2 or n % 2:
            raise ValueError(
                f"make_production_mesh(multi_pod=True) needs an even "
                f"device count >= 2, got {n}")
        shape = (2,) + device_grid(n // 2)
        axes = ("pod", "data", "model")
    else:
        shape = device_grid(n)
        axes = ("data", "model")
    import math
    used = math.prod(shape)
    return jax.make_mesh(tuple(shape), axes,
                         devices=jax.devices()[:used])


def make_local_mesh():
    """1x1 ``("data", "model")`` mesh over the first local device
    (CPU tests / examples)."""
    import jax
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
