"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets ``xla_force_host_platform_device_count`` before
any jax initialization; everything else must see the 1-device default).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over the single local device (CPU tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
