import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the REAL jit root (train_step for train shapes,
prefill/decode serve steps for the others) against sharded
ShapeDtypeStructs — no arrays are ever allocated — then records:

  * ``compiled.memory_analysis()``  -> bytes/device (does it fit 16 GB?)
  * ``compiled.cost_analysis()``    -> per-device HLO FLOPs & bytes
  * the collective schedule parsed from the compiled HLO
    (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute result bytes, per device)
  * the three roofline terms vs TPU v5e constants (197 TF bf16,
    819 GB/s HBM, ~50 GB/s/link ICI), MODEL_FLOPS, and the useful-compute
    ratio — consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional, Tuple


import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import params as pm
from repro.models.sharding import DEFAULT_RULES, ShardingCtx, use_ctx
from repro.models.transformer import init_cache, model_specs
from repro.train.data import specs_for_shape
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.steps import (make_decode_step, make_prefill_step,
                               make_train_step)

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
HBM_BYTES = 16e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum result bytes per collective kind from (post-SPMD) HLO text."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo.splitlines():
        line = line.strip()
        for kind in COLLECTIVES:
            # match "= <shapes> kind(" but not "-start/-done" duplicates
            m = re.search(rf"= (.*?) {kind}(-start)?\(", line)
            if m:
                out[kind]["count"] += 1
                out[kind]["bytes"] += _shape_bytes(m.group(1))
                break
    return out


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                ctx: Optional[ShardingCtx] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    shapes = specs_for_shape(arch, shape)

    def sds(shp, dtype, logical):
        sh = ctx.sharding(logical) if ctx is not None else None
        return jax.ShapeDtypeStruct(shp, dtype, sharding=sh)

    out = {}
    for name, shp in shapes.items():
        if name == "embeds":
            out[name] = sds(shp, jnp.float32, ("batch", "seq", "embed"))
        else:
            out[name] = sds(shp, jnp.int32, ("batch", "seq")[:len(shp)])
    return out


def _flops_lower(arch: ArchConfig, shape: ShapeConfig, n_layers: int,
                 donate: bool = False, serve_dtype=None
                 ) -> Tuple[float, float]:
    """(flops, bytes) of one step at ``n_layers``, from an UNROLLED,
    unpartitioned lowering — XLA's cost model counts lax.scan bodies once,
    so the scanned production graph undercounts by ~L; the unrolled small-L
    lowering is exact and extrapolates linearly in L.

    Decode cells RETURN the updated cache (the copy/in-place distinction is
    the dominant byte term; ``donate`` aliases it like the real serving
    loop does)."""
    import dataclasses as dc

    from repro.models.transformer import forward as fwd
    cfg = dc.replace(arch, n_layers=n_layers)
    specs = model_specs(cfg)
    params = pm.shape_structs(specs, None)
    if serve_dtype is not None and shape.kind != "train":
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype), params)
    ins = input_specs(cfg, shape, None)

    if shape.kind == "train":
        opt = jax.eval_shape(init_opt_state, params)
        fn = make_train_step(cfg, OptConfig(), unroll=True)
        jk = {"donate_argnums": (0, 1)} if donate else {}
        lowered = jax.jit(fn, **jk).lower(params, opt, dict(ins))
    elif shape.kind == "prefill":
        def fn(p, t, e):
            logits, _ = fwd(cfg, p, t, embeds=e, remat=False,
                            return_cache=False, unroll=True)
            return logits[:, -1]
        lowered = jax.jit(fn).lower(params, ins["tokens"],
                                    ins.get("embeds"))
    else:
        cache = pm.shape_structs(
            init_cache(cfg, shape.global_batch, shape.seq_len), None)
        def fn(p, c, t, i):
            logits, nc = fwd(cfg, p, t, cache=c, cache_index=i,
                             remat=False, return_cache=True, unroll=True)
            return jnp.argmax(logits[:, -1], -1), nc
        jk = {"donate_argnums": (1,)} if donate else {}
        lowered = jax.jit(fn, **jk).lower(params, cache, ins["tokens"],
                                          jax.ShapeDtypeStruct((),
                                                               jnp.int32))
    # compile (single device, unpartitioned): post-fusion byte counts —
    # the unoptimized module would overcount HBM traffic 5-20x.
    #
    # KNOWN PROXY ARTIFACTS (EXPERIMENTS.md §Perf): (a) the CPU backend
    # upcasts bf16 compute to f32, inflating byte counts ~2x on
    # KV-cache-heavy graphs and inverting bf16-vs-f32 comparisons; (b) the
    # cost model charges dynamic-update-slice its FULL buffer, so
    # donation/in-place updates show no byte reduction.  Iterations on
    # those axes are therefore evaluated with clearly-labelled analytic
    # TPU projections alongside this proxy.
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)))


_EST_CACHE: Dict[Tuple, Dict[str, float]] = {}


def estimate_global_cost(arch: ArchConfig, shape: ShapeConfig,
                         donate: bool = False, serve_dtype=None
                         ) -> Dict[str, float]:
    """Extrapolated whole-step global FLOPs/bytes at full depth.
    Mesh-independent (global numbers) -> cached per (arch, shape, variant)."""
    key = (arch.name, shape.name, donate, str(serve_dtype),
           arch.moe.capacity_factor if arch.moe else None)
    if key in _EST_CACHE:
        return _EST_CACHE[key]
    k = arch.moe.first_k_dense if arch.moe else 0
    f2, b2 = _flops_lower(arch, shape, k + 2, donate, serve_dtype)
    f4, b4 = _flops_lower(arch, shape, k + 4, donate, serve_dtype)
    body_f, body_b = (f4 - f2) / 2.0, (b4 - b2) / 2.0
    n_body = arch.n_layers - k - 2
    out = {"flops": f2 + n_body * body_f,
           "bytes": b2 + n_body * body_b,
           "per_layer_flops": body_f}
    _EST_CACHE[key] = out
    return out


def _cell_abstract(arch: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx,
                   serve_dtype=None, accum: int = 1) -> Tuple:
    """(jit-able fn, example args as sharded ShapeDtypeStructs)."""
    specs = model_specs(arch)
    params = pm.shape_structs(specs, ctx)
    if serve_dtype is not None and shape.kind != "train":
        # inference-weight quantization (perf variant): params streamed in
        # bf16 — halves the parameter-read term of serving cells
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype,
                                           sharding=s.sharding), params)
    ins = input_specs(arch, shape, ctx)

    if shape.kind == "train":
        opt_specs = jax.eval_shape(init_opt_state, params)

        def shard_like(opt_leaf, path_hint=None):
            return opt_leaf
        # moments share the param shardings; step is replicated
        po = pm.shardings(specs, ctx)
        opt = {"m": jax.tree.map(
                   lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                      sharding=sh),
                   opt_specs["m"], po),
               "v": jax.tree.map(
                   lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                      sharding=sh),
                   opt_specs["v"], po),
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
        fn = make_train_step(arch, OptConfig(), accum=accum)
        batch = dict(ins)
        return fn, (params, opt, batch)

    if shape.kind == "prefill":
        fn = make_prefill_step(arch, shape.seq_len)
        return fn, (params, ins["tokens"], ins.get("embeds"))

    # decode: serve_step over a full-length cache
    cache_specs = init_cache(arch, shape.global_batch, shape.seq_len)
    cache = pm.shape_structs(cache_specs, ctx)
    fn = make_decode_step(arch)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, cache, ins["tokens"], index)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             donate: bool = False, serve_bf16: bool = False,
             capacity_factor: float = None, accum: int = 1) -> Dict:
    arch = get_arch(arch_name)
    if capacity_factor is not None and arch.moe is not None:
        import dataclasses as dc
        arch = dc.replace(arch, moe=dc.replace(
            arch.moe, capacity_factor=capacity_factor))
    shape = SHAPES[shape_name]
    rec: Dict = {"arch": arch_name, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind,
                 "variant": dict(donate=donate, serve_bf16=serve_bf16,
                                 capacity_factor=capacity_factor,
                                 accum=accum)}
    if not arch.supports_shape(shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch: 512K dense decode is "
                         "O(L^2) with no architectural mitigation "
                         "(DESIGN.md §3)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(DEFAULT_RULES)
    dp = 32 if multi_pod else 16
    if shape.global_batch % dp != 0:
        # long_500k (batch=1): batch cannot split the data axis — replicate
        # it and spread the half-megatoken context over BOTH mesh axes
        rules["batch"] = None
        rules["kv_seq"] = ("pod", "data", "model") if multi_pod \
            else ("data", "model")
    ctx = ShardingCtx(mesh, rules)

    t0 = time.perf_counter()
    with use_ctx(mesh, rules):
        fn, args = _cell_abstract(
            arch, shape, ctx,
            serve_dtype=jnp.bfloat16 if serve_bf16 else None, accum=accum)
        jit_kwargs = {}
        if donate:
            if shape.kind == "train":
                jit_kwargs["donate_argnums"] = (0, 1)   # params, opt state
            elif shape.kind == "decode":
                jit_kwargs["donate_argnums"] = (1,)     # the KV/SSM cache
        with mesh:
            lowered = jax.jit(fn, **jit_kwargs).lower(*args)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    print(mem)                                   # proves it fits
    cost = compiled.cost_analysis()
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    chips = 512 if multi_pod else 256
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_bytes_dev = float(sum(v["bytes"] for v in coll.values()))

    # whole-step global FLOPs/bytes from the unrolled estimator (the
    # compiled per-device numbers undercount lax.scan bodies)
    t0 = time.perf_counter()
    est = estimate_global_cost(
        arch, shape, donate=donate,
        serve_dtype=jnp.bfloat16 if serve_bf16 else None)
    t_est = time.perf_counter() - t0

    t_comp = est["flops"] / (chips * PEAK_FLOPS)
    t_mem = est["bytes"] / (chips * HBM_BW)
    t_coll = coll_bytes_dev / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    n_act = arch.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_act * tokens
    else:
        model_flops = 2 * n_act * shape.global_batch

    bytes_per_device = (mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes
                        + mem.output_size_in_bytes)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        estimate_s=round(t_est, 2),
        chips=chips,
        memory=dict(argument=mem.argument_size_in_bytes,
                    temp=mem.temp_size_in_bytes,
                    output=mem.output_size_in_bytes,
                    total=bytes_per_device,
                    fits_hbm=bool(bytes_per_device <= HBM_BYTES)),
        compiled_flops_per_device=flops_dev,
        compiled_bytes_per_device=bytes_dev,
        hlo_flops=est["flops"],          # global, scan-corrected
        hlo_bytes=est["bytes"],
        collectives=coll,
        collective_bytes_per_device=coll_bytes_dev,
        roofline=dict(compute_s=t_comp, memory_s=t_mem,
                      collective_s=t_coll, dominant=dominant),
        model_flops=model_flops,
        useful_compute_ratio=(model_flops / est["flops"]
                              if est["flops"] else None),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--donate", action="store_true",
                    help="donate params/opt (train) or cache (decode)")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="stream params in bf16 for serve cells")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="override MoE capacity factor")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
        print(f"=== {tag}")
        try:
            rec = run_cell(a, s, mp, donate=args.donate,
                           serve_bf16=args.serve_bf16,
                           capacity_factor=args.capacity_factor,
                           accum=args.accum)
        except Exception as e:   # a failure here is a bug in our sharding
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-2000:]}
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_fail += st == "error"
        if st == "ok":
            r = rec["roofline"]
            print(f"    ok: lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"mem/dev={rec['memory']['total']/1e9:.2f}GB "
                  f"terms(c/m/x)=({r['compute_s']:.2e},{r['memory_s']:.2e},"
                  f"{r['collective_s']:.2e}) dom={r['dominant']}")
        else:
            print(f"    {st}: {rec.get('reason', rec.get('error'))}")
    print(f"SUMMARY ok={n_ok} skipped={n_skip} failed={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
