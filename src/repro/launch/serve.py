"""FIFO-sizing advisory service: JSON lines over TCP or stdio.

The always-on, multi-client face of the advisor: designs are traced once
into a shared registry, each client session is a stepwise optimizer, and
outstanding evaluation requests from *different* clients and *different*
designs are packed into single batched dispatches
(:mod:`repro.core.service`).  Progress streams back as
frontier/hypervolume delta events while the search runs.

  # serve two preloaded designs on TCP
  PYTHONPATH=src python -m repro.launch.serve \
      --designs gemm,FeedForward --port 7733

  # one-shot stdio session (requests in, responses + events out)
  printf '%s\n' \
      '{"op":"open","design":"gemm","optimizer":"grouped_sa","budget":200}' \
      '{"op":"run"}' \
      '{"op":"result","session":"s0"}' \
      | PYTHONPATH=src python -m repro.launch.serve --stdio

Protocol reference: ``docs/service.md``.  The previous occupant of this
entrypoint (the LLM prefill/decode demo) lives on unchanged as
``python -m repro.launch.decode_demo``.
"""

from __future__ import annotations

import argparse
import asyncio
import sys
from typing import Dict, Optional


class _BlockingWriter:
    """StreamWriter look-alike over a plain text stream (stdio mode
    with stdout redirected to a file, where pipe transports refuse)."""

    def __init__(self, stream):
        self._stream = stream

    def write(self, data: bytes) -> None:
        self._stream.write(data.decode())

    async def drain(self) -> None:
        self._stream.flush()

    def close(self) -> None:
        self._stream.flush()


class AdvisoryServer:
    """Asyncio front-end over the synchronous service core.

    One background *pump* task advances the service one batched round at
    a time and routes each session's progress events to the connection
    that opened it.  Rounds run inline on the event loop: evaluation is
    millisecond-scale (that is the paper's point), and single-threaded
    stepping keeps the core deterministic — no locks, no races between
    ``open``/``cancel`` and the round in flight.
    """

    def __init__(self, service=None, idle_sleep_s: float = 0.02,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every_s: Optional[float] = None,
                 **service_kwargs):
        from repro.core.service import AdvisoryService, ProtocolHandler
        self.service = service or AdvisoryService(**service_kwargs)
        self.handler = ProtocolHandler(self.service,
                                       snapshot_dir=snapshot_dir)
        self.idle_sleep_s = float(idle_sleep_s)
        self.snapshot_dir = snapshot_dir
        #: auto-snapshot cadence (needs snapshot_dir); None disables
        self.snapshot_every_s = snapshot_every_s
        self._last_snapshot = 0.0
        self._owners: Dict[str, asyncio.Queue] = {}   # sid -> out queue
        self._shutdown = asyncio.Event()
        self._pump_task: Optional[asyncio.Task] = None

    # ------------------------------------------------------------- pump
    def _route_events(self) -> None:
        """Deliver queued session events to their owning connections.

        Only *owned* sessions are drained: events for sessions whose
        connection has gone (or that were opened in-process) stay queued
        on the session until someone drains them — nothing is silently
        discarded, and the pump's per-tick work is bounded by the number
        of live connections, not by every session ever opened.
        """
        for sid, q in list(self._owners.items()):
            if sid not in self.service.sessions:   # released
                self._owners.pop(sid, None)
                continue
            for ev in self.service.drain_events(sid):
                q.put_nowait(ev)

    async def _pump(self) -> None:
        """Advance the service and fan events out to session owners.

        A failure inside a round (evaluation-engine error, worker
        death) must not die unobserved — it is reported to stderr and
        to every connected session owner, and the server shuts down
        rather than sit silently idle while clients wait on events.
        """
        try:
            while not self._shutdown.is_set():
                advanced = self.service.step()
                self._route_events()
                self._maybe_snapshot()
                # yield to the loop every round; back off only when idle
                await asyncio.sleep(0 if advanced else self.idle_sleep_s)
        except Exception as exc:   # noqa: BLE001 — terminal server fault
            import traceback
            traceback.print_exc(file=sys.stderr)
            fault = {"event": "error",
                     "error": f"{type(exc).__name__}: {exc}",
                     "fatal": True}
            for q in self._owners.values():
                q.put_nowait(dict(fault))
            self._shutdown.set()

    def _maybe_snapshot(self) -> None:
        """Periodic auto-snapshot: the crash-recovery complement of the
        explicit ``snapshot`` op.  A failed save is reported and retried
        next period — persistence trouble must not take down serving."""
        if not (self.snapshot_dir and self.snapshot_every_s):
            return
        import time
        now = time.perf_counter()
        if now - self._last_snapshot < self.snapshot_every_s:
            return
        self._last_snapshot = now
        if not len(self.service.registry):
            return
        from repro.core.service import save_snapshot
        try:
            save_snapshot(self.service.registry, self.snapshot_dir)
        except Exception as exc:   # noqa: BLE001 — keep serving
            print(f"auto-snapshot failed ({type(exc).__name__}: {exc}); "
                  f"will retry", file=sys.stderr)

    def ensure_pump(self) -> None:
        if self._pump_task is None or self._pump_task.done():
            self._pump_task = asyncio.ensure_future(self._pump())

    async def aclose(self) -> None:
        self._shutdown.set()
        if self._pump_task is not None:
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
        self.service.close()

    # ------------------------------------------------------ connections
    async def _run_cooperative(self, msg: dict) -> dict:
        """``{"op": "run"}`` with an ``await`` between rounds."""
        max_rounds = msg.get("max_rounds")
        rounds = 0
        while not self._shutdown.is_set():
            if not self.service.step():
                break
            rounds += 1
            self._route_events()
            if max_rounds is not None and rounds >= max_rounds:
                break
            await asyncio.sleep(0)
        out = {"ok": True, "rounds": rounds,
               "running": len(self.service.running)}
        if msg.get("id") is not None:
            out["id"] = msg["id"]
        return out

    async def _sender(self, q: asyncio.Queue, writer) -> None:
        from repro.core.service import encode_line
        faults = getattr(self.service, "faults", None)
        sent = 0
        while True:
            frame = await q.get()
            if frame is None:
                break
            writer.write(encode_line(frame).encode())
            await writer.drain()
            sent += 1
            if faults is not None and faults.take(
                    "drop_conn", at=sent) is not None:
                # simulated network drop mid-stream: hard-close the
                # transport; the client reconnects and replays its
                # event suffix via the 'attach' op
                writer.close()
                return

    async def handle_connection(self, reader, writer) -> None:
        """One JSON-lines client: requests in, responses + events out."""
        from repro.core.service import ProtocolError, decode_line
        q: asyncio.Queue = asyncio.Queue()
        sender = asyncio.ensure_future(self._sender(q, writer))
        opened = []
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                if not line.strip():
                    continue
                try:
                    msg = decode_line(line)
                except ProtocolError as exc:
                    q.put_nowait({"ok": False, "error": str(exc)})
                    continue
                if msg.get("op") == "run":
                    # drive cooperatively: handler._op_run would block
                    # the event loop (and every other connection) until
                    # ALL sessions finish; yielding between rounds keeps
                    # the server responsive while preserving semantics
                    resp = await self._run_cooperative(msg)
                else:
                    resp = self.handler.handle(msg)
                if msg.get("op") in ("open", "attach") and resp.get("ok"):
                    # attach re-homes the session's live event stream to
                    # the reconnected client (the replayed suffix rides
                    # in the attach response itself)
                    self._owners[resp["session"]] = q
                    if resp["session"] not in opened:
                        opened.append(resp["session"])
                q.put_nowait(resp)
                # synchronous ops ("run") may have produced events —
                # deliver them now, not at the pump's next tick
                self._route_events()
                if resp.get("shutdown"):
                    self._shutdown.set()
                    break
        finally:
            self._route_events()
            for sid in opened:
                self._owners.pop(sid, None)
            q.put_nowait(None)
            await sender
            writer.close()
            if hasattr(writer, "wait_closed"):
                try:
                    await writer.wait_closed()
                except (ConnectionError, NotImplementedError):
                    pass

    async def serve_tcp(self, host: str = "127.0.0.1", port: int = 7733):
        """Start the TCP listener (port 0 = ephemeral); returns the
        ``asyncio.Server`` — callers own its lifetime."""
        self.ensure_pump()
        return await asyncio.start_server(self.handle_connection,
                                          host, port)

    async def serve_stdio(self) -> None:
        """Serve stdin/stdout as one connection; at EOF, finish any
        still-running sessions and flush their events before exiting."""
        from repro.core.service import encode_line
        self.ensure_pump()
        loop = asyncio.get_event_loop()
        reader = asyncio.StreamReader()
        await loop.connect_read_pipe(
            lambda: asyncio.StreamReaderProtocol(reader), sys.stdin)
        try:
            w_transport, w_protocol = await loop.connect_write_pipe(
                asyncio.streams.FlowControlMixin, sys.stdout)
            writer = asyncio.StreamWriter(w_transport, w_protocol,
                                          reader, loop)
        except ValueError:
            # stdout redirected to a regular file: pipe transports
            # refuse it, but a blocking writer is perfectly fine there
            writer = _BlockingWriter(sys.stdout)
        await self.handle_connection(reader, writer)
        # piped usage: the input script may end while sessions run;
        # finish them and emit EVERYTHING still queued (the connection
        # teardown stops routing, so events pile up on the sessions)
        while self.service.running and not self._shutdown.is_set():
            self.service.step()
        for ev in self.service.drain_events():
            sys.stdout.write(encode_line(ev))
        sys.stdout.flush()


def parse_args(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Serve FIFO-sizing DSE sessions over JSON lines.")
    p.add_argument("--designs", default=None,
                   help="comma-list of designs to trace at startup "
                        "(others are traced lazily on first open)")
    p.add_argument("--port", type=int, default=7733,
                   help="TCP port (0 = ephemeral; printed at startup)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--stdio", action="store_true",
                   help="serve stdin/stdout instead of TCP")
    p.add_argument("--backend", default="numpy",
                   help="evaluator backend for every design "
                        "(numpy/worklist, jax/fixpoint, pallas)")
    p.add_argument("--max-iters", type=int, default=256)
    p.add_argument("--hetero", action="store_true",
                   help="pack cross-design batches into one fixpoint "
                        "dispatch (TPU-native path)")
    p.add_argument("--workers", type=int, default=0,
                   help="worklist worker processes (0 = inline)")
    p.add_argument("--shards", type=int, default=None, metavar="N",
                   help="with --hetero: shard the packed cross-design "
                        "dispatch over N jax devices (docs/mesh.md)")
    p.add_argument("--no-progress", action="store_true",
                   help="disable per-round progress events")
    p.add_argument("--snapshot-dir", default=None, metavar="DIR",
                   help="warm-restart snapshot directory: loaded at "
                        "startup when it holds a valid snapshot, and "
                        "the default target of the 'snapshot' op")
    p.add_argument("--snapshot-every", type=float, default=None,
                   metavar="S",
                   help="auto-snapshot the registry to --snapshot-dir "
                        "every S seconds (crash recovery; default off)")
    p.add_argument("--fault-plan", default=None, metavar="JSON|@FILE",
                   help="install a FaultPlan for chaos testing (inline "
                        "JSON or @path; docs/robustness.md)")
    p.add_argument("--max-sessions", type=int, default=None, metavar="N",
                   help="admission cap on concurrently running sessions "
                        "(overload replies carry E_OVERLOADED + a "
                        "retry-after hint; default unbounded)")
    return p.parse_args(argv)


async def amain(args) -> int:
    if args.hetero and args.workers:
        print("note: --workers is ignored with --hetero (the fused "
              "dispatch owns every full-solve row in this process)",
              file=sys.stderr)
    if args.shards and not args.hetero:
        print("note: --shards only shards the --hetero dispatch; "
              "use --backend mesh for per-design sharding",
              file=sys.stderr)
    import os
    import time

    from repro.core.service import EvalConfig, SnapshotError, load_snapshot

    config = EvalConfig(backend=args.backend, max_iters=args.max_iters)
    faults = None
    if args.fault_plan:
        from repro.core.faults import resolve_plan
        faults = resolve_plan(env={"REPRO_FAULTS": args.fault_plan})
        print(f"fault plan installed: {faults!r}", file=sys.stderr)
    server = AdvisoryServer(config=config, snapshot_dir=args.snapshot_dir,
                            snapshot_every_s=args.snapshot_every,
                            hetero=args.hetero, workers=args.workers,
                            shards=args.shards,
                            progress_events=not args.no_progress,
                            max_sessions=args.max_sessions,
                            faults=faults)
    # registry-ready timing: everything between here and the "ready"
    # line is design preparation (snapshot load or cold trace), the part
    # warm restarts compress — interpreter/jax startup is excluded so
    # benchmarks/restart_check.py measures the restart path itself
    t0 = time.perf_counter()
    restored = []
    if args.snapshot_dir and os.path.exists(
            os.path.join(args.snapshot_dir, "MANIFEST.json")):
        try:
            load_snapshot(args.snapshot_dir, server.service.registry)
            restored = server.service.registry.names()
            for name in restored:
                server.service.batcher.add_design(name)
            report = server.service.registry.restore_report or {}
            for name, reason in report.get("quarantined", {}).items():
                print(f"snapshot member quarantined ({reason}); "
                      f"{name} will re-trace on first use",
                      file=sys.stderr)
        except SnapshotError as exc:
            print(f"snapshot load failed ({exc}); cold-starting",
                  file=sys.stderr)
    if args.designs:
        for name in args.designs.split(","):
            name = name.strip()
            if name and name not in server.service.registry:
                server.service.registry.register(name)
                server.service.batcher.add_design(name)
        print(f"preloaded designs: {server.service.registry.names()}",
              file=sys.stderr)
    print(f"registry ready in {time.perf_counter() - t0:.6f}s "
          f"({'warm, ' + str(len(restored)) + ' restored' if restored else 'cold'})",
          file=sys.stderr)
    try:
        if args.stdio:
            await server.serve_stdio()
            return 0
        tcp = await server.serve_tcp(args.host, args.port)
        addr = tcp.sockets[0].getsockname()
        print(f"advisory service listening on {addr[0]}:{addr[1]}",
              file=sys.stderr)
        async with tcp:
            await self_shutdown_wait(server, tcp)
        return 0
    finally:
        await server.aclose()


async def self_shutdown_wait(server: AdvisoryServer, tcp) -> None:
    """Run until a client sends ``{"op": "shutdown"}``."""
    await server._shutdown.wait()
    tcp.close()


def main(argv=None) -> int:
    return asyncio.run(amain(parse_args(argv)))


if __name__ == "__main__":
    sys.exit(main())
