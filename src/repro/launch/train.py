"""Training driver: runnable end-to-end on CPU (reduced configs) and the
jit-root used by the dry-run at production scale.

Fault tolerance: auto-resume from the newest complete checkpoint (atomic
manifests mean a preempted save is invisible), async checkpointing off the
step path, deterministic stateless data (restart == exact replay).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time


import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptConfig
from repro.train.steps import init_train_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    # MiniCPM picks WSD; everyone else cosine (DESIGN.md §3)
    sched = "wsd" if args.arch == "minicpm-2b" else "cosine"
    opt_cfg = OptConfig(lr=args.lr, schedule=sched, warmup_steps=10,
                        total_steps=args.steps)

    F = cfg.frontend_tokens
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq - F + 1
                                  if F else args.seq,
                                  global_batch=args.batch,
                                  seed=args.seed), arch=cfg)

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    start_step = 0
    saver = None
    if args.ckpt:
        saver = ckpt_lib.AsyncCheckpointer(args.ckpt)
        latest = ckpt_lib.latest_step(args.ckpt)
        if latest is not None:
            state = ckpt_lib.restore(args.ckpt, latest,
                                     {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"resumed from step {latest}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, cdt=jnp.float32))
    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        raw = data.batch(step)
        batch = {"tokens": jnp.asarray(raw["tokens"] % cfg.vocab),
                 "labels": jnp.asarray(raw["labels"] % cfg.vocab)}
        if "embeds" in raw:
            batch["embeds"] = jnp.asarray(
                raw["embeds"][:, :, :cfg.d_model])
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.perf_counter() - t0):.1f}s)")
        if saver and args.ckpt and (step + 1) % args.save_every == 0:
            saver.save(step + 1, {"params": params, "opt": opt_state})
    if saver and args.ckpt:
        saver.save(args.steps, {"params": params, "opt": opt_state})
        saver.wait()
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": args.steps - start_step}


if __name__ == "__main__":
    out = main()
    print(out)
