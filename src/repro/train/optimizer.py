"""AdamW + LR schedules (cosine, and MiniCPM's WSD) on raw pytrees.

No optax dependency: the optimizer is part of the substrate deliverable.
Weight decay skips 1-D params (norms/biases).  All state is a pytree of
arrays sharded like the parameters (GSPMD propagates), so ZeRO-style
sharding comes for free wherever params carry an "fsdp" axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    wsd_decay_frac: float = 0.1     # final fraction of steps in decay
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM): hold at peak, then cool to min_lr
        decay_steps = int(cfg.total_steps * cfg.wsd_decay_frac)
        start = cfg.total_steps - decay_steps
        frac = jnp.clip((s - start) / max(decay_steps, 1), 0.0, 1.0)
        stable = 1.0 - (1.0 - cfg.min_lr_frac) * frac
        return cfg.lr * warm * stable
    # cosine
    frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: OptConfig, grads, state, params
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_n = b1 * m + (1 - b1) * g
        v_n = b2 * v + (1 - b2) * g * g
        mh = m_n / c1
        vh = v_n / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_n, v_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        pn, mn, vn = upd(g, m, v, p)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    new_params = jax.tree.unflatten(tdef, new_p)
    new_state = {"m": jax.tree.unflatten(tdef, new_m),
                 "v": jax.tree.unflatten(tdef, new_v), "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
