"""Deterministic synthetic data pipeline.

Stateless by construction: batch ``i`` is a pure function of
``(seed, step i, host slice)``, so restarts resume exactly, stragglers can
skip ahead deterministically, and elastic re-sharding never replays or
drops data.  The token stream follows a fixed sparse Markov chain so a
real model's loss measurably decreases (used by examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    markov_degree: int = 4      # successors per token (learnable structure)


class SyntheticLM:
    """Markov-chain token stream + stub frontend embeddings."""

    def __init__(self, cfg: DataConfig, arch: Optional[ArchConfig] = None):
        self.cfg = cfg
        self.arch = arch
        rng = np.random.default_rng(cfg.seed)
        # fixed random transition structure
        self.succ = rng.integers(0, cfg.vocab,
                                 size=(cfg.vocab, cfg.markov_degree),
                                 dtype=np.int32)

    def batch(self, step: int, host_slice: slice = slice(None)
              ) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        B = c.global_batch
        toks = np.empty((B, c.seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, c.vocab, size=B)
        choices = rng.integers(0, c.markov_degree,
                               size=(B, c.seq_len))
        for t in range(c.seq_len):
            toks[:, t + 1] = self.succ[toks[:, t], choices[:, t]]
        out = {"tokens": toks[host_slice, :-1],
               "labels": toks[host_slice, 1:].astype(np.int32)}
        if self.arch is not None and self.arch.frontend_tokens:
            F = self.arch.frontend_tokens
            out["embeds"] = rng.standard_normal(
                (B, F, self.arch.d_model)).astype(np.float32)[host_slice]
        return out


def specs_for_shape(arch: ArchConfig, shape: ShapeConfig,
                    dtype=np.int32) -> Dict[str, tuple]:
    """Input array shapes for a given (arch, shape) cell — the contract
    shared by the data pipeline and launch.input_specs."""
    B, S = shape.global_batch, shape.seq_len
    F = arch.frontend_tokens
    if shape.kind == "train":
        out = {"tokens": (B, S - F), "labels": (B, S - F)}
        if F:
            out["embeds"] = (B, F, arch.d_model)
        return out
    if shape.kind == "prefill":
        out = {"tokens": (B, S - F)}
        if F:
            out["embeds"] = (B, F, arch.d_model)
        return out
    # decode: one new token against a cache of length S
    return {"tokens": (B, 1)}
