"""Training/serving substrate: optimizer, steps, data, checkpointing."""

from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
from repro.train.steps import (init_train_state, loss_fn, make_decode_step,
                               make_eval_step, make_prefill_step,
                               make_train_step)

__all__ = [
    "OptConfig", "adamw_update", "init_opt_state", "init_train_state",
    "loss_fn", "make_decode_step", "make_eval_step", "make_prefill_step",
    "make_train_step",
]
