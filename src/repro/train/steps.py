"""Train / prefill / decode step builders (the jit roots of the system).

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the ones train.py/serve.py actually execute on small configs.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import forward, model_specs
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, jnp.ndarray],
            cdt=jnp.bfloat16, unroll: bool = False
            ) -> Tuple[jnp.ndarray, Dict]:
    """Causal-LM cross entropy; labels < 0 are masked (frontend prefix,
    padding).  Frontend archs prepend ``embeds`` (stub modality tokens)."""
    logits, _ = forward(cfg, params, batch["tokens"],
                        embeds=batch.get("embeds"),
                        remat=True, return_cache=False, unroll=unroll,
                        cdt=cdt)
    labels = batch["labels"]
    if "embeds" in batch:  # prefix positions carry no LM loss
        prefix = jnp.full(
            (labels.shape[0], batch["embeds"].shape[1]), -1, labels.dtype)
        labels = jnp.concatenate([prefix, labels], axis=1)
    mask = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1)
    loss = jnp.where(mask, nll, 0.0).sum() / denom
    # small z-loss stabilizer (standard at scale)
    zl = jnp.where(mask, jax.scipy.special.logsumexp(logits, -1) ** 2,
                   0.0).sum() / denom
    return loss + 1e-4 * zl, {"loss": loss,
                              "tokens": denom.astype(jnp.float32)}


def make_train_step(cfg: ArchConfig, opt: OptConfig, cdt=jnp.bfloat16,
                    unroll: bool = False, accum: int = 1):
    """One optimizer step.  ``accum`` > 1 splits the global batch into
    microbatches processed by an inner lax.scan with gradient
    accumulation: identical math, activation footprint divided by
    ``accum`` — the standard memory/step-time knob at scale."""
    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, cdt, unroll),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum <= 1:
            (_, aux), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (_, aux), g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, aux

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, auxs = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            aux = jax.tree.map(lambda a: a.mean(), auxs)
        new_params, new_state, om = adamw_update(opt, grads, opt_state,
                                                 params)
        metrics = dict(aux, **om)
        return new_params, new_state, metrics
    return train_step


def make_eval_step(cfg: ArchConfig, cdt=jnp.bfloat16):
    def eval_step(params, batch):
        _, aux = loss_fn(cfg, params, batch, cdt)
        return aux
    return eval_step


def make_prefill_step(cfg: ArchConfig, max_len: int, cdt=jnp.bfloat16):
    """Forward over the prompt, returning the filled cache + last logits.

    The cache is allocated at ``max_len``; prompt K/V occupy [0, S).
    """
    def prefill_step(params, tokens, embeds=None):
        logits, cache = forward(cfg, params, tokens, embeds=embeds,
                                remat=False, return_cache=True, cdt=cdt)
        cache = _pad_cache_to(cfg, cache, max_len)
        return logits[:, -1], cache
    return prefill_step


_KV_KEYS = ("k", "v", "c_kv", "k_rope")


def _pad_cache_to(cfg: ArchConfig, cache, max_len: int):
    """Grow per-layer KV tensors (stacked (L, B, S, ...) layout, dim 2 = S)
    from prompt length to the serving window.  SSM state is length-free."""
    if cfg.family == "ssm":
        return cache

    def pad(x):
        padw = [(0, 0)] * x.ndim
        padw[2] = (0, max_len - x.shape[2])
        return jnp.pad(x, padw)

    return {grp: {k: (pad(v) if k in _KV_KEYS and v.shape[2] < max_len
                      else v) for k, v in sub.items()}
            for grp, sub in cache.items()}


def make_decode_step(cfg: ArchConfig, cdt=jnp.bfloat16):
    """One new token against a pre-filled cache (the ``decode_*`` shapes)."""
    def decode_step(params, cache, tokens, index):
        logits, new_cache = forward(cfg, params, tokens, cache=cache,
                                    cache_index=index, remat=False,
                                    return_cache=True, cdt=cdt)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return decode_step


def init_train_state(cfg: ArchConfig, key: jax.Array,
                     param_dtype=jnp.float32):
    from repro.models import params as pm
    specs = model_specs(cfg)
    params = pm.materialize(specs, key, dtype=param_dtype)
    return params, init_opt_state(params)
