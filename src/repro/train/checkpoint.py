"""Fault-tolerant, mesh-agnostic checkpointing.

Checkpoints are *logical* (unsharded) arrays: one ``.npy`` per leaf plus a
JSON manifest, committed by atomic directory rename — a half-written
checkpoint is never visible, so preemption mid-save is safe.  Restore
re-shards onto ANY mesh via ``jax.device_put`` with the target shardings:
elastic scale-up/down is a restore with a different mesh.  A background
thread keeps saves off the training path; ``keep`` bounds disk usage.

(On a real multi-host pod the per-leaf gather becomes
``multihost_utils.process_allgather`` and each host writes its owned
shards; the manifest/commit protocol is unchanged.  This container is
single-process, so ``jax.device_get`` suffices.)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import List, Optional

import numpy as np

import jax


def _leaf_paths(tree) -> List[str]:
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree)[0]) \
        if jax.tree.leaves(tree) else ((), None)
    return [jax.tree_util.keystr(p) for p in paths]


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(os.path.join(final, "manifest.json")):
        return final                 # idempotent: this step is committed
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        name = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, name), arr)
        names.append({"key": jax.tree_util.keystr(path), "file": name,
                      "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {"step": int(step), "leaves": names}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final)          # atomic commit

    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree,
            shardings=None):
    """Restore into the structure of ``target_tree``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, leaves are placed sharded —
    this is the elastic-rescale path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    out = []
    for (path, leaf), sh in zip(flat, shard_flat):
        key = jax.tree_util.keystr(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(d, by_key[key]["file"]))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training; at most one in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
