"""Logical-axis sharding: one rules table maps logical names -> mesh axes.

Model code annotates activations with *logical* axis names via
:func:`constrain`; parameters carry logical names in their
:class:`~repro.models.params.ParamSpec`.  The launcher installs a
:class:`ShardingCtx` (mesh + rules); without one, every annotation is a
no-op — so the same model code runs unsharded on CPU smoke tests and fully
sharded under the production mesh.

Default rules (DESIGN.md §4):

    batch   -> ("pod", "data")    data parallel (pod axis folds in)
    vocab   -> "model"            embedding/logits tensor parallel
    heads   -> "model"            attention head TP (divisible archs)
    mlp     -> "model"            FFN hidden TP
    experts -> "model"            MoE expert parallel
    kv_seq  -> "model"            context-parallel KV (non-divisible archs)
    fsdp    -> "data"             ZeRO-3 style param sharding (large archs)
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axis = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,
    "mlp": "model",
    "experts": "model",
    "seq": None,
    "kv_seq": "model",
    # params' d_model dim is ZeRO-3 sharded over the data-parallel axes;
    # on ACTIVATIONS ("batch","seq","embed") the batch spec consumes those
    # axes first, so the embed dim stays unsharded there (spec() dedups).
    "embed": ("pod", "data"),
    "fsdp": ("pod", "data"),     # ZeRO-3 over all data-parallel replicas
    "layers": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "capacity": None,
    "conv": None,
    "state": None,
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Dict[str, Axis]

    def spec(self, logical: Sequence[Optional[str]]) -> PartitionSpec:
        axes = []
        used = set()
        for name in logical:
            ax = self.rules.get(name) if name else None
            # an axis may be consumed at most once per spec
            if ax is None:
                axes.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat
                         if a not in used and a in self.mesh.axis_names)
            used.update(flat)
            if not flat:
                axes.append(None)
            elif len(flat) == 1:
                axes.append(flat[0])
            else:
                axes.append(flat)
        return PartitionSpec(*axes)

    def sharding(self, logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


_ctx = threading.local()


def set_ctx(ctx: Optional[ShardingCtx]) -> None:
    _ctx.value = ctx


def get_ctx() -> Optional[ShardingCtx]:
    return getattr(_ctx, "value", None)


class use_ctx:
    """``with use_ctx(mesh, rules): ...`` — installs the sharding context."""

    def __init__(self, mesh: Optional[Mesh],
                 rules: Optional[Dict[str, Axis]] = None):
        self.ctx = (ShardingCtx(mesh, dict(DEFAULT_RULES, **(rules or {})))
                    if mesh is not None else None)

    def __enter__(self):
        self.prev = get_ctx()
        set_ctx(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        set_ctx(self.prev)
        return False


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a ctx)."""
    ctx = get_ctx()
    if ctx is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical))
