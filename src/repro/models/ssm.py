"""Mamba-2 SSD block (state-space duality, chunked matmul form).

The chunked algorithm (Dao & Gu 2024) turns the linear recurrence

    h_t = a_t h_{t-1} + dt_t * B_t x_t^T ;   y_t = C_t h_t + D x_t

into MXU-friendly work: within chunks of length Q the output is an
attention-like (Q x Q) masked matmul; across chunks a tiny scan carries
the (H, state, head_dim) boundary states.  Heads are sharded over "model"
("ssm_heads") when divisible (mamba2: 64 heads / 16 ✓); otherwise
replicated (hymba's 32-head bank — noted in the roofline table).

Decode is the O(1) recurrence on the carried state; the conv1d keeps a
(d_conv-1)-deep rolling buffer.  Neither grows with context length, which
is why the SSM archs run ``long_500k``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec


def ssd_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return {
        # projection to [z (gate), x, B, C, dt]
        "win": ParamSpec((d, 2 * d_in + 2 * s.d_state + heads),
                         ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.d_conv, conv_dim), ("conv", "ssm_inner"),
                            scale=0.1),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((heads,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((heads,), ("ssm_heads",), init="zeros"),
        "dd": ParamSpec((heads,), ("ssm_heads",), init="ones"),
        "norm": ParamSpec((d_in,), ("ssm_inner",), init="ones"),
        "wout": ParamSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _split(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * s.d_state], axis=-1)
    return z, xbc, dt, d_in, heads


def _ssd_chunked(xh, a, b, c, chunk: int):
    """xh (B,S,H,P) pre-scaled by dt; a (B,S,H) decay in (0,1);
    b/c (B,S,N).  Returns y (B,S,H,P) and final state (B,H,P,N)."""
    B, S, H, P = xh.shape
    N = b.shape[-1]
    nc = S // chunk
    xc = xh.reshape(B, nc, chunk, H, P)
    ac = a.reshape(B, nc, chunk, H)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    la = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-20)), axis=2)  # (B,nc,Q,H)
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]         # (B,nc,Q,K,H)
    iota = jnp.arange(chunk)
    causal = iota[:, None] >= iota[None, :]
    decay = jnp.where(causal[None, None, :, :, None],
                      jnp.exp(seg), 0.0)

    # intra-chunk: (C_q . B_k) * decay(q,k) applied to x_k
    cb = jnp.einsum("bnqs,bnks->bnqk", cc, bc)                # (B,nc,Q,K)
    y_intra = jnp.einsum("bnqk,bnqkh,bnkhp->bnqhp",
                         cb, decay.astype(cb.dtype), xc)

    # chunk-final states: sum_k decay_to_end(k) * b_k (x) x_k
    dte = jnp.exp(la[:, :, -1:, :] - la)                      # (B,nc,Q,H)
    states = jnp.einsum("bnkh,bnks,bnkhp->bnhps",
                        dte.astype(xc.dtype), bc, xc)         # (B,nc,H,P,N)
    a_chunk = jnp.exp(la[:, :, -1, :])                        # (B,nc,H)

    def scanf(h, t):
        st, ach = t
        h_new = h * ach[..., None, None].astype(h.dtype) + st
        return h_new, h        # emit the state ENTERING this chunk

    h0 = jnp.zeros((B, H, P, N), xh.dtype)
    h_last, h_in = lax.scan(scanf, h0,
                            (states.swapaxes(0, 1), a_chunk.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                                # (B,nc,H,P,N)

    # inter-chunk: y += C_q . (decay_from_start(q) * h_in)
    dfs = jnp.exp(la)                                         # (B,nc,Q,H)
    y_inter = jnp.einsum("bnqs,bnqh,bnhps->bnqhp",
                         cc, dfs.astype(cc.dtype), h_in)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_last


def ssd_block(
    p: Dict, cfg: ArchConfig, x: jnp.ndarray,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    cdt=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B,S,d) -> (y (B,S,d), new_cache).  cache = {"state","conv"}."""
    s = cfg.ssm
    B, S, _ = x.shape
    zxbcdt = x @ p["win"].astype(cdt)
    z, xbc, dt, d_in, heads = _split(cfg, zxbcdt)

    conv_w = p["conv_w"].astype(cdt)
    if cache is None:
        # causal depthwise conv over the sequence
        pad = jnp.pad(xbc, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        xbc_c = sum(pad[:, i:i + S] * conv_w[i] for i in range(s.d_conv))
        new_conv = pad[:, -(s.d_conv - 1):, :]   # rolling buffer for decode
    else:
        roll = jnp.concatenate([cache["conv"].astype(cdt), xbc], axis=1)
        xbc_c = sum(roll[:, i + S - 1:i + S] * conv_w[i]
                    for i in range(s.d_conv))
        new_conv = roll[:, -(s.d_conv - 1):, :]
    xbc_c = jax.nn.silu(xbc_c + p["conv_b"].astype(cdt))

    xs, b, c = jnp.split(xbc_c, [d_in, d_in + s.d_state], axis=-1)
    xs = xs.reshape(B, -1, heads, s.head_dim)
    dt_v = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-dt_v * jnp.exp(p["a_log"].astype(jnp.float32)))
    xh = xs * dt_v.astype(cdt)[..., None]

    if cache is None:
        y, h_last = _ssd_chunked(xh, a, b, c, min(s.chunk, S))
        new_state = h_last
    else:
        h = cache["state"].astype(cdt)
        h = h * a.astype(cdt)[:, 0, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", xh[:, 0], b[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", c[:, 0], h)[:, None]
        new_state = h

    y = y + xs * p["dd"].astype(cdt)[None, None, :, None]
    y = y.reshape(B, S, d_in) * jax.nn.silu(z)
    # RMS-style gate norm
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(cdt)
    y = y * p["norm"].astype(cdt)
    out = y @ p["wout"].astype(cdt)
    new_cache = {"state": new_state.astype(jnp.float32),
                 "conv": new_conv.astype(jnp.float32)}
    return out, new_cache
