"""GQA attention with context-parallel KV sharding.

Distribution strategy (DESIGN.md §4): assigned head counts are mostly NOT
divisible by the fixed 16-way model axis (12, 28, 36, 25, 24 heads; GQA kv
2-8), so head tensor-parallelism cannot use the full axis.  Instead the KV
*sequence* is sharded over "model" (``kv_seq`` rule): scores and the
softmax reduction are computed distributed over KV chunks, which splits
attention FLOPs/bytes across the axis for every arch and makes the KV
cache scale with both mesh axes (batch over "data", length over "model").
GSPMD inserts the reduce/all-gather collectives at the softmax and the
attention-output contraction; the §Perf log iterates on them.

Queries are processed in fixed-size chunks via ``lax.scan`` (flash-style)
so the (Q, S) score tile — not the full S x S matrix — bounds memory.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, rope_angles
from repro.models.params import ParamSpec
from repro.models.sharding import constrain

NEG_INF = -1e9
Q_CHUNK = 512


def gqa_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.n_heads, cfg.n_kv_heads
    out = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamSpec((h * hd,), ("heads",), init="zeros")
        out["bk"] = ParamSpec((kv * hd,), ("kv_heads",), init="zeros")
        out["bv"] = ParamSpec((kv * hd,), ("kv_heads",), init="zeros")
    return out


FULL_WINDOW = 1 << 30   # "no sliding window" sentinel (traced-friendly)


def _mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window) -> jnp.ndarray:
    """(Q, S) True where attention is allowed (causal + sliding window).

    ``window`` may be a Python int or a traced scalar (Hymba switches
    global/local per layer inside the layer scan); 0 or FULL_WINDOW means
    full causal attention.
    """
    w = jnp.where(jnp.asarray(window) <= 0, FULL_WINDOW, window)
    ok = k_pos[None, :] <= q_pos[:, None]
    ok &= k_pos[None, :] > q_pos[:, None] - w
    return ok


def _sdpa(q, k, v, q_pos, k_pos, window: int) -> jnp.ndarray:
    """q (B,Q,H,D); k/v (B,S,KV,D) with S context-sharded; GQA grouped."""
    B, Q, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Q, KV, G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32)).astype(q.dtype)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k)
    scores = jnp.where(_mask(q_pos, k_pos, window)[None, None, None],
                       scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Q, H, D)


def gqa_attention(
    p: Dict, cfg: ArchConfig, x: jnp.ndarray,
    positions: jnp.ndarray,
    window: int = 0,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    cdt=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Returns (attn_out (B,S,d), new_cache_entry or None).

    Modes: train/prefill (cache=None -> returns fresh K/V as cache entry);
    decode (cache given, x is the single new token, cache_index scalar).
    """
    B, S, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_

    q = x @ p["wq"].astype(cdt)
    k = x @ p["wk"].astype(cdt)
    v = x @ p["wv"].astype(cdt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)

    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is not None:
        # decode: append the new K/V at cache_index, attend over the cache
        ck = lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        cv = lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
        k_pos = jnp.arange(ck.shape[1])
        valid = k_pos <= cache_index
        qo = _sdpa(q, ck.astype(cdt), cv.astype(cdt),
                   positions, jnp.where(valid, k_pos, 1 << 30), window)
        out = qo.reshape(B, S, h * hd) @ p["wo"].astype(cdt)
        return out, {"k": ck, "v": cv}

    k = constrain(k, "batch", "kv_seq", "kv_heads", None)
    v = constrain(v, "batch", "kv_seq", "kv_heads", None)
    k_pos = positions

    if S <= Q_CHUNK:
        qo = _sdpa(q, k, v, positions, k_pos, window)
    else:
        n = S // Q_CHUNK
        qc = q.reshape(B, n, Q_CHUNK, h, hd).swapaxes(0, 1)
        pc = positions.reshape(n, Q_CHUNK)

        def step(_, qp):
            qi, pi = qp
            return None, _sdpa(qi, k, v, pi, k_pos, window)

        _, oc = lax.scan(step, None, (qc, pc))
        qo = oc.swapaxes(0, 1).reshape(B, S, h, hd)

    out = qo.reshape(B, S, h * hd) @ p["wo"].astype(cdt)
    return out, {"k": k, "v": v}
