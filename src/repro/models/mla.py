"""Multi-head Latent Attention (DeepSeek-V2), absorbed formulation.

The KV cache stores only the compressed latent c_kv (kv_lora_rank) plus the
shared decoupled-RoPE key (rope_head_dim) per position — MLA's point.  We
use the *absorbed* computation in every mode (W_uk folded into the query,
W_uv applied after the attention-weighted latent): nothing of size
(S, heads, head_dim) is ever materialized, which keeps 128-head x 32k-seq
prefill inside HBM.  Latent cache is context-sharded ("kv_seq" -> model)
like the GQA path.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.attention import NEG_INF, Q_CHUNK, _mask
from repro.models.layers import rope_angles
from repro.models.params import ParamSpec
from repro.models.sharding import constrain


def mla_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wdq": ParamSpec((d, m.q_lora_rank), ("embed", None)),
        "wuq_nope": ParamSpec((m.q_lora_rank, h, m.nope_head_dim),
                              (None, "heads", None)),
        "wuq_rope": ParamSpec((m.q_lora_rank, h, m.rope_head_dim),
                              (None, "heads", None)),
        "wdkv": ParamSpec((d, m.kv_lora_rank), ("embed", None)),
        "wk_rope": ParamSpec((d, m.rope_head_dim), ("embed", None)),
        "wuk": ParamSpec((m.kv_lora_rank, h, m.nope_head_dim),
                         (None, "heads", None)),
        "wuv": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                         (None, "heads", None)),
        "wo": ParamSpec((h * m.v_head_dim, d), ("heads", "embed")),
    }


def _apply_rope_1h(x, cos, sin):
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _mla_scores_out(q_lat, q_rope, c_kv, k_rope, q_pos, k_pos, scale):
    """q_lat (B,Q,H,C); q_rope (B,Q,H,R); c_kv (B,S,C); k_rope (B,S,R)."""
    s_lat = jnp.einsum("bqhc,bsc->bhqs", q_lat, c_kv)
    s_rope = jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope)
    scores = (s_lat + s_rope) * scale
    scores = jnp.where(_mask(q_pos, k_pos, 0)[None, None],
                       scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q_lat.dtype)
    return jnp.einsum("bhqs,bsc->bqhc", w, c_kv)   # attention-weighted latent


def mla_attention(
    p: Dict, cfg: ArchConfig, x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    cdt=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads

    # queries through the low-rank bottleneck
    q_lora = x @ p["wdq"].astype(cdt)
    q_nope = jnp.einsum("bsl,lhd->bshd", q_lora, p["wuq_nope"].astype(cdt))
    q_rope = jnp.einsum("bsl,lhr->bshr", q_lora, p["wuq_rope"].astype(cdt))
    cos, sin = rope_angles(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = _apply_rope_1h(q_rope, cos[..., None, :], sin[..., None, :])
    # absorb W_uk into the query: q_lat (B,S,H,kv_lora)
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, p["wuk"].astype(cdt))

    # keys/values: compressed latent + shared rope key
    c_kv_new = x @ p["wdkv"].astype(cdt)
    k_rope_new = _apply_rope_1h(x @ p["wk_rope"].astype(cdt), cos, sin)

    scale = 1.0 / jnp.sqrt(
        jnp.asarray(m.nope_head_dim + m.rope_head_dim, jnp.float32)
    ).astype(cdt)

    if cache is not None:
        c_kv = lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype),
            (0, cache_index, 0))
        k_rope = lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
            (0, cache_index, 0))
        c_kv = constrain(c_kv, "batch", "kv_seq", None)
        k_rope = constrain(k_rope, "batch", "kv_seq", None)
        k_pos = jnp.arange(c_kv.shape[1])
        k_pos = jnp.where(k_pos <= cache_index, k_pos, 1 << 30)
        lat = _mla_scores_out(q_lat, q_rope, c_kv.astype(cdt),
                              k_rope.astype(cdt), positions, k_pos, scale)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        c_kv = constrain(c_kv_new, "batch", "kv_seq", None)
        k_rope = constrain(k_rope_new, "batch", "kv_seq", None)
        k_pos = positions
        if S <= Q_CHUNK:
            lat = _mla_scores_out(q_lat, q_rope, c_kv, k_rope,
                                  positions, k_pos, scale)
        else:
            n = S // Q_CHUNK
            qlc = q_lat.reshape(B, n, Q_CHUNK, h, -1).swapaxes(0, 1)
            qrc = q_rope.reshape(B, n, Q_CHUNK, h, -1).swapaxes(0, 1)
            pc = positions.reshape(n, Q_CHUNK)

            def step(_, t):
                ql, qr, pi = t
                return None, _mla_scores_out(ql, qr, c_kv, k_rope,
                                             pi, k_pos, scale)
            _, oc = lax.scan(step, None, (qlc, qrc, pc))
            lat = oc.swapaxes(0, 1).reshape(B, S, h, -1)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}

    # un-absorb the value projection, then the output projection
    o = jnp.einsum("bqhl,lhv->bqhv", lat, p["wuv"].astype(cdt))
    out = o.reshape(B, S, h * m.v_head_dim) @ p["wo"].astype(cdt)
    return out, new_cache
