"""Composable decoder-only LM covering every assigned architecture family.

One block function dispatches on the arch family (dense / moe / ssm /
hybrid); the layer stack runs under ``lax.scan`` with ``jax.checkpoint``
(rematerialized activations), which keeps HLO size and compile time flat
in depth — essential for lowering 60-layer x 512-device graphs.
Heterogeneous leading layers (DeepSeek-V2's first dense FFN layer) are
stacked and scanned separately.

VLM/audio frontends are STUBS per the assignment: ``embeds`` (precomputed
patch/frame embeddings, (B, F, d_model)) are consumed as a sequence prefix
ahead of the token embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import params as pm
from repro.models.attention import FULL_WINDOW, gqa_attention, gqa_specs
from repro.models.layers import (embed, embed_specs, mlp, mlp_specs,
                                 norm_specs, rms_norm, unembed)
from repro.models.mla import mla_attention, mla_specs
from repro.models.moe import moe_ffn, moe_specs
from repro.models.params import ParamSpec
from repro.models.sharding import constrain
from repro.models.ssm import ssd_block, ssd_specs


# --------------------------------------------------------------- specs

def block_specs(cfg: ArchConfig, dense_ffn: bool = False) -> Dict:
    """Parameter specs for ONE layer."""
    d = cfg.d_model
    out: Dict[str, Any] = {"ln1": norm_specs(d)}
    if cfg.family == "ssm":
        out["ssm"] = ssd_specs(cfg)
        return out
    out["attn"] = mla_specs(cfg) if cfg.mla else gqa_specs(cfg)
    if cfg.hybrid_ssm:
        out["ssm"] = ssd_specs(cfg)
        out["post_attn"] = norm_specs(d)
        out["post_ssm"] = norm_specs(d)
    out["ln2"] = norm_specs(d)
    if cfg.moe is not None and not dense_ffn:
        out["ffn"] = moe_specs(cfg)
    else:
        ff = cfg.moe.d_ff_dense if (cfg.moe and dense_ffn) else cfg.d_ff
        out["ffn"] = mlp_specs(d, ff)
    return out


def model_specs(cfg: ArchConfig) -> Dict:
    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    out = {
        "embed": embed_specs(cfg),
        "final_norm": norm_specs(cfg.d_model),
        "layers": pm.stack_layers(block_specs(cfg), cfg.n_layers - k_dense),
    }
    if k_dense:
        out["dense_layers"] = pm.stack_layers(
            block_specs(cfg, dense_ffn=True), k_dense)
    return out


# --------------------------------------------------------------- cache

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    """Abstract KV/SSM cache specs (materialize with pm.materialize or use
    pm.shape_structs for the dry-run)."""
    def layer_cache() -> Dict:
        c: Dict[str, ParamSpec] = {}
        if cfg.family == "ssm" or cfg.hybrid_ssm:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            heads = d_in // s.head_dim
            c["ssm_state"] = ParamSpec(
                (batch, heads, s.head_dim, s.d_state),
                ("batch", "ssm_heads", None, None), jnp.float32, "zeros")
            c["ssm_conv"] = ParamSpec(
                (batch, s.d_conv - 1, d_in + 2 * s.d_state),
                ("batch", None, "ssm_inner"), jnp.float32, "zeros")
        if cfg.family != "ssm":
            if cfg.mla:
                m = cfg.mla
                c["c_kv"] = ParamSpec((batch, max_len, m.kv_lora_rank),
                                      ("batch", "kv_seq", None),
                                      dtype, "zeros")
                c["k_rope"] = ParamSpec((batch, max_len, m.rope_head_dim),
                                        ("batch", "kv_seq", None),
                                        dtype, "zeros")
            else:
                kv, hd = cfg.n_kv_heads, cfg.head_dim_
                c["k"] = ParamSpec((batch, max_len, kv, hd),
                                   ("batch", "kv_seq", "kv_heads", None),
                                   dtype, "zeros")
                c["v"] = ParamSpec((batch, max_len, kv, hd),
                                   ("batch", "kv_seq", "kv_heads", None),
                                   dtype, "zeros")
        return c

    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    out = {"layers": pm.stack_layers(layer_cache(), cfg.n_layers - k_dense)}
    if k_dense:
        out["dense_layers"] = pm.stack_layers(layer_cache(), k_dense)
    return out


# --------------------------------------------------------------- blocks

def _layer_windows(cfg: ArchConfig, n: int, offset: int = 0) -> np.ndarray:
    """Per-layer attention window (FULL_WINDOW = global)."""
    if not cfg.sliding_window:
        return np.full(n, FULL_WINDOW, dtype=np.int32)
    w = np.full(n, cfg.sliding_window, dtype=np.int32)
    for i in range(n):
        li = i + offset
        is_global = (cfg.global_attn_every and
                     (li % cfg.global_attn_every == 0
                      or li == cfg.n_layers - 1))
        if is_global:
            w[i] = FULL_WINDOW
    return w


def block_apply(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                positions: jnp.ndarray, window,
                cache: Optional[Dict], cache_index,
                dense_ffn: bool = False, cdt=jnp.bfloat16
                ) -> Tuple[jnp.ndarray, Dict]:
    rs = jnp.asarray(cfg.residual_scale, cdt)
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, p["ln1"]["w"], cfg.norm_eps)

    if cfg.family == "ssm":
        sc = ({"state": cache["ssm_state"], "conv": cache["ssm_conv"]}
              if cache is not None else None)
        y, nc = ssd_block(p["ssm"], cfg, h, sc, cache_index, cdt)
        new_cache.update(ssm_state=nc["state"], ssm_conv=nc["conv"])
        return x + y * rs, new_cache

    if cfg.mla:
        mc = ({"c_kv": cache["c_kv"], "k_rope": cache["k_rope"]}
              if cache is not None else None)
        attn_out, kvc = mla_attention(p["attn"], cfg, h, positions,
                                      mc, cache_index, cdt)
        new_cache.update(c_kv=kvc["c_kv"], k_rope=kvc["k_rope"])
    else:
        kc = ({"k": cache["k"], "v": cache["v"]}
              if cache is not None else None)
        attn_out, kvc = gqa_attention(p["attn"], cfg, h, positions, window,
                                      kc, cache_index, cdt)
        new_cache.update(k=kvc["k"], v=kvc["v"])

    if cfg.hybrid_ssm:
        sc = ({"state": cache["ssm_state"], "conv": cache["ssm_conv"]}
              if cache is not None else None)
        ssm_out, nc = ssd_block(p["ssm"], cfg, h, sc, cache_index, cdt)
        new_cache.update(ssm_state=nc["state"], ssm_conv=nc["conv"])
        y = 0.5 * (rms_norm(attn_out, p["post_attn"]["w"], cfg.norm_eps)
                   + rms_norm(ssm_out, p["post_ssm"]["w"], cfg.norm_eps))
    else:
        y = attn_out

    x = x + y * rs
    h2 = rms_norm(x, p["ln2"]["w"], cfg.norm_eps)
    if cfg.moe is not None and not dense_ffn:
        f = moe_ffn(p["ffn"], cfg, h2, cdt)
    else:
        f = mlp(p["ffn"], h2, cdt)
    return x + f * rs, new_cache


# --------------------------------------------------------------- model

def _scan_stack(cfg: ArchConfig, stacked_params: Dict, x: jnp.ndarray,
                positions: jnp.ndarray, windows: jnp.ndarray,
                cache: Optional[Dict], cache_index,
                dense_ffn: bool, remat: bool, collect_cache: bool,
                cdt, unroll: bool = False) -> Tuple[jnp.ndarray,
                                                    Optional[Dict]]:
    fn = block_apply
    if remat:
        # cfg / dense_ffn / cdt are Python-level: must stay static
        fn = jax.checkpoint(
            block_apply, static_argnums=(0, 7, 8),
            policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, xs):
        p, w, c = xs
        out, nc = fn(cfg, p, carry, positions, w, c, cache_index,
                     dense_ffn, cdt)
        # training discards the cache: returning None here lets scan skip
        # materializing the stacked (L, B, S, ...) K/V tensors entirely
        return out, (nc if collect_cache else None)

    if unroll:
        # python loop (HLO grows with L): used by the dry-run's FLOPs
        # estimator, where XLA's cost model must see every layer
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        ncs = []
        for i in range(n):
            sl = jax.tree.map(lambda a: a[i], stacked_params)
            cl = (jax.tree.map(lambda a: a[i], cache)
                  if cache is not None else None)
            x, nc = body(x, (sl, jnp.asarray(windows)[i], cl))
            ncs.append(nc)
        if collect_cache:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            return x, stacked
        return x, None

    xs = (stacked_params, jnp.asarray(windows), cache)
    x, new_cache = lax.scan(body, x, xs)
    return x, new_cache


def forward(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
            embeds: Optional[jnp.ndarray] = None,
            cache: Optional[Dict] = None,
            cache_index=None,
            positions: Optional[jnp.ndarray] = None,
            remat: bool = True,
            return_cache: bool = True,
            unroll: bool = False,
            cdt=jnp.bfloat16) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """tokens (B, S_text); embeds (B, F, d) optional frontend prefix.

    Train/prefill: cache=None or zero-filled cache to fill; returns
    (logits (B, S, vocab_padded), new_cache).  Decode: tokens (B, 1),
    cache + cache_index given.
    """
    x = embed(params["embed"], cfg, tokens, cdt)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(cdt), x], axis=1)
    x = constrain(x, "batch", "seq", "embed")
    S = x.shape[1]
    if positions is None:
        if cache_index is not None and S == 1:
            positions = jnp.asarray(cache_index)[None]
        else:
            positions = jnp.arange(S)

    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    new_cache: Dict[str, Any] = {}
    if k_dense:
        x, nc = _scan_stack(cfg, params["dense_layers"], x, positions,
                            _layer_windows(cfg, k_dense),
                            cache.get("dense_layers") if cache else None,
                            cache_index, True, remat, return_cache, cdt,
                            unroll)
        new_cache["dense_layers"] = nc
    x, nc = _scan_stack(cfg, params["layers"], x, positions,
                        _layer_windows(cfg, cfg.n_layers - k_dense, k_dense),
                        cache.get("layers") if cache else None,
                        cache_index, False, remat, return_cache, cdt,
                        unroll)
    new_cache["layers"] = nc

    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = unembed(params["embed"], cfg, x)
    return logits, (new_cache if return_cache else None)
