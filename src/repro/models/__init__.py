"""Model zoo substrate: composable decoder blocks for all assigned archs."""

from repro.models.transformer import (block_specs, forward, init_cache,
                                      model_specs)

__all__ = ["block_specs", "forward", "init_cache", "model_specs"]
