"""Shared model primitives: RMSNorm, RoPE, SwiGLU MLP, embeddings.

Forward functions take a params dict produced by the matching ``*_specs``
builder (one source of truth per module; tests assert tree compatibility).
Compute runs in ``cdt`` (bf16 on TPU), params are stored fp32.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec
from repro.models.sharding import constrain


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight.astype(dt)


def norm_specs(d: int) -> Dict[str, ParamSpec]:
    return {"w": ParamSpec((d,), ("embed",), init="ones")}


# ------------------------------------------------------------------ RoPE

def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ----------------------------------------------------------------- MLP

def mlp_specs(d: int, ff: int) -> Dict[str, ParamSpec]:
    return {
        "gate": ParamSpec((d, ff), ("embed", "mlp")),
        "up": ParamSpec((d, ff), ("embed", "mlp")),
        "down": ParamSpec((ff, d), ("mlp", "embed")),
    }


def mlp(p: Dict, x: jnp.ndarray, cdt=jnp.bfloat16) -> jnp.ndarray:
    """SwiGLU MLP; hidden dim tensor-parallel over the ``mlp`` axis."""
    g = x @ p["gate"].astype(cdt)
    u = x @ p["up"].astype(cdt)
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "mlp")
    return h @ p["down"].astype(cdt)


# ----------------------------------------------------------- embeddings

def embed_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    # pad vocab up to a multiple of 16 so it shards over the model axis
    vpad = -(-cfg.vocab // 16) * 16
    out = {"tok": ParamSpec((vpad, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["out"] = ParamSpec((cfg.d_model, vpad), ("embed", "vocab"))
    return out


def embed(p: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
          cdt=jnp.bfloat16) -> jnp.ndarray:
    e = jnp.take(p["tok"].astype(cdt), tokens, axis=0)
    return e * jnp.asarray(cfg.embed_scale, cdt)


def unembed(p: Dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Final projection in fp32; returns logits over the PADDED vocab
    (ids >= cfg.vocab are never targets; loss masks them out)."""
    if cfg.tie_embeddings:
        w = p["tok"].astype(jnp.float32).T
    else:
        w = p["out"].astype(jnp.float32)
    logits = x.astype(jnp.float32) @ w
    logits = logits * cfg.logit_scale
    return constrain(logits, "batch", "seq", "vocab")
