"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style).

Expert weights are expert-parallel ("experts" -> model axis); the dispatch
buffer (E, C, d) is sharded (experts -> model, capacity -> data), so GSPMD
materializes the token->expert exchange as all-to-all-class collectives —
the honest communication pattern of EP at scale, visible to the roofline.

Top-k routing with per-expert capacity C = ceil(cf * N * k / E); overflow
tokens are dropped (standard), underflow slots padded with zeros.  Shared
experts (DeepSeek-V2) are plain dense FFNs added to the routed output.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import mlp, mlp_specs
from repro.models.params import ParamSpec
from repro.models.sharding import constrain


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.n_experts, mo.d_ff_expert
    out = {
        "router": ParamSpec((d, e), ("embed", None), scale=0.006),
        "gate": ParamSpec((e, d, f), ("experts", "fsdp", None)),
        "up": ParamSpec((e, d, f), ("experts", "fsdp", None)),
        "down": ParamSpec((e, f, d), ("experts", None, "fsdp")),
    }
    for i in range(mo.n_shared):
        out[f"shared{i}"] = mlp_specs(d, mo.d_ff_shared)
    return out


def moe_ffn(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
            cdt=jnp.bfloat16) -> jnp.ndarray:
    """x (B, S, d) -> (B, S, d)."""
    mo = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = mo.n_experts, mo.top_k
    C = max(8, int(-(-mo.capacity_factor * N * K // E)))
    C = min(C, N)

    xf = x.reshape(N, d)
    logits = (xf @ p["router"].astype(cdt)).astype(jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates_all, K)          # (N, K)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # per-(token, slot) position within its expert's capacity buffer
    counts = jnp.zeros((E,), jnp.int32)
    pos = jnp.zeros((N, K), jnp.int32)
    for j in range(K):
        onehot = jax.nn.one_hot(top_e[:, j], E, dtype=jnp.int32)
        within = jnp.cumsum(onehot, axis=0) - 1          # (N, E)
        pos = pos.at[:, j].set(
            jnp.take_along_axis(within + counts[None, :],
                                top_e[:, j:j + 1], axis=1)[:, 0])
        counts = counts + onehot.sum(axis=0)
    keep = (pos < C)
    pos_c = jnp.minimum(pos, C - 1)

    # dispatch: scatter tokens into the (E, C, d) buffer
    buf = jnp.zeros((E, C, d), cdt)
    for j in range(K):
        contrib = xf * keep[:, j:j + 1].astype(cdt)
        buf = buf.at[top_e[:, j], pos_c[:, j]].add(contrib)
    buf = constrain(buf, "experts", "capacity", None)

    # expert computation (batched over the expert axis)
    g = jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(cdt))
    h = jax.nn.silu(g) * u
    h = constrain(h, "experts", "capacity", None)
    ob = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(cdt))
    ob = constrain(ob, "experts", "capacity", None)

    # combine: gather each token's expert outputs, weight by gates
    y = jnp.zeros((N, d), cdt)
    for j in range(K):
        o = ob[top_e[:, j], pos_c[:, j]]
        w = (top_g[:, j] * keep[:, j]).astype(cdt)
        y = y + o * w[:, None]

    y = y.reshape(B, S, d)
    for i in range(mo.n_shared):
        y = y + mlp(p[f"shared{i}"], x, cdt)   # shared experts: dense path
    return y
