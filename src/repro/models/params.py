"""Parameter metadata: one source of truth for shapes, init, and sharding.

Models build a pytree of :class:`ParamSpec`; the same tree then yields
(a) materialized parameters for tests/training, (b) NamedShardings for the
dry-run/pjit, and (c) ShapeDtypeStructs for ``jax.eval_shape``-style use.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.sharding import ShardingCtx


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"           # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape,
                                                      self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def materialize(tree, key: jax.Array, dtype=None):
    """Initialize real parameter arrays from a ParamSpec tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dt))
        else:
            out.append((jax.random.normal(k, spec.shape, dtype=jnp.float32)
                        * spec.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def shape_structs(tree, ctx: Optional[ShardingCtx] = None):
    """ShapeDtypeStructs (optionally sharded) for the dry-run."""
    def f(spec: ParamSpec):
        sh = ctx.sharding(spec.logical) if ctx is not None else None
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sh)
    return tree_map_specs(f, tree)


def shardings(tree, ctx: ShardingCtx):
    return tree_map_specs(lambda s: ctx.sharding(s.logical), tree)


def specs(tree, ctx: ShardingCtx):
    return tree_map_specs(lambda s: ctx.spec(s.logical), tree)


def n_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def stack_layers(tree, n: int):
    """Add a leading stacked-layers axis to every spec (for lax.scan)."""
    def f(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("layers",) + s.logical,
                         s.dtype, s.init, s.scale)
    return tree_map_specs(f, tree)
