"""Hymba-1.5B [arXiv:2411.13676; hf] — hybrid-head: parallel attn + SSM.

Every block runs GQA attention (25 heads, kv=5) and a Mamba head bank in
PARALLEL on the same input; per-path RMSNorm then mean fusion.  Most layers
use sliding-window attention (window 1024); every 8th layer (and the last)
is global — giving sub-quadratic long-context decode (long_500k runs).
ssm_state=16 per the assignment.
"""

from repro.configs.base import ArchConfig, SsmConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    hybrid_ssm=True,
    sliding_window=1024, global_attn_every=8,
    ssm=SsmConfig(d_state=16, d_conv=4, expand=2, head_dim=50, chunk=256),
    source="arXiv:2411.13676",
)
