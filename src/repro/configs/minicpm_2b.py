"""MiniCPM-2B [arXiv:2404.06395; hf] — llama-like dense + WSD schedule.

MiniCPM's training tricks are reflected here: scaled embeddings
(``embed_scale=12``), depth-scaled residual branches
(``1.4 / sqrt(n_layers)``), and logits scaled by ``1/(d_model/256)``.
The WSD learning-rate schedule is selected in train/optimizer.py when
``schedule="wsd"`` (the default train.py picks it for this arch).
"""

import math

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, tie_embeddings=True,
    embed_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=256.0 / 2304.0,
    source="arXiv:2404.06395",
)
