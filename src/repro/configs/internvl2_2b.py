"""InternVL2-2B [arXiv:2404.16821; hf] — InternViT frontend + InternLM2-1.8B.

The assigned backbone is the InternLM2-1.8B decoder; the InternViT vision
tower is a STUB per the assignment: ``input_specs()`` supplies 256
precomputed patch embeddings per sample (the 448x448 pixel-unshuffled tile)
which the backbone consumes as a prefix.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    rope_theta=1_000_000.0,
    frontend_tokens=256,
    source="arXiv:2404.16821",
)
