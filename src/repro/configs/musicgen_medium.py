"""MusicGen-medium [arXiv:2306.05284; hf] — decoder over EnCodec tokens.

48-layer decoder, d_model 1536, 24 heads (MHA: kv=24), d_ff 6144 (GELU MLP
in the original; we keep the SwiGLU substrate with matched width), vocab
2048 (one EnCodec codebook).  The EnCodec frontend + codebook delay pattern
is a STUB: ``input_specs()`` provides the summed codebook frame embeddings
for the prompt region; generation proceeds token-by-token per codebook.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    frontend_tokens=256,
    source="arXiv:2306.05284",
)
