"""Assigned-architecture registry: ``--arch <id>`` resolves here.

The paper (FIFOAdvisor) contributes an EDA algorithm, not a network
architecture; its "own configs" are the Stream-HLS dataflow designs in
:mod:`repro.designs`.  The LM pool below exercises the distributed
substrate (models, sharding, dry-run, roofline).
"""

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

from repro.configs.qwen2_1_5b import CONFIG as _qwen2_1_5b
from repro.configs.internlm2_1_8b import CONFIG as _internlm2_1_8b
from repro.configs.qwen2_7b import CONFIG as _qwen2_7b
from repro.configs.minicpm_2b import CONFIG as _minicpm_2b
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2_236b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.mamba2_1_3b import CONFIG as _mamba2_1_3b
from repro.configs.hymba_1_5b import CONFIG as _hymba_1_5b
from repro.configs.internvl2_2b import CONFIG as _internvl2_2b
from repro.configs.musicgen_medium import CONFIG as _musicgen_medium

ARCHS = {
    c.name: c for c in [
        _qwen2_1_5b, _internlm2_1_8b, _qwen2_7b, _minicpm_2b,
        _deepseek_v2_236b, _qwen3_moe, _mamba2_1_3b, _hymba_1_5b,
        _internvl2_2b, _musicgen_medium,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "get_arch"]
