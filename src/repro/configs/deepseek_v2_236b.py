"""DeepSeek-V2 236B [arXiv:2405.04434; hf] — MLA + MoE 160e top-6.

MLA: kv_lora_rank=512, q_lora_rank=1536, decoupled RoPE head 64,
nope/v head dims 128.  MoE: 2 shared + 160 routed experts (top-6),
expert FFN width 1536; the first layer uses a dense FFN (width 12288).
"""

from repro.configs.base import ArchConfig, MlaConfig, MoeConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    mla=MlaConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoeConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared=2, d_ff_shared=1536,
                  first_k_dense=1, d_ff_dense=12288),
    source="arXiv:2405.04434",
)
