"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality).

48 blocks, d_model 2048, d_state 128, expand 2 (d_inner 4096), head_dim 64
(64 SSD heads), conv width 4.  Runs long_500k (constant-size state).
"""

from repro.configs.base import ArchConfig, SsmConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=64, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm=SsmConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060",
)
