"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` instance in its own
module (``repro/configs/<id>.py``), selectable by ``--arch <id>`` in the
launchers.  ``reduced()`` yields the family-preserving small config used by
CPU smoke tests; the full config is exercised only through the dry-run
(ShapeDtypeStruct, no allocation).

Input shapes (identical for every LM arch, per the assignment):

    train_4k     seq 4096,  global_batch 256   (train_step)
    prefill_32k  seq 32768, global_batch 32    (serve prefill)
    decode_32k   seq 32768, global_batch 128   (serve decode: 1 new token)
    long_500k    seq 524288, global_batch 1    (decode; sub-quadratic only)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    first_k_dense: int = 0          # leading layers that use a dense FFN
    d_ff_dense: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MlaConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoeConfig] = None
    mla: Optional[MlaConfig] = None
    ssm: Optional[SsmConfig] = None
    # hybrid (Hymba): parallel attention+SSM heads, sliding-window attn
    hybrid_ssm: bool = False
    sliding_window: int = 0         # 0 = full attention
    global_attn_every: int = 0      # hybrid: every k-th layer is global
    # modality frontend stub: number of precomputed embedding tokens
    frontend_tokens: int = 0
    # MiniCPM-style scaling tricks
    embed_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # notes for DESIGN.md / roofline
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k? (constant-state or windowed attn)"""
        return self.family == "ssm" or (self.hybrid_ssm
                                        and self.sliding_window > 0)

    def supports_shape(self, shape: str) -> bool:
        if shape == "long_500k":
            return self.subquadratic
        return shape in SHAPES

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim_
        p = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per_layer = d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim) \
                + d_in * d + d_in * s.d_conv
        else:
            if self.mla is not None:
                m = self.mla
                q_dim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                per_layer = (d * m.q_lora_rank + m.q_lora_rank * q_dim
                             + d * (m.kv_lora_rank + m.rope_head_dim)
                             + m.kv_lora_rank * self.n_heads
                             * (m.nope_head_dim + m.v_head_dim)
                             + self.n_heads * m.v_head_dim * d)
            else:
                per_layer = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            if self.hybrid_ssm:
                s = self.ssm
                d_in = s.expand * d
                per_layer += d * (2 * d_in + 2 * s.d_state
                                  + d_in // s.head_dim) + d_in * d
            if self.moe is not None:
                mo = self.moe
                per_layer += d * mo.n_experts          # router
                per_layer += mo.n_experts * 3 * d * mo.d_ff_expert
                per_layer += mo.n_shared * 3 * d * mo.d_ff_shared
            else:
                per_layer += 3 * d * self.d_ff
        return int(p + L * per_layer)

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if self.moe is None:
            return self.n_params()
        mo = self.moe
        d, L = self.d_model, self.n_layers
        inactive = (mo.n_experts - mo.top_k) * 3 * d * mo.d_ff_expert
        return int(self.n_params() - L * inactive)

    def reduced(self) -> "ArchConfig":
        """Family-preserving small config for CPU smoke tests."""
        def shrink_moe(m: Optional[MoeConfig]) -> Optional[MoeConfig]:
            if m is None:
                return None
            return dataclasses.replace(
                m, n_experts=min(8, m.n_experts), top_k=min(2, m.top_k),
                d_ff_expert=32, n_shared=min(1, m.n_shared), d_ff_shared=32,
                first_k_dense=min(1, m.first_k_dense), d_ff_dense=64)

        def shrink_mla(m: Optional[MlaConfig]) -> Optional[MlaConfig]:
            if m is None:
                return None
            return MlaConfig(kv_lora_rank=16, q_lora_rank=24,
                             rope_head_dim=8, nope_head_dim=16, v_head_dim=16)

        def shrink_ssm(s: Optional[SsmConfig]) -> Optional[SsmConfig]:
            if s is None:
                return None
            return SsmConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                             chunk=32)

        return dataclasses.replace(
            self,
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_ff=128, vocab=512, head_dim=16,
            moe=shrink_moe(self.moe), mla=shrink_mla(self.mla),
            ssm=shrink_ssm(self.ssm),
            sliding_window=min(self.sliding_window, 32)
            if self.sliding_window else 0,
            frontend_tokens=min(self.frontend_tokens, 8),
        )
