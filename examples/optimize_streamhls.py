"""DSE over any Stream-HLS benchmark with any optimizer set.

  PYTHONPATH=src python examples/optimize_streamhls.py \
      --design k15mmtree --optimizers greedy grouped_sa nsga2 --budget 500
"""

import argparse

from repro.core import FifoAdvisor
from repro.core.optimizers import OPTIMIZERS
from repro.designs import STREAMHLS_DESIGNS, make_design


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--design", default="k15mmtree",
                    choices=sorted(STREAMHLS_DESIGNS))
    ap.add_argument("--optimizers", nargs="+", default=["greedy",
                    "grouped_random", "grouped_sa"],
                    choices=sorted(OPTIMIZERS))
    ap.add_argument("--budget", type=int, default=500)
    ap.add_argument("--alpha", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    adv = FifoAdvisor(make_design(args.design))
    bm = adv.baseline_max
    print(f"{args.design}: {adv.graph.n_fifos} FIFOs, "
          f"{adv.graph.n_events} trace events, trace {adv.trace_time_s:.2f}s")
    print(f"Baseline-Max ({bm.latency} cyc, {bm.bram} BRAM) | Baseline-Min "
          f"{'DEADLOCKS' if adv.baseline_min.deadlocked else adv.baseline_min.latency}")

    for opt in args.optimizers:
        r = adv.run(opt, budget=args.budget, seed=args.seed)
        sel = r.selected(alpha=args.alpha)
        star = (f"{int(sel[0][0])} cyc @ {int(sel[0][1])} BRAM"
                if sel else "none")
        print(f"  {opt:16s} {r.result.n_evals:5d} evals "
              f"{r.result.runtime_s:7.2f}s  |front|={len(r.frontier_points):3d} "
              f"star[{args.alpha}]: {star}")


if __name__ == "__main__":
    main()
