"""The paper's §IV-D case study: a FlowGNN-PNA-like accelerator whose FIFO
feasibility depends on the runtime graph — only simulation can size it.

  PYTHONPATH=src python examples/ddcf_case_study.py
"""


from repro.core import FifoAdvisor
from repro.designs import flowgnn_pna


def main():
    for seed in (7, 1234):
        d = flowgnn_pna(seed=seed)
        adv = FifoAdvisor(d)
        print(f"graph seed {seed}: hand-sized baseline "
              f"{adv.baseline_max.latency} cyc @ {adv.baseline_max.bram} "
              f"BRAM | all-FIFOs-=2 deadlocks: "
              f"{adv.baseline_min.deadlocked}")
        r = adv.run("grouped_sa", budget=800, seed=0)
        sel = r.selected(alpha=0.7)
        if sel:
            (lat, bram), depths = sel
            print(f"  FIFOAdvisor pick: {int(lat)} cyc @ {int(bram)} BRAM "
                  f"({bram / max(adv.baseline_max.bram, 1):.0%} of "
                  f"hand-sized memory)")
            named = {f.name: int(depths[f.index]) for f in d.fifos
                     if f.name.startswith(("deg_", "skip", "feat"))}
            print(f"  control-queue depths: {named}")


if __name__ == "__main__":
    main()
