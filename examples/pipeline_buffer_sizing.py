"""The FIFOAdvisor <-> distributed-training bridge (DESIGN.md §5).

Takes per-layer compute cost straight from the dry-run roofline records
(per-layer FLOPs / chip peak), compiles a pipeline-parallel stage graph
into a dataflow design, and lets the UNMODIFIED FIFOAdvisor machinery size
the activation/grad/stash queues — the latency axis is pipeline makespan
(bubbles), the memory axis is buffered microbatches.

  PYTHONPATH=src python examples/pipeline_buffer_sizing.py \
      --arch qwen2-7b --stages 8 --microbatches 16
"""

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch                       # noqa: E402
from repro.core import FifoAdvisor                       # noqa: E402
from repro.core.bridge import pipeline_design, \
    stages_from_layer_cost                               # noqa: E402

PEAK_FLOPS = 197e12
CLOCK_HZ = 940e6        # v5e core clock: cycles = seconds * clock


def layer_cycles_from_dryrun(arch: str) -> int:
    """Per-layer fwd cycles from the recorded dry-run (train_4k cell)."""
    pat = os.path.join("benchmarks", "results", "dryrun",
                       f"{arch}__train_4k__16x16.json")
    for path in glob.glob(pat):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok" and rec.get("hlo_flops"):
            n_layers = get_arch(arch).n_layers
            per_layer_s = (rec["hlo_flops"] / n_layers / 8  # fwd ~1/8 step
                           / (rec["chips"] * PEAK_FLOPS))
            return max(1, int(per_layer_s * CLOCK_HZ / 1000))  # kilocycles
    return 25   # fallback if the dry-run has not been run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--budget", type=int, default=400)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    per_stage_layers = max(1, cfg.n_layers // args.stages)
    cyc = layer_cycles_from_dryrun(args.arch)
    print(f"{args.arch}: {cfg.n_layers} layers -> {args.stages} stages x "
          f"{per_stage_layers} layers, ~{cyc} kcyc/layer fwd "
          f"(from dry-run roofline)")

    # mild imbalance: embedding-heavy first stage, loss-heavy last stage
    imb = [1.15] + [1.0] * (args.stages - 2) + [1.25]
    stages = stages_from_layer_cost(args.stages, per_stage_layers, cyc,
                                    imbalance=imb)
    d = pipeline_design(stages, n_microbatches=args.microbatches)
    adv = FifoAdvisor(d)
    print(f"pipeline design: {adv.graph.n_fifos} queues, "
          f"{adv.graph.n_events} trace events")
    print(f"  all-queues-max (GPipe-like): {adv.baseline_max.latency} cyc "
          f"@ {adv.baseline_max.bram} buffer units")
    print(f"  all-queues-2 (1F1B-like): "
          f"{'DEADLOCK' if adv.baseline_min.deadlocked else adv.baseline_min.latency}")

    r = adv.run("grouped_sa", budget=args.budget, seed=0)
    print("  frontier (makespan cycles, buffer units):")
    for lat, bram in r.frontier_points[:10]:
        print(f"    {int(lat):8d}  {int(bram):4d}")
    (lat, bram), depths = r.selected(alpha=0.7)
    stash = [int(depths[d.fifo_index(f'stash_{i}')])
             for i in range(args.stages)]
    print(f"  alpha=0.7 pick: {int(lat)} cyc @ {int(bram)} units; "
          f"stash depths (microbatches in flight) = {stash}")


if __name__ == "__main__":
    main()
