"""Advisory-service example: three clients, two designs, one batcher.

  PYTHONPATH=src python examples/advisory_service.py

Opens concurrent sessions on two designs through the in-process
AdvisorClient, streams their progress events, cancels one mid-run, and
shows that a served session's frontier is bit-identical to a solo
FifoAdvisor.run() with the same seed.  The same protocol runs over TCP
via `python -m repro.launch.serve` (see docs/service.md).
"""

import numpy as np

from repro.core import FifoAdvisor
from repro.core.service import AdvisorClient
from repro.designs import make_design


def main():
    client = AdvisorClient()

    # three clients arrive: two designs, mixed optimizers/seeds
    a = client.open("gemm", optimizer="grouped_sa", budget=200, seed=0)
    b = client.open("FeedForward", optimizer="grouped_random",
                    budget=200, seed=1)
    c = client.open("gemm", optimizer="grouped_random", budget=800,
                    seed=2)

    # interleave a few rounds, then one client disconnects
    for _ in range(4):
        client.request({"op": "step"})
    print(f"cancelling {c} mid-run:", c.cancel())

    client.drive()   # run the survivors to completion

    for h in (a, b):
        st = h.status()
        print(f"{h}: {st['design']}/{st['optimizer']} -> {st['state']} "
              f"after {st['rounds']} rounds, {st['n_evals']} simulated")
        for ev in client.events(h)[-3:]:
            print(f"   {ev['event']:9s} frontier={ev['frontier_size']} "
                  f"hv={ev['hypervolume']:.0f}")

    # the service guarantee: batched == solo, bit for bit
    served = a.result()
    solo = FifoAdvisor(make_design("gemm")).run("grouped_sa", budget=200,
                                                seed=0)
    assert np.array_equal(served.frontier_points, solo.frontier_points)
    print("\nserved frontier == solo frontier:", True)
    print("selected (alpha=0.7):", a.result_json()["selected"])

    stats = client.request({"op": "stats"})["stats"]
    print(f"service: {stats['n_sessions']} sessions, "
          f"{stats['batcher']['rounds']} rounds, designs traced once: "
          f"{sorted(stats['designs'])}")


if __name__ == "__main__":
    main()
