"""End-to-end LM training driver on the synthetic Markov stream.

Default is a CPU-sized run that shows a clear loss decrease in ~2 minutes;
``--preset 100m`` configures a ~100M-parameter model (the few-hundred-step
run the substrate supports on real accelerators — on this CPU container it
is hours, so it is opt-in).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    if args.preset == "100m":
        # ~100M params: d=768, widen batch; runnable on one accelerator
        argv = ["--arch", args.arch, "--steps", str(args.steps),
                "--batch", "16", "--seq", "512", "--ckpt", args.ckpt]
        print("NOTE: 100m preset is sized for a real accelerator; "
              "expect hours on CPU")
    else:
        argv = ["--arch", args.arch, "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--ckpt", args.ckpt,
                "--save-every", "100"]
    out = train_main(argv)
    print(f"loss: {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"over {out['steps']} steps")
    assert out["last_loss"] < out["first_loss"], "loss did not decrease"


if __name__ == "__main__":
    main()
