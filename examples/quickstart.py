"""Quickstart: size the FIFOs of an HLS dataflow design in ~seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import EvalConfig, FifoAdvisor
from repro.core.design import Design


def build_design() -> Design:
    """A producer/worker/consumer diamond with a slow worker: the skip
    queue must cover the worker's latency or the design stalls/deadlocks."""
    d = Design("quickstart")
    d.fifo("raw", width=32)
    d.fifo("skip", width=32)
    d.fifo("cooked", width=32)
    N = 256

    @d.task("source")
    def source(ctx):
        for i in range(N):
            yield ctx.delay(1)
            yield ctx.write("raw", i)
            yield ctx.write("skip", i)

    @d.task("worker")
    def worker(ctx):
        for _ in range(N):
            v = yield ctx.read("raw")
            yield ctx.delay(6)            # slow compute
            yield ctx.write("cooked", 2 * v)

    @d.task("join")
    def join(ctx):
        acc = 0
        for _ in range(N):
            a = yield ctx.read("skip")
            b = yield ctx.read("cooked")
            yield ctx.delay(1)
            acc += a + b
        ctx.result("sum", acc)

    return d


def main():
    # backend="numpy" (default) is the worklist evaluator with the
    # incremental fast path; "jax" / "pallas" select the batched scan
    # backends (docs/backends.md)
    advisor = FifoAdvisor(build_design(), EvalConfig(backend="numpy"))
    print(f"Baseline-Max: latency={advisor.baseline_max.latency} "
          f"BRAMs={advisor.baseline_max.bram}")
    print(f"Baseline-Min: latency={advisor.baseline_min.latency} "
          f"deadlocked={advisor.baseline_min.deadlocked}")

    result = advisor.run("grouped_sa", budget=400, seed=0)
    print("\nPareto frontier (latency, FIFO BRAMs):")
    for lat, bram in result.frontier_points:
        print(f"  {int(lat):6d} cycles  {int(bram):3d} BRAMs")

    (lat, bram), depths = result.selected(alpha=0.7)
    print(f"\nalpha=0.7 pick: {int(lat)} cycles @ {int(bram)} BRAMs")
    for f, dep in zip(advisor.design.fifos, depths):
        print(f"  {f.name:8s} depth {int(dep)}")

    # one incremental re-simulation (the LightningSim primitive): what
    # happens to latency if the chosen config shrinks the skip queue?
    probe = depths.astype(int).copy()
    probe[1] = max(1, probe[1] // 2)
    advisor.incremental_latency(depths)          # seed the base state
    lat2, dead = advisor.incremental_latency(probe)
    print(f"\nincremental probe skip->{probe[1]}: "
          f"{'DEADLOCK' if dead else f'{lat2} cycles'}")

    cs = advisor.cache_stats()
    print(f"cache: {cs.hits} hits / {cs.misses} misses "
          f"({cs.hit_rate:.0%} hit rate)")


if __name__ == "__main__":
    main()
