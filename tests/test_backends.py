"""Unified evaluation-backend subsystem: cross-backend equivalence, the
dispatch policy, the vectorized config cache, and the incremental
re-simulation fast path.

The three registered backends (numpy worklist, jit/vmap fixpoint scan,
Pallas kernel in interpret mode) share operand preparation but differ in
the entire solve; exact agreement on randomized designs — latency, BRAM,
and deadlock — is the subsystem's core invariant.
"""

import numpy as np
import pytest

from repro.core import build_simgraph
from repro.core.backends import (ConfigCache, available_backends,
                                 get_backend)
from repro.core.backends import worklist as wl
from repro.core.design import Design
from repro.core.optimizers import EvalContext
from repro.core.config import EvalConfig
from repro.core.simulate import BatchedEvaluator
from repro.designs.builder import map_stage, producer, sink, streams
from repro.designs.ddcf import mult_by_2


def random_chain(seed: int) -> Design:
    """Random producer -> k map stages -> sink chain (always sequentially
    executable; arbitrary rate mismatches and lane counts)."""
    rng = np.random.default_rng(seed)
    count = int(rng.integers(4, 32))
    k = int(rng.integers(1, 4))
    lanes = int(rng.choice([1, 2]))
    d = Design(f"chain{seed}")
    cur = streams(d, "s0", lanes)
    producer(d, "prod", cur, [1.0] * count, ii=int(rng.integers(1, 4)),
             start_delay=int(rng.integers(0, 6)))
    for i in range(k):
        nxt = streams(d, f"s{i + 1}", lanes)
        map_stage(d, f"m{i}", cur, nxt, count, ii=int(rng.integers(1, 4)),
                  extra_delay=int(rng.integers(0, 5)))
        cur = nxt
    sink(d, "sink", cur, count, ii=int(rng.integers(1, 4)))
    return d


def test_registry_has_canonical_backends():
    assert set(available_backends()) == {"worklist", "fixpoint", "pallas",
                                         "mesh"}
    # aliases resolve to the same classes
    assert get_backend("numpy") is get_backend("worklist")
    assert get_backend("jax") is get_backend("fixpoint")
    assert get_backend("sharded") is get_backend("mesh")
    with pytest.raises(ValueError):
        get_backend("nope")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_backend_equivalence_on_random_designs(seed):
    """worklist == fixpoint == pallas(interpret) on randomized designs and
    randomized depth matrices (latency, BRAM, and deadlock)."""
    d = random_chain(seed)
    g = build_simgraph(d)
    rng = np.random.default_rng(seed + 100)
    u = g.upper_bounds
    cfgs = np.stack([u, np.full(g.n_fifos, 2)] +
                    [rng.integers(2, np.maximum(3, u + 1))
                     for _ in range(6)])
    results = {}
    for backend in ("numpy", "jax", "pallas"):
        ev = BatchedEvaluator(
            g, EvalConfig(backend=backend, max_iters=128))
        results[backend] = ev.evaluate(cfgs)
    for backend in ("jax", "pallas"):
        for a, b in zip(results["numpy"], results[backend]):
            np.testing.assert_array_equal(a, b, err_msg=backend)


def test_backend_equivalence_on_known_deadlock():
    """mult_by_2(n) deadlocks iff depth(x) < n - 1; every backend must
    agree on both sides of the boundary."""
    d = mult_by_2(16)
    g = build_simgraph(d)
    cfgs = np.array([[14, 2], [15, 2], [16, 2], [2, 2]])
    expect_dead = np.array([True, False, False, True])
    for backend in ("numpy", "jax", "pallas"):
        ev = BatchedEvaluator(g, EvalConfig(backend=backend, max_iters=128))
        _, _, dead = ev.evaluate(cfgs)
        np.testing.assert_array_equal(dead, expect_dead, err_msg=backend)


def test_dispatch_escalates_unresolved_rows():
    """A tiny iteration cap forces UNRESOLVED rows; the dispatch policy
    must escalate them to the worklist and still return exact results."""
    d = mult_by_2(24)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g, EvalConfig(backend="jax", max_iters=3))
    lat, _, dead = ev.evaluate(np.array([[24, 2], [2, 2]]))
    assert ev.stats.n_fallbacks >= 1
    ref_lat, ref_dead = wl.evaluate_np(g, np.array([24, 2]))
    assert not dead[0] and int(lat[0]) == ref_lat
    assert bool(dead[1])


def test_dispatch_bucket_padding_matches_unpadded():
    """Bucketing pads C to fixed jit shapes; results must be identical to
    evaluating the exact batch."""
    d = random_chain(3)
    g = build_simgraph(d)
    rng = np.random.default_rng(3)
    u = g.upper_bounds
    cfgs = np.stack([rng.integers(2, np.maximum(3, u + 1))
                     for _ in range(5)])     # 5 -> bucket 8
    ev = BatchedEvaluator(g, EvalConfig(backend="jax", max_iters=128))
    ev_ref = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=64))
    for a, b in zip(ev.evaluate(cfgs), ev_ref.evaluate(cfgs)):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------ incremental

@pytest.mark.parametrize("seed", [0, 1])
def test_incremental_random_walk_matches_full(seed):
    """Chained single/multi-FIFO deltas agree with full solves at every
    step, including deadlocked intermediate states as bases."""
    d = random_chain(seed + 10)
    g = build_simgraph(d)
    rng = np.random.default_rng(seed)
    F = g.n_fifos
    u = np.maximum(g.upper_bounds, 3)
    state = wl.solve(g, np.maximum(2, u))
    for step in range(30):
        nxt = state.depths.copy()
        for _ in range(int(rng.integers(1, 3))):
            f = int(rng.integers(0, F))
            nxt[f] = int(rng.integers(1, u[f] + 2))
        state = wl.solve_delta(g, state, nxt)
        full = wl.solve(g, nxt)
        assert state.deadlocked == full.deadlocked, step
        assert state.latency == full.latency, step
        np.testing.assert_array_equal(state.t, full.t)
        np.testing.assert_array_equal(state.seg_cursor, full.seg_cursor)


def test_incremental_from_deadlocked_base():
    d = mult_by_2(24)
    g = build_simgraph(d)
    base = wl.solve(g, np.array([2, 2]))
    assert base.deadlocked
    st = wl.solve_delta(g, base, np.array([40, 2]))
    full = wl.solve(g, np.array([40, 2]))
    assert (st.latency, st.deadlocked) == (full.latency, full.deadlocked)
    assert not st.deadlocked


def test_evaluator_incremental_api_matches_evaluate():
    d = mult_by_2(24)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=64))
    base = np.array([40, 2])
    trials = np.array([[24, 2], [2, 2], [40, 8]])
    lat_i, bram_i, dead_i = ev.evaluate_incremental(base, trials)
    lat_f, bram_f, dead_f = ev.evaluate(trials)
    np.testing.assert_array_equal(lat_i, np.where(dead_f, -1, lat_f))
    np.testing.assert_array_equal(bram_i, bram_f)
    np.testing.assert_array_equal(dead_i, dead_f)
    assert ev.stats.n_incremental == 3


def test_advisor_incremental_latency_chain():
    from repro.core import FifoAdvisor
    adv = FifoAdvisor(mult_by_2(32))
    lat, dead = adv.incremental_latency(np.array([40, 2]))
    assert not dead and lat > 0
    # second call deltas against the first config implicitly
    lat2, dead2 = adv.incremental_latency(np.array([40, 4]))
    ref, refd = wl.evaluate_np(adv.graph, np.array([40, 4]))
    assert (lat2, dead2) == (ref, refd)
    assert adv.evaluator.incr_stats.n_delta >= 1


# ------------------------------------------------------------- ConfigCache

def test_config_cache_hits_and_exactness():
    cache = ConfigCache(n_fifos=3)
    m = np.array([[2, 3, 4], [5, 6, 7], [2, 3, 4]])
    lat, bram, dead, miss = cache.lookup(m)
    assert miss.all()
    cache.insert(m, np.array([10, 20, 10]), np.array([1, 2, 1]),
                 np.array([False, True, False]))
    lat, bram, dead, miss = cache.lookup(m)
    assert not miss.any()
    np.testing.assert_array_equal(lat, [10, 20, 10])
    np.testing.assert_array_equal(bram, [1, 2, 1])
    np.testing.assert_array_equal(dead, [False, True, False])
    assert cache.stats.hits == 3 and cache.stats.misses == 3
    # unseen rows still miss
    _, _, _, miss = cache.lookup(np.array([[9, 9, 9]]))
    assert miss.all()


def test_config_cache_grows_past_initial_capacity():
    cache = ConfigCache(n_fifos=2, initial_capacity=16)
    rng = np.random.default_rng(0)
    m = rng.integers(2, 1000, size=(200, 2))
    m = np.unique(m, axis=0)
    cache.insert(m, np.arange(len(m)), np.arange(len(m)),
                 np.zeros(len(m), dtype=bool))
    lat, _, _, miss = cache.lookup(m)
    assert not miss.any()
    np.testing.assert_array_equal(lat, np.arange(len(m)))


def test_eval_context_budget_counts_only_misses():
    """Satellite fix: cache hits must not burn simulator budget."""
    d = mult_by_2(16)
    g = build_simgraph(d)
    ctx = EvalContext(g)
    m = np.array([[15, 2], [15, 3]])
    ctx.evaluate(m)
    assert ctx.n_evals == 2
    ctx.evaluate(m)                      # pure cache hits
    assert ctx.n_evals == 2
    assert ctx.cache.stats.hits == 2
    # history still records the hit rows (frontier bookkeeping)
    assert sum(c.shape[0] for c in ctx._configs) == 4


def test_shared_cache_across_contexts():
    """The advisor-level cache is shared: a second optimizer context gets
    hits for configs the first one evaluated."""
    d = mult_by_2(16)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g)
    cache = ConfigCache(g.n_fifos)
    ctx1 = EvalContext(g, ev, cache=cache)
    ctx2 = EvalContext(g, ev, cache=cache)
    m = np.array([[15, 2]])
    ctx1.evaluate(m)
    ctx2.evaluate(m)
    assert ctx2.n_evals == 0
    assert cache.stats.hits == 1


def test_depths_from_group_indices_initializes_all_columns():
    """Satellite fix: FIFOs outside every group get their largest
    candidate depth, not uninitialized memory."""
    d = mult_by_2(16)
    g = build_simgraph(d)
    ctx = EvalContext(g)
    # simulate a design whose groups don't cover fifo 1
    ctx.groups = [np.array([0])]
    ctx.group_grid_sizes = np.array([ctx.grid_sizes[0]])
    out = ctx.depths_from_group_indices(np.array([[0], [1]]))
    assert out.shape == (2, g.n_fifos)
    expected = ctx.candidates[1][-1]
    np.testing.assert_array_equal(out[:, 1], [expected, expected])
