"""Event-graph condensation: exactness, index maps, and the cascade.

The condensation engine (``repro.core.condense``) may pick its anchor
set however it likes — correctness rides on the per-row certificate
(`verify_rows`): a passed certificate proves the expanded condensed
solution IS the raw least fixpoint.  These tests pin that contract:

* bit-exact latency / deadlock / per-event times vs the raw worklist on
  analytical designs, Stream-HLS designs, and fuzz-generated designs
  (committed corpus + fresh seeds) at all-1 / all-2 / upper / random
  depth rows,
* determinism and idempotence of the condensed build,
* ``solve_delta`` parity on condensed graphs,
* graceful certificate failure (never a wrong result, only a fallback),
* the BatchedEvaluator cascade returning results identical to the raw
  path for every registered backend.
"""

import importlib.util
import os

import numpy as np
import pytest

import repro.core.backends.worklist as wl
from repro.core import build_simgraph
from repro.core.condense import (condense, condense_auto, expand_times,
                                 verify_rows)
from repro.core.config import EvalConfig
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design, mult_by_2
from repro.designs.generate import generate_design, load_corpus_specs, \
    build_design

HAS_JAX = importlib.util.find_spec("jax") is not None
CORPUS_DIR = os.path.join(os.path.dirname(__file__), "fuzz_corpus")


def _probe_rows(g, n_random=4, seed=0):
    """The differential row set: all-1, all-2, upper, random in [1, u]."""
    rng = np.random.default_rng(seed)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    rows = [np.ones_like(u), np.full_like(u, 2), u.copy()]
    for _ in range(n_random):
        rows.append(rng.integers(1, u + 1))
    return rows


def _assert_rows_exact(g, cgs, rows):
    """Every row, every rung: accepted results must match the raw solve
    bit for bit (latency, deadlock verdict, expanded per-event times)."""
    for row in rows:
        raw = wl.solve(g, row)
        for cg in cgs:
            st = wl.solve(cg, row)
            if st.deadlocked:
                # sound: the relaxed system stalling implies raw stalls
                assert raw.deadlocked
                continue
            ok = verify_rows(cg, row[None, :], st.t[None, :])[0]
            if not ok:
                continue            # certificate failed -> row falls back
            assert not raw.deadlocked
            assert st.latency == raw.latency
            np.testing.assert_array_equal(expand_times(cg, st.t), raw.t)


@pytest.mark.parametrize("n", [8, 32])
def test_mult_by_2_identity(n):
    g = build_simgraph(mult_by_2(n))
    cgs = condense_auto(g)
    _assert_rows_exact(g, cgs, _probe_rows(g))


@pytest.mark.parametrize("name", ["gemm", "FeedForward", "mvt"])
def test_streamhls_identity(name):
    g = build_simgraph(make_design(name))
    cgs = condense_auto(g)
    assert cgs, "streamhls designs must produce at least one rung"
    assert max(cg.compression for cg in cgs) > 1.5
    _assert_rows_exact(g, cgs, _probe_rows(g))


def test_condense_deterministic_and_idempotent():
    """Same graph, same parameters -> identical anchor choice; the
    condensed arrays are a pure function of (graph, floor, tuning)."""
    g1 = build_simgraph(make_design("gemm"))
    g2 = build_simgraph(make_design("gemm"))
    a = condense(g1, seed=3)
    b = condense(g2, seed=3)
    np.testing.assert_array_equal(a.orig_of, b.orig_of)
    np.testing.assert_array_equal(a.delta, b.delta)
    np.testing.assert_array_equal(a.cond_of, b.cond_of)
    # re-condensing the same graph is a no-op on the anchor structure
    c = condense(g1, seed=3)
    np.testing.assert_array_equal(a.orig_of, c.orig_of)


def test_index_maps_are_consistent():
    g = build_simgraph(make_design("gemm"))
    cg = condense(g)
    E, Ec = g.n_events, cg.n_events
    assert 0 < Ec < E
    # orig_of/cond_of round-trip: every anchor covers itself at offset 0
    np.testing.assert_array_equal(cg.cond_of[cg.orig_of], np.arange(Ec))
    assert (cg.off_of[cg.orig_of] == 0).all()
    # every raw event's covering anchor precedes it in its own segment
    assert (cg.orig_of[cg.cond_of] <= np.arange(E)).all()
    # metadata reported in RAW terms
    np.testing.assert_array_equal(cg.max_occupancy, g.max_occupancy)
    assert cg.unbounded_latency == g.unbounded_latency
    assert cg.latency_upper_bound() == g.latency_upper_bound()


def test_occupancy_and_blame_unchanged_by_condensation():
    """Condensation is evaluation-side only: advisor-level occupancy,
    certification, and deadlock blame all report raw-graph facts."""
    from repro.core.advisor import FifoAdvisor
    adv = FifoAdvisor(mult_by_2(12))
    np.testing.assert_array_equal(
        adv.graph.max_occupancy,
        condense(adv.graph).max_occupancy)
    assert list(adv.min_safe_depths()) == [11, 1]
    wfg = adv.explain_deadlock(np.array([1, 1]))
    assert wfg.blame() == ["x", "y"]


def test_solve_delta_parity_on_condensed_graphs():
    """The incremental solver on a condensed graph matches a full
    condensed solve (and the raw solve on certified rows)."""
    g = build_simgraph(make_design("gemm"))
    cg = condense(g)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    base_row = u.copy()
    base = wl.solve(cg, base_row)
    rng = np.random.default_rng(7)
    for _ in range(6):
        row = base_row.copy()
        for f in rng.integers(0, g.n_fifos, 2):
            row[f] = rng.integers(max(1, u[f] // 2), u[f] + 1)
        full = wl.solve(cg, row)
        delta = wl.solve_delta(cg, base, row)
        assert delta.latency == full.latency
        assert delta.deadlocked == full.deadlocked
        np.testing.assert_array_equal(delta.t, full.t)
        if not full.deadlocked and verify_rows(
                cg, row[None, :], full.t[None, :])[0]:
            raw = wl.solve(g, row)
            assert delta.latency == raw.latency


def test_certificate_rejects_or_flags_deadlock_rows():
    """A row that deadlocks raw can NEVER be certified feasible: either
    the condensed solve stalls too, or the certificate fails."""
    g = build_simgraph(make_design("k15mmtree"))
    row = np.full(g.n_fifos, 2, dtype=np.int64)   # paper's Baseline-Min
    raw = wl.solve(g, row)
    assert raw.deadlocked
    for cg in condense_auto(g):
        st = wl.solve(cg, row)
        if not st.deadlocked:
            assert not verify_rows(cg, row[None, :], st.t[None, :])[0]


def test_evaluator_cascade_identical_to_raw():
    """BatchedEvaluator with the cascade == without, on every backend
    available in this environment, over the full differential row set."""
    backends = ["numpy"] + (["jax"] if HAS_JAX else [])
    for name in ["gemm", "FeedForward"]:
        g = build_simgraph(make_design(name))
        rows = np.stack(_probe_rows(g, n_random=6))
        # feasible-leaning rows exercise the in-box cascade path
        rng = np.random.default_rng(1)
        u = g.upper_bounds
        hot = np.stack([np.maximum(
            2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
            for _ in range(8)])
        rows = np.concatenate([rows, hot])
        for backend in backends:
            ev_raw = BatchedEvaluator(
                g, EvalConfig(backend=backend, max_iters=64,
                              condense=None))
            ev_c = BatchedEvaluator(
                g, EvalConfig(backend=backend, max_iters=64))
            got_raw = ev_raw.evaluate(rows)
            got_c = ev_c.evaluate(rows)
            for a, b in zip(got_raw, got_c):
                np.testing.assert_array_equal(a, b)
            if backend != "numpy":
                # the scan cascade must actually fire on the hot rows
                assert ev_c.stats.n_condensed > 0


def test_forced_worklist_cascade_identical_to_raw():
    """Explicitly passing condensed rungs forces the cascade on the
    numpy worklist too (auto keeps it scan-only); results stay exact."""
    g = build_simgraph(make_design("mvt"))
    cgs = condense_auto(g)
    rows = np.stack(_probe_rows(g, n_random=6, seed=5))
    ev_raw = BatchedEvaluator(
        g, EvalConfig(backend="numpy", max_iters=64, condense=None))
    ev_c = BatchedEvaluator(
        g, EvalConfig(backend="numpy", max_iters=64), rungs=cgs)
    for a, b in zip(ev_raw.evaluate(rows), ev_c.evaluate(rows)):
        np.testing.assert_array_equal(a, b)


def _fuzz_graphs(seeds):
    for seed in seeds:
        gen = generate_design(seed, quick=True)
        yield seed, build_simgraph(gen.design)


def test_fuzz_corpus_condensed_identity():
    """The committed shrunk-reproducer corpus replays clean through the
    condensation cascade."""
    paths = [os.path.join(CORPUS_DIR, p) for p in sorted(
        os.listdir(CORPUS_DIR)) if p.endswith(".json")]
    specs = load_corpus_specs(paths)
    assert specs, "corpus must not be empty"
    for spec in specs:
        g = build_simgraph(build_design(spec).design)
        cgs = condense_auto(g)
        _assert_rows_exact(g, cgs, _probe_rows(g, n_random=3))


@pytest.mark.parametrize("seed", range(0, 24, 3))
def test_fuzz_fresh_seeds_condensed_identity(seed):
    """Fresh generator seeds: condensed-vs-raw identity on the
    differential row set (the fuzz CLI sweeps a wider range)."""
    for _, g in _fuzz_graphs([seed]):
        cgs = condense_auto(g)
        _assert_rows_exact(g, cgs, _probe_rows(g, n_random=3, seed=seed))
