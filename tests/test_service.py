"""Advisory service: cross-session batching correctness + protocol.

The load-bearing invariant mirrors the campaign engine's: batching is
*routing only*.  Concurrent sessions — interleaved round by round,
merged/deduplicated per design, optionally packed across designs into
one hetero dispatch — must produce histories and frontiers bit-identical
to solo ``FifoAdvisor.run()`` calls with the same seeds.  (Budget
accounting ``n_evals`` counts cache *misses*, so it legitimately shrinks
under cache sharing; configurations, latencies, frontiers, and
hypervolumes never change.)
"""

import asyncio
import json

import numpy as np
import pytest

from repro.core import FifoAdvisor
from repro.core.campaign.router import RoundRouter
from repro.core.service import (AdvisorClient, AdvisoryService,
                                DesignRegistry, ProtocolError,
                                ProtocolHandler)
from repro.designs import make_design

DESIGNS = ("gemm", "FeedForward")
BUDGET = 60

#: (design, optimizer, seed) mix covering 2 designs x 2 optimizers
SESSIONS = [("gemm", "grouped_sa", 0), ("gemm", "grouped_random", 3),
            ("FeedForward", "grouped_sa", 1),
            ("FeedForward", "grouped_random", 0)]


def solo_run(design, optimizer, seed, budget=BUDGET):
    return FifoAdvisor(make_design(design)).run(optimizer, budget=budget,
                                                seed=seed)


def assert_identical(dse, ref, key=""):
    assert np.array_equal(dse.result.configs, ref.result.configs), key
    assert np.array_equal(dse.result.latency, ref.result.latency), key
    assert np.array_equal(dse.result.bram, ref.result.bram), key
    assert np.array_equal(dse.result.deadlock, ref.result.deadlock), key
    assert np.array_equal(dse.frontier_points, ref.frontier_points), key
    assert dse.hypervolume() == ref.hypervolume(), key


# --------------------------------------------------------------- batching
def test_concurrent_sessions_bit_identical_to_solo():
    """2 designs x 2 optimizers batched together == 4 solo runs."""
    with AdvisoryService() as svc:
        sids = [svc.open_session(d, optimizer=o, budget=BUDGET,
                                 seed=s).id for d, o, s in SESSIONS]
        svc.run_until_idle()
        for sid, (d, o, s) in zip(sids, SESSIONS):
            assert_identical(svc.result(sid), solo_run(d, o, s),
                             f"{d}:{o}:s{s}")


def test_forced_hetero_packing_bit_identical():
    """hetero=True packs cross-design rows into shared dispatches and
    still reproduces every solo run exactly."""
    with AdvisoryService(hetero=True, max_iters=64) as svc:
        sids = [svc.open_session(d, optimizer=o, budget=BUDGET,
                                 seed=s).id for d, o, s in SESSIONS]
        svc.run_until_idle()
        disp = svc.batcher.router.hetero
        assert disp is not None and disp.stats.n_dispatches > 0
        # both designs share each round's dispatch: never more
        # dispatches than rounds (separate per-design dispatch would
        # need up to one per design per round)
        assert disp.stats.n_dispatches <= svc.batcher.rounds
        assert set(disp.worklists) == set(DESIGNS)
        for sid, (d, o, s) in zip(sids, SESSIONS):
            assert_identical(svc.result(sid), solo_run(d, o, s),
                             f"hetero {d}:{o}:s{s}")


def test_mid_run_cancel_keeps_prefix_and_peers_exact():
    """Cancelling one session mid-run yields its history prefix and
    leaves every other session bit-identical to its solo run."""
    with AdvisoryService() as svc:
        victim = svc.open_session("gemm", optimizer="grouped_sa",
                                  budget=400, seed=5)
        peers = [svc.open_session(d, optimizer=o, budget=BUDGET,
                                  seed=s).id for d, o, s in SESSIONS]
        for _ in range(3):
            svc.step()
        svc.cancel(victim.id)
        assert victim.state == "cancelled"
        svc.run_until_idle()

        part = svc.result(victim.id)
        n = part.result.configs.shape[0]
        assert 0 < n
        ref = solo_run("gemm", "grouped_sa", 5, budget=400)
        assert n < ref.result.configs.shape[0]
        assert np.array_equal(part.result.configs,
                              ref.result.configs[:n])
        assert np.array_equal(part.result.latency,
                              ref.result.latency[:n])
        events = victim.drain_events()
        assert events and events[-1]["event"] == "cancelled"
        # cancelled sessions never advance again
        before = victim.rounds
        svc.step()
        assert victim.rounds == before

        for sid, (d, o, s) in zip(peers, SESSIONS):
            assert_identical(svc.result(sid), solo_run(d, o, s),
                             f"peer {d}:{o}:s{s}")


def test_progress_events_stream_frontier_deltas():
    with AdvisoryService() as svc:
        sess = svc.open_session("gemm", optimizer="grouped_random",
                                budget=BUDGET, seed=0)
        svc.run_until_idle()
        events = sess.drain_events()
        assert events[-1]["event"] == "done"
        progress = [e for e in events if e["event"] == "progress"]
        assert progress, "no progress events streamed"
        hv = 0.0
        for e in progress:
            assert e["hv_delta"] == pytest.approx(
                e["hypervolume"] - hv)
            assert e["hypervolume"] >= hv   # cumulative-history frontier
            hv = e["hypervolume"]
        assert events[-1]["hypervolume"] == pytest.approx(hv)


def test_pooled_service_handles_late_and_custom_designs():
    """Worker-pool mode: a design opened after the pool exists (rebuild)
    and a custom Design object (pinned inline — fresh worker processes
    cannot rebuild it by name) both evaluate correctly."""
    from repro.core.design import Design

    def build_design():
        d = Design("qs")
        d.fifo("a", width=32)

        @d.task("src")
        def src(ctx):
            for i in range(64):
                yield ctx.delay(1)
                yield ctx.write("a", i)

        @d.task("sink")
        def sink(ctx):
            for _ in range(64):
                yield ctx.read("a")
                yield ctx.delay(2)

        return d

    with AdvisoryService(workers=1) as svc:
        first = svc.open_session("gemm", optimizer="grouped_random",
                                 budget=40, seed=0)
        late = svc.open_session("FeedForward",
                                optimizer="grouped_random",
                                budget=40, seed=1)       # pool rebuild
        custom = svc.open_session("qs", design_obj=build_design(),
                                  optimizer="grouped_random",
                                  budget=40, seed=2)     # inline-only
        assert "qs" in svc.batcher.router.inline_only
        assert svc.batcher.router.pool is not None
        svc.run_until_idle()
        assert {s.state for s in (first, late, custom)} == {"done"}
        assert_identical(svc.result(first.id),
                         solo_run("gemm", "grouped_random", 0, 40))
        assert_identical(svc.result(late.id),
                         solo_run("FeedForward", "grouped_random", 1, 40))
        solo_custom = FifoAdvisor(build_design()).run(
            "grouped_random", budget=40, seed=2)
        assert_identical(svc.result(custom.id), solo_custom)


# --------------------------------------------------------------- registry
def test_registry_traces_each_design_once():
    reg = DesignRegistry()
    a1 = reg.register("gemm")
    a2 = reg.register("gemm")
    assert a1 is a2
    assert reg.names() == ["gemm"]
    with AdvisoryService(registry=reg) as svc:
        s1 = svc.open_session("gemm", budget=20, seed=0)
        svc.run_until_idle()
        assert s1.ctx.n_evals > 0
        # a later identical session rides the shared cache entirely:
        # same trajectory, zero new simulations
        s2 = svc.open_session("gemm", budget=20, seed=0)
        assert s2.advisor is a1
        svc.run_until_idle()
        assert s2.ctx.n_evals == 0
        assert np.array_equal(s1.ctx.history()[0], s2.ctx.history()[0])
    assert reg.stats()["gemm"]["cache"]["hits"] > 0


def test_service_and_campaign_share_the_router():
    """The factoring the service rides on: one routing implementation."""
    from repro.core.campaign import Campaign, CampaignSpec
    camp = Campaign(CampaignSpec(designs=("gemm",),
                                 optimizers=("grouped_random",),
                                 budget=20))
    with AdvisoryService() as svc:
        assert type(camp.router) is type(svc.batcher.router) is RoundRouter
    camp.close()


# --------------------------------------------------------------- protocol
def test_protocol_roundtrip_and_errors():
    handler = ProtocolHandler(AdvisoryService())
    resp = handler.handle({"op": "open", "design": "gemm",
                           "optimizer": "grouped_random", "budget": 30,
                           "id": "req-1"})
    assert resp["ok"] and resp["id"] == "req-1"
    sid = resp["session"]
    assert handler.handle({"op": "status", "session": sid})[
        "state"] == "running"
    run = handler.handle({"op": "run"})
    assert run["ok"] and run["running"] == 0
    res = handler.handle({"op": "result", "session": sid})
    assert res["ok"] and res["state"] == "done"
    assert res["result"]["frontier"]
    assert res["result"]["n_evals"] > 0
    events = handler.poll_events(sid)
    assert events and events[-1]["event"] == "done"

    assert not handler.handle({"op": "nope"})["ok"]
    assert not handler.handle({"op": "open"})["ok"]
    assert not handler.handle({"op": "status", "session": "s99"})["ok"]
    bad = handler.handle({"op": "cancel", "id": 7})
    assert not bad["ok"] and bad["id"] == 7


def test_release_evicts_session_and_hetero_ignores_workers():
    with AdvisorClient() as client:
        sid = client.open("gemm", optimizer="grouped_random", budget=20)
        client.drive()
        assert client.result(sid).result.configs.shape[0] > 0
        rel = client.release(sid)
        assert rel["released"] and rel["state"] == "done"
        with pytest.raises(ProtocolError):
            client.status(sid)     # forgotten server-side
        assert client.service.sessions == {}
    # hetero owns full-solve rows in-process: workers are normalized off
    with AdvisoryService(hetero=True, workers=4) as svc:
        assert svc.batcher.workers == 0


def test_optimizer_close_is_public_and_terminal():
    from repro.core.optimizers import OPTIMIZERS
    adv = FifoAdvisor(make_design("gemm"))
    opt = OPTIMIZERS["grouped_random"](adv.make_context(seed=0),
                                       budget=500)
    req = opt.propose()
    assert req is not None
    opt.close()
    assert opt.done and opt.propose() is None


def test_advisor_client_run_matches_solo():
    with AdvisorClient() as client:
        dse = client.run("gemm", optimizer="grouped_sa", budget=BUDGET,
                         seed=2)
        assert_identical(dse, solo_run("gemm", "grouped_sa", 2))
        payload = client.result_json("s0")
        assert payload["design"] == "gemm"
        assert json.dumps(payload)   # JSON-ready end to end
        with pytest.raises(ProtocolError):
            client.request({"op": "result", "session": "s42"})


# ----------------------------------------------------------------- server
def test_tcp_server_round_trip():
    """Full wire path: TCP connect, open, run, events, result, shutdown."""
    from repro.launch.serve import AdvisoryServer

    async def scenario():
        server = AdvisoryServer(idle_sleep_s=0.001)
        tcp = await server.serve_tcp("127.0.0.1", 0)
        port = tcp.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)

        async def rpc(msg):
            writer.write((json.dumps(msg) + "\n").encode())
            await writer.drain()
            while True:
                frame = json.loads(await reader.readline())
                if "event" in frame:
                    frames.append(frame)
                    continue
                return frame

        frames = []
        opened = await rpc({"op": "open", "design": "gemm",
                            "optimizer": "grouped_random",
                            "budget": 40, "id": 1})
        assert opened["ok"] and opened["id"] == 1
        sid = opened["session"]
        # the background pump drives the session without explicit "run"
        for _ in range(200):
            status = await rpc({"op": "status", "session": sid})
            if status["state"] == "done":
                break
            await asyncio.sleep(0.01)
        assert status["state"] == "done"
        result = await rpc({"op": "result", "session": sid})
        assert result["ok"] and result["result"]["frontier"]
        # events were pushed while polling
        deadline = 100
        while not any(f["event"] == "done" for f in frames) and deadline:
            line = await asyncio.wait_for(reader.readline(), timeout=2)
            frames.append(json.loads(line))
            deadline -= 1
        assert any(f["event"] == "done" for f in frames)
        bye = await rpc({"op": "shutdown"})
        assert bye["ok"]
        writer.close()
        tcp.close()
        await tcp.wait_closed()
        await server.aclose()

    asyncio.run(asyncio.wait_for(scenario(), timeout=120))
