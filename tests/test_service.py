"""Advisory service: cross-session batching correctness + protocol.

The load-bearing invariant mirrors the campaign engine's: batching is
*routing only*.  Concurrent sessions — interleaved round by round,
merged/deduplicated per design, optionally packed across designs into
one hetero dispatch — must produce histories and frontiers bit-identical
to solo ``FifoAdvisor.run()`` calls with the same seeds.  (Budget
accounting ``n_evals`` counts cache *misses*, so it legitimately shrinks
under cache sharing; configurations, latencies, frontiers, and
hypervolumes never change.)
"""

import asyncio
import json

import numpy as np
import pytest

from repro.core import EvalConfig, FifoAdvisor
from repro.core.campaign.router import RoundRouter
from repro.core.service import (AdvisorClient, AdvisoryService,
                                DesignRegistry, ProtocolError,
                                ProtocolHandler, SessionHandle, adapt_v1)
from repro.core.service.protocol import (E_BAD_DESIGN, E_BAD_OPTIMIZER,
                                         E_BAD_REQUEST, E_BAD_SESSION,
                                         E_OVERLOADED, E_PROTO, PROTO,
                                         SUPPORTED_PROTOS)
from repro.designs import make_design

DESIGNS = ("gemm", "FeedForward")
BUDGET = 60

#: (design, optimizer, seed) mix covering 2 designs x 2 optimizers
SESSIONS = [("gemm", "grouped_sa", 0), ("gemm", "grouped_random", 3),
            ("FeedForward", "grouped_sa", 1),
            ("FeedForward", "grouped_random", 0)]


def solo_run(design, optimizer, seed, budget=BUDGET):
    return FifoAdvisor(make_design(design)).run(optimizer, budget=budget,
                                                seed=seed)


def assert_identical(dse, ref, key=""):
    assert np.array_equal(dse.result.configs, ref.result.configs), key
    assert np.array_equal(dse.result.latency, ref.result.latency), key
    assert np.array_equal(dse.result.bram, ref.result.bram), key
    assert np.array_equal(dse.result.deadlock, ref.result.deadlock), key
    assert np.array_equal(dse.frontier_points, ref.frontier_points), key
    assert dse.hypervolume() == ref.hypervolume(), key


# --------------------------------------------------------------- batching
def test_concurrent_sessions_bit_identical_to_solo():
    """2 designs x 2 optimizers batched together == 4 solo runs."""
    with AdvisoryService() as svc:
        sids = [svc.open_session(d, optimizer=o, budget=BUDGET,
                                 seed=s).id for d, o, s in SESSIONS]
        svc.run_until_idle()
        for sid, (d, o, s) in zip(sids, SESSIONS):
            assert_identical(svc.result(sid), solo_run(d, o, s),
                             f"{d}:{o}:s{s}")


def test_forced_hetero_packing_bit_identical():
    """hetero=True packs cross-design rows into shared dispatches and
    still reproduces every solo run exactly."""
    with AdvisoryService(hetero=True,
                         config=EvalConfig(max_iters=64)) as svc:
        sids = [svc.open_session(d, optimizer=o, budget=BUDGET,
                                 seed=s).id for d, o, s in SESSIONS]
        svc.run_until_idle()
        disp = svc.batcher.router.hetero
        assert disp is not None and disp.stats.n_dispatches > 0
        # both designs share each round's dispatch: never more
        # dispatches than rounds (separate per-design dispatch would
        # need up to one per design per round)
        assert disp.stats.n_dispatches <= svc.batcher.rounds
        assert set(disp.worklists) == set(DESIGNS)
        for sid, (d, o, s) in zip(sids, SESSIONS):
            assert_identical(svc.result(sid), solo_run(d, o, s),
                             f"hetero {d}:{o}:s{s}")


def test_mid_run_cancel_keeps_prefix_and_peers_exact():
    """Cancelling one session mid-run yields its history prefix and
    leaves every other session bit-identical to its solo run."""
    with AdvisoryService() as svc:
        victim = svc.open_session("gemm", optimizer="grouped_sa",
                                  budget=400, seed=5)
        peers = [svc.open_session(d, optimizer=o, budget=BUDGET,
                                  seed=s).id for d, o, s in SESSIONS]
        for _ in range(3):
            svc.step()
        svc.cancel(victim.id)
        assert victim.state == "cancelled"
        svc.run_until_idle()

        part = svc.result(victim.id)
        n = part.result.configs.shape[0]
        assert 0 < n
        ref = solo_run("gemm", "grouped_sa", 5, budget=400)
        assert n < ref.result.configs.shape[0]
        assert np.array_equal(part.result.configs,
                              ref.result.configs[:n])
        assert np.array_equal(part.result.latency,
                              ref.result.latency[:n])
        events = victim.drain_events()
        assert events and events[-1]["event"] == "cancelled"
        # cancelled sessions never advance again
        before = victim.rounds
        svc.step()
        assert victim.rounds == before

        for sid, (d, o, s) in zip(peers, SESSIONS):
            assert_identical(svc.result(sid), solo_run(d, o, s),
                             f"peer {d}:{o}:s{s}")


def test_progress_events_stream_frontier_deltas():
    with AdvisoryService() as svc:
        sess = svc.open_session("gemm", optimizer="grouped_random",
                                budget=BUDGET, seed=0)
        svc.run_until_idle()
        events = sess.drain_events()
        assert events[-1]["event"] == "done"
        progress = [e for e in events if e["event"] == "progress"]
        assert progress, "no progress events streamed"
        hv = 0.0
        for e in progress:
            assert e["hv_delta"] == pytest.approx(
                e["hypervolume"] - hv)
            assert e["hypervolume"] >= hv   # cumulative-history frontier
            hv = e["hypervolume"]
        assert events[-1]["hypervolume"] == pytest.approx(hv)


def test_pooled_service_handles_late_and_custom_designs():
    """Worker-pool mode: a design opened after the pool exists (rebuild)
    and a custom Design object (pinned inline — fresh worker processes
    cannot rebuild it by name) both evaluate correctly."""
    from repro.core.design import Design

    def build_design():
        d = Design("qs")
        d.fifo("a", width=32)

        @d.task("src")
        def src(ctx):
            for i in range(64):
                yield ctx.delay(1)
                yield ctx.write("a", i)

        @d.task("sink")
        def sink(ctx):
            for _ in range(64):
                yield ctx.read("a")
                yield ctx.delay(2)

        return d

    with AdvisoryService(workers=1) as svc:
        first = svc.open_session("gemm", optimizer="grouped_random",
                                 budget=40, seed=0)
        late = svc.open_session("FeedForward",
                                optimizer="grouped_random",
                                budget=40, seed=1)       # pool rebuild
        custom = svc.open_session("qs", design_obj=build_design(),
                                  optimizer="grouped_random",
                                  budget=40, seed=2)     # inline-only
        assert "qs" in svc.batcher.router.inline_only
        assert svc.batcher.router.pool is not None
        svc.run_until_idle()
        assert {s.state for s in (first, late, custom)} == {"done"}
        assert_identical(svc.result(first.id),
                         solo_run("gemm", "grouped_random", 0, 40))
        assert_identical(svc.result(late.id),
                         solo_run("FeedForward", "grouped_random", 1, 40))
        solo_custom = FifoAdvisor(build_design()).run(
            "grouped_random", budget=40, seed=2)
        assert_identical(svc.result(custom.id), solo_custom)


# --------------------------------------------------------------- registry
def test_registry_traces_each_design_once():
    reg = DesignRegistry()
    a1 = reg.register("gemm")
    a2 = reg.register("gemm")
    assert a1 is a2
    assert reg.names() == ["gemm"]
    with AdvisoryService(registry=reg) as svc:
        s1 = svc.open_session("gemm", budget=20, seed=0)
        svc.run_until_idle()
        assert s1.ctx.n_evals > 0
        # a later identical session rides the shared cache entirely:
        # same trajectory, zero new simulations
        s2 = svc.open_session("gemm", budget=20, seed=0)
        assert s2.advisor is a1
        svc.run_until_idle()
        assert s2.ctx.n_evals == 0
        assert np.array_equal(s1.ctx.history()[0], s2.ctx.history()[0])
    assert reg.stats()["gemm"]["cache"]["hits"] > 0


def test_service_and_campaign_share_the_router():
    """The factoring the service rides on: one routing implementation."""
    from repro.core.campaign import Campaign, CampaignSpec
    camp = Campaign(CampaignSpec(designs=("gemm",),
                                 optimizers=("grouped_random",),
                                 budget=20))
    with AdvisoryService() as svc:
        assert type(camp.router) is type(svc.batcher.router) is RoundRouter
    camp.close()


# --------------------------------------------------------------- protocol
def test_protocol_roundtrip_and_errors():
    handler = ProtocolHandler(AdvisoryService())
    resp = handler.handle({"op": "open", "design": "gemm",
                           "optimizer": "grouped_random", "budget": 30,
                           "id": "req-1"})
    assert resp["ok"] and resp["id"] == "req-1"
    sid = resp["session"]
    assert handler.handle({"op": "status", "session": sid})[
        "state"] == "running"
    run = handler.handle({"op": "run"})
    assert run["ok"] and run["running"] == 0
    res = handler.handle({"op": "result", "session": sid})
    assert res["ok"] and res["state"] == "done"
    assert res["result"]["frontier"]
    assert res["result"]["n_evals"] > 0
    events = handler.poll_events(sid)
    assert events and events[-1]["event"] == "done"

    assert not handler.handle({"op": "nope"})["ok"]
    assert not handler.handle({"op": "open"})["ok"]
    assert not handler.handle({"op": "status", "session": "s99"})["ok"]
    bad = handler.handle({"op": "cancel", "id": 7})
    assert not bad["ok"] and bad["id"] == 7


def test_error_frames_carry_stable_codes():
    """Every failure class maps to its documented ERROR_CODES entry —
    clients branch on ``code``, never on message prose."""
    handler = ProtocolHandler(AdvisoryService())
    cases = [
        ({"op": "nope"}, E_PROTO),
        ({"op": "hello", "proto": 99}, E_PROTO),
        ({"op": "open"}, E_BAD_REQUEST),
        ({"op": "status"}, E_BAD_REQUEST),
        ({"op": "open", "design": "no_such_design"}, E_BAD_DESIGN),
        ({"op": "open", "design": "gemm",
          "optimizer": "no_such_optimizer"}, E_BAD_OPTIMIZER),
        ({"op": "status", "session": "s99"}, E_BAD_SESSION),
        ({"op": "snapshot"}, E_BAD_REQUEST),
    ]
    for msg, code in cases:
        out = handler.handle(msg)
        assert not out["ok"] and out["code"] == code, (msg, out)
        assert out["error"]            # the v1 human string is still there


def test_hello_negotiates_proto_and_advertises_ops():
    with AdvisorClient() as client:
        assert client.proto == PROTO
        for proto in SUPPORTED_PROTOS:
            hello = client.request({"op": "hello", "proto": proto})
            assert hello["proto"] == proto
            assert "release" in hello["ops"]
            assert "close" not in hello["ops"]   # v1 spelling not advertised
        with pytest.raises(ProtocolError) as err:
            client.request({"op": "hello", "proto": 3})
        assert err.value.code == E_PROTO


def test_v1_messages_round_trip_through_adapter():
    """Every v1 request — including the renamed ``close`` — must keep
    working verbatim against a v2 handler (no hello, v1 field names)."""
    assert adapt_v1({"op": "close", "session": "s0"})["op"] == "release"
    assert adapt_v1({"op": "status", "session": "s0"})["op"] == "status"
    handler = ProtocolHandler(AdvisoryService())
    opened = handler.handle({"op": "open", "design": "gemm",
                             "optimizer": "grouped_random", "budget": 20,
                             "id": "v1-1"})
    assert opened["ok"] and opened["id"] == "v1-1"
    sid = opened["session"]
    v1_ops = [{"op": "status", "session": sid},
              {"op": "step"},
              {"op": "run"},
              {"op": "result", "session": sid},
              {"op": "designs"},
              {"op": "stats"},
              {"op": "cancel", "session": sid},
              {"op": "close", "session": sid},    # v1 name for release
              {"op": "shutdown"}]
    for msg in v1_ops:
        out = handler.handle(dict(msg, id=f"v1-{msg['op']}"))
        assert out["ok"], (msg, out)
        assert out["id"] == f"v1-{msg['op']}"
    # the closed session is really gone
    assert not handler.handle({"op": "status", "session": sid})["ok"]


def test_session_handle_stream_and_context_manager():
    with AdvisorClient() as client:
        with client.open("gemm", optimizer="grouped_random",
                         budget=30, progress=True) as h:
            assert isinstance(h, SessionHandle)
            assert isinstance(h, str)          # the handle IS the sid
            assert json.dumps({"session": h})  # JSON-safe as a string
            events = list(h.stream())
            assert events and events[-1]["event"] == "done"
            assert any(e["event"] == "progress" for e in events)
            assert h.status()["state"] == "done"
            assert h.result().result.configs.shape[0] > 0
            assert h.result_json()["design"] == "gemm"
        # the with-block released the session server-side
        assert client.service.sessions == {}
        with pytest.raises(ProtocolError) as err:
            h.status()
        assert err.value.code == E_BAD_SESSION


def test_deprecated_sid_methods_still_work_and_warn():
    with AdvisorClient() as client:
        h = client.open("gemm", optimizer="grouped_random", budget=20)
        client.drive()
        sid = str(h)
        with pytest.warns(DeprecationWarning, match="status"):
            assert client.status(sid)["state"] == "done"
        with pytest.warns(DeprecationWarning, match="result"):
            assert client.result(sid).result.configs.shape[0] > 0
        with pytest.warns(DeprecationWarning, match="result_json"):
            assert client.result_json(sid)["design"] == "gemm"
        with pytest.warns(DeprecationWarning, match="release"):
            rel = client.release(sid)
        assert rel["released"] and rel["state"] == "done"


def test_overload_sheds_with_retry_after():
    """At the session cap, ``open`` fails fast with E_OVERLOADED and a
    positive retry hint; running sessions never exceed the cap and
    admission resumes after a release."""
    with AdvisorClient(max_sessions=2) as client:
        h1 = client.open("gemm", optimizer="grouped_random", budget=20)
        h2 = client.open("gemm", optimizer="grouped_random", budget=20,
                         seed=1)
        with pytest.raises(ProtocolError) as err:
            client.open("gemm", optimizer="grouped_random", budget=20,
                        seed=2)
        assert err.value.code == E_OVERLOADED
        assert err.value.extra["retry_after_s"] > 0
        assert err.value.extra["max_sessions"] == 2
        assert len(client.service.running) <= 2
        assert client.service.stats()["rejected"] == 1
        client.drive()
        assert_identical(h1.result(), solo_run("gemm", "grouped_random",
                                               0, 20))
        h1.release()
        h2.release()
        h3 = client.open("gemm", optimizer="grouped_random", budget=20,
                         seed=2)             # admission resumes
        client.drive()
        assert h3.status()["state"] == "done"


def test_release_evicts_session_and_hetero_ignores_workers():
    with AdvisorClient() as client:
        h = client.open("gemm", optimizer="grouped_random", budget=20)
        client.drive()
        assert h.result().result.configs.shape[0] > 0
        rel = h.release()
        assert rel["released"] and rel["state"] == "done"
        with pytest.raises(ProtocolError):
            h.status()     # forgotten server-side
        assert client.service.sessions == {}
    # hetero owns full-solve rows in-process: workers are normalized off
    with AdvisoryService(hetero=True, workers=4) as svc:
        assert svc.batcher.workers == 0


def test_optimizer_close_is_public_and_terminal():
    from repro.core.optimizers import OPTIMIZERS
    adv = FifoAdvisor(make_design("gemm"))
    opt = OPTIMIZERS["grouped_random"](adv.make_context(seed=0),
                                       budget=500)
    req = opt.propose()
    assert req is not None
    opt.close()
    assert opt.done and opt.propose() is None


def test_advisor_client_run_matches_solo():
    with AdvisorClient() as client:
        h = client.open("gemm", optimizer="grouped_sa", budget=BUDGET,
                        seed=2)
        client.drive()
        assert_identical(h.result(), solo_run("gemm", "grouped_sa", 2))
        payload = h.result_json()
        assert payload["design"] == "gemm"
        assert json.dumps(payload)   # JSON-ready end to end
        with pytest.raises(ProtocolError):
            client.request({"op": "result", "session": "s42"})


# ----------------------------------------------------------------- server
def test_tcp_server_round_trip():
    """Full wire path: TCP connect, open, run, events, result, shutdown."""
    from repro.launch.serve import AdvisoryServer

    async def scenario():
        server = AdvisoryServer(idle_sleep_s=0.001)
        tcp = await server.serve_tcp("127.0.0.1", 0)
        port = tcp.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)

        async def rpc(msg):
            writer.write((json.dumps(msg) + "\n").encode())
            await writer.drain()
            while True:
                frame = json.loads(await reader.readline())
                if "event" in frame:
                    frames.append(frame)
                    continue
                return frame

        frames = []
        opened = await rpc({"op": "open", "design": "gemm",
                            "optimizer": "grouped_random",
                            "budget": 40, "id": 1})
        assert opened["ok"] and opened["id"] == 1
        sid = opened["session"]
        # the background pump drives the session without explicit "run"
        for _ in range(200):
            status = await rpc({"op": "status", "session": sid})
            if status["state"] == "done":
                break
            await asyncio.sleep(0.01)
        assert status["state"] == "done"
        result = await rpc({"op": "result", "session": sid})
        assert result["ok"] and result["result"]["frontier"]
        # events were pushed while polling
        deadline = 100
        while not any(f["event"] == "done" for f in frames) and deadline:
            line = await asyncio.wait_for(reader.readline(), timeout=2)
            frames.append(json.loads(line))
            deadline -= 1
        assert any(f["event"] == "done" for f in frames)
        bye = await rpc({"op": "shutdown"})
        assert bye["ok"]
        writer.close()
        tcp.close()
        await tcp.wait_closed()
        await server.aclose()

    asyncio.run(asyncio.wait_for(scenario(), timeout=120))
