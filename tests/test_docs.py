"""Doc-freshness gate: every fenced ``python`` block must execute.

Extracts fenced code blocks tagged ``python`` from README.md and
``docs/*.md`` and ``exec``'s each in a fresh namespace with the CWD
pointed at a temp directory (snippets may write checkpoints/results).
A block whose first line is ``# doc: skip`` is exempt (pseudo-code,
interface sketches) — everything else is live code, so the snippets in
the docs cannot rot away from the API.

The whole module is jax-free by construction (snippets use the numpy
worklist backend), and CI runs it in a dedicated no-jax job to keep the
lazy-import property honest.
"""

import os
import re

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SKIP_MARK = "# doc: skip"
FENCE_RE = re.compile(r"```python[ \t]*\n(.*?)^```", re.S | re.M)


def doc_pages():
    pages = [os.path.join(REPO_ROOT, "README.md")]
    docs_dir = os.path.join(REPO_ROOT, "docs")
    for name in sorted(os.listdir(docs_dir)):
        if name.endswith(".md"):
            pages.append(os.path.join(docs_dir, name))
    return pages


def collect_blocks():
    blocks = []
    for path in doc_pages():
        with open(path) as f:
            text = f.read()
        rel = os.path.relpath(path, REPO_ROOT)
        for i, code in enumerate(FENCE_RE.findall(text)):
            blocks.append(pytest.param(rel, i, code, id=f"{rel}:{i}"))
    return blocks


BLOCKS = collect_blocks()


def test_docs_have_snippets():
    """The gate must be guarding something: all ten pages + README."""
    pages = {b.values[0] for b in BLOCKS}
    assert "README.md" in pages
    for page in ("architecture", "backends", "bounds", "campaign",
                 "fuzzing", "mesh", "optimizers", "performance",
                 "robustness", "service"):
        assert f"docs/{page}.md" in pages, f"docs/{page}.md has no "\
            "python snippets (or was deleted)"


@pytest.mark.parametrize("page, index, code", BLOCKS)
def test_doc_snippet_executes(page, index, code, tmp_path, monkeypatch):
    first = code.lstrip().splitlines()[0].strip() if code.strip() else ""
    if first.startswith(SKIP_MARK):
        pytest.skip(f"{page} block {index} is marked {SKIP_MARK}")
    # snippets may write artifacts (campaign checkpoints, result JSONs)
    monkeypatch.chdir(tmp_path)
    namespace = {"__name__": f"__doc_snippet_{index}__"}
    try:
        exec(compile(code, f"<{page} block {index}>", "exec"), namespace)
    except Exception as exc:   # noqa: BLE001 - repackage with context
        pytest.fail(
            f"{page} python block {index} no longer runs "
            f"({type(exc).__name__}: {exc}); update the doc or mark the "
            f"block with '{SKIP_MARK}'")
