"""Beyond-paper local lower-bound pruning (core/prune.py): soundness +
effectiveness."""

import pytest

from repro.core import EvalConfig, FifoAdvisor, build_simgraph
from repro.core.optimizers import EvalContext
from repro.core.prune import local_lower_bounds, pair_feasible, task_pairs
from repro.core.simulate import evaluate_np
from repro.designs import make_design
from repro.designs.ddcf import mult_by_2


@pytest.fixture(scope="module")
def tree_graph():
    return build_simgraph(make_design("k15mmtree"))


def test_pruned_depths_are_globally_deadlocked(tree_graph):
    """Soundness: every candidate removed by the lower bound deadlocks the
    FULL design even with every other FIFO maximally sized."""
    g = tree_graph
    ctx = EvalContext(g)            # unpruned grids
    lb = local_lower_bounds(g, ctx.candidates)
    checked = 0
    for f in range(g.n_fifos):
        below = ctx.candidates[f][ctx.candidates[f] < lb[f]]
        if below.size:
            cfg = g.upper_bounds.copy()
            cfg[f] = below[-1]      # the largest pruned candidate
            _, dead = evaluate_np(g, cfg)
            assert dead, (f, int(below[-1]))
            checked += 1
    assert checked > 0              # the hazard designs DO get pruned


def test_bounds_never_prune_feasible_min_on_benign_designs():
    """On designs whose Baseline-Min is feasible, depth 2 must survive."""
    for name in ("gemm", "FeedForward", "k7mmtree_balanced"):
        g = build_simgraph(make_design(name))
        ctx = EvalContext(g)
        lb = local_lower_bounds(g, ctx.candidates)
        assert (lb == 2).all(), name


def test_single_fifo_pairs_not_pruned():
    g = build_simgraph(mult_by_2(32))
    ctx = EvalContext(g)
    lb = local_lower_bounds(g, ctx.candidates)
    # mult_by_2's deadlock involves ONE fifo pair per (x, y): pair analysis
    # with both fifos between the same tasks DOES see it
    pairs = task_pairs(g)
    assert len(pairs) == 1 and len(list(pairs.values())[0]) == 2
    # x needs depth >= n-1 = 31; the grid's first surviving candidate
    # must be >= 31
    assert lb[g.design.fifo_index("x")] >= 31


def test_pruning_removes_deadlocked_samples(tree_graph):
    adv_off = FifoAdvisor(make_design("k15mmtree"))
    adv_on = FifoAdvisor(make_design("k15mmtree"),
                         EvalConfig(local_bounds=True))
    r_off = adv_off.run("random", budget=200, seed=0)
    r_on = adv_on.run("random", budget=200, seed=0)
    assert r_off.result.deadlock.sum() > 100
    assert r_on.result.deadlock.sum() <= 5
    assert r_on.hypervolume() >= r_off.hypervolume()


def test_pair_feasible_monotone(tree_graph):
    """Feasibility is monotone in depth (the bisection's invariant)."""
    g = tree_graph
    pairs = {p: fs for p, fs in task_pairs(g).items() if len(fs) > 1}
    pair, fifos = next(iter(pairs.items()))
    top = {f: int(g.upper_bounds[f]) for f in fifos}
    f0 = fifos[0]
    feas = [pair_feasible(g, pair, fifos, {**top, f0: d})
            for d in (2, 8, 32, 128, int(g.upper_bounds[f0]))]
    # once feasible, stays feasible
    first_true = feas.index(True) if True in feas else len(feas)
    assert all(feas[first_true:])
