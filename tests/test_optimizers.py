"""Optimizer invariants + paper-claim regression checks (fixed seeds)."""

import numpy as np
import pytest

from repro.core import FifoAdvisor
from repro.core.optimizers import OPTIMIZERS
from repro.designs import make_design
from repro.designs.ddcf import flowgnn_pna, mult_by_2


@pytest.fixture(scope="module")
def advisor_ff():
    return FifoAdvisor(make_design("FeedForward"))


@pytest.fixture(scope="module")
def advisor_tree():
    return FifoAdvisor(make_design("k15mmtree"))


@pytest.mark.parametrize("opt", sorted(OPTIMIZERS))
def test_every_optimizer_produces_feasible_frontier(advisor_ff, opt):
    r = advisor_ff.run(opt, budget=200, seed=1)
    pts = r.frontier_points
    assert pts.shape[0] >= 1
    assert (pts >= 0).all()
    # frontier configs within bounds
    cfgs = r.frontier_configs
    assert (cfgs >= 2).all()
    assert (cfgs <= advisor_ff.graph.upper_bounds[None, :]).all()


def test_budget_respected(advisor_ff):
    for opt in ("random", "grouped_random", "sa", "grouped_sa"):
        r = advisor_ff.run(opt, budget=100, seed=0)
        assert r.result.n_evals <= 132   # budget + small batch padding


def test_greedy_latency_guarantee(advisor_ff):
    r = advisor_ff.run("greedy", budget=10_000, seed=0, epsilon=0.01)
    sel = r.selected(alpha=0.7)
    assert sel is not None
    (lat, bram), depths = sel
    assert lat <= advisor_ff.baseline_max.latency * 1.01
    # greedy must also save memory on this design
    assert bram < advisor_ff.baseline_max.bram


def test_deadlocked_baseline_min_gets_undeadlocked(advisor_tree):
    """Paper Fig. 4(b): designs whose Baseline-Min deadlocks are fixed by
    FIFOAdvisor with little-to-no BRAM."""
    assert advisor_tree.baseline_min.deadlocked
    r = advisor_tree.run("grouped_sa", budget=400, seed=0)
    pts = r.frontier_points
    assert pts.shape[0] >= 1          # found feasible configs at all
    best_bram = pts[:, 1].min()
    assert best_bram <= advisor_tree.baseline_max.bram * 0.5


def test_grouped_sa_dominates_random_hypervolume(advisor_ff):
    """Paper's headline qualitative claim, fixed-seed regression."""
    r_rand = advisor_ff.run("random", budget=300, seed=2)
    r_gsa = advisor_ff.run("grouped_sa", budget=300, seed=2)
    assert r_gsa.hypervolume() >= r_rand.hypervolume() * 0.999


def test_ddcf_design_optimizable():
    adv = FifoAdvisor(flowgnn_pna(n_nodes=32, n_edges=96))
    r = adv.run("grouped_sa", budget=200, seed=0)
    assert r.frontier_points.shape[0] >= 1
    sel = r.selected()
    assert sel is not None


def test_incremental_latency_consistency():
    adv = FifoAdvisor(mult_by_2(32))
    lat, dead = adv.incremental_latency(np.array([40, 2]))
    assert not dead and lat > 0
    lat2, dead2 = adv.incremental_latency(np.array([2, 2]))
    assert dead2


def test_history_union_is_frontier_superset(advisor_ff):
    r = advisor_ff.run("nsga2", budget=200, seed=3)
    pts, idx = r.result.feasible_points()
    front = r.frontier_points
    # every frontier point appears in the evaluated history
    hist = {tuple(p) for p in pts.tolist()}
    for p in front.tolist():
        assert tuple(p) in hist
