"""Data pipeline: determinism, skip-ahead, frontend stubs."""

import numpy as np

from repro.configs import get_arch
from repro.train.data import DataConfig, SyntheticLM, specs_for_shape
from repro.configs.base import SHAPES


def test_deterministic_and_stateless():
    c = DataConfig(vocab=100, seq_len=16, global_batch=4)
    d1 = SyntheticLM(c)
    d2 = SyntheticLM(c)
    b_a = d1.batch(5)
    # skip-ahead: a fresh pipeline jumping straight to step 5 matches
    for s in [0, 3]:
        d2.batch(s)
    b_b = d2.batch(5)
    np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])
    np.testing.assert_array_equal(b_a["labels"], b_b["labels"])
    # different steps differ
    assert not np.array_equal(d1.batch(6)["tokens"], b_a["tokens"])


def test_labels_are_next_tokens():
    c = DataConfig(vocab=50, seq_len=8, global_batch=2)
    b = SyntheticLM(c).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """Each token's successor comes from a fixed small set (the model can
    learn this; examples/train_lm.py relies on it)."""
    c = DataConfig(vocab=64, seq_len=64, global_batch=8, markov_degree=2)
    d = SyntheticLM(c)
    succ = {t: set(d.succ[t]) for t in range(64)}
    b = d.batch(1)
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    for row in toks:
        for t, nxt in zip(row[:-1], row[1:]):
            assert nxt in succ[int(t)]


def test_frontend_embeds_present():
    arch = get_arch("internvl2-2b").reduced()
    c = DataConfig(vocab=arch.vocab, seq_len=16, global_batch=2)
    b = SyntheticLM(c, arch=arch).batch(0)
    assert b["embeds"].shape == (2, arch.frontend_tokens, arch.d_model)


def test_specs_for_shape_contract():
    arch = get_arch("internvl2-2b")
    s = specs_for_shape(arch, SHAPES["train_4k"])
    B, S, F = 256, 4096, arch.frontend_tokens
    assert s["tokens"] == (B, S - F)
    assert s["embeds"] == (B, F, arch.d_model)
    sd = specs_for_shape(arch, SHAPES["decode_32k"])
    assert sd["tokens"] == (128, 1)
