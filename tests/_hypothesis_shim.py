"""Minimal stand-in for the ``hypothesis`` property-testing API.

The test suite declares ``hypothesis`` as a test dependency (see
``pyproject.toml``), but some execution environments cannot install it.
``conftest.py`` registers this shim in ``sys.modules`` *only when the real
package is missing*, so the suite always collects and runs.

Semantics: each ``@given`` test runs ``max_examples`` times (default 25)
against values drawn from a deterministically seeded RNG (seeded from the
test's qualified name), so failures are reproducible run-to-run.  This is
deliberately simpler than real hypothesis — no shrinking, no database, no
adaptive search — but exercises the same property over a comparable sample
of the input space.

Implements exactly the surface this repo's tests use: ``given``,
``settings``, and the ``strategies`` (``st``) members ``integers``,
``floats``, ``lists``, ``tuples``, ``sampled_from``, ``booleans``,
``just``, ``one_of``, and ``composite``.  ``tests/test_hypothesis_shim.py``
smoke-tests this surface against whichever implementation is active, so
the shim cannot silently drift from the real package.
"""

from __future__ import annotations

import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25

__version__ = "0.0-shim"


class SearchStrategy:
    """Base strategy: subclasses draw one example from an RNG."""

    def example(self, rng: random.Random):
        raise NotImplementedError

    def map(self, fn):
        return _Mapped(self, fn)


class _Mapped(SearchStrategy):
    def __init__(self, inner, fn):
        self.inner = inner
        self.fn = fn

    def example(self, rng):
        return self.fn(self.inner.example(rng))


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def example(self, rng):
        return rng.randint(self.min_value, self.max_value)


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.min_value = float(min_value)
        self.max_value = float(max_value)

    def example(self, rng):
        return rng.uniform(self.min_value, self.max_value)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng):
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size if max_size is not None
                            else min_size + 10)

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng):
        return tuple(s.example(rng) for s in self.strategies)


class _Booleans(SearchStrategy):
    def example(self, rng):
        return rng.random() < 0.5


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class _OneOf(SearchStrategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng):
        return rng.choice(self.strategies).example(rng)


class _Composite(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs

    def example(self, rng):
        def draw(strategy):
            return strategy.example(rng)
        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    def builder(*args, **kwargs):
        return _Composite(fn, args, kwargs)
    return builder


def settings(max_examples: int = None, deadline=None, **_ignored):
    """Decorator recording run options for ``given`` (subset of the real
    API; unknown options are accepted and ignored)."""
    def decorate(fn):
        opts = dict(getattr(fn, "_shim_settings", {}))
        if max_examples is not None:
            opts["max_examples"] = int(max_examples)
        fn._shim_settings = opts
        return fn
    return decorate


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        opts = getattr(fn, "_shim_settings", {})
        n = opts.get("max_examples", DEFAULT_MAX_EXAMPLES)
        seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

        def runner():
            rng = random.Random(seed)
            for _ in range(n):
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng)
                          for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the wrapped function's strategy parameters (it would try to
        # resolve them as fixtures).
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__qualname__ = fn.__qualname__
        runner.hypothesis_shim = True
        return runner
    return decorate


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _Integers
strategies.floats = _Floats
strategies.lists = _Lists
strategies.tuples = _Tuples
strategies.sampled_from = _SampledFrom
strategies.booleans = _Booleans
strategies.just = _Just
strategies.one_of = _OneOf
strategies.composite = composite
strategies.SearchStrategy = SearchStrategy


def install() -> None:
    """Register this module as ``hypothesis`` in ``sys.modules``."""
    shim = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", shim)
    sys.modules.setdefault("hypothesis.strategies", strategies)
