"""End-to-end behaviour of the full system (paper-level claims).

These are the headline reproduction checks: trace-once + incremental
evaluation equals independent cycle-accurate simulation across the whole
Stream-HLS suite, Baseline-Min deadlocks happen exactly where designed,
and the DSE produces the paper's qualitative outcome (grouped optimizers
≈ baseline latency at ~zero FIFO BRAM).
"""

import numpy as np
import pytest

from repro.core import FifoAdvisor, build_simgraph, simulate
from repro.core.simulate import BatchedEvaluator
from repro.designs import STREAMHLS_DESIGNS, flowgnn_pna, make_design
from repro.designs.streamhls import TABLE_II_DESIGNS

FAST_DESIGNS = ["atax", "gemm", "gesummv", "FeedForward", "k7mmseq_balanced",
                "k15mmtree", "ResidualBlock", "DepthSepConvBlock"]


@pytest.mark.parametrize("name", FAST_DESIGNS)
def test_trace_sim_matches_oracle(name):
    """Table-II analogue: trace-based latency == cycle-accurate DES."""
    d = make_design(name)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g)
    rng = np.random.default_rng(42)
    u = g.upper_bounds
    cfgs = np.stack([u, np.full(g.n_fifos, 2)] +
                    [rng.integers(2, np.maximum(3, u + 1))
                     for _ in range(4)])
    lat, _, dead = ev.evaluate(cfgs)
    for i in range(cfgs.shape[0]):
        r = simulate(d, cfgs[i])
        assert r.deadlocked == bool(dead[i])
        if not r.deadlocked:
            assert r.latency == int(lat[i])


def test_all_designs_trace_and_have_feasible_baseline_max():
    for name in STREAMHLS_DESIGNS:
        d = make_design(name)
        g = build_simgraph(d)
        ev = BatchedEvaluator(g)
        lat, bram, dead = ev.evaluate(g.upper_bounds[None, :])
        assert not dead[0], name
        assert lat[0] > 0, name


def test_baseline_min_deadlocks_exactly_on_tree_designs():
    """The reorder-buffer hazard (transposed operand) deadlocks Baseline-
    Min on the k15mmtree family — the paper's k15mmtree observation."""
    deadlockers = set()
    for name in TABLE_II_DESIGNS:
        g = build_simgraph(make_design(name))
        ev = BatchedEvaluator(g)
        _, _, dead = ev.evaluate(np.full((1, g.n_fifos), 2))
        if dead[0]:
            deadlockers.add(name)
    assert "k15mmtree" in deadlockers
    assert all(n.startswith("k15mmtree") for n in deadlockers)


def test_paper_headline_grouped_sa_outcome():
    """Fig. 4(a): grouped SA finds ≈ Baseline-Max latency at a fraction of
    the FIFO BRAM cost (fixed-seed regression, conservative thresholds)."""
    adv = FifoAdvisor(make_design("FeedForward"))
    r = adv.run("grouped_sa", budget=600, seed=0)
    sel = r.selected(alpha=0.7)
    assert sel is not None
    (lat, bram), _ = sel
    assert lat <= adv.baseline_max.latency * 1.02
    assert bram <= adv.baseline_max.bram * 0.25


def test_srl_read_latency_effect_footnote2():
    """Shrinking FIFOs below the SRL threshold can REDUCE latency below
    Baseline-Max (one less read-delay cycle) — paper footnote 2."""
    adv = FifoAdvisor(make_design("k15mmseq"))
    r = adv.run("greedy", budget=10_000, seed=0)
    pts = r.frontier_points
    assert pts[:, 0].min() < adv.baseline_max.latency


def test_ddcf_case_study_graph_dependence():
    """§IV-D: feasibility depends on the runtime graph; the minimal
    feasible uniform msg-queue depth is a property of the input data."""
    def min_feasible_depth(seed):
        d = flowgnn_pna(n_nodes=48, n_edges=192, seed=seed)
        g = build_simgraph(d)
        ev = BatchedEvaluator(g)
        for depth in [2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64]:
            cfg = np.maximum(g.upper_bounds, 2).copy()
            for f in range(g.n_fifos):
                if d.fifos[f].name.startswith("deg_"):
                    cfg[f] = depth
            _, _, dead = ev.evaluate(cfg[None, :])
            if not dead[0]:
                return depth
        return None

    d1 = min_feasible_depth(7)
    d2 = min_feasible_depth(1234)
    assert d1 is not None and d2 is not None
    assert d1 >= 2 and d2 >= 2
